"""AOT compile path: lower the TinyLM prefill variants to HLO *text* and
write the weight blob + metadata the Rust runtime consumes.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the published `xla` 0.1.6 crate links) rejects (`proto.id() <= INT_MAX`).
The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Outputs (in --out-dir, default ../artifacts):
  prefill_t{T}.hlo.txt   one module per chunk-length variant
  weights.bin            all weights, f32 little-endian, artifact order
  model_meta.json        config + weight specs + variant list

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ModelConfig, example_args, init_weights, make_prefill_fn, weight_specs


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg: ModelConfig, T: int) -> str:
    fn = make_prefill_fn(cfg, T, use_pallas=True)
    lowered = jax.jit(fn).lower(*example_args(cfg, T))
    return to_hlo_text(lowered)


def write_weights(cfg: ModelConfig, path: str) -> list:
    """Write weights.bin; returns the spec list with byte offsets."""
    ws = init_weights(cfg)
    specs = weight_specs(cfg)
    entries = []
    offset = 0
    with open(path, "wb") as f:
        for (name, shape), w in zip(specs, ws):
            arr = np.asarray(w, dtype="<f4")
            assert arr.shape == tuple(shape)
            f.write(arr.tobytes())
            entries.append(
                {"name": name, "shape": list(shape), "offset": offset, "len": int(arr.size)}
            )
            offset += arr.size * 4
    return entries


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--chunks", default=None, help="comma-separated T values")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = ModelConfig()
    chunks = (
        tuple(int(x) for x in args.chunks.split(",")) if args.chunks else cfg.chunks
    )

    variants = []
    for T in chunks:
        text = lower_variant(cfg, T)
        path = os.path.join(args.out_dir, f"prefill_t{T}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        variants.append({"chunk": T, "file": f"prefill_t{T}.hlo.txt"})
        print(f"lowered prefill_t{T}: {len(text)} chars -> {path}")

    weights = write_weights(cfg, os.path.join(args.out_dir, "weights.bin"))

    meta = {
        "model": "TinyLM",
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "block_k": cfg.block_k,
            "seed": cfg.seed,
        },
        "variants": variants,
        "weights": weights,
        "io": {
            "inputs": ["tokens[T] i32", "kv[L,2,S,H,D] f32", "cache_len[1] i32", "*weights f32"],
            "outputs": ["logits[T,V] f32", "kv[L,2,S,H,D] f32"],
            "tuple_return": True,
        },
    }
    meta_path = os.path.join(args.out_dir, "model_meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
