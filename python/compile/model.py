"""Layer-2 JAX model: TinyLM — a GPT-style transformer with chunked prefill
and KV-cache reuse, the compute graph the Rust coordinator serves.

The forward pass calls the Layer-1 Pallas kernel
(`kernels.attention.attention`) for every layer's attention, so the kernel
lowers into the same HLO module that `aot.py` exports.

Shapes are static per AOT variant (chunk length T is a compile-time
constant; the KV buffer has a fixed max sequence S). The KV cache is both
an input and an output so the Rust engine can thread it between chunks:

    prefill_chunk: (tokens[T] i32, kv[L,2,S,H,D] f32, cache_len[1] i32,
                    *weights) -> (logits[T,V] f32, kv'[L,2,S,H,D] f32)

Weights are *runtime inputs* (not baked constants): `aot.py` writes them to
`artifacts/weights.bin` and the Rust runtime feeds them per call. This
keeps the HLO text small and mirrors real serving engines where weights
live on-device.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import attention
from .kernels.ref import attention_ref, gelu_ref, rmsnorm_ref


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 2048
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    head_dim: int = 32
    d_ff: int = 256
    max_seq: int = 512
    block_k: int = 128  # Pallas KV tile
    seed: int = 1234
    # chunk variants to AOT-compile (T values); decode uses T=1
    chunks: tuple = (1, 16, 64, 128)

    @property
    def qkv_dim(self):
        return 3 * self.n_heads * self.head_dim


# Per-layer weight names, in artifact order.
LAYER_WEIGHTS = ("ln1", "wqkv", "wo", "ln2", "w1", "w2")


def weight_specs(cfg: ModelConfig):
    """Ordered (name, shape) list — the contract with the Rust runtime."""
    specs = [("embed", (cfg.vocab, cfg.d_model)), ("pos", (cfg.max_seq, cfg.d_model))]
    for layer in range(cfg.n_layers):
        d, h, hd, ff = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.d_ff
        specs += [
            (f"l{layer}.ln1", (d,)),
            (f"l{layer}.wqkv", (d, 3 * h * hd)),
            (f"l{layer}.wo", (h * hd, d)),
            (f"l{layer}.ln2", (d,)),
            (f"l{layer}.w1", (d, ff)),
            (f"l{layer}.w2", (ff, d)),
        ]
    specs.append(("ln_f", (cfg.d_model,)))
    return specs


def init_weights(cfg: ModelConfig):
    """Deterministic init (numpy PRNG; written to weights.bin by aot.py)."""
    rng = np.random.default_rng(cfg.seed)
    ws = []
    for name, shape in weight_specs(cfg):
        if name.endswith(("ln1", "ln2")) or name in ("ln_f",):
            w = np.ones(shape, dtype=np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / np.sqrt(fan_in)
            w = rng.normal(0.0, std, size=shape).astype(np.float32)
        ws.append(jnp.asarray(w))
    return ws


def _unpack(cfg: ModelConfig, weights):
    """Split the flat weight list into (embed, pos, layers, ln_f)."""
    embed, pos = weights[0], weights[1]
    layers = []
    idx = 2
    for _ in range(cfg.n_layers):
        layers.append(dict(zip(LAYER_WEIGHTS, weights[idx : idx + 6])))
        idx += 6
    ln_f = weights[idx]
    return embed, pos, layers, ln_f


def prefill_chunk(cfg: ModelConfig, tokens, kv, cache_len, weights, *, use_pallas=True):
    """Run one prefill chunk of T tokens against a KV cache.

    Args:
      tokens: [T] int32 token ids.
      kv: [L, 2, S, H, D] float32 cache; rows < cache_len valid.
      cache_len: [1] int32.
      weights: flat list per `weight_specs`.
      use_pallas: False switches attention to the jnp oracle (used by tests
        to isolate kernel-vs-model errors; the AOT path always uses Pallas).

    Returns:
      (logits [T, vocab], kv' [L, 2, S, H, D])
    """
    T = tokens.shape[0]
    H, D, S = cfg.n_heads, cfg.head_dim, cfg.max_seq
    embed, pos, layers, ln_f = _unpack(cfg, weights)
    cache_len = jnp.asarray(cache_len, jnp.int32).reshape((1,))
    cl = cache_len[0]

    # Positions are global: cache_len + chunk-local index.
    positions = cl + jnp.arange(T, dtype=jnp.int32)
    # clamp so padded over-length chunks stay in-bounds (masked anyway)
    positions = jnp.minimum(positions, S - 1)
    x = embed[tokens] + pos[positions]  # [T, d]

    new_kv = []
    for layer_idx, lw in enumerate(layers):
        h_in = rmsnorm_ref(x, lw["ln1"])
        qkv = h_in @ lw["wqkv"]  # [T, 3*H*D]
        q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(T, H, D)
        k_new = k_new.reshape(T, H, D)
        v_new = v_new.reshape(T, H, D)

        # Write the chunk's K/V into the cache at [cache_len, cache_len+T).
        k_buf = jax.lax.dynamic_update_slice(kv[layer_idx, 0], k_new, (cl, 0, 0))
        v_buf = jax.lax.dynamic_update_slice(kv[layer_idx, 1], v_new, (cl, 0, 0))
        new_kv.append(jnp.stack([k_buf, v_buf]))

        if use_pallas:
            attn = attention(q, k_buf, v_buf, cache_len, block_k=cfg.block_k)
        else:
            attn = attention_ref(q, k_buf, v_buf, cl)
        x = x + attn.reshape(T, H * D) @ lw["wo"]

        h2 = rmsnorm_ref(x, lw["ln2"])
        x = x + gelu_ref(h2 @ lw["w1"]) @ lw["w2"]

    x = rmsnorm_ref(x, ln_f)
    logits = x @ embed.T  # weight-tied output head
    return logits, jnp.stack(new_kv)


def make_prefill_fn(cfg: ModelConfig, T: int, *, use_pallas=True):
    """Build the function to AOT-lower for chunk length T.

    Signature: (tokens[T], kv, cache_len[1], *weights) -> (logits, kv').
    """

    def fn(tokens, kv, cache_len, *weights):
        return prefill_chunk(cfg, tokens, kv, cache_len, list(weights), use_pallas=use_pallas)

    return fn


def example_args(cfg: ModelConfig, T: int):
    """ShapeDtypeStructs for jax.jit(...).lower()."""
    tok = jax.ShapeDtypeStruct((T,), jnp.int32)
    kv = jax.ShapeDtypeStruct(
        (cfg.n_layers, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim), jnp.float32
    )
    cl = jax.ShapeDtypeStruct((1,), jnp.int32)
    ws = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in weight_specs(cfg)]
    return (tok, kv, cl, *ws)


def empty_kv(cfg: ModelConfig):
    return jnp.zeros(
        (cfg.n_layers, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim), jnp.float32
    )


def prefill_full(cfg: ModelConfig, tokens, weights, *, use_pallas=False):
    """Monolithic prefill of a whole prompt (reference for chunked runs)."""
    kv = empty_kv(cfg)
    logits, kv = prefill_chunk(
        cfg, tokens, kv, jnp.zeros((1,), jnp.int32), weights, use_pallas=use_pallas
    )
    return logits, kv
