"""Layer-1 Pallas kernel: block-wise chunked-prefill attention with a
KV-cache offset (the prefill hot-spot of the serving stack).

Design — TPU adaptation of the flash-attention threadblock scheme
(DESIGN.md §Hardware-Adaptation):

  * Grid = (heads, S // BLOCK_K). For each head, KV tiles of BLOCK_K rows
    are streamed HBM->VMEM by the BlockSpec index maps (the TPU analogue of
    CUDA shared-memory staging).
  * The q(T,D) @ k(D,BLOCK_K) and p(T,BLOCK_K) @ v(BLOCK_K,D) contractions
    are MXU-shaped matmuls.
  * The online-softmax running state (row max `m`, denominator `l`, and the
    unnormalized accumulator `acc`) lives in VMEM scratch and is carried
    across the KV-tile grid dimension (the analogue of register
    accumulators in the CUDA kernel).
  * `cache_len` arrives as a tiny SMEM-resident scalar input, so the same
    compiled kernel serves both fresh prefill (cache_len=0) and
    cache-extension chunks (cache_len>0). Masking is position-based:
    chunk row i (global position cache_len+i) may attend to global
    column j iff j <= cache_len + i.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO. Real-TPU VMEM/MXU
estimates are derived analytically in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 128


def _attn_kernel(cache_len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, block_k, scale):
    """One (head, kv-tile) grid step of the online-softmax attention.

    Refs (per BlockSpec):
      cache_len_ref: [1]        int32, same for every grid step.
      q_ref:         [T, 1, D]  the chunk's queries for this head.
      k_ref/v_ref:   [BK, 1, D] this KV tile for this head.
      o_ref:         [T, 1, D]  output for this head.
      acc_ref/m_ref/l_ref: VMEM scratch carried across kv tiles.
    """
    kt = pl.program_id(1)
    n_kt = pl.num_programs(1)

    q = q_ref[:, 0, :]  # [T, D]
    k = k_ref[:, 0, :]  # [BK, D]
    v = v_ref[:, 0, :]  # [BK, D]
    T = q.shape[0]

    # Reset the carry at the first KV tile of each head.
    @pl.when(kt == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = cache_len_ref[0]

    # scores: [T, BK] on the MXU.
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale

    # Causal-with-offset mask in *global* coordinates.
    rows = jax.lax.broadcasted_iota(jnp.int32, (T, block_k), 0)  # chunk row i
    cols = jax.lax.broadcasted_iota(jnp.int32, (T, block_k), 1) + kt * block_k
    mask = cols <= (cache_len + rows)
    s = jnp.where(mask, s, -1e30)

    # Online softmax update.
    m_prev = m_ref[...]  # [T, 1]
    l_prev = l_ref[...]  # [T, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)  # [T, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    # Guard fully-masked tiles: exp(-1e30 - m) underflows to 0, fine.
    p = jnp.exp(s - m_new)  # [T, BK]
    correction = jnp.exp(m_prev - m_new)  # [T, 1]
    l_new = l_prev * correction + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [T, D]
    acc_ref[...] = acc_ref[...] * correction + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    # Final tile: normalize and write out.
    @pl.when(kt == n_kt - 1)
    def _():
        # l is >= 1 whenever at least one column is unmasked (the diagonal
        # always is), so the divide is safe.
        o_ref[:, 0, :] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k",))
def attention(q, k, v, cache_len, *, block_k=DEFAULT_BLOCK_K):
    """Block-wise chunked-prefill attention (Pallas, interpret mode).

    Args:
      q: [T, H, D] new-chunk queries.
      k: [S, H, D] key buffer (rows < cache_len + T valid).
      v: [S, H, D] value buffer.
      cache_len: scalar or [1] int32 — previously-cached positions.
      block_k: KV tile rows per grid step; S % block_k must be 0.

    Returns:
      [T, H, D] attention output, matching `ref.attention_ref`.
    """
    T, H, D = q.shape
    S = k.shape[0]
    if S % block_k != 0:
        raise ValueError(f"S={S} not divisible by block_k={block_k}")
    cache_len = jnp.asarray(cache_len, dtype=jnp.int32).reshape((1,))
    scale = 1.0 / (D**0.5)

    grid = (H, S // block_k)
    kernel = functools.partial(_attn_kernel, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda h, kt: (0,)),            # cache_len
            pl.BlockSpec((T, 1, D), lambda h, kt: (0, h, 0)),  # q: per-head
            pl.BlockSpec((block_k, 1, D), lambda h, kt: (kt, h, 0)),  # k tile
            pl.BlockSpec((block_k, 1, D), lambda h, kt: (kt, h, 0)),  # v tile
        ],
        out_specs=pl.BlockSpec((T, 1, D), lambda h, kt: (0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((T, H, D), q.dtype),
        scratch_shapes=[
            # VMEM carries across the kv-tile grid dimension.
            pltpu.VMEM((T, D), jnp.float32),   # acc
            pltpu.VMEM((T, 1), jnp.float32),   # m (running max)
            pltpu.VMEM((T, 1), jnp.float32),   # l (running denominator)
        ],
        interpret=True,
    )(cache_len, q, k, v)


def vmem_bytes(T, D, block_k, dtype_bytes=4):
    """Analytic VMEM footprint of one grid step (EXPERIMENTS.md §Perf):
    q tile + k tile + v tile + out tile + scratch (acc, m, l)."""
    q_t = T * D * dtype_bytes
    kv_t = 2 * block_k * D * dtype_bytes
    o_t = T * D * dtype_bytes
    scratch = (T * D + 2 * T) * 4
    return q_t + kv_t + o_t + scratch


def mxu_flops(T, S, D, H):
    """FLOPs of the two matmuls (scores + PV) across a full call."""
    return 2 * H * (T * S * D) * 2
