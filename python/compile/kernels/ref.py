"""Pure-jnp correctness oracles for the Layer-1 Pallas kernel and the
Layer-2 model.

Everything here is deliberately naive: materialize the full attention
matrix, mask, softmax. The Pallas kernel (`attention.py`) must match these
numerics to tight tolerance under pytest/hypothesis sweeps.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, cache_len):
    """Reference chunked-prefill attention with a KV-cache offset.

    Args:
      q: [T, H, D] queries for the new chunk (chunk-local rows).
      k: [S, H, D] full key buffer; rows [0, cache_len + T) are valid.
      v: [S, H, D] full value buffer.
      cache_len: scalar int — number of previously cached positions.
        Row i of the chunk sits at global position cache_len + i and may
        attend to global positions j <= cache_len + i (causal).

    Returns:
      [T, H, D] attention output.
    """
    T, H, D = q.shape
    S = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.array(D, dtype=q.dtype))
    # [H, T, S]
    scores = jnp.einsum("thd,shd->hts", q, k) * scale
    rows = jnp.arange(T)[:, None]  # chunk-local row
    cols = jnp.arange(S)[None, :]  # global col
    mask = cols <= (cache_len + rows)  # [T, S]
    neg = jnp.asarray(-1e30, dtype=q.dtype)
    scores = jnp.where(mask[None, :, :], scores, neg)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hts,shd->thd", probs, v)


def rmsnorm_ref(x, w, eps=1e-6):
    """RMSNorm over the last axis."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * w


def gelu_ref(x):
    """tanh-approximation GELU (matches the model)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def mlp_ref(x, w1, w2):
    """Gateless 2-layer MLP with GELU."""
    return gelu_ref(x @ w1) @ w2
