"""AOT path checks: lowering emits loadable HLO text; weights.bin layout
matches weight_specs; metadata is consistent with the Rust runtime contract."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_variant, to_hlo_text, write_weights
from compile.model import ModelConfig, weight_specs

SMALL = ModelConfig(
    vocab=64, d_model=32, n_layers=1, n_heads=2, head_dim=16, d_ff=64, max_seq=64, block_k=16
)


def test_lowered_text_is_hlo(tmp_path):
    text = lower_variant(SMALL, T=4)
    assert "HloModule" in text
    assert "ENTRY" in text
    # no Mosaic custom-calls may appear (interpret=True guarantees this)
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_lowered_text_parameter_count():
    text = lower_variant(SMALL, T=4)
    n_weights = len(weight_specs(SMALL))
    # tokens + kv + cache_len + weights
    expected_params = 3 + n_weights
    assert text.count("parameter(") >= expected_params


def test_weights_bin_layout(tmp_path):
    path = tmp_path / "weights.bin"
    entries = write_weights(SMALL, str(path))
    specs = weight_specs(SMALL)
    assert len(entries) == len(specs)
    total_floats = sum(int(np.prod(s)) for _, s in specs)
    assert os.path.getsize(path) == total_floats * 4
    # offsets are contiguous
    off = 0
    for e in entries:
        assert e["offset"] == off
        off += e["len"] * 4
    # spot-check: ln weights are all-ones
    with open(path, "rb") as f:
        ln1 = next(e for e in entries if e["name"].endswith("ln1"))
        f.seek(ln1["offset"])
        vals = struct.unpack(f"<{ln1['len']}f", f.read(ln1["len"] * 4))
        assert all(v == 1.0 for v in vals)


def test_write_weights_deterministic(tmp_path):
    p1, p2 = tmp_path / "a.bin", tmp_path / "b.bin"
    write_weights(SMALL, str(p1))
    write_weights(SMALL, str(p2))
    assert p1.read_bytes() == p2.read_bytes()


def test_to_hlo_text_roundtrips_simple_fn():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert "HloModule" in text


def test_default_config_variants_sane():
    cfg = ModelConfig()
    assert all(t <= cfg.max_seq for t in cfg.chunks)
    assert cfg.max_seq % cfg.block_k == 0
    assert 1 in cfg.chunks, "decode variant (T=1) required by the engine"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/model_meta.json")),
    reason="artifacts not built",
)
def test_built_artifacts_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "model_meta.json")) as f:
        meta = json.load(f)
    cfg = meta["config"]
    full = ModelConfig()
    assert cfg["vocab"] == full.vocab
    assert cfg["max_seq"] == full.max_seq
    for v in meta["variants"]:
        path = os.path.join(root, v["file"])
        assert os.path.exists(path), v["file"]
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head
    total = sum(w["len"] for w in meta["weights"]) * 4
    assert os.path.getsize(os.path.join(root, "weights.bin")) == total
