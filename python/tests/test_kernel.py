"""L1 correctness: the Pallas attention kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, cache lengths, and KV tile sizes — the CORE
correctness signal for the kernel that every AOT artifact embeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, mxu_flops, vmem_bytes
from compile.kernels.ref import attention_ref

TOL = dict(rtol=2e-5, atol=2e-5)


def make_qkv(T, H, D, S, seed=0):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k0, (T, H, D), jnp.float32)
    k = jax.random.normal(k1, (S, H, D), jnp.float32)
    v = jax.random.normal(k2, (S, H, D), jnp.float32)
    return q, k, v


# ---------------------------------------------------------------------------
# deterministic unit cases
# ---------------------------------------------------------------------------


def test_matches_ref_basic():
    q, k, v = make_qkv(8, 2, 16, 64)
    out = attention(q, k, v, 5, block_k=16)
    ref = attention_ref(q, k, v, 5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_zero_cache_is_pure_causal():
    q, k, v = make_qkv(16, 2, 16, 64)
    out = attention(q, k, v, 0, block_k=16)
    ref = attention_ref(q, k, v, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_first_row_zero_cache_attends_only_itself():
    """Row 0 with empty cache sees exactly position 0 => out == v[0]."""
    q, k, v = make_qkv(4, 2, 16, 32)
    out = attention(q, k, v, 0, block_k=16)
    np.testing.assert_allclose(np.asarray(out)[0], np.asarray(v)[0], **TOL)


def test_full_cache_chunk_of_one():
    """T=1 decode step against an almost-full cache."""
    S = 64
    q, k, v = make_qkv(1, 4, 8, S)
    cl = S - 1
    out = attention(q, k, v, cl, block_k=16)
    ref = attention_ref(q, k, v, cl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_garbage_beyond_mask_ignored():
    """Poison unmasked-out rows of K/V with huge values; result unchanged."""
    T, H, D, S, cl = 8, 2, 16, 64, 4
    q, k, v = make_qkv(T, H, D, S)
    valid = cl + T
    k_poison = k.at[valid:].set(1e9)
    v_poison = v.at[valid:].set(-1e9)
    out_clean = attention(q, k, v, cl, block_k=16)
    out_poison = attention(q, k_poison, v_poison, cl, block_k=16)
    np.testing.assert_allclose(np.asarray(out_clean), np.asarray(out_poison), **TOL)


def test_block_k_invariance():
    """Different KV tile sizes must produce identical results."""
    q, k, v = make_qkv(8, 2, 16, 128)
    outs = [np.asarray(attention(q, k, v, 7, block_k=bk)) for bk in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, **TOL)


def test_indivisible_block_k_raises():
    q, k, v = make_qkv(4, 2, 8, 48)
    with pytest.raises(ValueError, match="not divisible"):
        attention(q, k, v, 0, block_k=32)


def test_probs_are_convex_combination():
    """Output rows lie within [min(v), max(v)] per dim (softmax convexity)."""
    q, k, v = make_qkv(8, 2, 16, 64, seed=3)
    out = np.asarray(attention(q, k, v, 10, block_k=16))
    vmax = np.asarray(v).max()
    vmin = np.asarray(v).min()
    assert out.max() <= vmax + 1e-5
    assert out.min() >= vmin - 1e-5


def test_scale_invariance_of_uniform_values():
    """If all V rows are identical, output equals that row regardless of Q."""
    T, H, D, S = 4, 2, 8, 32
    q, k, _ = make_qkv(T, H, D, S, seed=9)
    v_const = jnp.broadcast_to(jnp.arange(D, dtype=jnp.float32), (S, H, D))
    out = np.asarray(attention(q, k, v_const, 3, block_k=16))
    expect = np.broadcast_to(np.arange(D, dtype=np.float32), (T, H, D))
    np.testing.assert_allclose(out, expect, **TOL)


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    T=st.sampled_from([1, 2, 4, 8, 16]),
    H=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([4, 8, 16]),
    s_tiles=st.integers(min_value=1, max_value=4),
    block_k=st.sampled_from([8, 16]),
    seed=st.integers(min_value=0, max_value=2**16),
    data=st.data(),
)
def test_hypothesis_shapes_and_cache(T, H, D, s_tiles, block_k, seed, data):
    S = s_tiles * block_k
    if S < T:
        S = ((T + block_k - 1) // block_k) * block_k
    max_cl = S - T
    cl = data.draw(st.integers(min_value=0, max_value=max_cl))
    q, k, v = make_qkv(T, H, D, S, seed=seed)
    out = attention(q, k, v, cl, block_k=block_k)
    ref = attention_ref(q, k, v, cl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-5, atol=5e-5)


@settings(max_examples=10, deadline=None)
@given(scale=st.sampled_from([1e-3, 1.0, 1e3]), seed=st.integers(0, 1000))
def test_hypothesis_extreme_magnitudes(scale, seed):
    """Online softmax must stay stable across score magnitudes."""
    q, k, v = make_qkv(8, 2, 16, 64, seed=seed)
    q = q * scale
    out = attention(q, k, v, 5, block_k=16)
    ref = attention_ref(q, k, v, 5)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# perf-model sanity (EXPERIMENTS.md §Perf inputs)
# ---------------------------------------------------------------------------


def test_vmem_fits_tpu_budget():
    """Default production tile (T=128, D=32, block_k=128) must fit in the
    ~16 MiB VMEM of a TPU core with ample headroom."""
    assert vmem_bytes(128, 32, 128) < 1 << 20  # < 1 MiB


def test_mxu_flops_formula():
    # 2 matmuls * 2*T*S*D each, per head
    assert mxu_flops(T=2, S=4, D=8, H=3) == 2 * 3 * (2 * 4 * 8) * 2
