"""L2 correctness: TinyLM chunked prefill vs monolithic, Pallas vs oracle,
cache-reuse semantics the Rust engine depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    ModelConfig,
    empty_kv,
    init_weights,
    make_prefill_fn,
    prefill_chunk,
    prefill_full,
    weight_specs,
)

# Small config so the interpret-mode Pallas kernel stays fast.
CFG = ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16, d_ff=64, max_seq=64, block_k=16
)
WS = init_weights(CFG)


def toks(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, n), jnp.int32)


def run_chunks(tokens, sizes):
    """Prefill `tokens` in chunks of the given sizes; returns (last_logits, kv)."""
    assert sum(sizes) == tokens.shape[0]
    kv = empty_kv(CFG)
    off = 0
    logits = None
    for t in sizes:
        logits, kv = prefill_chunk(
            CFG, tokens[off : off + t], kv, jnp.array([off], jnp.int32), WS
        )
        off += t
    return logits, kv


def test_chunked_equals_monolithic():
    t = toks(24)
    lg_full, kv_full = prefill_full(CFG, t, WS, use_pallas=True)
    lg_last, kv = run_chunks(t, [16, 8])
    np.testing.assert_allclose(
        np.asarray(lg_last), np.asarray(lg_full[16:]), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(kv[:, :, :24]), np.asarray(kv_full[:, :, :24]), rtol=1e-5, atol=1e-5
    )


def test_pallas_matches_oracle_model():
    """Whole model with Pallas attention vs jnp-oracle attention."""
    t = toks(16, seed=1)
    kv = empty_kv(CFG)
    cl = jnp.array([0], jnp.int32)
    lg_p, kv_p = prefill_chunk(CFG, t, kv, cl, WS, use_pallas=True)
    lg_r, kv_r = prefill_chunk(CFG, t, kv, cl, WS, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(kv_p), np.asarray(kv_r), rtol=1e-4, atol=1e-4)


def test_prefix_reuse_changes_nothing():
    """KV built from a shared prefix + different suffixes: the shared rows
    must be identical (the property the radix cache exploits)."""
    prefix = toks(16, seed=2)
    sfx_a = toks(8, seed=3)
    sfx_b = toks(8, seed=4)
    _, kv_a = run_chunks(jnp.concatenate([prefix, sfx_a]), [16, 8])
    _, kv_b = run_chunks(jnp.concatenate([prefix, sfx_b]), [16, 8])
    np.testing.assert_allclose(
        np.asarray(kv_a[:, :, :16]), np.asarray(kv_b[:, :, :16]), rtol=1e-6, atol=1e-6
    )
    # and the suffix rows must differ
    assert np.abs(np.asarray(kv_a[:, :, 16:24]) - np.asarray(kv_b[:, :, 16:24])).max() > 1e-3


def test_padding_is_harmless():
    """Chunk padded past the real tokens: rows written by the pad are later
    overwritten when the real continuation arrives (engine relies on this)."""
    t = toks(20, seed=5)
    # pad to 24 with zeros, run as one 24-chunk, then continue correctly
    padded = jnp.concatenate([t[:16], jnp.zeros(8, jnp.int32)])
    kv = empty_kv(CFG)
    _, kv = prefill_chunk(CFG, padded[:16], kv, jnp.array([0], jnp.int32), WS)
    # garbage write: pretend a pad chunk ran at offset 16
    _, kv_garbage = prefill_chunk(CFG, jnp.zeros(8, jnp.int32), kv, jnp.array([16], jnp.int32), WS)
    # now the real continuation overwrites those rows
    lg, kv_fixed = prefill_chunk(CFG, t[16:20], kv_garbage, jnp.array([16], jnp.int32), WS)
    lg_ref, kv_ref = run_chunks(t, [16, 4])
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(kv_fixed[:, :, :20]), np.asarray(kv_ref[:, :, :20]), rtol=1e-5, atol=1e-5
    )


def test_logits_shape_and_finite():
    t = toks(8, seed=6)
    lg, kv = prefill_chunk(CFG, t, empty_kv(CFG), jnp.array([0], jnp.int32), WS)
    assert lg.shape == (8, CFG.vocab)
    assert kv.shape == (CFG.n_layers, 2, CFG.max_seq, CFG.n_heads, CFG.head_dim)
    assert np.isfinite(np.asarray(lg)).all()


def test_weight_specs_cover_all_params():
    specs = weight_specs(CFG)
    names = [n for n, _ in specs]
    assert names[0] == "embed" and names[1] == "pos" and names[-1] == "ln_f"
    assert len([n for n in names if n.startswith("l0.")]) == 6
    assert len(set(names)) == len(names)
    ws = init_weights(CFG)
    assert len(ws) == len(specs)
    for (name, shape), w in zip(specs, ws):
        assert tuple(w.shape) == tuple(shape), name


def test_determinism_across_inits():
    a = init_weights(CFG)
    b = init_weights(CFG)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=8, deadline=None)
@given(
    split=st.integers(min_value=1, max_value=23),
    seed=st.integers(min_value=0, max_value=100),
)
def test_hypothesis_any_chunk_split(split, seed):
    """Any two-way chunk split reproduces the monolithic logits."""
    t = toks(24, seed=seed)
    lg_full, _ = prefill_full(CFG, t, WS, use_pallas=False)
    kv = empty_kv(CFG)
    _, kv = prefill_chunk(CFG, t[:split], kv, jnp.array([0], jnp.int32), WS, use_pallas=False)
    lg2, _ = prefill_chunk(
        CFG, t[split:], kv, jnp.array([split], jnp.int32), WS, use_pallas=False
    )
    np.testing.assert_allclose(
        np.asarray(lg2), np.asarray(lg_full[split:]), rtol=1e-5, atol=1e-5
    )


def test_make_prefill_fn_signature():
    fn = make_prefill_fn(CFG, 8)
    t = toks(8, seed=7)
    lg, kv = fn(t, empty_kv(CFG), jnp.array([0], jnp.int32), *WS)
    assert lg.shape == (8, CFG.vocab)
