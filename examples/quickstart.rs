//! Quickstart: the ContextPilot public API in ~60 lines.
//!
//! Three users ask related questions; their retrievals overlap but arrive
//! in different orders. ContextPilot aligns them against the context
//! index, schedules the batch, and the engine's prefix cache turns the
//! overlap into KV reuse.
//!
//!     cargo run --release --example quickstart

use contextpilot::corpus::{Corpus, CorpusConfig};
use contextpilot::engine::{ModelSku, ReusePolicy, SimEngine};
use contextpilot::pilot::{ContextPilot, PilotConfig};
use contextpilot::quality::{ModelEra, QualityModel};
use contextpilot::tokenizer::Tokenizer;
use contextpilot::types::*;

fn main() {
    // A small corpus of context blocks (documents / chunks / memories).
    let corpus = Corpus::generate(&CorpusConfig::default(), &Tokenizer::default());

    // Three requests retrieving overlapping blocks in different orders —
    // the Fig. 2a scenario.
    let mk = |id: u64, ids: &[u32]| Request {
        id: RequestId(id),
        session: SessionId(id as u32),
        turn: 0,
        context: ids.iter().map(|&i| BlockId(i)).collect(),
        query: QueryId(id),
    };
    let batch = vec![
        mk(1, &[2, 1, 3]), // user A
        mk(2, &[2, 6, 1]), // user B — same blocks {1,2}, different order
        mk(3, &[1, 2, 9]), // user C
    ];

    // ContextPilot proxy: offline mode pre-builds the context index.
    let mut pilot = ContextPilot::new(PilotConfig::default());
    pilot.build_offline(&batch);
    let outputs = pilot.process_batch(&batch, &corpus);

    // Serve through an engine with a radix prefix cache.
    let mut engine = SimEngine::new(
        ModelSku::Qwen3_32B.profile(),
        ReusePolicy::RadixPrefix,
        100_000,
    );
    let quality = QualityModel::new(ModelEra::Modern, false);

    println!("{:<8} {:>14} {:>14} {:>10} {:>8}", "request", "prompt tokens", "cached tokens", "ttft (s)", "quality");
    for out in outputs {
        let (served, evicted) = engine.serve(&out.request, &out.prompt, &corpus, &quality, 16);
        pilot.on_evict(&evicted); // keep the index in sync with the cache
        println!(
            "{:<8} {:>14} {:>14} {:>10.4} {:>8.3}",
            served.request.id.0,
            served.prompt_tokens,
            served.cached_tokens,
            served.ttft,
            served.quality
        );
    }
    println!(
        "\naggregate hit ratio: {:.1}%  (aligned contexts share one cached prefix)",
        engine.cache.stat_matched_tokens as f64 / engine.cache.stat_lookup_tokens as f64 * 100.0
    );
}
