//! END-TO-END VALIDATION (EXPERIMENTS.md §E2E): serve batched requests
//! through the REAL stack — TinyLM compiled from JAX+Pallas via
//! `make artifacts`, executed through PJRT from the Rust coordinator with
//! an actual KV-reusing radix cache — and report measured wall-clock
//! latency/throughput with and without ContextPilot.
//!
//! This proves all three layers compose: the Pallas attention kernel
//! (L1) lowers into the TinyLM HLO (L2), which the Rust engine (L3)
//! executes with real KV-cache literals flowing through the radix tree.
//!
//!     make artifacts && cargo run --release --example e2e_serving

use contextpilot::corpus::{Corpus, CorpusConfig};
use contextpilot::pilot::{ContextPilot, PilotConfig};
use contextpilot::runtime::{RealEngine, TinyLmRuntime};
use contextpilot::tokenizer::Tokenizer;
use contextpilot::types::*;
use contextpilot::util::cli::Args;
use contextpilot::util::histogram::Summary;


fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("requests", 24);
    let decode = args.get_usize("decode", 4);

    // Small corpus so prompts fit TinyLM's 512-token window.
    let corpus = Corpus::generate(
        &CorpusConfig {
            n_docs: 64,
            lines_per_doc: 3,
            words_per_line: 6,
            ..Default::default()
        },
        &Tokenizer::new(2048),
    );
    // The Fig. 2a scenario at model scale: users query a handful of hot
    // topics; each retrieval returns the topic's block set in a
    // *user-specific order* (per-query relevance). Exact prefix matching
    // fails on the permutations; alignment canonicalizes them.
    let mut rng = contextpilot::util::prng::Rng::new(0xE2E);
    let topics: Vec<Vec<u32>> = vec![
        vec![1, 2, 3, 4],
        vec![9, 10, 11, 12],
        vec![20, 21, 22, 23],
    ];
    let requests: Vec<Request> = (0..n as u64)
        .map(|i| {
            let mut ids = topics[(i as usize) % topics.len()].clone();
            rng.shuffle(&mut ids);
            Request {
                id: RequestId(i),
                session: SessionId(i as u32),
                turn: 0,
                context: ids.into_iter().map(BlockId).collect(),
                query: QueryId(i),
            }
        })
        .collect();

    let run = |with_pilot: bool| -> anyhow::Result<(Summary, u64, u64)> {
        let runtime = TinyLmRuntime::load("artifacts")?;
        let mut engine = RealEngine::new(runtime, 1 << 20);
        let mut pilot = with_pilot.then(|| {
            let mut p = ContextPilot::new(PilotConfig {
                dedup: None, // single-turn workload: alignment is the lever
                ..PilotConfig::default()
            });
            p.build_offline(&requests);
            p
        });
        let mut ttft = Summary::new();
        match &mut pilot {
            Some(p) => {
                for out in p.process_batch(&requests, &corpus) {
                    let (served, evicted, _) =
                        engine.serve(&out.request, &out.prompt, &corpus, decode)?;
                    p.on_evict(&evicted);
                    ttft.record(served.ttft);
                }
            }
            None => {
                for r in &requests {
                    let (served, _, _) = engine.serve(r, &Prompt::baseline(r), &corpus, decode)?;
                    ttft.record(served.ttft);
                }
            }
        }
        Ok((ttft, engine.stat_prefilled_tokens, engine.stat_reused_tokens))
    };

    println!("e2e real-model serving: {n} requests, decode={decode} (TinyLM via PJRT CPU)\n");
    let (mut base, base_prefilled, base_reused) = run(false)?;
    let (mut pilot, p_prefilled, p_reused) = run(true)?;
    println!(
        "{:<16} {:>12} {:>12} {:>16} {:>14}",
        "config", "mean TTFT", "p99 TTFT", "prefilled toks", "reused toks"
    );
    println!(
        "{:<16} {:>11.4}s {:>11.4}s {:>16} {:>14}",
        "baseline", base.mean(), base.p99(), base_prefilled, base_reused
    );
    println!(
        "{:<16} {:>11.4}s {:>11.4}s {:>16} {:>14}",
        "+ ContextPilot", pilot.mean(), pilot.p99(), p_prefilled, p_reused
    );
    println!(
        "\nmeasured prefill speedup: {:.2}x  (reused tokens {:.1}% -> {:.1}%)",
        base.mean() / pilot.mean(),
        base_reused as f64 / (base_prefilled + base_reused).max(1) as f64 * 100.0,
        p_reused as f64 / (p_prefilled + p_reused).max(1) as f64 * 100.0,
    );
    Ok(())
}
