//! Agentic memory (Mem0-style, §7.2): per-user memory stores retrieved
//! with high temporal locality. ContextPilot runs in online mode; aligned
//! memories hit the prefix cache across turns.
//!
//!     cargo run --release --example agent_memory -- --users 4 --turns 10

use contextpilot::engine::ModelSku;
use contextpilot::experiments::{corpus_for, run_system, RunConfig, SystemKind};
use contextpilot::pilot::PilotConfig;
use contextpilot::util::cli::Args;
use contextpilot::workload::{mem0, Dataset};

fn main() {
    let args = Args::from_env();
    let users = args.get_usize("users", 4);
    let turns = args.get_usize("turns", 10);
    let k = args.get_usize("k", 20);

    let corpus = corpus_for(Dataset::LoCoMo);
    let workload = mem0(users, turns, k, args.get_u64("seed", 7));
    let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_4B, Dataset::LoCoMo);
    cfg.offline = false; // online mode with cold start, like the paper

    println!(
        "Mem0-style memory workload: {users} users x {turns} turns, k={k}\n"
    );
    println!("{:<14} {:>10} {:>10} {:>10}", "system", "mean TTFT", "hit ratio", "quality");
    for system in [
        SystemKind::RadixCache,
        SystemKind::LMCache,
        SystemKind::ContextPilot(PilotConfig::default()),
    ] {
        let mut m = run_system(&system, &workload, &corpus, &cfg);
        println!(
            "{:<14} {:>9.4}s {:>9.1}% {:>10.3}",
            system.name(),
            m.mean_ttft(),
            m.hit_ratio() * 100.0,
            m.mean_quality()
        );
    }
}
