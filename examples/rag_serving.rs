//! Multi-session RAG serving: the Table-2 scenario as a runnable demo.
//! Compares all four systems on a MultihopRAG-profile workload and prints
//! the paper-style summary (F1, prefill throughput, hit ratio, TTFT).
//!
//!     cargo run --release --example rag_serving -- --sessions 300 --k 15

use contextpilot::engine::ModelSku;
use contextpilot::experiments::{corpus_for, run_f1, run_system, RunConfig, SystemKind};
use contextpilot::util::cli::Args;
use contextpilot::workload::{multi_session, Dataset};

fn main() {
    let args = Args::from_env();
    let sessions = args.get_usize("sessions", 300);
    let k = args.get_usize("k", 15);
    let seed = args.get_u64("seed", 0x5EED);

    let dataset = Dataset::MultihopRag;
    let corpus = corpus_for(dataset);
    let workload = multi_session(dataset, sessions, k, seed);
    let cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);

    println!(
        "MultihopRAG-profile, {} sessions, k={}, model {} — offline mode\n",
        sessions,
        k,
        ModelSku::Qwen3_32B.name()
    );
    println!(
        "{:<14} {:>6} {:>14} {:>10} {:>10}",
        "system", "F1", "prefill tok/s", "hit ratio", "mean TTFT"
    );
    for system in SystemKind::all_default() {
        let mut m = run_system(&system, &workload, &corpus, &cfg);
        let f1 = run_f1(&m, &workload, &cfg, 60.4);
        println!(
            "{:<14} {:>6.1} {:>14.0} {:>9.1}% {:>9.3}s",
            system.name(),
            f1,
            m.prefill_throughput(),
            m.hit_ratio() * 100.0,
            m.mean_ttft()
        );
    }
}
