//! Multi-turn conversation with de-duplication: the §6 walkthrough.
//! Shows, turn by turn, which blocks were served in full, which were
//! replaced by location annotations, and the resulting token savings.
//!
//!     cargo run --release --example multi_turn_chat -- --turns 8

use contextpilot::corpus::{Corpus, CorpusConfig};
use contextpilot::engine::{ModelSku, ReusePolicy, SimEngine};
use contextpilot::pilot::{ContextPilot, PilotConfig};
use contextpilot::quality::{ModelEra, QualityModel};
use contextpilot::tokenizer::Tokenizer;
use contextpilot::types::Segment;
use contextpilot::util::cli::Args;
use contextpilot::workload::{multi_turn, Dataset};

fn main() {
    let args = Args::from_env();
    let turns = args.get_usize("turns", 8);
    let corpus = Corpus::generate(
        &CorpusConfig {
            n_docs: 800,
            ..Default::default()
        },
        &Tokenizer::default(),
    );
    let workload = multi_turn(Dataset::MtRag, turns, 10, args.get_u64("seed", 42));

    let mut pilot = ContextPilot::new(PilotConfig::default());
    let mut engine = SimEngine::new(
        ModelSku::Qwen3_4B.profile(),
        ReusePolicy::RadixPrefix,
        500_000,
    );
    let quality = QualityModel::new(ModelEra::Modern, false);

    let mut saved_tokens = 0usize;
    for req in &workload.requests {
        let out = pilot.process(req, &corpus);
        let full: usize = req.context.iter().map(|&b| corpus.doc_tokens(b)).sum();
        let mut kept = 0usize;
        let mut refs = Vec::new();
        for seg in &out.prompt.segments {
            match seg {
                Segment::Block(b) => kept += corpus.doc_tokens(*b),
                Segment::PartialBlock { block, kept: k, .. } => {
                    kept += k
                        .iter()
                        .map(|&l| {
                            Tokenizer::default().count(&corpus.doc(*block).lines[l as usize])
                        })
                        .sum::<usize>()
                }
                Segment::LocationRef(b) => refs.push(*b),
                _ => {}
            }
        }
        saved_tokens += full.saturating_sub(kept);
        let (served, evicted) = engine.serve(req, &out.prompt, &corpus, &quality, 24);
        pilot.on_evict(&evicted);
        println!(
            "turn {:>2}: {} blocks retrieved, {} deduped -> refs {:?}",
            req.turn,
            req.context.len(),
            out.dedup_stats.blocks_deduped,
            refs.iter().map(|b| b.0).collect::<Vec<_>>()
        );
        println!(
            "         prompt {} tok ({} cached), ttft {:.4}s, quality {:.3}",
            served.prompt_tokens, served.cached_tokens, served.ttft, served.quality
        );
    }
    println!("\ncontext tokens avoided by de-duplication: {saved_tokens}");
}
