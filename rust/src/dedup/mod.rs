//! Context de-duplication (§6, Algorithm 3).
//!
//! Two levels:
//!  * **Block-level**: a block that already appeared in this conversation's
//!    prior turns is replaced by a *location annotation* ("Please refer to
//!    [CB_x] in the previous conversation") — its KV is already cached in
//!    the history prefix.
//!  * **Content-level**: novel blocks are split into variable-length
//!    sub-blocks by content-defined chunking (boundary after line ℓ when
//!    `Hash(ℓ) mod M == 0`, following LBFS-style CDC); a sub-block whose
//!    hash was already contributed by a *different* block is elided and
//!    annotated with a reference to the first occurrence.
//!
//! CDC boundaries depend only on local content, so identical text produces
//! identical sub-blocks at any offset — unlike fixed-size chunking where
//! one insertion shifts every later boundary (§6).

use crate::corpus::Corpus;
use crate::index::tree::ContextIndex;
use crate::types::{BlockId, Context, Segment, SessionId};

#[derive(Clone, Copy, Debug)]
pub struct DedupConfig {
    /// CDC modulus M: expected sub-block length in lines.
    pub modulus: u64,
    /// Enable content-level (sub-block) de-duplication.
    pub content_level: bool,
}

impl Default for DedupConfig {
    fn default() -> Self {
        Self {
            modulus: 2,
            content_level: true,
        }
    }
}

#[inline]
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Content-defined chunking: split `lines` into sub-blocks, cutting after
/// any line whose hash ≡ 0 (mod M). Returns (start, end) line ranges.
pub fn cdc_boundaries(lines: &[String], modulus: u64) -> Vec<(usize, usize)> {
    let m = modulus.max(1);
    let mut out = Vec::new();
    let mut start = 0usize;
    for (i, line) in lines.iter().enumerate() {
        if fnv1a64(line.as_bytes()) % m == 0 {
            out.push((start, i + 1));
            start = i + 1;
        }
    }
    if start < lines.len() {
        out.push((start, lines.len()));
    }
    out
}

fn subblock_hash(lines: &[String]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for l in lines {
        h ^= fnv1a64(l.as_bytes());
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Statistics of one de-duplication pass (drives Table 4's token savings).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DedupStats {
    pub blocks_in: usize,
    pub blocks_deduped: usize,
    pub subblocks_deduped: usize,
    pub lines_elided: usize,
}

/// Algorithm 3: de-duplicate `context` against the conversation record of
/// `session`, returning the prompt segments for the context region and
/// updating the record for future turns.
pub fn dedup_context(
    index: &mut ContextIndex,
    session: SessionId,
    context: &Context,
    corpus: &Corpus,
    cfg: &DedupConfig,
) -> (Vec<Segment>, DedupStats) {
    let mut segments = Vec::with_capacity(context.len());
    let mut stats = DedupStats {
        blocks_in: context.len(),
        ..Default::default()
    };
    // Take the record out to sidestep aliasing; put back at the end.
    let mut record = std::mem::take(index.conversation(session));
    for &b in context {
        if record.seen_blocks.contains(&b) {
            // block-level duplicate: annotate, no prefill
            segments.push(Segment::LocationRef(b));
            stats.blocks_deduped += 1;
            continue;
        }
        if !cfg.content_level {
            segments.push(Segment::Block(b));
            continue;
        }
        // content-level: CDC split + sub-block hash matching
        let lines = &corpus.doc(b).lines;
        let ranges = cdc_boundaries(lines, cfg.modulus);
        let mut kept: Vec<u32> = Vec::with_capacity(lines.len());
        let mut refs: Vec<BlockId> = Vec::new();
        let mut elided_any = false;
        for &(s, e) in &ranges {
            let h = subblock_hash(&lines[s..e]);
            match record.seen_subblocks.get(&h) {
                Some(&owner) if owner != b => {
                    // duplicate span from a different block: elide + annotate
                    elided_any = true;
                    stats.subblocks_deduped += 1;
                    stats.lines_elided += e - s;
                    if !refs.contains(&owner) {
                        refs.push(owner);
                    }
                }
                _ => {
                    record.seen_subblocks.entry(h).or_insert(b);
                    kept.extend((s as u32)..(e as u32));
                }
            }
        }
        if elided_any {
            segments.push(Segment::PartialBlock { block: b, kept, refs });
        } else {
            segments.push(Segment::Block(b));
        }
    }
    // register this turn's blocks for future comparisons
    record.seen_blocks.extend(context.iter().copied());
    *index.conversation(session) = record;
    (segments, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{Corpus, CorpusConfig};
    use crate::tokenizer::Tokenizer;

    fn setup() -> (ContextIndex, Corpus) {
        let tok = Tokenizer::default();
        let cfg = CorpusConfig {
            n_docs: 60,
            fact_pool: 8,        // small pool => much cross-doc duplication
            shared_line_prob: 0.4,
            ..Default::default()
        };
        (ContextIndex::new(0.001), Corpus::generate(&cfg, &tok))
    }

    fn ctx(ids: &[u32]) -> Context {
        ids.iter().map(|&i| BlockId(i)).collect()
    }

    #[test]
    fn first_turn_keeps_all_blocks() {
        let (mut ix, corpus) = setup();
        let (segs, stats) = dedup_context(
            &mut ix,
            SessionId(0),
            &ctx(&[1, 2, 3]),
            &corpus,
            &DedupConfig {
                content_level: false,
                ..Default::default()
            },
        );
        assert_eq!(stats.blocks_deduped, 0);
        assert!(segs.iter().all(|s| matches!(s, Segment::Block(_))));
    }

    #[test]
    fn paper_example_second_turn() {
        // §6: turn 1 retrieves {1,2,4}; turn 2 {1,5,2} -> {1,2} annotated,
        // only {5} fully processed.
        let (mut ix, corpus) = setup();
        let cfg = DedupConfig {
            content_level: false,
            ..Default::default()
        };
        dedup_context(&mut ix, SessionId(6), &ctx(&[1, 2, 4]), &corpus, &cfg);
        let (segs, stats) =
            dedup_context(&mut ix, SessionId(6), &ctx(&[1, 5, 2]), &corpus, &cfg);
        assert_eq!(stats.blocks_deduped, 2);
        assert_eq!(segs[0], Segment::LocationRef(BlockId(1)));
        assert_eq!(segs[1], Segment::Block(BlockId(5)));
        assert_eq!(segs[2], Segment::LocationRef(BlockId(2)));
    }

    #[test]
    fn sessions_do_not_leak() {
        let (mut ix, corpus) = setup();
        let cfg = DedupConfig::default();
        dedup_context(&mut ix, SessionId(1), &ctx(&[1, 2]), &corpus, &cfg);
        let (_, stats) = dedup_context(&mut ix, SessionId(2), &ctx(&[1, 2]), &corpus, &cfg);
        assert_eq!(stats.blocks_deduped, 0, "records must be per-session");
    }

    #[test]
    fn content_level_elides_shared_facts() {
        let (mut ix, corpus) = setup();
        let cfg = DedupConfig::default();
        // find two docs sharing a fact line
        let mut pair = None;
        'outer: for a in 0..corpus.len() {
            for b in (a + 1)..corpus.len() {
                let la: std::collections::HashSet<_> =
                    corpus.docs[a].lines.iter().collect();
                if corpus.docs[b].lines.iter().any(|l| la.contains(l)) {
                    pair = Some((a as u32, b as u32));
                    break 'outer;
                }
            }
        }
        let (a, b) = pair.expect("corpus should contain shared lines");
        let (_, stats) =
            dedup_context(&mut ix, SessionId(3), &ctx(&[a, b]), &corpus, &cfg);
        // NOTE: elision requires the shared lines to fall in matching CDC
        // sub-blocks; with a dense fact pool this happens frequently but is
        // not guaranteed for one specific pair. Run over many pairs:
        let mut total = stats.subblocks_deduped;
        for s in 10..30u32 {
            let c: Context = (0..6).map(|i| BlockId((s * 2 + i) % 60)).collect();
            let (_, st) = dedup_context(&mut ix, SessionId(100 + s), &c, &corpus, &cfg);
            total += st.subblocks_deduped;
        }
        assert!(total > 0, "content-level dedup never fired");
    }

    #[test]
    fn cdc_is_content_local() {
        // identical text produces identical sub-blocks regardless of offset
        let lines: Vec<String> = (0..12).map(|i| format!("shared line {i}")).collect();
        let mut shifted = vec!["prefix junk".to_string()];
        shifted.extend(lines.clone());
        let b1 = cdc_boundaries(&lines, 4);
        let b2 = cdc_boundaries(&shifted, 4);
        // sub-block hashes of the shared suffix must coincide
        let h1: Vec<u64> = b1.iter().map(|&(s, e)| subblock_hash(&lines[s..e])).collect();
        let h2: Vec<u64> = b2
            .iter()
            .map(|&(s, e)| subblock_hash(&shifted[s..e]))
            .collect();
        let shared: Vec<_> = h1.iter().filter(|h| h2.contains(h)).collect();
        // all but possibly the first chunk of each must match
        assert!(
            shared.len() + 1 >= h1.len(),
            "CDC not offset-invariant: {} of {} chunks shared",
            shared.len(),
            h1.len()
        );
    }

    #[test]
    fn cdc_covers_all_lines_exactly_once() {
        use crate::util::prng::Rng;
        use crate::util::prop;
        prop::quickcheck("cdc partitions lines", |rng: &mut Rng, size| {
            let lines: Vec<String> = (0..size).map(|_| prop::gen_text(rng, 4)).collect();
            let ranges = cdc_boundaries(&lines, 3);
            let mut covered = 0usize;
            let mut prev_end = 0usize;
            for &(s, e) in &ranges {
                if s != prev_end || e <= s {
                    return false;
                }
                covered += e - s;
                prev_end = e;
            }
            covered == lines.len()
        });
    }

    #[test]
    fn dedup_never_invents_or_loses_blocks() {
        let (mut ix, corpus) = setup();
        let cfg = DedupConfig::default();
        let c = ctx(&[5, 9, 13, 20]);
        dedup_context(&mut ix, SessionId(4), &ctx(&[9, 20]), &corpus, &cfg);
        let (segs, _) = dedup_context(&mut ix, SessionId(4), &c, &corpus, &cfg);
        let mentioned: Vec<BlockId> = segs
            .iter()
            .map(|s| match s {
                Segment::Block(b)
                | Segment::LocationRef(b)
                | Segment::PartialBlock { block: b, .. } => *b,
                _ => panic!("unexpected segment"),
            })
            .collect();
        assert_eq!(mentioned, c);
    }

    #[test]
    fn token_count_never_grows() {
        // deduped prompt region must not exceed the baseline block tokens
        let (mut ix, corpus) = setup();
        let tok = Tokenizer::default();
        let cfg = DedupConfig::default();
        let c = ctx(&[2, 4, 6, 8]);
        dedup_context(&mut ix, SessionId(5), &ctx(&[4, 8]), &corpus, &cfg);
        let (segs, _) = dedup_context(&mut ix, SessionId(5), &c, &corpus, &cfg);
        let baseline: usize = c.iter().map(|&b| corpus.doc_tokens(b)).sum();
        let annotation_overhead = 12; // words per location annotation
        let mut deduped = 0usize;
        for s in &segs {
            match s {
                Segment::Block(b) => deduped += corpus.doc_tokens(*b),
                Segment::LocationRef(_) => deduped += annotation_overhead,
                Segment::PartialBlock { block, kept, refs } => {
                    for &l in kept {
                        deduped += tok.count(&corpus.doc(*block).lines[l as usize]);
                    }
                    deduped += annotation_overhead * refs.len();
                }
                _ => {}
            }
        }
        assert!(
            deduped <= baseline + annotation_overhead,
            "dedup grew the prompt: {deduped} > {baseline}"
        );
    }
}
