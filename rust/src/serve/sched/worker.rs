//! The per-shard scheduler loop: claim → run → account.
//!
//! Each shard has exactly one of these loops (thread `cp-sched-{s}`), so
//! everything a loop does to its shard is single-writer: the loop takes
//! the shard lock once per *slice* (a bounded burst of admission and
//! chunk steps) and the dispatch lock only at step boundaries, which
//! keeps the hot path lock-light while letting `metrics()` /
//! `trace_events()` / new submissions interleave between slices.
//!
//! Determinism: every decision in this file is a function of the
//! dispatch state (queues, frontier, seal) and the shard's run-queue
//! clock — never of wall time or of which thread got scheduled first.
//! Open-loop admission is deliberately one request per step: batching
//! simultaneous arrivals would let in-batch reordering (baseline LPM
//! order, pilot batch rewrites) depend on how many arrivals a racing
//! worker happened to see at once.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use crate::api::Error;
use crate::engine::iface::InferenceEngine;
use crate::obs::{Counter, EventKind, TierOp};
use crate::serve::shard::Shard;
use crate::serve::ServeConfig;
use crate::types::{Request, RequestId, ServedRequest};

use super::{
    lock_dispatch, ActiveReq, Ctl, Dispatch, OverloadPolicy, ResultCell, ShardQueue, Shared,
    TimedEntry, WaveJob,
};

/// Upper bound on steps per slice: the shard lock is released (and the
/// dispatch re-examined) at least this often, so observers and control
/// operations are never starved by a long open-loop run.
const MAX_SLICE_STEPS: usize = 256;

/// What the loop decided to do after examining the dispatch state.
enum Claim {
    /// Control said stop: exit the loop.
    Stop,
    /// Nothing runnable: wait on the work condvar.
    Park,
    /// Serve one wave slice through the classic queue pipeline.
    Wave(WaveJob),
    /// Run a slice of open-loop admission / chunk steps.
    Slice,
}

/// One scheduling decision inside a slice.
enum Step {
    /// Admit the open-loop arrival that is due at the shard clock.
    Admit { entry: TimedEntry, clock: f64 },
    /// Run one chunk of the front active request.
    Chunk { entry: ActiveReq, start: f64, dur: f64 },
    /// Nothing runnable right now: end the slice.
    Idle,
}

/// Fills every touched-but-unresolved cell with [`Error::ShardPoisoned`]
/// if the slice panics (unwinding through the worker's `catch_unwind`).
/// Armed for the whole slice *including* the final `record_served` —
/// completed requests are in no queue by then, so only the guard can
/// resolve their cells on a panic. Disarmed on every orderly exit —
/// error returns resolve their cells explicitly, queued entries are
/// swept by the worker's dead-shard sweep. Fills are first-write-wins,
/// so covering already-resolved cells is harmless.
struct SliceGuard {
    cells: Vec<Arc<ResultCell>>,
    armed: bool,
}

impl Drop for SliceGuard {
    fn drop(&mut self) {
        if self.armed {
            for c in &self.cells {
                c.fill(Err(Error::ShardPoisoned("shard")));
            }
        }
    }
}

/// The loop body for shard `s`. Runs until control says stop.
pub(super) fn run<E: InferenceEngine>(shared: Arc<Shared<E>>, s: usize) {
    loop {
        let claim = {
            let mut d = shared.state.lock().unwrap_or_else(|p| p.into_inner());
            loop {
                match claim_work(shared.engine.config(), &mut d, s) {
                    Claim::Stop => return,
                    Claim::Park => {
                        d = shared.work.wait(d).unwrap_or_else(|p| p.into_inner());
                    }
                    c => break c,
                }
            }
        };
        let failed = match claim {
            Claim::Wave(job) => run_wave(&shared, s, job),
            Claim::Slice => match catch_unwind(AssertUnwindSafe(|| run_slice(&shared, s))) {
                Ok(Ok(())) => None,
                Ok(Err(e)) => Some(e),
                Err(_) => Some(Error::ShardPoisoned("shard")),
            },
            Claim::Stop | Claim::Park => unreachable!("parked claims never escape the inner loop"),
        };
        let mut d = shared.state.lock().unwrap_or_else(|p| p.into_inner());
        d.queues[s].busy = false;
        if let Some(e) = failed {
            d.queues[s].dead = true;
            sweep_dead(&mut d.queues[s], e);
        }
        shared.idle.notify_all();
    }
}

/// Decide what shard `s`'s loop should do next. Marks the queue busy
/// when it hands out work.
///
/// Open-loop slices take priority over waves: every admission runnable
/// under the current frontier lands before a wave queued while it was
/// pending, whether or not the worker had already run it — so the
/// engine-visible order is a function of the dispatch state, not of
/// worker progress. Once no slice is runnable, a queued wave *is*
/// claimed even while open-loop work sits frontier-gated: waves run on
/// the queue-pipeline clock and never touch the run-queue clock, and
/// gated [`ActiveReq`]s carry already-served records whose remaining
/// chunks are pure virtual-time replay. Without this, a caller blocking
/// on a wave behind a gated shard would deadlock — it is the very
/// thread that would advance the frontier.
///
/// A due-but-Delay-blocked front arrival does not make a slice runnable
/// (see [`super::timed_front_progress`]): a slice on it would be a
/// no-op, and claiming it anyway would spin this loop until the
/// frontier moves.
fn claim_work(cfg: &ServeConfig, d: &mut Dispatch, s: usize) -> Claim {
    if d.ctl == Ctl::Stopping {
        return Claim::Stop;
    }
    let sealed = d.sealed;
    let frontier = d.frontier;
    let paused = d.ctl == Ctl::Paused;
    let q = &mut d.queues[s];
    if q.dead || paused {
        return Claim::Park;
    }
    let slice_runnable = if q.active.is_empty() {
        // an idle shard jumps its clock to the next arrival, so any
        // queued arrival is admissible
        !q.timed.is_empty()
    } else {
        super::timed_front_progress(cfg, q) || sealed || q.clock < frontier
    };
    if slice_runnable {
        q.busy = true;
        return Claim::Slice;
    }
    if let Some(job) = q.waves.pop_front() {
        q.busy = true;
        return Claim::Wave(job);
    }
    Claim::Park
}

/// Serve one wave slice through the shard's classic queue pipeline and
/// post the results into the wave's seal. Returns the error (for the
/// dead-shard sweep) if the slice failed or panicked; the seal is
/// always accounted either way, so the wave's submitter never hangs.
fn run_wave<E: InferenceEngine>(shared: &Shared<E>, s: usize, job: WaveJob) -> Option<Error> {
    let WaveJob { batch, idxs, seal } = job;
    let served = catch_unwind(AssertUnwindSafe(|| {
        shared.engine.serve_shard_queue(s, &batch, &shared.corpus)
    }))
    .unwrap_or_else(|_| Err(Error::ShardPoisoned("shard")));
    match served {
        Ok(served) => {
            let idx_of: HashMap<RequestId, usize> =
                batch.iter().zip(&idxs).map(|(r, &i)| (r.id, i)).collect();
            // filter_map instead of index: an engine that returns an
            // unknown id must not panic the loop — the missing slot
            // surfaces as EngineFailure at the seal's waiter
            let pairs: Vec<(usize, ServedRequest)> = served
                .into_iter()
                .filter_map(|sr| idx_of.get(&sr.request.id).map(|&i| (i, sr)))
                .collect();
            seal.complete(idxs.len(), pairs);
            None
        }
        Err(e) => {
            seal.fail(e.clone(), idxs.len());
            Some(e)
        }
    }
}

/// Run up to [`MAX_SLICE_STEPS`] open-loop steps on shard `s`: admit
/// due arrivals (one per step), run prefill chunks round-robin, resolve
/// completed requests. The shard lock is held for the whole slice; the
/// dispatch lock is taken briefly per step.
fn run_slice<E: InferenceEngine>(shared: &Shared<E>, s: usize) -> Result<(), Error> {
    let mut completed: Vec<(ServedRequest, Arc<ResultCell>)> = Vec::new();
    let mut guard = SliceGuard {
        cells: Vec::new(),
        armed: true,
    };
    let mut shard = shared.engine.lock_shard(s)?;
    let mut worked = false;
    let mut failed: Option<Error> = None;
    for _ in 0..MAX_SLICE_STEPS {
        let step = match next_step(shared, s, &mut shard) {
            Ok(st) => st,
            Err(e) => {
                failed = Some(e);
                break;
            }
        };
        match step {
            Step::Idle => break,
            Step::Admit { entry, clock } => {
                worked = true;
                guard.cells.push(Arc::clone(&entry.cell));
                if let Err(e) = admit(shared, s, &mut shard, entry, clock) {
                    failed = Some(e);
                    break;
                }
            }
            Step::Chunk { entry, start, dur } => {
                worked = true;
                guard.cells.push(Arc::clone(&entry.cell));
                match run_chunk(shared, s, &mut shard, entry, start, dur) {
                    Ok(Some(done)) => completed.push(done),
                    Ok(None) => {}
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
        }
    }
    if failed.is_none() && worked {
        if let Err(e) = shared.engine.publish_probes(&shard) {
            failed = Some(e);
        }
    }
    drop(shard);
    if let Some(e) = failed {
        guard.armed = false;
        for (_, cell) in &completed {
            cell.fill(Err(e.clone()));
        }
        return Err(e);
    }
    if completed.is_empty() {
        guard.armed = false;
        return Ok(());
    }
    // affinity attribution takes the placement ledger, so it must run
    // with the shard lock released (placement → shard order). The guard
    // stays armed across it: completed requests are in no queue anymore,
    // so if record_served panics only the guard can resolve their cells
    // (the dead-shard sweep never sees them).
    let (serveds, cells): (Vec<ServedRequest>, Vec<Arc<ResultCell>>) =
        completed.into_iter().unzip();
    match shared.engine.record_served(&serveds) {
        Ok(()) => {
            for (sr, cell) in serveds.into_iter().zip(cells) {
                cell.fill(Ok(sr));
            }
            guard.armed = false;
            Ok(())
        }
        Err(e) => {
            guard.armed = false;
            for cell in &cells {
                cell.fill(Err(e.clone()));
            }
            Err(e)
        }
    }
}

/// One scheduling decision for shard `s`, on its run-queue clock.
///
/// Priority order: (1) admit the front arrival if due — applying
/// deadline and queue-bound backpressure, (2) run a chunk, but only
/// while the clock is **strictly** below the arrival frontier (or the
/// arrivals are sealed) — at `clock == frontier` an arrival may still
/// land at exactly the frontier, so running ahead would make progress
/// depend on worker timing, (3) idle.
fn next_step<E: InferenceEngine>(
    shared: &Shared<E>,
    s: usize,
    shard: &mut Shard<E>,
) -> Result<Step, Error> {
    let cfg = shared.engine.config();
    let mut d = lock_dispatch(shared)?;
    if d.ctl != Ctl::Running {
        return Ok(Step::Idle);
    }
    let sealed = d.sealed;
    let frontier = d.frontier;
    let q = &mut d.queues[s];
    // idle jump: with nothing mid-prefill, virtual time skips to the
    // next arrival instead of crawling there chunk by chunk
    if q.active.is_empty() {
        if let Some(front) = q.timed.front() {
            if front.vt > q.clock {
                q.clock = front.vt;
            }
        }
    }
    loop {
        let Some(front) = q.timed.front_mut() else { break };
        if front.vt > q.clock {
            break;
        }
        let lateness = q.clock - front.vt;
        let blown = cfg.deadline.is_some_and(|dl| lateness > dl);
        let over = cfg.queue_bound.is_some_and(|b| q.active.len() >= b);
        if blown || (over && cfg.on_overload == OverloadPolicy::Shed) {
            if let Some(entry) = q.timed.pop_front() {
                let clock = q.clock;
                shed(shard, clock, &entry);
            }
            continue;
        }
        if over {
            // Delay: the arrival stays queued until the shard drains
            // below the bound; marked (counter + trace event) once
            if !front.delayed {
                front.delayed = true;
                let (rid, sess) = (front.req.id.0, front.req.session.0);
                let clock = q.clock;
                shard.registry.add(Counter::BackpressureDelayed, 1);
                sync_tracer(shard, clock);
                if let Some(tracer) = &mut shard.tracer {
                    tracer.emit(
                        clock,
                        0.0,
                        Some(rid),
                        Some(sess),
                        EventKind::Backpressure { action: "delayed" },
                    );
                }
            }
            break;
        }
        if let Some(entry) = q.timed.pop_front() {
            let clock = q.clock;
            return Ok(Step::Admit { entry, clock });
        }
    }
    if sealed || q.clock < frontier {
        if let Some(entry) = q.active.pop_front() {
            let start = q.clock;
            let dur = entry.plan.get(entry.next).copied().unwrap_or(0.0);
            q.clock += dur;
            return Ok(Step::Chunk { entry, start, dur });
        }
    }
    Ok(Step::Idle)
}

/// Shed one arrival: counter, trace marker, and an
/// [`Error::Overloaded`] resolution on its cell. Deterministic — the
/// decision was made on the shard's virtual clock.
fn shed<E: InferenceEngine>(shard: &mut Shard<E>, clock: f64, entry: &TimedEntry) {
    shard.registry.add(Counter::BackpressureShed, 1);
    sync_tracer(shard, clock);
    if let Some(tracer) = &mut shard.tracer {
        tracer.emit(
            clock,
            0.0,
            Some(entry.req.id.0),
            Some(entry.req.session.0),
            EventKind::Backpressure { action: "shed" },
        );
    }
    entry.cell.fill(Err(Error::Overloaded(entry.req.id)));
}

/// Admit one open-loop arrival at `clock`: run the cache/engine half of
/// the pipeline now (engine work is atomic per request, exactly as on
/// the wave path) and queue the request's chunk plan on the run queue;
/// the clock-visible prefill then elapses chunk by chunk.
fn admit<E: InferenceEngine>(
    shared: &Shared<E>,
    s: usize,
    shard: &mut Shard<E>,
    entry: TimedEntry,
    clock: f64,
) -> Result<(), Error> {
    let cfg = shared.engine.config();
    if cfg.obs.trace {
        sync_tracer(shard, clock);
        if let Some(tracer) = &mut shard.tracer {
            let (rid, sess) = (Some(entry.req.id.0), Some(entry.req.session.0));
            tracer.emit(clock, 0.0, rid, sess, EventKind::Admitted);
            tracer.emit(
                clock,
                0.0,
                rid,
                sess,
                EventKind::Placed {
                    policy: cfg.placement.name(),
                    affinity: entry.affinity,
                },
            );
            tracer.emit(clock, 0.0, rid, sess, EventKind::Queued);
        }
    }
    let reqs: Vec<Request> = vec![entry.req.clone()];
    let (served, plans, evicted, demoted) = shard.serve_pipeline(&reqs, &shared.corpus);
    if let Err(e) = shared.engine.track_ownership(s, &served, &evicted) {
        entry.cell.fill(Err(e.clone()));
        return Err(e);
    }
    if demoted > 0 {
        if let Some(tracer) = &mut shard.tracer {
            tracer.emit(
                clock,
                0.0,
                None,
                None,
                EventKind::Tier {
                    op: TierOp::Demote,
                    tier: "dram",
                    tokens: demoted,
                },
            );
        }
    }
    let (mut sr, plan) = match served.into_iter().zip(plans).next() {
        Some(pair) => pair,
        None => {
            let e = Error::EngineFailure(format!(
                "request {:?} was admitted but the engine returned nothing",
                entry.req.id
            ));
            entry.cell.fill(Err(e.clone()));
            return Err(e);
        }
    };
    if sr.request.id != entry.req.id {
        let e = Error::EngineFailure(format!(
            "engine served {:?} for admitted request {:?}",
            sr.request.id, entry.req.id
        ));
        entry.cell.fill(Err(e.clone()));
        return Err(e);
    }
    sr.prefill_chunks = plan.len() as u32;
    let active = ActiveReq {
        served: sr,
        plan,
        next: 0,
        vt: entry.vt,
        cell: entry.cell,
    };
    let depth = {
        let mut d = match lock_dispatch(shared) {
            Ok(d) => d,
            Err(e) => {
                active.cell.fill(Err(e.clone()));
                return Err(e);
            }
        };
        let q = &mut d.queues[s];
        q.active.push_back(active);
        q.active.len()
    };
    shard.max_queue_depth = shard.max_queue_depth.max(depth);
    shard.registry.add(Counter::QueueWaves, 1);
    shard.registry.max(Counter::MaxQueueDepth, depth as u64);
    Ok(())
}

/// Run one chunk of an active request on the virtual timeline. Returns
/// the finished `(record, cell)` when this was the last chunk, `None`
/// when the request went back to the run queue (round-robin — this is
/// what lets a short arrival overtake a long prefill).
fn run_chunk<E: InferenceEngine>(
    shared: &Shared<E>,
    s: usize,
    shard: &mut Shard<E>,
    mut entry: ActiveReq,
    start: f64,
    dur: f64,
) -> Result<Option<(ServedRequest, Arc<ResultCell>)>, Error> {
    let end = start + dur;
    if let Some(tracer) = &mut shard.tracer {
        let sr = &entry.served;
        // reconstruct the chunk's token count from its share of the
        // request's engine occupancy (uncached + promoted region)
        let occupying = sr.prompt_tokens.saturating_sub(sr.tier_hits.hbm);
        let tokens = if sr.ttft > 0.0 {
            (dur / sr.ttft * occupying as f64).round() as u32
        } else {
            0
        };
        tracer.emit(
            start,
            dur,
            Some(sr.request.id.0),
            Some(sr.request.session.0),
            EventKind::PrefillChunk {
                index: entry.next as u32,
                of: entry.plan.len() as u32,
                tokens,
            },
        );
    }
    sync_tracer(shard, end);
    entry.next += 1;
    if entry.next < entry.plan.len() {
        let mut d = match lock_dispatch(shared) {
            Ok(d) => d,
            Err(e) => {
                entry.cell.fill(Err(e.clone()));
                return Err(e);
            }
        };
        d.queues[s].active.push_back(entry);
        return Ok(None);
    }
    let ActiveReq { mut served, cell, vt, .. } = entry;
    // sojourn semantics: TTFT as the arrival saw it — completion on the
    // shard clock minus the virtual arrival time (queueing + chunked
    // prefill + backpressure delay all included)
    served.queued_ttft = end - vt;
    shard.metrics.record(&served);
    shard.record_request_counters(&served);
    if let Some(tracer) = &mut shard.tracer {
        let (rid, sess) = (Some(served.request.id.0), Some(served.request.session.0));
        if served.tier_hits.dram > 0 {
            tracer.emit(
                end,
                0.0,
                rid,
                sess,
                EventKind::Tier {
                    op: TierOp::Promote,
                    tier: "dram",
                    tokens: served.tier_hits.dram as u64,
                },
            );
        }
        if served.tier_hits.ssd > 0 {
            tracer.emit(
                end,
                0.0,
                rid,
                sess,
                EventKind::Tier {
                    op: TierOp::Promote,
                    tier: "ssd",
                    tokens: served.tier_hits.ssd as u64,
                },
            );
        }
        tracer.emit(end, 0.0, rid, sess, EventKind::Resolved);
    }
    Ok(Some((served, cell)))
}

/// Advance the shard's tracer clock forward to the run-queue time `t`
/// (never backwards — tracer time is monotone).
fn sync_tracer<E: InferenceEngine>(shard: &mut Shard<E>, t: f64) {
    if let Some(tracer) = &mut shard.tracer {
        let c = tracer.clock();
        if t > c {
            tracer.advance(t - c);
        }
    }
}

/// Fail everything queued on a dead shard: wave seals are accounted
/// (their submitters unblock with the error), timed and active cells
/// resolve to the error.
pub(super) fn sweep_dead(q: &mut ShardQueue, e: Error) {
    for job in q.waves.drain(..) {
        job.seal.fail(e.clone(), job.idxs.len());
    }
    for t in q.timed.drain(..) {
        t.cell.fill(Err(e.clone()));
    }
    for a in q.active.drain(..) {
        a.cell.fill(Err(e.clone()));
    }
}
