//! Continuous-batching scheduler: long-lived per-shard scheduler loops.
//!
//! Before this subsystem existed the serving layer was **wave-batched**:
//! every admission wave ran behind a flush barrier — all requests of a
//! wave were placed, served and resolved before the next wave could
//! start, so a short request admitted behind a long prefill waited for
//! the *entire* wave, not just for its own shard time. The scheduler
//! replaces the barrier with one long-lived loop per shard:
//!
//! ```text
//!   api::Server ── serve_wave ──▶ Scheduler ──▶ per-shard WaveJob queue
//!              └── submit_at ───▶     │    ──▶ per-shard timed queue
//!                                     ▼
//!                      "cp-sched-{s}" worker threads
//!              admit → chunked-prefill slices → resolve ResultCells
//! ```
//!
//! Two admission paths feed the same loops:
//!
//! * **Waves** ([`Scheduler::serve_wave`]) keep the facade's batch
//!   semantics bit-identical: one [`WaveJob`] per shard, served through
//!   the exact same `serve_queue` pipeline the barrier used, results
//!   collected through a [`SealState`] rendezvous. No barrier across
//!   *shards* remains — a shard that finishes its slice of a wave can
//!   start the next wave's slice immediately.
//! * **Open-loop arrivals** ([`Scheduler::submit_at`]) carry a virtual
//!   arrival time. They are admitted mid-flight into the shard's run
//!   queue when the shard's clock reaches them, and their chunked
//!   prefills interleave with whatever is already active — a short
//!   request admitted behind a long prefill overtakes it chunk by chunk
//!   instead of waiting for the long request's wave.
//!
//! The two paths compose on one shard without blocking each other: a
//! loop prefers open-loop slices while any are runnable under the
//! frontier, then claims queued waves — *including* while open-loop
//! work sits frontier-gated, since waves run on the queue-pipeline
//! clock and leave the run-queue clock untouched. A wave submitted
//! behind an unsealed, gated shard therefore completes without anyone
//! advancing the frontier (the submitting thread is typically the one
//! that would).
//!
//! **Determinism.** Progress is a pure function of the arrival sequence,
//! never of worker speed. The *frontier* — the largest arrival time
//! submitted so far — gates chunk execution: a shard may run a chunk only
//! while its clock is strictly below the frontier (or after
//! [`Scheduler::seal_arrivals`]), because an arrival might still land at
//! exactly the frontier. Admissions (arrival time ≤ shard clock) always
//! take priority over chunks. Probe-reading placement
//! ([`crate::serve::PlacementKind::ContextAware`]) quiesces the loops
//! before each unpinned placement (see [`Scheduler::submit_at`]), so
//! even the shard *choice* is a function of the arrival prefix. The
//! result is bit-identical across worker counts and across runs.
//!
//! **Backpressure** ([`OverloadPolicy`], [`ServeConfig::queue_bound`],
//! [`ServeConfig::deadline`]) is applied at admission time on the shard's
//! virtual clock, so shedding and delaying are exactly as deterministic
//! as serving: a replay of the same arrival sequence sheds the same
//! requests ([`Error::Overloaded`]).
//!
//! [`ServeConfig::queue_bound`]: crate::serve::ServeConfig::queue_bound
//! [`ServeConfig::deadline`]: crate::serve::ServeConfig::deadline
//! [`Error::Overloaded`]: crate::api::Error::Overloaded

mod worker;

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

use crate::api::Error;
use crate::corpus::Corpus;
use crate::engine::iface::InferenceEngine;
use crate::obs::EventKind;
use crate::serve::engine::{shard_guard, ServingEngine};
use crate::serve::ServeConfig;
use crate::types::{Request, ServedRequest};

/// What the scheduler does with an open-loop arrival whose shard is
/// over its [`queue_bound`](crate::serve::ServeConfig::queue_bound)
/// (deadline misses always shed, whatever the policy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Reject the arrival: its ticket resolves to
    /// [`Error::Overloaded`](crate::api::Error::Overloaded) and the
    /// shard never sees it. Bounds queue depth *and* admission latency.
    Shed,
    /// Keep the arrival queued until the shard drains below the bound.
    /// Nothing is lost, but tail admission latency grows with overload
    /// (the request may then still blow its deadline and be shed).
    Delay,
}

impl OverloadPolicy {
    /// CLI / telemetry name.
    pub fn name(self) -> &'static str {
        match self {
            OverloadPolicy::Shed => "shed",
            OverloadPolicy::Delay => "delay",
        }
    }

    /// Parse a `--overload` CLI value.
    pub fn parse(s: &str) -> Result<OverloadPolicy, Error> {
        match s {
            "shed" => Ok(OverloadPolicy::Shed),
            "delay" => Ok(OverloadPolicy::Delay),
            other => Err(Error::InvalidConfig(format!(
                "unknown overload policy '{other}' (expected shed|delay)"
            ))),
        }
    }
}

/// One submission's result slot, shared between its ticket and the
/// scheduler thread that resolves it. First write wins; recovers the
/// inner value even from a poisoned slot so a waiter is never stranded.
pub(crate) struct ResultCell {
    slot: Mutex<Option<Result<ServedRequest, Error>>>,
    ready: Condvar,
}

impl ResultCell {
    pub(crate) fn new() -> ResultCell {
        ResultCell {
            slot: Mutex::new(None),
            ready: Condvar::new(),
        }
    }

    /// Resolve the cell (first write wins). Runs on a scheduler (or
    /// flushing) thread.
    pub(crate) fn fill(&self, r: Result<ServedRequest, Error>) {
        let mut slot = self.slot.lock().unwrap_or_else(|p| p.into_inner());
        if slot.is_none() {
            *slot = Some(r);
            self.ready.notify_all();
        }
    }

    /// Non-blocking peek (clones; for the non-consuming `try_result`).
    pub(crate) fn peek(&self) -> Result<Option<Result<ServedRequest, Error>>, Error> {
        Ok(shard_guard(&self.slot, "ticket slot")?.clone())
    }

    /// Non-blocking take. Only consuming waiters call this: a cell has
    /// exactly one ticket, so moving the response out is safe.
    pub(crate) fn take_now(&self) -> Result<Option<Result<ServedRequest, Error>>, Error> {
        Ok(shard_guard(&self.slot, "ticket slot")?.take())
    }

    /// Block until the scheduler fills the cell, then move the result out.
    pub(crate) fn take_filled(&self) -> Result<ServedRequest, Error> {
        let mut slot = shard_guard(&self.slot, "ticket slot")?;
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self
                .ready
                .wait(slot)
                .map_err(|_| Error::ShardPoisoned("ticket slot"))?;
        }
    }
}

/// Rendezvous for one wave: per-arrival result slots plus a count of
/// shard jobs still outstanding. The submitting thread waits until every
/// shard's slice of the wave completed (or failed), *without* blocking
/// any scheduler loop — shards post their slice and move on.
pub(crate) struct SealState {
    out: Mutex<SealOut>,
    done: Condvar,
}

struct SealOut {
    slots: Vec<Option<ServedRequest>>,
    /// Arrivals not yet accounted for. Decremented by the *expected*
    /// per-job count (not by how many records the engine returned), so a
    /// contract-violating engine that drops a request surfaces as a
    /// missing slot instead of a hang.
    remaining: usize,
    /// First failure wins; later shard slices still run and are counted.
    err: Option<Error>,
}

impl SealState {
    fn new(n: usize) -> SealState {
        SealState {
            out: Mutex::new(SealOut {
                slots: (0..n).map(|_| None).collect(),
                remaining: n,
                err: None,
            }),
            done: Condvar::new(),
        }
    }

    /// Poison-recovering lock: a seal is write-once per slot and the
    /// waiter re-validates (missing slots fail), so torn state from a
    /// panicked filler cannot corrupt a result.
    fn lock(&self) -> MutexGuard<'_, SealOut> {
        self.out.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Post one shard job's results: `expected` arrivals accounted for,
    /// `pairs` of (arrival index, record) actually served.
    fn complete(&self, expected: usize, pairs: Vec<(usize, ServedRequest)>) {
        let mut out = self.lock();
        for (i, sr) in pairs {
            if out.slots[i].is_none() {
                out.slots[i] = Some(sr);
            }
        }
        out.remaining = out.remaining.saturating_sub(expected);
        if out.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Post one shard job's failure, accounting for its `expected`
    /// arrivals so the waiter still unblocks.
    fn fail(&self, e: Error, expected: usize) {
        let mut out = self.lock();
        if out.err.is_none() {
            out.err = Some(e);
        }
        out.remaining = out.remaining.saturating_sub(expected);
        if out.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Wait until every shard job posted, then take the slots and the
    /// first error (if any). Waits for *all* shards even after an error,
    /// so no job is left running against freed expectations.
    fn wait(&self) -> (Vec<Option<ServedRequest>>, Option<Error>) {
        let mut out = self.lock();
        while out.remaining > 0 {
            out = self
                .done
                .wait(out)
                .unwrap_or_else(|p| p.into_inner());
        }
        (std::mem::take(&mut out.slots), out.err.take())
    }
}

/// One shard's slice of an admission wave: the requests (in the wave's
/// arrival order) plus their arrival indices, and the seal to post
/// results into.
pub(super) struct WaveJob {
    pub(super) batch: Vec<Request>,
    pub(super) idxs: Vec<usize>,
    pub(super) seal: Arc<SealState>,
}

/// An open-loop arrival waiting (on the shard's virtual clock) to be
/// admitted.
pub(super) struct TimedEntry {
    /// Virtual arrival time.
    pub(super) vt: f64,
    pub(super) req: Request,
    /// Whether placement chose the shard by context affinity.
    pub(super) affinity: bool,
    pub(super) cell: Arc<ResultCell>,
    /// Whether a `Backpressure { action: "delayed" }` marker was already
    /// emitted for this entry (emitted once, on first deferral).
    pub(super) delayed: bool,
}

/// An admitted open-loop request whose chunked prefill is in flight.
pub(super) struct ActiveReq {
    /// The served record (engine work is done; the scheduler replays its
    /// chunk plan on the run-queue clock and stamps the sojourn TTFT).
    pub(super) served: ServedRequest,
    /// Per-chunk durations from the chunked-prefill admission plan.
    pub(super) plan: Vec<f64>,
    /// Next chunk index to run.
    pub(super) next: usize,
    /// Virtual arrival time (sojourn = completion clock − this).
    pub(super) vt: f64,
    pub(super) cell: Arc<ResultCell>,
}

/// One shard's run state, owned by the dispatch lock.
pub(super) struct ShardQueue {
    /// Pending wave slices, FIFO.
    pub(super) waves: VecDeque<WaveJob>,
    /// Open-loop arrivals, FIFO in arrival order (arrival times are
    /// globally nondecreasing, so FIFO == time order).
    pub(super) timed: VecDeque<TimedEntry>,
    /// Admitted open-loop requests with chunks left to run, round-robin.
    pub(super) active: VecDeque<ActiveReq>,
    /// The shard's run-queue virtual clock (seconds). Distinct from the
    /// tracer clock, which is synced forward to this one lazily.
    pub(super) clock: f64,
    /// A worker is currently running this shard's work.
    pub(super) busy: bool,
    /// A slice on this shard failed or panicked; everything queued is
    /// swept with an error and new work is refused.
    pub(super) dead: bool,
}

impl ShardQueue {
    fn new() -> ShardQueue {
        ShardQueue {
            waves: VecDeque::new(),
            timed: VecDeque::new(),
            active: VecDeque::new(),
            clock: 0.0,
            busy: false,
            dead: false,
        }
    }
}

/// Whether the front of `q.timed` can make progress right now under the
/// backpressure config: it will be admitted, shed (deadline blown or
/// over the bound under [`OverloadPolicy::Shed`]), or is still owed its
/// one-time `delayed` marker. A Delay-blocked arrival — due, over the
/// bound, already marked — makes no progress until the shard drains
/// below the bound, so it must *not* count as runnable: the worker
/// would spin claiming no-op slices, and `drain`/the placement quiesce
/// would wait on a state only a later frontier advance can change.
/// Shared by the worker's claim and the scheduler's `runnable` so the
/// two can never disagree.
pub(super) fn timed_front_progress(cfg: &ServeConfig, q: &ShardQueue) -> bool {
    let Some(front) = q.timed.front() else {
        return false;
    };
    if front.vt > q.clock {
        return false;
    }
    if cfg.deadline.is_some_and(|dl| q.clock - front.vt > dl) {
        return true; // will be shed
    }
    let over = cfg.queue_bound.is_some_and(|b| q.active.len() >= b);
    if !over || cfg.on_overload == OverloadPolicy::Shed {
        return true;
    }
    !front.delayed
}

/// Scheduler control state.
#[derive(Clone, Copy, PartialEq, Eq)]
pub(super) enum Ctl {
    Running,
    /// Loops park; queues keep accepting work.
    Paused,
    /// Loops exit at the next claim.
    Stopping,
}

/// Everything the worker loops share, behind one dispatch mutex.
pub(super) struct Dispatch {
    pub(super) queues: Vec<ShardQueue>,
    /// Largest arrival time submitted so far. Chunks may run only while
    /// the shard clock is *strictly* below this (an arrival may still
    /// land at exactly the frontier), or after sealing.
    pub(super) frontier: f64,
    /// No further open-loop arrivals will come; shards may run to
    /// completion.
    pub(super) sealed: bool,
    pub(super) ctl: Ctl,
}

pub(super) struct Shared<E: InferenceEngine> {
    pub(super) engine: Arc<ServingEngine<E>>,
    pub(super) corpus: Arc<Corpus>,
    pub(super) state: Mutex<Dispatch>,
    /// Signaled when work arrives or control state changes.
    pub(super) work: Condvar,
    /// Signaled when a worker finishes a slice (drain waits on this).
    pub(super) idle: Condvar,
}

/// Lock the dispatch state, converting poison into the typed error.
pub(super) fn lock_dispatch<E: InferenceEngine>(
    shared: &Shared<E>,
) -> Result<MutexGuard<'_, Dispatch>, Error> {
    shard_guard(&shared.state, "scheduler dispatch")
}

/// The per-shard scheduler: spawns one long-lived loop per shard on
/// first use, owns their lifecycle (pause / resume / drain / shutdown on
/// drop), and fronts both admission paths. One instance lives inside
/// each [`crate::api::Server`].
pub(crate) struct Scheduler<E: InferenceEngine> {
    shared: Arc<Shared<E>>,
    /// Worker join handles; empty until the first admission
    /// (lazy spawn keeps servers that only ever use the wave path from
    /// paying thread startup — they still go through the loops, which
    /// spawn on the first wave).
    threads: Mutex<Vec<JoinHandle<()>>>,
    /// Serializes every arrival-sequence mutation ([`Scheduler::submit_at`],
    /// [`Scheduler::advance_arrivals`], [`Scheduler::seal_arrivals`]): the
    /// frontier/seal state a submission checks cannot change before it
    /// commits, so an arrival is rejected *before* placement runs (no
    /// ledger side effects for never-admitted requests), and the
    /// probe-quiesce below observes a stable frontier.
    submit: Mutex<()>,
}

impl<E: InferenceEngine> Scheduler<E> {
    pub(crate) fn new(engine: Arc<ServingEngine<E>>, corpus: Arc<Corpus>) -> Scheduler<E> {
        let n = engine.n_shards();
        Scheduler {
            shared: Arc::new(Shared {
                engine,
                corpus,
                state: Mutex::new(Dispatch {
                    queues: (0..n).map(|_| ShardQueue::new()).collect(),
                    frontier: 0.0,
                    sealed: false,
                    ctl: Ctl::Running,
                }),
                work: Condvar::new(),
                idle: Condvar::new(),
            }),
            threads: Mutex::new(Vec::new()),
            submit: Mutex::new(()),
        }
    }

    /// Spawn the per-shard loops if they are not running yet. Emits the
    /// `sched_started` lifecycle marker from the control thread *before*
    /// the loops exist, so the marker's clock is deterministic.
    fn ensure_started(&self) -> Result<(), Error> {
        let mut threads = shard_guard(&self.threads, "scheduler threads")?;
        if !threads.is_empty() {
            return Ok(());
        }
        self.shared.engine.emit_sched_event(EventKind::SchedStarted)?;
        let n = self.shared.engine.n_shards();
        for s in 0..n {
            let shared = Arc::clone(&self.shared);
            let handle = std::thread::Builder::new()
                .name(format!("cp-sched-{s}"))
                .spawn(move || worker::run(shared, s))
                .map_err(|e| Error::EngineFailure(format!("spawning scheduler loop: {e}")))?;
            threads.push(handle);
        }
        Ok(())
    }

    /// Serve one admission wave through the per-shard loops: place the
    /// batch, fan one [`WaveJob`] out per shard, wait on the seal and
    /// return records in arrival order. Semantically identical to the
    /// old flush barrier for the requests *within* the wave — but no
    /// cross-wave barrier exists: a shard finishing its slice picks up
    /// the next queued job immediately.
    pub(crate) fn serve_wave(&self, reqs: &[Request]) -> Result<Vec<ServedRequest>, Error> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        self.ensure_started()?;
        let engine = &self.shared.engine;
        let mut wants_probe = false;
        for r in reqs {
            if engine.placement_wants_probe(r.session)? {
                wants_probe = true;
                break;
            }
        }
        if wants_probe {
            // same probe quiesce as submit_at: the snapshots this wave's
            // placement reads must be the engine state after every prior
            // arrival's admission, not wherever the loops happened to be.
            // The submit lock holds the frontier still while we wait.
            let _submit = shard_guard(&self.submit, "arrival submission")?;
            let cfg = engine.config();
            let mut d = lock_dispatch(&self.shared)?;
            while d.queues.iter().any(|q| Self::runnable(cfg, &d, q)) {
                d = self
                    .shared
                    .idle
                    .wait(d)
                    .map_err(|_| Error::ShardPoisoned("scheduler dispatch"))?;
            }
        }
        let placements = engine.place_batch(reqs)?;
        let queues = engine.queues_for(&placements);
        if engine.config().obs.trace {
            engine.emit_admission_events(reqs, &placements, &queues)?;
        }
        let seal = Arc::new(SealState::new(reqs.len()));
        {
            let mut d = lock_dispatch(&self.shared)?;
            for (s, idxs) in queues.iter().enumerate() {
                if idxs.is_empty() {
                    continue;
                }
                if d.queues[s].dead {
                    seal.fail(Error::ShardPoisoned("shard"), idxs.len());
                    continue;
                }
                let batch: Vec<Request> = idxs.iter().map(|&i| reqs[i].clone()).collect();
                d.queues[s].waves.push_back(WaveJob {
                    batch,
                    idxs: idxs.clone(),
                    seal: Arc::clone(&seal),
                });
            }
            self.shared.work.notify_all();
        }
        let (slots, err) = seal.wait();
        if let Some(e) = err {
            return Err(e);
        }
        let mut out = Vec::with_capacity(reqs.len());
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(sr) => out.push(sr),
                None => {
                    return Err(Error::EngineFailure(format!(
                        "request {:?} was placed but never served",
                        reqs[i].id
                    )))
                }
            }
        }
        engine.record_served(&out)?;
        Ok(out)
    }

    /// Submit one open-loop arrival at virtual time `at` (seconds,
    /// nondecreasing across calls). Places the request, enqueues it on
    /// its shard's timed queue and returns the result cell immediately;
    /// the shard's loop admits it when its clock reaches `at`.
    ///
    /// Placement is deterministic for every policy. Probe-reading
    /// policies ([`crate::serve::PlacementKind::ContextAware`]) get it
    /// by *quiescing*: before an unpinned session is placed, the
    /// scheduler waits until no shard has work runnable under the
    /// current frontier, so the probe snapshots the decision reads are
    /// exactly the engine state after every prior arrival's admission —
    /// a pure function of the arrival sequence, never of how far the
    /// worker loops happened to progress in wall time.
    pub(crate) fn submit_at(&self, req: Request, at: f64) -> Result<Arc<ResultCell>, Error> {
        if !at.is_finite() || at < 0.0 {
            return Err(Error::InvalidConfig(format!(
                "arrival time must be finite and >= 0, got {at}"
            )));
        }
        self.ensure_started()?;
        let _submit = shard_guard(&self.submit, "arrival submission")?;
        let wants_probe = self.shared.engine.placement_wants_probe(req.session)?;
        {
            let mut d = lock_dispatch(&self.shared)?;
            Self::check_admissible(&d, at)?;
            if wants_probe {
                // probe quiesce (see the doc comment above); the submit
                // lock keeps the frontier stable while we wait
                let cfg = self.shared.engine.config();
                while d.queues.iter().any(|q| Self::runnable(cfg, &d, q)) {
                    d = self
                        .shared
                        .idle
                        .wait(d)
                        .map_err(|_| Error::ShardPoisoned("scheduler dispatch"))?;
                }
            }
        }
        let placement = {
            let mut ps = self.shared.engine.place_batch(std::slice::from_ref(&req))?;
            ps.pop().ok_or_else(|| {
                Error::EngineFailure("placement returned no shard for arrival".into())
            })?
        };
        let cell = Arc::new(ResultCell::new());
        let entry = TimedEntry {
            vt: at,
            req,
            affinity: placement.affinity,
            cell: Arc::clone(&cell),
            delayed: false,
        };
        {
            let mut d = lock_dispatch(&self.shared)?;
            // the submit lock makes the pre-check final: nothing else can
            // seal or advance the frontier before this commit
            debug_assert!(
                Self::check_admissible(&d, at).is_ok(),
                "frontier/seal mutated outside the submit lock"
            );
            if d.queues[placement.shard].dead {
                // placed, then refused — the session pin persists, exactly
                // as on the wave path where a dead shard fails the seal
                // after placement; later turns of the session fail the
                // same way instead of silently migrating
                return Err(Error::ShardPoisoned("shard"));
            }
            d.frontier = at;
            d.queues[placement.shard].timed.push_back(entry);
            self.shared.work.notify_all();
        }
        Ok(cell)
    }

    fn check_admissible(d: &Dispatch, at: f64) -> Result<(), Error> {
        if d.sealed {
            return Err(Error::InvalidConfig(
                "arrivals are sealed: no submit_at after seal_arrivals".into(),
            ));
        }
        if at < d.frontier {
            return Err(Error::InvalidConfig(format!(
                "arrival times must be nondecreasing: got {at} after {}",
                d.frontier
            )));
        }
        Ok(())
    }

    /// Declare the open-loop arrival sequence finished: shards may run
    /// their queues to completion (the frontier stops gating chunks).
    /// Permanent for this server.
    pub(crate) fn seal_arrivals(&self) -> Result<(), Error> {
        let _submit = shard_guard(&self.submit, "arrival submission")?;
        let mut d = lock_dispatch(&self.shared)?;
        d.sealed = true;
        d.frontier = f64::INFINITY;
        self.shared.work.notify_all();
        Ok(())
    }

    /// Advance the arrival frontier to at least `upto` without
    /// submitting: a promise that no arrival earlier than `upto` will
    /// come, letting shards run chunks up to (strictly below) it.
    pub(crate) fn advance_arrivals(&self, upto: f64) -> Result<(), Error> {
        if !upto.is_finite() || upto < 0.0 {
            return Err(Error::InvalidConfig(format!(
                "arrival frontier must be finite and >= 0, got {upto}"
            )));
        }
        let _submit = shard_guard(&self.submit, "arrival submission")?;
        let mut d = lock_dispatch(&self.shared)?;
        if upto > d.frontier {
            d.frontier = upto;
        }
        self.shared.work.notify_all();
        Ok(())
    }

    /// Pause every loop at its next step boundary (queued work keeps
    /// accumulating; nothing is lost).
    pub(crate) fn pause(&self) -> Result<(), Error> {
        {
            let mut d = lock_dispatch(&self.shared)?;
            if d.ctl == Ctl::Running {
                d.ctl = Ctl::Paused;
            }
        }
        self.shared.engine.emit_sched_event(EventKind::SchedPaused)
    }

    /// Resume paused loops.
    pub(crate) fn resume(&self) -> Result<(), Error> {
        {
            let mut d = lock_dispatch(&self.shared)?;
            if d.ctl == Ctl::Paused {
                d.ctl = Ctl::Running;
            }
            self.shared.work.notify_all();
        }
        self.shared.engine.emit_sched_event(EventKind::SchedResumed)
    }

    /// Block until no shard has runnable work (all queues empty or
    /// parked behind the frontier / a pause), then emit the
    /// `sched_drained` marker. With the loops never started this is just
    /// the marker — there is nothing to wait for.
    pub(crate) fn drain(&self) -> Result<(), Error> {
        let started = !shard_guard(&self.threads, "scheduler threads")?.is_empty();
        if started {
            let cfg = self.shared.engine.config();
            let mut d = lock_dispatch(&self.shared)?;
            while d.queues.iter().any(|q| Self::runnable(cfg, &d, q)) {
                d = self
                    .shared
                    .idle
                    .wait(d)
                    .map_err(|_| Error::ShardPoisoned("scheduler dispatch"))?;
            }
        }
        self.shared.engine.emit_sched_event(EventKind::SchedDrained)
    }

    /// Whether a shard queue has work a loop will still pick up (or is
    /// mid-slice). Mirrors the worker's claim conditions: a queued wave
    /// is always claimable (even behind frontier-gated active work), so
    /// drain never returns with a wave pending; a Delay-blocked front
    /// arrival is *not* runnable (see [`timed_front_progress`]), so
    /// drain and the placement quiesce don't hang on backpressure only
    /// a later arrival can release.
    fn runnable(cfg: &ServeConfig, d: &Dispatch, q: &ShardQueue) -> bool {
        if q.dead {
            return false;
        }
        if q.busy {
            return true;
        }
        if matches!(d.ctl, Ctl::Paused | Ctl::Stopping) {
            return false;
        }
        if !q.waves.is_empty() {
            return true;
        }
        if q.active.is_empty() {
            return !q.timed.is_empty();
        }
        timed_front_progress(cfg, q) || d.sealed || q.clock < d.frontier
    }
}

impl<E: InferenceEngine> Drop for Scheduler<E> {
    fn drop(&mut self) {
        {
            let mut d = self
                .shared
                .state
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            d.ctl = Ctl::Stopping;
            self.shared.work.notify_all();
        }
        let threads = {
            let mut t = self
                .threads
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            std::mem::take(&mut *t)
        };
        for t in threads {
            // a panicked loop already swept its queue; nothing to do here
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overload_policy_parses_both_names_and_rejects_unknown() {
        assert_eq!(OverloadPolicy::parse("shed").unwrap(), OverloadPolicy::Shed);
        assert_eq!(
            OverloadPolicy::parse("delay").unwrap(),
            OverloadPolicy::Delay
        );
        assert_eq!(OverloadPolicy::Shed.name(), "shed");
        assert_eq!(OverloadPolicy::Delay.name(), "delay");
        let err = OverloadPolicy::parse("drop").unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)));
        assert!(err.to_string().contains("drop"));
    }

    #[test]
    fn result_cell_is_first_write_wins() {
        use crate::types::{Prompt, Request, RequestId, SessionId};
        let cell = ResultCell::new();
        assert!(cell.peek().unwrap().is_none());
        cell.fill(Err(Error::ShardPoisoned("shard")));
        let req = Request {
            id: RequestId(1),
            session: SessionId(1),
            turn: 0,
            context: Vec::new(),
            query: crate::types::QueryId(0),
        };
        let sr = ServedRequest {
            prompt: Prompt::baseline(&req),
            request: req,
            prompt_tokens: 0,
            cached_tokens: 0,
            ttft: 0.0,
            wall: 0.0,
            quality: 0.0,
            queued_ttft: 0.0,
            prefill_chunks: 1,
            tier_hits: Default::default(),
        };
        cell.fill(Ok(sr));
        assert_eq!(
            cell.take_now().unwrap().unwrap().unwrap_err(),
            Error::ShardPoisoned("shard")
        );
    }

    #[test]
    fn seal_state_accounts_expected_not_returned() {
        // an engine that drops a request must surface as a missing slot,
        // not hang the waiter
        let seal = SealState::new(2);
        seal.complete(2, Vec::new());
        let (slots, err) = seal.wait();
        assert!(err.is_none());
        assert!(slots.iter().all(Option::is_none));
    }

    #[test]
    fn seal_state_first_error_wins_but_waits_for_all_jobs() {
        let seal = SealState::new(3);
        seal.fail(Error::ShardPoisoned("shard"), 1);
        seal.fail(Error::EngineFailure("later".into()), 2);
        let (_, err) = seal.wait();
        assert_eq!(err, Some(Error::ShardPoisoned("shard")));
    }
}
