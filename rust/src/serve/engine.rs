//! The `ServingEngine`: a lock-striped shard array plus a worker pool,
//! generic over the backend ([`crate::engine::InferenceEngine`]). Since
//! the facade redesign this is the crate-private **engine room** behind
//! [`crate::api::Server`]: the facade hands it whole admission waves
//! (`serve_batch`), and each session's requests land on its pinned shard
//! in arrival order, which is what makes results independent of the
//! worker count.
//!
//! Which shard a session is pinned *to* is the placement layer's decision
//! ([`crate::serve::placement`], [`crate::serve::ServeConfig::placement`]):
//! batches are partitioned through the policy at enqueue time —
//! deterministically, in arrival order, before any worker runs — and a
//! session's later turns always reuse its first-turn pin.
//!
//! Every facade-boundary lock acquisition goes through [`shard_guard`],
//! so a worker thread that panicked while holding a shard surfaces to
//! callers as a recoverable [`Error::ShardPoisoned`] instead of a
//! cascading `expect` panic. Locks *inside* a shard's pipeline (none
//! today — shard state is single-owner behind its mutex) may stay
//! infallible: once a guard is held, the hot path runs lock-free.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::api::Error;
use crate::corpus::Corpus;
use crate::engine::iface::InferenceEngine;
use crate::engine::sim::SimEngine;
use crate::index::tree::ContextIndex;
use crate::metrics::{RunMetrics, ShardStats};
use crate::obs::{merge_events, Counter, EventKind, Registry, StorageOp, TraceEvent};
use crate::serve::placement::{Placement, PlacementBook, ShardProbe};
use crate::serve::probe::ProbeDirectory;
use crate::serve::shard::{shard_of, Shard};
use crate::serve::{PlacementKind, ServeConfig};
use crate::types::{Request, RequestId, ServedRequest, SessionId};
use crate::util::json::Json;
use crate::util::threadpool::par_map_tasks;

/// Lock a facade-boundary mutex, converting poison (a worker thread
/// panicked while holding it) into a recoverable
/// [`Error::ShardPoisoned`] naming the component. The single choke point
/// replacing the former per-site `lock().expect("… poisoned")` calls.
pub(crate) fn shard_guard<'a, T>(
    m: &'a Mutex<T>,
    what: &'static str,
) -> Result<MutexGuard<'a, T>, Error> {
    m.lock().map_err(|_| Error::ShardPoisoned(what))
}

pub struct ServingEngine<E = SimEngine> {
    cfg: ServeConfig,
    /// Lock striping: one mutex per shard; concurrent callers contend only
    /// when they hit the same shard.
    shards: Vec<Mutex<Shard<E>>>,
    /// Session placement ledger: the policy, the session → shard pins and
    /// the per-shard placement/affinity telemetry. Lock order is strictly
    /// placement → shard (no path takes this while holding a shard).
    /// Placement probes taken while holding this never lock shards: they
    /// read `probes`, whose entry mutexes are strict leaves.
    placement: Mutex<PlacementBook>,
    /// Published per-shard probe snapshots ([`crate::serve::probe`]):
    /// refreshed under each shard's lock at every state mutation, read
    /// under the placement lock by `probe_shards` — the lock-light probe
    /// fast path.
    probes: ProbeDirectory,
    /// Engine request id → owning shard, so external eviction notifications
    /// (§4.1) can be routed without broadcasting to every shard. Entries
    /// are pruned by engine-reported and external evictions; under an
    /// engine/policy that never evicts (e.g. CacheBlend-style block reuse)
    /// the map grows with served-request count — acceptable at one small
    /// entry per request, but a retention bound is the first thing to add
    /// if this layer ever fronts an unbounded stream with such a policy.
    req_shard: Mutex<HashMap<RequestId, usize>>,
    /// Engine-wide counter/gauge registry ([`crate::obs`]); shared with
    /// every shard, always on, lock-free.
    registry: Arc<Registry>,
}

impl<E: InferenceEngine> ServingEngine<E> {
    /// Serving engine over an arbitrary backend: `factory` is called once
    /// per shard (in shard order) to build that shard's engine instance.
    ///
    /// Shard/worker counts are clamped to ≥ 1 as a last-resort guard;
    /// the facade builder ([`crate::api::ServerBuilder`]) rejects zero
    /// values with a typed error before they ever reach this layer.
    pub fn with_engine_factory(
        mut cfg: ServeConfig,
        mut factory: impl FnMut(&ServeConfig) -> E,
    ) -> ServingEngine<E> {
        cfg.n_shards = cfg.n_shards.max(1);
        cfg.n_workers = cfg.n_workers.max(1);
        let registry = Arc::new(Registry::new());
        let shards = (0..cfg.n_shards)
            .map(|i| Mutex::new(Shard::new(i, &cfg, factory(&cfg), registry.clone())))
            .collect();
        let placement = Mutex::new(PlacementBook::new(cfg.placement, cfg.n_shards));
        // fresh directory entries (empty block set, zero residency) are
        // exactly the fresh shards' state — no construction-time publish
        let probes = ProbeDirectory::new(cfg.n_shards);
        ServingEngine {
            shards,
            cfg,
            placement,
            probes,
            req_shard: Mutex::new(HashMap::new()),
            registry,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn n_workers(&self) -> usize {
        self.cfg.n_workers
    }

    /// The (normalized) configuration this engine runs with.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The shard this session was placed on, if any request of it has
    /// been placed.
    pub fn placed_shard(&self, session: SessionId) -> Result<Option<usize>, Error> {
        Ok(shard_guard(&self.placement, "placement ledger")?.pinned(session))
    }

    /// The shard a session runs on: its recorded placement when it has
    /// been placed, otherwise the session-hash default (exact under
    /// [`crate::serve::PlacementKind::SessionHash`]; a prediction for
    /// not-yet-placed sessions under other policies).
    pub fn shard_of_session(&self, session: SessionId) -> Result<usize, Error> {
        Ok(self
            .placed_shard(session)?
            .unwrap_or_else(|| shard_of(session, self.shards.len())))
    }

    /// Probe every shard for one placement decision: the request's block
    /// overlap with the shard's context index (0 without a pilot) and the
    /// engine's prefix-cache residency. Called while the placement lock
    /// is held, but reads the published [`ProbeDirectory`] instead of
    /// locking shards — O(distinct request blocks) per shard, zero
    /// shard-lock acquisitions. Identical to probing the live shards:
    /// waves publish at their end, and probes run before the next wave's
    /// workers start.
    fn probe_shards(&self, req: &Request, book: &PlacementBook) -> Result<Vec<ShardProbe>, Error> {
        self.probes.probe(&req.context, book, &self.registry)
    }

    /// Place a batch through the policy at enqueue time: one shard index
    /// per request, decided in arrival order before any worker runs (so
    /// placement is invariant in `n_workers`). Pinned sessions reuse their
    /// first-turn shard; each batch is one placement wave.
    pub(crate) fn place_batch(&self, reqs: &[Request]) -> Result<Vec<Placement>, Error> {
        let mut book = shard_guard(&self.placement, "placement ledger")?;
        book.begin_wave();
        self.registry.add(Counter::PlacementWaves, 1);
        reqs.iter()
            .map(|r| {
                if book.wants_probe(r.session) {
                    let probes = self.probe_shards(r, &book)?;
                    self.registry.add(Counter::PlacementProbes, 1);
                    Ok(book.assign_placed(r, Some(&probes)))
                } else {
                    Ok(book.assign_placed(r, None))
                }
            })
            .collect()
    }

    /// Whether placing a request of `session` would read the published
    /// probe snapshots: the policy wants probes and the session has no
    /// pin yet. The open-loop scheduler quiesces its loops before such
    /// placements so the snapshots are deterministic.
    pub(crate) fn placement_wants_probe(&self, session: SessionId) -> Result<bool, Error> {
        Ok(shard_guard(&self.placement, "placement ledger")?.wants_probe(session))
    }

    /// Arrival indices per shard, preserving arrival order within a shard.
    pub(crate) fn queues_for(&self, placements: &[Placement]) -> Vec<Vec<usize>> {
        let mut queues: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, p) in placements.iter().enumerate() {
            queues[p.shard].push(i);
        }
        queues
    }

    /// Stamp `admitted` / `placed` / `queued` markers for one admission
    /// wave. Runs after placement and before any worker touches a queue:
    /// each shard's events are emitted in that shard's arrival order at
    /// its current virtual clock, so the stream is worker-count
    /// invariant. Only called when tracing is enabled.
    pub(crate) fn emit_admission_events(
        &self,
        reqs: &[Request],
        placements: &[Placement],
        queues: &[Vec<usize>],
    ) -> Result<(), Error> {
        let policy = self.cfg.placement.name();
        for (s, idxs) in queues.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let mut shard = shard_guard(&self.shards[s], "shard")?;
            let Some(tracer) = &mut shard.tracer else {
                continue;
            };
            let t = tracer.clock();
            for &i in idxs {
                let req = Some(reqs[i].id.0);
                let sess = Some(reqs[i].session.0);
                tracer.emit(t, 0.0, req, sess, EventKind::Admitted);
                let placed = EventKind::Placed {
                    policy,
                    affinity: placements[i].affinity,
                };
                tracer.emit(t, 0.0, req, sess, placed);
                tracer.emit(t, 0.0, req, sess, EventKind::Queued);
            }
        }
        Ok(())
    }

    /// Offline mode (§5.1): cluster-build each shard's context index over
    /// its own slice of the batch (Alg. 4), shards built in parallel. The
    /// partition runs through the placement policy and pins the sessions,
    /// so the subsequent serves land exactly where their index was built.
    /// No-op for shards without a pilot or without requests.
    pub fn build_offline(&self, reqs: &[Request]) -> Result<(), Error> {
        let queues = self.queues_for(&self.place_batch(reqs)?);
        par_map_tasks(self.shards.len(), self.cfg.n_workers, |s| {
            if queues[s].is_empty() {
                return Ok(());
            }
            let mine: Vec<Request> = queues[s].iter().map(|&i| reqs[i].clone()).collect();
            let mut shard = shard_guard(&self.shards[s], "shard")?;
            if let Some(p) = &mut shard.pilot {
                p.build_offline(&mine);
            }
            // the build replaced the index wholesale: republish its probe
            // snapshot while the shard lock is still held
            self.probes.publish(&shard)?;
            Ok(())
        })
        .into_iter()
        .collect()
    }

    /// Serve a batch: requests are partitioned into per-shard queues and
    /// the worker pool drives the queues concurrently, each through the
    /// full pilot pipeline in arrival order. Returns records in the
    /// original arrival order.
    ///
    /// Request ids must be unique within the engine's lifetime (the
    /// facade's ticket ledger and the workload generators both guarantee
    /// it); they key both the §4.1 eviction plumbing and the order
    /// restoration here. Results are independent of `n_workers` because
    /// every stateful structure is shard-local.
    ///
    /// Batching granularity is the caller's: Alg.-5 may reorder freely
    /// *within* a batch, so submit one batch per arrival wave (e.g. per
    /// turn, as the experiment runner does) when turn ordering should be
    /// reflected in engine history; a whole multi-turn workload in one
    /// batch is still deterministic, just scheduled as one wave. The
    /// chunked-prefill virtual clock likewise spans one wave per shard.
    pub fn serve_batch(
        &self,
        reqs: &[Request],
        corpus: &Corpus,
    ) -> Result<Vec<ServedRequest>, Error> {
        let placements = self.place_batch(reqs)?;
        let queues = self.queues_for(&placements);
        if self.cfg.obs.trace {
            self.emit_admission_events(reqs, &placements, &queues)?;
        }
        let per_shard: Vec<Result<Vec<(usize, ServedRequest)>, Error>> =
            par_map_tasks(self.shards.len(), self.cfg.n_workers, |s| {
                let idxs = &queues[s];
                if idxs.is_empty() {
                    return Ok(Vec::new());
                }
                // the clone exists because the pilot pipeline takes a
                // contiguous &[Request]; it is one small Vec per request
                // vs. the thousands of tokens rendered per serve, so
                // borrowing is not worth rippling the pilot API.
                let batch: Vec<Request> = idxs.iter().map(|&i| reqs[i].clone()).collect();
                let served = self.serve_shard_queue(s, &batch, corpus)?;
                let arrival: HashMap<RequestId, usize> =
                    idxs.iter().map(|&i| (reqs[i].id, i)).collect();
                Ok(served
                    .into_iter()
                    .map(|sr| (arrival[&sr.request.id], sr))
                    .collect())
            });

        // arrival-order output
        let mut slots: Vec<Option<ServedRequest>> = Vec::with_capacity(reqs.len());
        slots.resize_with(reqs.len(), || None);
        for tagged in per_shard {
            for (i, sr) in tagged? {
                slots[i] = Some(sr);
            }
        }
        let out: Vec<ServedRequest> = slots
            .into_iter()
            .enumerate()
            .map(|(i, x)| {
                x.ok_or_else(|| {
                    Error::EngineFailure(format!(
                        "request {:?} was placed but never served",
                        reqs[i].id
                    ))
                })
            })
            .collect::<Result<_, _>>()?;
        // affinity attribution (no shard lock held: placement → shard order)
        self.record_served(&out)?;
        Ok(out)
    }

    /// One shard's slice of an admission wave: lock the shard, drive its
    /// queue through [`Shard::serve_queue`], keep the request → shard
    /// ownership map current under the shard lock, and republish the
    /// shard's probe snapshot before releasing it. This is the per-shard
    /// wave body shared — by construction, so results are bit-identical —
    /// between the worker-pool path ([`ServingEngine::serve_batch`]) and
    /// the continuous-batching scheduler's wave jobs
    /// ([`crate::serve::sched`]). Returns records in execution order
    /// (Alg.-5 may reorder within the queue).
    pub(crate) fn serve_shard_queue(
        &self,
        s: usize,
        batch: &[Request],
        corpus: &Corpus,
    ) -> Result<Vec<ServedRequest>, Error> {
        let mut shard = shard_guard(&self.shards[s], "shard")?;
        let (served, evicted) = shard.serve_queue(batch, corpus);
        // ownership-map upkeep while still holding the shard lock:
        // a concurrent serve on this shard cannot interleave its
        // eviction removals with these inserts (shard → map nesting
        // is safe: no path holds the map lock while taking a shard)
        self.track_ownership(s, &served, &evicted)?;
        // republish this shard's probe snapshot before releasing
        // the lock: the next wave's placement probes read the
        // directory instead of locking shards
        self.probes.publish(&shard)?;
        Ok(served)
    }

    /// Lock shard `s` (scheduler slices hold the guard across several
    /// chunk steps; every other path should prefer the higher-level
    /// helpers).
    pub(crate) fn lock_shard(&self, s: usize) -> Result<MutexGuard<'_, Shard<E>>, Error> {
        shard_guard(&self.shards[s], "shard")
    }

    /// Record request → shard ownership for `served` and drop entries for
    /// `evicted`. Caller must hold shard `s`'s lock (shard → map nesting).
    pub(crate) fn track_ownership(
        &self,
        s: usize,
        served: &[ServedRequest],
        evicted: &[RequestId],
    ) -> Result<(), Error> {
        let mut map = shard_guard(&self.req_shard, "request map")?;
        for sr in served {
            map.insert(sr.request.id, s);
        }
        for r in evicted {
            map.remove(r);
        }
        Ok(())
    }

    /// Republish one shard's probe snapshot (caller holds the shard lock).
    pub(crate) fn publish_probes(&self, shard: &Shard<E>) -> Result<(), Error> {
        self.probes.publish(shard)
    }

    /// Attribute affinity reuse for served requests in the placement
    /// ledger. Must be called with **no shard lock held** (placement →
    /// shard order).
    pub(crate) fn record_served(&self, out: &[ServedRequest]) -> Result<(), Error> {
        shard_guard(&self.placement, "placement ledger")?.record_served(out);
        Ok(())
    }

    /// The engine-wide counter registry (shared with every shard).
    pub(crate) fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Stamp one scheduler-lifecycle marker (started/paused/resumed/
    /// drained) on every shard's tracer at that shard's current virtual
    /// clock. Emitted from the *control* thread — never from worker
    /// timing — so the markers land at deterministic clocks. No-op when
    /// tracing is off. Takes only shard locks (never the dispatch or
    /// placement locks), so it is safe from any scheduler control path
    /// that holds neither.
    pub(crate) fn emit_sched_event(&self, kind: EventKind) -> Result<(), Error> {
        if !self.cfg.obs.trace {
            return Ok(());
        }
        for m in &self.shards {
            let mut shard = shard_guard(m, "shard")?;
            if let Some(tracer) = &mut shard.tracer {
                let t = tracer.clock();
                tracer.emit(t, 0.0, None, None, kind.clone());
            }
        }
        Ok(())
    }

    /// External eviction callback (§4.1): route each request id to the
    /// shard that owns it and prune that shard's context index. Unknown
    /// ids (already evicted engine-side) are ignored.
    pub fn on_evict(&self, reqs: &[RequestId]) -> Result<(), Error> {
        let mut by_shard: HashMap<usize, Vec<RequestId>> = HashMap::new();
        {
            let mut map = shard_guard(&self.req_shard, "request map")?;
            for r in reqs {
                if let Some(s) = map.remove(r) {
                    by_shard.entry(s).or_default().push(*r);
                }
            }
        }
        for (s, ids) in by_shard {
            let mut shard = shard_guard(&self.shards[s], "shard")?;
            if let Some(p) = &mut shard.pilot {
                p.on_evict(&ids);
            }
            // §4.1 pruning shrank the index: republish under the lock
            self.probes.publish(&shard)?;
        }
        Ok(())
    }

    /// Durable checkpoint (behind [`crate::api::Server::checkpoint`]):
    /// spill every shard's hot/warm KV into its cold-tier storage backend,
    /// prune each context index with whatever the spill finally discarded
    /// (§4.1 — a checkpoint discard is an eviction like any other), and
    /// return the versioned warm-state snapshot: the placement book, the
    /// request → shard ownership map, and the per-shard context indices.
    /// The caller persists the returned value as one `snapshot.json`; the
    /// cold KV payloads themselves already live in the per-shard storage
    /// backends the spill flushed.
    ///
    /// Offline-build placements ([`crate::pilot::ContextPilot`]'s private
    /// ledger) are wave-scoped and deliberately not part of durable state.
    ///
    /// Lock order: placement → shard → request map, same as serving.
    pub fn checkpoint_snapshot(&self) -> Result<Json, Error> {
        let placement = shard_guard(&self.placement, "placement ledger")?.to_snapshot();
        let mut shard_rows = Vec::with_capacity(self.shards.len());
        for (s, m) in self.shards.iter().enumerate() {
            let mut shard = shard_guard(m, "shard")?;
            let discards = shard
                .engine
                .spill_for_checkpoint()
                .map_err(|e| Error::Storage(format!("shard {s}: {e}")))?;
            if let Some(p) = &mut shard.pilot {
                p.on_evict(&discards);
            }
            {
                let mut map = shard_guard(&self.req_shard, "request map")?;
                for r in &discards {
                    map.remove(r);
                }
            }
            self.registry.add(Counter::StorageFlushes, 1);
            if let Some(tracer) = &mut shard.tracer {
                let t = tracer.clock();
                let kind = EventKind::Storage {
                    op: StorageOp::Flush,
                };
                tracer.emit(t, 0.0, None, None, kind);
            }
            // the spill moved residency and the discard pruned the index:
            // republish this shard's probe snapshot under its lock
            self.probes.publish(&shard)?;
            let index = match &shard.pilot {
                Some(p) => p.index.to_snapshot(),
                None => Json::Null,
            };
            shard_rows.push(Json::obj(vec![("index", index)]));
        }
        let mut req_rows: Vec<(u64, usize)> = shard_guard(&self.req_shard, "request map")?
            .iter()
            .map(|(r, &s)| (r.0, s))
            .collect();
        req_rows.sort_unstable();
        Ok(Json::obj(vec![
            ("version", Json::num(1.0)),
            ("n_shards", Json::num(self.shards.len() as f64)),
            ("placement", placement),
            (
                "req_shard",
                Json::arr(
                    req_rows
                        .into_iter()
                        .map(|(r, s)| Json::arr(vec![Json::u64(r), Json::num(s as f64)]))
                        .collect(),
                ),
            ),
            ("shards", Json::arr(shard_rows)),
        ]))
    }

    /// Rehydrate warm state from a [`ServingEngine::checkpoint_snapshot`]
    /// value (behind [`crate::api::ServerBuilder::resume_from`]). The
    /// engine must be freshly built with the same shard count; the cold KV
    /// itself is rehydrated separately when each shard's engine opens its
    /// storage backend. Validation is all-or-nothing: every structural
    /// problem is found *before* any state is replaced, and surfaces as
    /// [`Error::CorruptSnapshot`]. A snapshot index for a shard configured
    /// without a pilot is dropped (placement pins are pilot-independent,
    /// like restoring under a different placement policy).
    pub fn restore_snapshot(&self, j: &Json) -> Result<(), Error> {
        let (book, map, indices) =
            Self::parse_snapshot(self.cfg.placement, self.shards.len(), j)
                .map_err(Error::CorruptSnapshot)?;
        *shard_guard(&self.placement, "placement ledger")? = book;
        *shard_guard(&self.req_shard, "request map")? = map;
        for (s, ix) in indices.into_iter().enumerate() {
            let mut shard = shard_guard(&self.shards[s], "shard")?;
            if let Some(ix) = ix {
                if let Some(p) = &mut shard.pilot {
                    p.index = ix;
                }
            }
            // every shard republishes (restored index + rehydrated engine
            // residency), so the first post-resume probes see warm state
            self.probes.publish(&shard)?;
        }
        Ok(())
    }

    /// Decode + validate a snapshot without touching live state.
    fn parse_snapshot(
        kind: PlacementKind,
        n_shards: usize,
        j: &Json,
    ) -> Result<
        (
            PlacementBook,
            HashMap<RequestId, usize>,
            Vec<Option<ContextIndex>>,
        ),
        String,
    > {
        let version = j
            .get("version")
            .as_usize()
            .ok_or("missing snapshot version")?;
        if version != 1 {
            return Err(format!("unsupported snapshot version {version}"));
        }
        let n = j.get("n_shards").as_usize().ok_or("missing n_shards")?;
        if n != n_shards {
            return Err(format!(
                "snapshot taken with {n} shards, but the resumed server has {n_shards}"
            ));
        }
        let book = PlacementBook::from_snapshot(kind, n, j.get("placement"))?;
        let rows = j.get("req_shard").as_arr().ok_or("missing req_shard")?;
        let mut map = HashMap::with_capacity(rows.len());
        for row in rows {
            let pair = row
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or("malformed req_shard row")?;
            let r = pair[0].as_u64().ok_or("bad request id in req_shard")?;
            let s = pair[1]
                .as_usize()
                .filter(|&s| s < n)
                .ok_or("req_shard row points past the shard array")?;
            if map.insert(RequestId(r), s).is_some() {
                return Err(format!("request {r} owned by two shards"));
            }
        }
        let shards = j.get("shards").as_arr().ok_or("missing shards array")?;
        if shards.len() != n {
            return Err(format!(
                "shards array holds {} rows for {n} shards",
                shards.len()
            ));
        }
        let mut indices = Vec::with_capacity(n);
        for (s, row) in shards.iter().enumerate() {
            row.as_obj().ok_or_else(|| format!("shard {s} row is not an object"))?;
            indices.push(match row.get("index") {
                Json::Null => None,
                idx => Some(
                    ContextIndex::from_snapshot(idx)
                        .map_err(|e| format!("shard {s} index: {e}"))?,
                ),
            });
        }
        Ok((book, map, indices))
    }

    /// Aggregate run metrics plus a per-shard telemetry snapshot. Shard
    /// rows carry the placement telemetry (sessions placed there and the
    /// cached tokens attributed to affinity placements); the aggregate's
    /// `total_affinity_hit_tokens` is their sum.
    pub fn metrics(&self) -> Result<(RunMetrics, Vec<ShardStats>), Error> {
        // snapshot placement first, then release (placement → shard order)
        let (placed_sessions, affinity_hits) = {
            let book = shard_guard(&self.placement, "placement ledger")?;
            (
                book.placed_sessions().to_vec(),
                book.affinity_hit_tokens().to_vec(),
            )
        };
        let mut agg = RunMetrics::new();
        let mut per = Vec::with_capacity(self.shards.len());
        for (i, m) in self.shards.iter().enumerate() {
            let mut shard = shard_guard(m, "shard")?;
            agg.merge(&shard.metrics);
            let mut stats = shard.stats();
            stats.placed_sessions = placed_sessions[i];
            stats.affinity_hit_tokens = affinity_hits[i];
            per.push(stats);
        }
        agg.total_affinity_hit_tokens = affinity_hits.iter().sum();
        Ok((agg, per))
    }

    /// Snapshot of the engine-wide counter registry, in slot order.
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        self.registry.snapshot()
    }

    /// Merged trace-event stream across all shards, ordered by
    /// `(virtual time, shard, seq)`. Empty when tracing is disabled
    /// ([`crate::obs::ObsConfig::trace`]).
    pub fn trace_events(&self) -> Result<Vec<TraceEvent>, Error> {
        let mut streams = Vec::with_capacity(self.shards.len());
        for m in &self.shards {
            let shard = shard_guard(m, "shard")?;
            streams.push(shard.tracer.as_ref().map_or_else(Vec::new, |t| t.snapshot()));
        }
        Ok(merge_events(streams))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusConfig;
    use crate::engine::costmodel::ModelSku;
    use crate::tokenizer::Tokenizer;
    use crate::types::{BlockId, QueryId};

    fn sim_engine(cfg: ServeConfig) -> ServingEngine {
        ServingEngine::with_engine_factory(cfg, ServeConfig::sim_engine)
    }

    fn corpus() -> Corpus {
        Corpus::generate(
            &CorpusConfig {
                n_docs: 60,
                ..Default::default()
            },
            &Tokenizer::default(),
        )
    }

    fn req(id: u64, session: u32, ids: &[u32]) -> Request {
        Request {
            id: RequestId(id),
            session: SessionId(session),
            turn: 0,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(id),
        }
    }

    fn small_cfg(shards: usize, workers: usize) -> ServeConfig {
        let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        cfg.n_shards = shards;
        cfg.n_workers = workers;
        cfg.decode_tokens = 8;
        cfg
    }

    #[test]
    fn shard_guard_reports_poison_as_typed_error() {
        let m = std::sync::Arc::new(Mutex::new(0usize));
        assert!(shard_guard(&m, "shard").is_ok());
        let m2 = m.clone();
        // poison the mutex by panicking while holding it
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison on purpose");
        })
        .join();
        assert_eq!(shard_guard(&m, "shard").unwrap_err(), Error::ShardPoisoned("shard"));
    }

    #[test]
    fn batch_output_is_in_arrival_order() {
        let corpus = corpus();
        let engine = sim_engine(small_cfg(4, 4));
        let reqs: Vec<Request> = (0..24)
            .map(|i| req(i, i as u32 % 7, &[(i % 9) as u32 + 1, (i % 5) as u32 + 10]))
            .collect();
        let served = engine.serve_batch(&reqs, &corpus).unwrap();
        assert_eq!(served.len(), reqs.len());
        for (i, s) in served.iter().enumerate() {
            assert_eq!(s.request.id, reqs[i].id);
        }
    }

    #[test]
    fn sessions_are_pinned_to_one_shard() {
        let corpus = corpus();
        let engine = sim_engine(small_cfg(4, 2));
        let reqs: Vec<Request> = (0..16).map(|i| req(i, 5, &[1, 2, 3])).collect();
        engine.serve_batch(&reqs, &corpus).unwrap();
        let (_, per) = engine.metrics().unwrap();
        let active: Vec<_> = per.iter().filter(|s| s.served > 0).collect();
        assert_eq!(active.len(), 1, "one session must live on one shard");
        assert_eq!(active[0].served, 16);
        assert_eq!(
            active[0].shard,
            engine.shard_of_session(SessionId(5)).unwrap()
        );
        assert_eq!(
            engine.placed_shard(SessionId(5)).unwrap(),
            Some(active[0].shard)
        );
        assert_eq!(engine.placed_shard(SessionId(99)).unwrap(), None);
    }

    #[test]
    fn offline_build_then_serve_matches_sequential_pilot() {
        use crate::engine::sim::{ReusePolicy, SimEngine};
        use crate::pilot::{ContextPilot, PilotConfig};
        use crate::quality::{ModelEra, QualityModel};

        let corpus = corpus();
        let reqs: Vec<Request> = (0..12)
            .map(|i| req(i, i as u32, &[(i % 4) as u32 + 1, (i % 4) as u32 + 2, 9]))
            .collect();
        // sharded, offline
        let engine = sim_engine(small_cfg(3, 3));
        engine.build_offline(&reqs).unwrap();
        let served = engine.serve_batch(&reqs, &corpus).unwrap();
        // ground truth per shard: a hand-rolled concrete-engine pipeline
        for shard in 0..3 {
            let mine: Vec<Request> = reqs
                .iter()
                .filter(|r| shard_of(r.session, 3) == shard)
                .cloned()
                .collect();
            if mine.is_empty() {
                continue;
            }
            let mut pilot = ContextPilot::new(PilotConfig::default());
            pilot.build_offline(&mine);
            let mut eng = SimEngine::new(
                ModelSku::Qwen3_4B.profile(),
                ReusePolicy::RadixPrefix,
                60_000,
            );
            let qm = QualityModel::new(ModelEra::Modern, false);
            for o in pilot.process_batch(&mine, &corpus) {
                let (truth, evicted) = eng.serve(&o.request, &o.prompt, &corpus, &qm, 8);
                pilot.on_evict(&evicted);
                let got = served
                    .iter()
                    .find(|s| s.request.id == truth.request.id)
                    .unwrap();
                assert_eq!(got.cached_tokens, truth.cached_tokens);
                assert_eq!(got.prompt_tokens, truth.prompt_tokens);
            }
        }
    }

    #[test]
    fn external_eviction_prunes_owning_shard_only() {
        let corpus = corpus();
        let engine = sim_engine(small_cfg(4, 2));
        let reqs: Vec<Request> = (0..20)
            .map(|i| req(i, i as u32, &[1, 2, (i % 6) as u32 + 3]))
            .collect();
        engine.serve_batch(&reqs, &corpus).unwrap();
        let ids: Vec<RequestId> = reqs.iter().map(|r| r.id).collect();
        engine.on_evict(&ids).unwrap();
        let (_, per) = engine.metrics().unwrap();
        for s in per {
            assert!(
                s.index_nodes <= 1,
                "shard {} index not pruned: {} nodes",
                s.shard,
                s.index_nodes
            );
        }
        // idempotent: evicting again is a no-op
        engine.on_evict(&ids).unwrap();
    }

    #[test]
    fn metrics_aggregate_equals_per_shard_sum() {
        let corpus = corpus();
        let engine = sim_engine(small_cfg(5, 4));
        let reqs: Vec<Request> = (0..40)
            .map(|i| req(i, i as u32 % 11, &[(i % 7) as u32 + 1, (i % 3) as u32 + 8]))
            .collect();
        let served = engine.serve_batch(&reqs, &corpus).unwrap();
        let (agg, per) = engine.metrics().unwrap();
        assert_eq!(agg.len(), served.len());
        assert_eq!(per.iter().map(|s| s.served).sum::<usize>(), served.len());
        let cached: usize = served.iter().map(|s| s.cached_tokens).sum();
        let total: usize = served.iter().map(|s| s.prompt_tokens).sum();
        assert!((agg.hit_ratio() - cached as f64 / total as f64).abs() < 1e-9);
    }

    #[test]
    fn round_robin_placement_spreads_new_sessions_evenly() {
        use crate::serve::PlacementKind;
        let corpus = corpus();
        let mut cfg = small_cfg(4, 2);
        cfg.placement = PlacementKind::RoundRobin;
        let engine = sim_engine(cfg);
        // 12 single-turn sessions over 4 shards: exactly 3 sessions each
        let reqs: Vec<Request> = (0..12).map(|i| req(i, i as u32, &[1, 2])).collect();
        engine.serve_batch(&reqs, &corpus).unwrap();
        let (m, per) = engine.metrics().unwrap();
        for s in &per {
            assert_eq!(s.placed_sessions, 3, "shard {} not balanced", s.shard);
            assert_eq!(s.affinity_hit_tokens, 0, "rr never claims affinity");
        }
        assert_eq!(m.total_affinity_hit_tokens, 0);
    }

    #[test]
    fn context_aware_placement_co_places_shared_contexts() {
        use crate::pilot::PilotConfig;
        use crate::serve::PlacementKind;
        let corpus = corpus();
        let mut cfg = small_cfg(4, 2);
        cfg.placement = PlacementKind::ContextAware;
        // Alg.-5 scheduling off: arrival order decides which group member
        // eats the cold miss, so the first-placed (non-affinity) session
        // is also the first served and the affinity attribution below is
        // exact rather than order-dependent
        cfg.pilot = Some(PilotConfig::with(true, true, true, false));
        let engine = sim_engine(cfg);
        // two context groups, 4 sessions each, interleaved arrival
        let reqs: Vec<Request> = (0..8)
            .map(|i| {
                let blocks: &[u32] = if i % 2 == 0 { &[1, 2, 3] } else { &[7, 8, 9] };
                req(i, i as u32, blocks)
            })
            .collect();
        let served = engine.serve_batch(&reqs, &corpus).unwrap();
        let even = engine.shard_of_session(SessionId(0)).unwrap();
        let odd = engine.shard_of_session(SessionId(1)).unwrap();
        for i in 0..8u32 {
            let want = if i % 2 == 0 { even } else { odd };
            assert_eq!(
                engine.shard_of_session(SessionId(i)).unwrap(),
                want,
                "session {i} split from its context group"
            );
        }
        assert_ne!(even, odd, "disjoint groups should spread for load");
        // group members after the first hit the group's shared prefix,
        // and that reuse is attributed to affinity placement
        let reused: usize = served.iter().map(|s| s.cached_tokens).sum();
        assert!(reused > 0, "co-placement produced no reuse");
        let (m, per) = engine.metrics().unwrap();
        assert_eq!(m.total_affinity_hit_tokens as usize, reused);
        assert_eq!(
            per.iter().map(|s| s.affinity_hit_tokens).sum::<u64>(),
            m.total_affinity_hit_tokens
        );
        assert_eq!(per.iter().map(|s| s.placed_sessions).sum::<usize>(), 8);
    }

    #[test]
    fn context_aware_returns_recurring_context_to_its_home_shard() {
        use crate::serve::PlacementKind;
        let corpus = corpus();
        let mut cfg = small_cfg(4, 1);
        cfg.placement = PlacementKind::ContextAware;
        let engine = sim_engine(cfg);
        // wave 1: one session warms blocks {1,2,3}; spread filler sessions
        let w1: Vec<Request> = vec![
            req(1, 1, &[1, 2, 3]),
            req(2, 2, &[11, 12]),
            req(3, 3, &[13, 14]),
            req(4, 4, &[15, 16]),
        ];
        engine.serve_batch(&w1, &corpus).unwrap();
        // wave 2: a NEW session with the recurring context must land on
        // session 1's shard via the real index probe (the wave-local
        // overlay was cleared between batches)
        let w2 = vec![req(9, 9, &[1, 2, 3])];
        let served = engine.serve_batch(&w2, &corpus).unwrap();
        assert_eq!(
            engine.shard_of_session(SessionId(9)).unwrap(),
            engine.shard_of_session(SessionId(1)).unwrap(),
            "recurring blocks not routed home"
        );
        assert!(
            served[0].cached_tokens > 0,
            "affinity routing should hit the warmed cache"
        );
    }

    #[test]
    fn session_hash_placement_reproduces_shard_of() {
        let corpus = corpus();
        let engine = sim_engine(small_cfg(5, 2));
        let reqs: Vec<Request> = (0..30)
            .map(|i| req(i, (i % 13) as u32, &[(i % 9) as u32 + 1]))
            .collect();
        engine.serve_batch(&reqs, &corpus).unwrap();
        for s in 0..13u32 {
            assert_eq!(
                engine.shard_of_session(SessionId(s)).unwrap(),
                shard_of(SessionId(s), 5),
                "session {s} diverged from the legacy hash"
            );
        }
    }

    #[test]
    fn checkpoint_snapshot_roundtrips_through_a_fresh_engine() {
        let corpus = corpus();
        let engine = sim_engine(small_cfg(3, 2));
        let reqs: Vec<Request> = (0..18)
            .map(|i| req(i, i as u32 % 6, &[(i % 7) as u32 + 1, 9]))
            .collect();
        engine.serve_batch(&reqs, &corpus).unwrap();
        let snap = engine.checkpoint_snapshot().unwrap();
        let fresh = sim_engine(small_cfg(3, 2));
        fresh.restore_snapshot(&snap).unwrap();
        // session pins survive verbatim
        for s in 0..6u32 {
            assert_eq!(
                fresh.placed_shard(SessionId(s)).unwrap(),
                engine.placed_shard(SessionId(s)).unwrap()
            );
        }
        // re-checkpointing the restored engine reproduces the snapshot
        // byte-for-byte (no tier store here, so the spill is a no-op and
        // only warm state is in play)
        let snap2 = fresh.checkpoint_snapshot().unwrap();
        assert_eq!(snap.to_string(), snap2.to_string());
    }

    #[test]
    fn restore_rejects_shard_count_mismatch_and_garbage() {
        let corpus = corpus();
        let engine = sim_engine(small_cfg(3, 2));
        engine.serve_batch(&[req(1, 1, &[1, 2])], &corpus).unwrap();
        let snap = engine.checkpoint_snapshot().unwrap();
        let other = sim_engine(small_cfg(2, 2));
        match other.restore_snapshot(&snap) {
            Err(Error::CorruptSnapshot(msg)) => {
                assert!(msg.contains("shards"), "unhelpful message: {msg}")
            }
            r => panic!("expected CorruptSnapshot, got {r:?}"),
        }
        match engine.restore_snapshot(&Json::Null) {
            Err(Error::CorruptSnapshot(_)) => {}
            r => panic!("expected CorruptSnapshot, got {r:?}"),
        }
    }

    #[test]
    fn trace_off_by_default_and_counters_always_on() {
        let corpus = corpus();
        let engine = sim_engine(small_cfg(3, 2));
        let reqs: Vec<Request> = (0..9)
            .map(|i| req(i, i as u32, &[(i % 4) as u32 + 1, 9]))
            .collect();
        engine.serve_batch(&reqs, &corpus).unwrap();
        assert!(
            engine.trace_events().unwrap().is_empty(),
            "tracing must default off"
        );
        let counters = engine.counters();
        assert!(counters.contains(&("requests_served", 9)));
        assert!(counters.contains(&("placement_waves", 1)));
        assert!(counters.contains(&("trace_events_dropped", 0)));
    }

    #[test]
    fn traced_run_covers_the_request_lifecycle_in_order() {
        use crate::obs::ObsConfig;
        let corpus = corpus();
        let mut cfg = small_cfg(3, 2);
        cfg.obs = ObsConfig::tracing();
        let engine = sim_engine(cfg);
        let reqs: Vec<Request> = (0..9)
            .map(|i| req(i, i as u32, &[(i % 4) as u32 + 1, 9]))
            .collect();
        engine.serve_batch(&reqs, &corpus).unwrap();
        engine.checkpoint_snapshot().unwrap();
        let events = engine.trace_events().unwrap();
        for name in [
            "admitted",
            "placed",
            "queued",
            "prefill_chunk",
            "storage",
            "resolved",
        ] {
            assert!(
                events.iter().any(|e| e.kind.name() == name),
                "missing lifecycle phase {name}"
            );
        }
        for w in events.windows(2) {
            assert!(w[0].t <= w[1].t, "merged stream must be time-ordered");
        }
        let resolved = events
            .iter()
            .filter(|e| e.kind == EventKind::Resolved)
            .count();
        assert_eq!(resolved, 9, "one resolved marker per request");
        assert!(engine.counters().contains(&("storage_flushes", 3)));
    }

    #[test]
    fn chunked_admission_does_not_change_batch_results() {
        let corpus = corpus();
        let reqs: Vec<Request> = (0..30)
            .map(|i| req(i, i as u32 % 9, &[(i % 8) as u32 + 1, (i % 5) as u32 + 9, 20]))
            .collect();
        let plain = sim_engine(small_cfg(4, 2));
        let a = plain.serve_batch(&reqs, &corpus).unwrap();
        let mut cfg = small_cfg(4, 2);
        cfg.prefill_chunk = Some(96);
        let chunked = sim_engine(cfg);
        let b = chunked.serve_batch(&reqs, &corpus).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.id, y.request.id);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.cached_tokens, y.cached_tokens, "chunking changed cache semantics");
        }
        assert!(
            b.iter().any(|s| s.prefill_chunks > 1),
            "budget below prompt length must split at least one prefill"
        );
    }
}
