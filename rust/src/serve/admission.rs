//! Chunked-prefill admission: head-of-line-blocking relief for long
//! prompts (cf. Context Parallelism, Yang et al. 2024; FastKV, Jo et al.
//! 2025 — long-context prefill as schedulable chunks, not one monolithic
//! call).
//!
//! A shard queue is served by one engine; without chunking a million-token
//! prefill occupies it end-to-end and every short request behind it eats
//! the full delay. With `prefill_chunk` set, a request whose *uncached*
//! prefill exceeds the chunk budget is split into chunks — cut points
//! snapped to radix-node boundaries ([`crate::engine::InferenceEngine::
//! chunk_boundaries`]) so chunk ends coincide with shareable prefixes —
//! and the shard round-robins the queue one chunk at a time: a long
//! request yields the engine to the requests behind it between chunks.
//!
//! Chunking is a *scheduling overlay*: the engine still performs each
//! request's cache match/insert atomically in the pipeline's execution
//! order, so hit/miss results are bit-identical with chunking on or off
//! (chunked prefill computes the same tokens — only *when* they are
//! computed changes). What moves is the per-request queue-aware TTFT
//! ([`crate::types::ServedRequest::queued_ttft`]), accounted on a
//! per-shard virtual clock and reported through
//! [`crate::metrics::RunMetrics`].

/// Split one served request's engine occupancy (`ttft` seconds covering
/// its uncached prefill plus any cold-tier promotion load) into per-chunk
/// durations.
///
/// * `prefill_chunk` — admission chunk budget in tokens; `None` disables
///   chunking (single chunk).
/// * `hot_tokens`/`prompt_tokens` — only the region
///   `[hot_tokens, prompt_tokens)` occupies the engine and is therefore
///   chunkable. `hot_tokens` counts HBM hits alone
///   ([`crate::types::TierHits::hbm`]): tokens *promoted* from a cold
///   tier still occupy the engine while their KV loads, so they belong to
///   the chunkable region — callers pass `tier_hits.hbm`, not
///   `cached_tokens`.
/// * `boundaries` — ascending token offsets at which the prompt may be
///   split (radix-node / segment ends). Cuts snap to the largest boundary
///   within budget; a boundary gap wider than the budget falls back to a
///   hard cut so a single giant block cannot defeat admission.
///
/// Durations are proportional to chunk token counts and always sum to
/// `ttft` (the first chunk absorbs the constant overheads and promotion
/// load pro rata), so the virtual clock advances by exactly the unchunked
/// amount in total.
pub fn chunk_plan(
    prefill_chunk: Option<usize>,
    hot_tokens: usize,
    prompt_tokens: usize,
    ttft: f64,
    boundaries: &[usize],
) -> Vec<f64> {
    let occupying = prompt_tokens.saturating_sub(hot_tokens);
    let Some(chunk) = prefill_chunk else {
        return vec![ttft];
    };
    let chunk = chunk.max(1);
    if occupying <= chunk {
        return vec![ttft];
    }
    let mut cuts: Vec<usize> = Vec::new();
    let mut pos = hot_tokens;
    while prompt_tokens - pos > chunk {
        let snapped = boundaries
            .iter()
            .copied()
            .filter(|&b| b > pos && b <= pos + chunk)
            .max();
        let cut = snapped.unwrap_or(pos + chunk);
        cuts.push(cut);
        pos = cut;
    }
    cuts.push(prompt_tokens);
    let mut durations = Vec::with_capacity(cuts.len());
    let mut prev = hot_tokens;
    for &c in &cuts {
        durations.push(ttft * (c - prev) as f64 / occupying as f64);
        prev = c;
    }
    durations
}

/// One chunk's slot on the admission virtual clock, as reported by
/// [`interleave_with`]: request `task` ran chunk `chunk` (of its
/// `n_chunks`-chunk plan) over `[start, end)` virtual seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChunkRun {
    /// Index into `plans` (queue position of the request).
    pub task: usize,
    /// 0-based chunk index within the request's plan.
    pub chunk: usize,
    /// Total chunks in the request's plan.
    pub n_chunks: usize,
    /// Virtual-clock time the chunk started.
    pub start: f64,
    /// Virtual-clock time the chunk finished (`start` + duration).
    pub end: f64,
}

/// Run one shard queue's chunk plans on a virtual clock with round-robin
/// chunk admission: the queue is walked in execution order, each request
/// runs one chunk per turn, and a request with chunks remaining rotates to
/// the back of the queue. Single-chunk (short / unchunked) requests
/// therefore complete on their first turn instead of waiting out every
/// long prefill ahead of them; with all-single-chunk plans this degrades
/// to plain FIFO (prefix sums).
///
/// Returns each request's completion time (its queue-aware TTFT), indexed
/// like `plans`.
pub fn interleave(plans: &[Vec<f64>]) -> Vec<f64> {
    interleave_with(plans, |_| {})
}

/// [`interleave`] that also reports every executed chunk, in execution
/// order, through `on_chunk` — the tracing hook behind
/// [`crate::obs`]'s `prefill_chunk` span events. The schedule (and the
/// returned completion times) is identical to [`interleave`]'s; the
/// callback is pure observation.
pub fn interleave_with(plans: &[Vec<f64>], mut on_chunk: impl FnMut(ChunkRun)) -> Vec<f64> {
    let mut queue: std::collections::VecDeque<usize> = (0..plans.len()).collect();
    let mut next_chunk = vec![0usize; plans.len()];
    let mut finish = vec![0f64; plans.len()];
    let mut clock = 0f64;
    while let Some(t) = queue.pop_front() {
        match plans[t].get(next_chunk[t]).copied() {
            Some(d) => {
                let start = clock;
                clock += d;
                on_chunk(ChunkRun {
                    task: t,
                    chunk: next_chunk[t],
                    n_chunks: plans[t].len(),
                    start,
                    end: clock,
                });
                next_chunk[t] += 1;
                if next_chunk[t] < plans[t].len() {
                    queue.push_back(t);
                } else {
                    finish[t] = clock;
                }
            }
            // degenerate empty plan: completes instantly at the current clock
            None => finish[t] = clock,
        }
    }
    finish
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total(plan: &[f64]) -> f64 {
        plan.iter().sum()
    }

    #[test]
    fn unchunked_is_a_single_slot() {
        assert_eq!(chunk_plan(None, 100, 500, 2.0, &[200, 300]), vec![2.0]);
        // under budget: no split either
        let p = chunk_plan(Some(1000), 0, 400, 1.5, &[100, 400]);
        assert_eq!(p, vec![1.5]);
    }

    #[test]
    fn cuts_snap_to_boundaries_and_durations_sum_to_ttft() {
        // uncached region [0, 1000), budget 300, boundaries at multiples
        // of 250: cuts must land on 250, 500, 750, 1000.
        let bounds = [250, 500, 750, 1000];
        let p = chunk_plan(Some(300), 0, 1000, 4.0, &bounds);
        assert_eq!(p.len(), 4);
        for d in &p {
            assert!((d - 1.0).abs() < 1e-9, "equal 250-token chunks: {p:?}");
        }
        assert!((total(&p) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn boundary_gap_falls_back_to_hard_cut() {
        // one giant block with no internal boundary: budget still splits it
        let p = chunk_plan(Some(100), 0, 350, 3.5, &[350]);
        assert_eq!(p.len(), 4); // 100 + 100 + 100 + 50
        assert!((total(&p) - 3.5).abs() < 1e-9);
        assert!(p[3] < p[0], "tail chunk is the 50-token remainder");
    }

    #[test]
    fn cached_prefix_is_not_chunked() {
        // 900 of 1000 tokens cached: uncached 100 <= budget 128 -> single
        let p = chunk_plan(Some(128), 900, 1000, 0.3, &[500, 950, 1000]);
        assert_eq!(p, vec![0.3]);
        // uncached 300: cuts only in [700, 1000)
        let p = chunk_plan(Some(128), 700, 1000, 0.9, &[100, 800, 900, 1000]);
        assert_eq!(p.len(), 3);
        assert!((total(&p) - 0.9).abs() < 1e-9);
    }

    #[test]
    fn promoted_tokens_are_chunkable() {
        // 900 of 1000 tokens "cached", but only 100 of those are hot HBM
        // hits — the 800 promoted tokens occupy the engine while loading,
        // so the chunkable region is [100, 1000), not [900, 1000)
        let hot = 100;
        let p = chunk_plan(Some(300), hot, 1000, 1.8, &[300, 600, 900, 1000]);
        assert_eq!(p.len(), 4, "cuts at 300/600/900 then the 100-token tail");
        assert!((total(&p) - 1.8).abs() < 1e-9);
        // had the caller passed cached_tokens (900) instead, no split:
        assert_eq!(chunk_plan(Some(300), 900, 1000, 1.8, &[1000]).len(), 1);
    }

    #[test]
    fn interleave_of_single_chunks_is_fifo() {
        let plans = vec![vec![1.0], vec![2.0], vec![0.5]];
        assert_eq!(interleave(&plans), vec![1.0, 3.0, 3.5]);
    }

    #[test]
    fn short_request_overtakes_chunked_long_prefill() {
        // long = 4 chunks of 1s, short = 0.1s: FIFO would make the short
        // wait 4s; round-robin admits it after the first chunk.
        let plans = vec![vec![1.0, 1.0, 1.0, 1.0], vec![0.1]];
        let finish = interleave(&plans);
        assert!((finish[1] - 1.1).abs() < 1e-9, "short at {}", finish[1]);
        // the long request still completes at the total span
        assert!((finish[0] - 4.1).abs() < 1e-9);
    }

    #[test]
    fn interleave_span_is_total_work() {
        let plans = vec![vec![0.5, 0.5], vec![0.25], vec![1.0, 0.75]];
        let finish = interleave(&plans);
        let span = finish.iter().cloned().fold(0.0f64, f64::max);
        let work: f64 = plans.iter().map(|p| total(p)).sum();
        assert!((span - work).abs() < 1e-9);
    }

    #[test]
    fn interleave_with_reports_every_chunk_and_agrees_with_interleave() {
        let plans = vec![vec![1.0, 1.0], vec![0.5], vec![0.25, 0.25]];
        let mut runs: Vec<ChunkRun> = Vec::new();
        let finish = interleave_with(&plans, |r| runs.push(r));
        assert_eq!(finish, interleave(&plans), "observation must not reschedule");
        // every chunk of every plan is reported exactly once
        assert_eq!(runs.len(), 5);
        for (task, plan) in plans.iter().enumerate() {
            for chunk in 0..plan.len() {
                let r = runs
                    .iter()
                    .find(|r| r.task == task && r.chunk == chunk)
                    .expect("chunk reported");
                assert_eq!(r.n_chunks, plan.len());
                assert!((r.end - r.start - plan[chunk]).abs() < 1e-9);
            }
        }
        // execution order: contiguous, monotone slots starting at 0
        assert_eq!(runs[0].start, 0.0);
        for w in runs.windows(2) {
            assert!((w[0].end - w[1].start).abs() < 1e-9, "no clock gaps");
        }
        // a request's finish time is its last chunk's end
        let last_of_0 = runs.iter().rev().find(|r| r.task == 0).unwrap();
        assert!((last_of_0.end - finish[0]).abs() < 1e-9);
    }

    #[test]
    fn empty_queue_and_empty_plan_are_safe() {
        assert!(interleave(&[]).is_empty());
        let finish = interleave(&[vec![], vec![1.0]]);
        assert_eq!(finish[0], 0.0);
        assert!((finish[1] - 1.0).abs() < 1e-9);
    }
}
