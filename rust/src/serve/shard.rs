//! One serving shard: a ContextPilot proxy + inference engine pair owning
//! the sessions hashed to it. The shard is generic over the engine
//! ([`crate::engine::InferenceEngine`]) — the same pipeline drives the
//! simulated engine, the PJRT-backed real engine and test mocks. All
//! mutable state is private to the shard, so interleavings of *other*
//! shards cannot change this shard's results — the determinism contract
//! `rust/tests/serve_stress.rs` pins down.

use std::sync::Arc;

use crate::corpus::Corpus;
use crate::engine::iface::InferenceEngine;
use crate::engine::sim::SimEngine;
use crate::metrics::{RunMetrics, ShardStats};
use crate::obs::{Counter, EventKind, Registry, TierOp, Tracer};
use crate::pilot::ContextPilot;
use crate::quality::QualityModel;
use crate::serve::{admission, ServeConfig};
use crate::types::{Prompt, Request, RequestId, ServedRequest, SessionId};
use crate::util::prng::SplitMix64;

/// Deterministic session → shard assignment (SplitMix64 of the session
/// id). Sessions are pinned so conversation history, dedup records and the
/// per-shard context index stay consistent without cross-shard locks; the
/// hash spreads the sequential session ids the generators emit.
pub fn shard_of(session: SessionId, n_shards: usize) -> usize {
    (SplitMix64::new(session.0 as u64).next_u64() % n_shards.max(1) as u64) as usize
}

/// Per-request admission inputs: the decode budget and (when chunking is
/// enabled) the radix-node boundaries of the prompt. A free function over
/// the shard's disjoint fields so both serve paths can call it while the
/// pilot is mutably borrowed. Boundary extraction re-renders the prompt
/// segment-by-segment — a known second render on the chunked hot path
/// (engines could return boundaries from `serve` itself to fold the two;
/// not worth widening the trait until profiles say so) — which is why it
/// is skipped entirely when `prefill_chunk` is off.
fn admission_inputs<E: InferenceEngine>(
    engine: &mut E,
    decode_override: &Option<std::collections::HashMap<RequestId, usize>>,
    decode_tokens: usize,
    prefill_chunk: Option<usize>,
    req: &Request,
    prompt: &Prompt,
    corpus: &Corpus,
) -> (usize, Vec<usize>) {
    let decode = decode_override
        .as_ref()
        .and_then(|m| m.get(&req.id).copied())
        .unwrap_or(decode_tokens);
    let boundaries = if prefill_chunk.is_some() {
        engine.chunk_boundaries(req, prompt, corpus)
    } else {
        Vec::new()
    };
    (decode, boundaries)
}

pub struct Shard<E = SimEngine> {
    pub(crate) id: usize,
    /// `None` = baseline mode: engine-only, LPM-ordered queues (when the
    /// engine prefers LPM).
    pub(crate) pilot: Option<ContextPilot>,
    pub(crate) engine: E,
    pub(crate) quality: QualityModel,
    pub(crate) decode_tokens: usize,
    pub(crate) decode_override: Option<std::collections::HashMap<RequestId, usize>>,
    pub(crate) prefill_chunk: Option<usize>,
    pub(crate) metrics: RunMetrics,
    pub(crate) max_queue_depth: usize,
    /// Engine-wide counter registry ([`crate::obs`]), shared by every
    /// shard; always on.
    pub(crate) registry: Arc<Registry>,
    /// Per-shard lifecycle tracer; `Some` only when
    /// [`crate::obs::ObsConfig::trace`] is set (the disabled path
    /// allocates nothing on the hot path).
    pub(crate) tracer: Option<Tracer>,
}

impl<E: InferenceEngine> Shard<E> {
    pub(crate) fn new(
        id: usize,
        cfg: &ServeConfig,
        engine: E,
        registry: Arc<Registry>,
    ) -> Shard<E> {
        let tracer = cfg
            .obs
            .trace
            .then(|| Tracer::new(id, cfg.obs.trace_capacity, registry.clone()));
        Shard {
            id,
            pilot: cfg.pilot.clone().map(ContextPilot::new),
            engine,
            quality: QualityModel::new(cfg.era, cfg.multi_hop),
            decode_tokens: cfg.decode_tokens,
            decode_override: cfg.decode_override.clone(),
            prefill_chunk: cfg.prefill_chunk,
            metrics: RunMetrics::new(),
            max_queue_depth: 0,
            registry,
            tracer,
        }
    }

    /// Drive one queue of requests (arrival order) through the full
    /// pipeline. Returns the served records (execution order — Alg.-5 may
    /// reorder within the queue) and every engine request id evicted while
    /// serving; the evictions have already been fed back into this shard's
    /// context index (§4.1).
    ///
    /// Engine cache operations run atomically per request in execution
    /// order regardless of chunking; the chunked-prefill admission overlay
    /// only redistributes *when* each request's prefill time elapses on
    /// the shard's virtual clock (`queued_ttft`).
    pub(crate) fn serve_queue(
        &mut self,
        batch: &[Request],
        corpus: &Corpus,
    ) -> (Vec<ServedRequest>, Vec<RequestId>) {
        self.max_queue_depth = self.max_queue_depth.max(batch.len());
        let (mut out, plans, all_evicted, demoted) = self.serve_pipeline(batch, corpus);
        // admission accounting: one virtual clock per queue wave; with
        // tracing on, the identical schedule also reports per-chunk slots
        let mut runs: Vec<admission::ChunkRun> = Vec::new();
        let finish = if self.tracer.is_some() {
            admission::interleave_with(&plans, |r| runs.push(r))
        } else {
            admission::interleave(&plans)
        };
        for (k, served) in out.iter_mut().enumerate() {
            served.queued_ttft = finish[k];
            served.prefill_chunks = plans[k].len() as u32;
            self.metrics.record(served);
            self.record_request_counters(served);
        }
        if !batch.is_empty() {
            self.registry.add(Counter::QueueWaves, 1);
            self.registry.max(Counter::MaxQueueDepth, batch.len() as u64);
        }
        self.trace_wave(&out, &runs, &finish, demoted);
        (out, all_evicted)
    }

    /// The cache/engine half of [`Shard::serve_queue`]: run `batch` in
    /// execution order through the pilot rewrite (or baseline LPM
    /// ordering) and the engine, feed evictions back into the context
    /// index, and build each request's chunked-prefill plan. Returns
    /// `(served, plans, evicted, demoted_tokens)` with **no** admission
    /// accounting applied: `queued_ttft`/`prefill_chunks` are unset and
    /// nothing is recorded in [`RunMetrics`]. The wave path finishes the
    /// job by interleaving the plans on the wave's virtual clock
    /// (`serve_queue`); the continuous-batching scheduler instead steps
    /// the plans chunk-by-chunk on the shard's run-queue clock
    /// ([`crate::serve::sched`]), which is exactly why the split exists.
    /// Tier-delta counters are bumped here (they are a pure function of
    /// the engine calls, not of the admission overlay).
    pub(crate) fn serve_pipeline(
        &mut self,
        batch: &[Request],
        corpus: &Corpus,
    ) -> (Vec<ServedRequest>, Vec<Vec<f64>>, Vec<RequestId>, u64) {
        let cache_before = self.engine.cache_stats();
        let mut out = Vec::with_capacity(batch.len());
        let mut plans: Vec<Vec<f64>> = Vec::with_capacity(batch.len());
        let mut all_evicted = Vec::new();
        match &mut self.pilot {
            Some(pilot) => {
                for (i, rw) in pilot.rewrite_batch(batch, corpus) {
                    let req = &batch[i];
                    let (decode, boundaries) = admission_inputs(
                        &mut self.engine,
                        &self.decode_override,
                        self.decode_tokens,
                        self.prefill_chunk,
                        req,
                        &rw.prompt,
                        corpus,
                    );
                    let (served, evicted) =
                        self.engine
                            .serve(req, &rw.prompt, corpus, &self.quality, decode);
                    pilot.on_evict(&evicted);
                    all_evicted.extend(evicted);
                    // hot hits skip the engine entirely; promoted (cold-
                    // tier) tokens occupy it while loading, so the
                    // chunkable region starts at the hot boundary
                    plans.push(admission::chunk_plan(
                        self.prefill_chunk,
                        served.tier_hits.hbm,
                        served.prompt_tokens,
                        served.ttft,
                        &boundaries,
                    ));
                    out.push(served);
                }
            }
            None => {
                // baseline: radix-style engines use longest-prefix-match
                // ordering within the queue (what SGLang's scheduler does);
                // non-prefix mechanisms serve in arrival order — mirroring
                // the sequential experiment runner so sharded and unsharded
                // results stay comparable per system.
                let order: Vec<usize> = if self.engine.prefers_lpm() {
                    self.engine.lpm_order(batch, corpus)
                } else {
                    (0..batch.len()).collect()
                };
                for i in order {
                    let req = &batch[i];
                    let prompt = Prompt::baseline(req);
                    let (decode, boundaries) = admission_inputs(
                        &mut self.engine,
                        &self.decode_override,
                        self.decode_tokens,
                        self.prefill_chunk,
                        req,
                        &prompt,
                        corpus,
                    );
                    let (served, evicted) =
                        self.engine
                            .serve(req, &prompt, corpus, &self.quality, decode);
                    all_evicted.extend(evicted);
                    plans.push(admission::chunk_plan(
                        self.prefill_chunk,
                        served.tier_hits.hbm,
                        served.prompt_tokens,
                        served.ttft,
                        &boundaries,
                    ));
                    out.push(served);
                }
            }
        }
        let cache_after = self.engine.cache_stats();
        let demoted = cache_after.demoted_tokens.saturating_sub(cache_before.demoted_tokens);
        self.registry.add(Counter::DemotedTokens, demoted);
        self.registry.add(
            Counter::PromotedTokens,
            cache_after.promoted_tokens.saturating_sub(cache_before.promoted_tokens),
        );
        self.registry.add(
            Counter::DiscardedTokens,
            cache_after.discarded_tokens.saturating_sub(cache_before.discarded_tokens),
        );
        (out, plans, all_evicted, demoted)
    }

    /// Bump the always-on per-request registry counters for one served
    /// request (the registry mirrors [`RunMetrics`]; a test pins the two
    /// equal where they overlap).
    pub(crate) fn record_request_counters(&self, served: &ServedRequest) {
        let r = &self.registry;
        r.add(Counter::RequestsServed, 1);
        r.add(Counter::PromptTokens, served.prompt_tokens as u64);
        r.add(Counter::CachedTokens, served.cached_tokens as u64);
        r.add(Counter::HotHitTokens, served.tier_hits.hbm as u64);
        r.add(Counter::WarmHitTokens, served.tier_hits.dram as u64);
        r.add(Counter::ColdHitTokens, served.tier_hits.ssd as u64);
        r.add(Counter::PrefillChunks, served.prefill_chunks as u64);
    }

    /// Stamp one admission wave's events on the shard's virtual clock:
    /// each executed chunk as a span, per-request tier promotions and the
    /// `resolved` marker at the request's queue-aware completion, and the
    /// wave's demotion total (if any) at the wave end. No-op unless
    /// tracing is enabled. The clock advances by the wave's span — the
    /// total work interleaved — so timestamps are cumulative simulated
    /// seconds, independent of worker scheduling.
    fn trace_wave(
        &mut self,
        out: &[ServedRequest],
        runs: &[admission::ChunkRun],
        finish: &[f64],
        demoted_tokens: u64,
    ) {
        let Some(tracer) = &mut self.tracer else {
            return;
        };
        let base = tracer.clock();
        for run in runs {
            let s = &out[run.task];
            // reconstruct the chunk's token count from its share of the
            // request's engine occupancy (uncached + promoted region)
            let occupying = s.prompt_tokens.saturating_sub(s.tier_hits.hbm);
            let tokens = if s.ttft > 0.0 {
                ((run.end - run.start) / s.ttft * occupying as f64).round() as u32
            } else {
                0
            };
            tracer.emit(
                base + run.start,
                run.end - run.start,
                Some(s.request.id.0),
                Some(s.request.session.0),
                EventKind::PrefillChunk {
                    index: run.chunk as u32,
                    of: run.n_chunks as u32,
                    tokens,
                },
            );
        }
        for (k, s) in out.iter().enumerate() {
            let (req, sess) = (Some(s.request.id.0), Some(s.request.session.0));
            if s.tier_hits.dram > 0 {
                let kind = EventKind::Tier {
                    op: TierOp::Promote,
                    tier: "dram",
                    tokens: s.tier_hits.dram as u64,
                };
                tracer.emit(base + finish[k], 0.0, req, sess, kind);
            }
            if s.tier_hits.ssd > 0 {
                let kind = EventKind::Tier {
                    op: TierOp::Promote,
                    tier: "ssd",
                    tokens: s.tier_hits.ssd as u64,
                };
                tracer.emit(base + finish[k], 0.0, req, sess, kind);
            }
            tracer.emit(base + finish[k], 0.0, req, sess, EventKind::Resolved);
        }
        let span = finish.iter().copied().fold(0.0f64, f64::max);
        if demoted_tokens > 0 {
            let kind = EventKind::Tier {
                op: TierOp::Demote,
                tier: "dram",
                tokens: demoted_tokens,
            };
            tracer.emit(base + span, 0.0, None, None, kind);
        }
        tracer.advance(span);
    }

    /// Serve a single request as a one-element queue. Alg.-5 scheduling
    /// of a singleton is the identity and a singleton queue has nothing
    /// to interleave with, so `queued_ttft == ttft`. The facade's
    /// streaming path reaches the shard through `serve_queue` (a wait
    /// flushes a whole admission wave), so this exists only to pin the
    /// queue/singleton agreement property in the tests below.
    #[cfg(test)]
    pub(crate) fn serve_one(
        &mut self,
        req: &Request,
        corpus: &Corpus,
    ) -> (ServedRequest, Vec<RequestId>) {
        self.max_queue_depth = self.max_queue_depth.max(1);
        let (mut served, evicted, boundaries) = match &mut self.pilot {
            Some(pilot) => {
                let rw = pilot.rewrite(req, corpus);
                let (decode, boundaries) = admission_inputs(
                    &mut self.engine,
                    &self.decode_override,
                    self.decode_tokens,
                    self.prefill_chunk,
                    req,
                    &rw.prompt,
                    corpus,
                );
                let (served, evicted) =
                    self.engine
                        .serve(req, &rw.prompt, corpus, &self.quality, decode);
                pilot.on_evict(&evicted);
                (served, evicted, boundaries)
            }
            None => {
                let prompt = Prompt::baseline(req);
                let (decode, boundaries) = admission_inputs(
                    &mut self.engine,
                    &self.decode_override,
                    self.decode_tokens,
                    self.prefill_chunk,
                    req,
                    &prompt,
                    corpus,
                );
                let (served, evicted) =
                    self.engine
                        .serve(req, &prompt, corpus, &self.quality, decode);
                (served, evicted, boundaries)
            }
        };
        let plan = admission::chunk_plan(
            self.prefill_chunk,
            served.tier_hits.hbm,
            served.prompt_tokens,
            served.ttft,
            &boundaries,
        );
        served.queued_ttft = served.ttft;
        served.prefill_chunks = plan.len() as u32;
        self.metrics.record(&served);
        self.record_request_counters(&served);
        self.registry.add(Counter::QueueWaves, 1);
        self.registry.max(Counter::MaxQueueDepth, 1);
        (served, evicted)
    }

    /// Telemetry snapshot (sorts the latency samples for percentiles).
    /// Placement telemetry (`placed_sessions`, `affinity_hit_tokens`) is
    /// engine-level state the shard cannot see; the serving engine
    /// fills those two fields from its placement ledger.
    pub(crate) fn stats(&mut self) -> ShardStats {
        let cache = self.engine.cache_stats();
        ShardStats {
            shard: self.id,
            served: self.metrics.len(),
            placed_sessions: 0,
            affinity_hit_tokens: 0,
            max_queue_depth: self.max_queue_depth,
            hit_ratio: self.metrics.hit_ratio(),
            p50_ttft: self.metrics.ttft.p50(),
            p99_ttft: self.metrics.ttft.p99(),
            p99_queued_ttft: self.metrics.queued_ttft.p99(),
            prefill_chunks: self.metrics.total_prefill_chunks,
            index_nodes: self.pilot.as_ref().map_or(0, |p| p.index_size()),
            index_blocks: self.pilot.as_ref().map_or(0, |p| p.index.distinct_blocks()),
            resident_tokens: cache.resident_tokens,
            dram_resident_tokens: cache.dram_resident_tokens,
            ssd_resident_tokens: cache.ssd_resident_tokens,
            warm_hit_tokens: cache.warm_hit_tokens,
            cold_hit_tokens: cache.cold_hit_tokens,
            sessions: self.engine.session_count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::costmodel::ModelSku;
    use crate::types::{BlockId, QueryId};

    fn req(id: u64, session: u32, ids: &[u32]) -> Request {
        Request {
            id: RequestId(id),
            session: SessionId(session),
            turn: 0,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(id),
        }
    }

    fn corpus() -> Corpus {
        use crate::corpus::CorpusConfig;
        use crate::tokenizer::Tokenizer;
        Corpus::generate(
            &CorpusConfig {
                n_docs: 40,
                ..Default::default()
            },
            &Tokenizer::default(),
        )
    }

    fn sim_shard(id: usize, cfg: &ServeConfig) -> Shard {
        Shard::new(id, cfg, cfg.sim_engine(), Arc::new(Registry::new()))
    }

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        for n in [1usize, 2, 5, 8, 64] {
            for s in 0..200u32 {
                let a = shard_of(SessionId(s), n);
                let b = shard_of(SessionId(s), n);
                assert_eq!(a, b);
                assert!(a < n);
            }
        }
    }

    #[test]
    fn shard_of_spreads_sequential_sessions() {
        let n = 8;
        let mut counts = vec![0usize; n];
        for s in 0..800u32 {
            counts[shard_of(SessionId(s), n)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!((50..200).contains(&c), "shard {i} got {c} of 800");
        }
    }

    #[test]
    fn queue_and_singleton_paths_agree() {
        let corpus = corpus();
        let cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        let batch = vec![req(1, 1, &[1, 2, 3]), req(2, 2, &[1, 2, 9])];
        let mut as_queue = sim_shard(0, &cfg);
        let (q, _) = as_queue.serve_queue(&batch, &corpus);
        let mut one_by_one = sim_shard(0, &cfg);
        // serve in the same execution order the queue chose
        for served in &q {
            let (s, _) = one_by_one.serve_one(&served.request, &corpus);
            assert_eq!(s.cached_tokens, served.cached_tokens);
            assert_eq!(s.prompt_tokens, served.prompt_tokens);
        }
    }

    #[test]
    fn baseline_shard_orders_by_longest_prefix() {
        let corpus = corpus();
        let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        cfg.pilot = None;
        let mut shard = sim_shard(0, &cfg);
        // warm the cache with {1,2,3}
        shard.serve_queue(&[req(1, 1, &[1, 2, 3])], &corpus);
        // a queue where the second request shares the cached prefix: LPM
        // must serve it first
        let (out, _) = shard.serve_queue(&[req(2, 2, &[7, 8]), req(3, 3, &[1, 2, 5])], &corpus);
        assert_eq!(out[0].request.id, RequestId(3));
        assert!(out[0].cached_tokens > 0);
    }

    #[test]
    fn stats_reflect_served_traffic() {
        let corpus = corpus();
        let cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        let mut shard = sim_shard(3, &cfg);
        let batch = vec![
            req(1, 1, &[1, 2, 3]),
            req(2, 2, &[1, 2, 9]),
            req(3, 3, &[4, 5]),
        ];
        shard.serve_queue(&batch, &corpus);
        let st = shard.stats();
        assert_eq!(st.shard, 3);
        assert_eq!(st.served, 3);
        assert_eq!(st.max_queue_depth, 3);
        assert_eq!(st.sessions, 3);
        assert!(st.index_nodes > 1, "pilot index should hold leaves");
        assert!(st.resident_tokens > 0);
        assert!(st.p99_ttft >= st.p50_ttft);
        // unchunked: one prefill slot per request, FIFO accounting
        assert_eq!(st.prefill_chunks, 3);
        assert!(st.p99_queued_ttft >= st.p99_ttft);
    }

    #[test]
    fn registry_and_tracer_observe_a_wave() {
        let corpus = corpus();
        let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        cfg.obs = crate::obs::ObsConfig::tracing();
        let mut shard = sim_shard(0, &cfg);
        let batch = vec![req(1, 1, &[1, 2, 3]), req(2, 2, &[1, 2, 9])];
        let (out, _) = shard.serve_queue(&batch, &corpus);
        assert_eq!(shard.registry.get(Counter::RequestsServed), 2);
        assert_eq!(shard.registry.get(Counter::QueueWaves), 1);
        assert_eq!(shard.registry.get(Counter::MaxQueueDepth), 2);
        assert_eq!(
            shard.registry.get(Counter::PromptTokens),
            shard.metrics.total_prompt_tokens
        );
        let tracer = shard.tracer.as_ref().expect("tracing enabled");
        let events = tracer.snapshot();
        let resolved = events
            .iter()
            .filter(|e| e.kind == EventKind::Resolved)
            .count();
        assert_eq!(resolved, 2, "one resolved marker per request");
        let chunks = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::PrefillChunk { .. }))
            .count();
        assert_eq!(chunks as u64, shard.registry.get(Counter::PrefillChunks));
        // the virtual clock advanced by the wave span (max completion)
        let span = out.iter().map(|s| s.queued_ttft).fold(0.0f64, f64::max);
        assert!((tracer.clock() - span).abs() < 1e-9);
    }

    #[test]
    fn tracing_off_means_no_tracer_but_counters_still_run() {
        let corpus = corpus();
        let cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        let mut shard = sim_shard(0, &cfg);
        shard.serve_queue(&[req(1, 1, &[1, 2])], &corpus);
        assert!(shard.tracer.is_none(), "default config must not trace");
        assert_eq!(shard.registry.get(Counter::RequestsServed), 1);
    }

    #[test]
    fn queued_ttft_is_fifo_prefix_sum_without_chunking() {
        let corpus = corpus();
        let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        cfg.pilot = None;
        let mut shard = sim_shard(0, &cfg);
        let batch = vec![req(1, 1, &[1, 2, 3]), req(2, 2, &[4, 5, 6])];
        let (out, _) = shard.serve_queue(&batch, &corpus);
        assert!((out[0].queued_ttft - out[0].ttft).abs() < 1e-12);
        assert!((out[1].queued_ttft - (out[0].ttft + out[1].ttft)).abs() < 1e-9);
        assert!(out.iter().all(|s| s.prefill_chunks == 1));
    }

    #[test]
    fn chunking_preserves_results_and_unblocks_short_requests() {
        let corpus = corpus();
        let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        cfg.pilot = None;
        // long request (8 blocks) ahead of a short one (1 block), cold
        // cache so LPM keeps arrival order
        let batch = vec![req(1, 1, &[1, 2, 3, 4, 5, 6, 7, 8]), req(2, 2, &[9])];

        let mut plain = sim_shard(0, &cfg);
        let (unchunked, _) = plain.serve_queue(&batch, &corpus);

        cfg.prefill_chunk = Some(64);
        let mut chunked_shard = sim_shard(0, &cfg);
        let (chunked, _) = chunked_shard.serve_queue(&batch, &corpus);

        // cache semantics identical
        for (a, b) in unchunked.iter().zip(&chunked) {
            assert_eq!(a.request.id, b.request.id);
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(a.cached_tokens, b.cached_tokens);
            assert!((a.ttft - b.ttft).abs() < 1e-12);
        }
        // the long prefill was split; the short request was not
        assert!(chunked[0].prefill_chunks > 1, "long prompt must chunk");
        assert_eq!(chunked[1].prefill_chunks, 1);
        // head-of-line relief: the short request finishes strictly earlier
        assert!(
            chunked[1].queued_ttft < unchunked[1].queued_ttft,
            "chunked {} vs unchunked {}",
            chunked[1].queued_ttft,
            unchunked[1].queued_ttft
        );
        // conservation: the long request still pays its full prefill
        assert!(chunked[0].queued_ttft >= unchunked[0].ttft - 1e-9);
    }
}
