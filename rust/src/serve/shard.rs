//! One serving shard: a ContextPilot proxy + simulated engine pair owning
//! the sessions hashed to it. All mutable state is private to the shard,
//! so interleavings of *other* shards cannot change this shard's results —
//! the determinism contract `rust/tests/serve_stress.rs` pins down.

use crate::corpus::Corpus;
use crate::engine::sim::{ReusePolicy, SimEngine};
use crate::metrics::{RunMetrics, ShardStats};
use crate::pilot::ContextPilot;
use crate::quality::QualityModel;
use crate::serve::ServeConfig;
use crate::types::{Prompt, Request, RequestId, ServedRequest, SessionId};
use crate::util::prng::SplitMix64;

/// Deterministic session → shard assignment (SplitMix64 of the session
/// id). Sessions are pinned so conversation history, dedup records and the
/// per-shard context index stay consistent without cross-shard locks; the
/// hash spreads the sequential session ids the generators emit.
pub fn shard_of(session: SessionId, n_shards: usize) -> usize {
    (SplitMix64::new(session.0 as u64).next_u64() % n_shards.max(1) as u64) as usize
}

pub struct Shard {
    pub(crate) id: usize,
    /// `None` = baseline mode: engine-only, LPM-ordered queues.
    pub(crate) pilot: Option<ContextPilot>,
    pub(crate) engine: SimEngine,
    pub(crate) quality: QualityModel,
    pub(crate) decode_tokens: usize,
    pub(crate) metrics: RunMetrics,
    pub(crate) max_queue_depth: usize,
}

impl Shard {
    pub(crate) fn new(id: usize, cfg: &ServeConfig) -> Shard {
        Shard {
            id,
            pilot: cfg.pilot.clone().map(ContextPilot::new),
            engine: SimEngine::new(cfg.profile, cfg.policy, cfg.capacity_tokens),
            quality: QualityModel::new(cfg.era, cfg.multi_hop),
            decode_tokens: cfg.decode_tokens,
            metrics: RunMetrics::new(),
            max_queue_depth: 0,
        }
    }

    /// Drive one queue of requests (arrival order) through the full
    /// pipeline. Returns the served records (execution order — Alg.-5 may
    /// reorder within the queue) and every engine request id evicted while
    /// serving; the evictions have already been fed back into this shard's
    /// context index (§4.1).
    pub(crate) fn serve_queue(
        &mut self,
        batch: &[Request],
        corpus: &Corpus,
    ) -> (Vec<ServedRequest>, Vec<RequestId>) {
        self.max_queue_depth = self.max_queue_depth.max(batch.len());
        let mut out = Vec::with_capacity(batch.len());
        let mut all_evicted = Vec::new();
        match &mut self.pilot {
            Some(pilot) => {
                for o in pilot.process_batch(batch, corpus) {
                    let (served, evicted) = self.engine.serve(
                        &o.request,
                        &o.prompt,
                        corpus,
                        &self.quality,
                        self.decode_tokens,
                    );
                    pilot.on_evict(&evicted);
                    all_evicted.extend(evicted);
                    self.metrics.record(&served);
                    out.push(served);
                }
            }
            None => {
                // baseline: radix-cache serving uses longest-prefix-match
                // ordering within the queue (what SGLang's scheduler does);
                // the other baseline mechanisms serve in arrival order —
                // mirroring the sequential experiment runner so sharded and
                // unsharded results stay comparable per system.
                let order: Vec<usize> =
                    if matches!(self.engine.policy, ReusePolicy::RadixPrefix) {
                        self.engine.lpm_order(batch, corpus)
                    } else {
                        (0..batch.len()).collect()
                    };
                for i in order {
                    let r = &batch[i];
                    let (served, evicted) = self.engine.serve(
                        r,
                        &Prompt::baseline(r),
                        corpus,
                        &self.quality,
                        self.decode_tokens,
                    );
                    all_evicted.extend(evicted);
                    self.metrics.record(&served);
                    out.push(served);
                }
            }
        }
        (out, all_evicted)
    }

    /// Serve a single request (the streaming path). Identical pipeline to a
    /// one-element queue: Alg.-5 scheduling of a singleton is the identity.
    pub(crate) fn serve_one(
        &mut self,
        req: &Request,
        corpus: &Corpus,
    ) -> (ServedRequest, Vec<RequestId>) {
        self.max_queue_depth = self.max_queue_depth.max(1);
        let (served, evicted) = match &mut self.pilot {
            Some(pilot) => {
                let o = pilot.process(req, corpus);
                let (served, evicted) = self.engine.serve(
                    &o.request,
                    &o.prompt,
                    corpus,
                    &self.quality,
                    self.decode_tokens,
                );
                pilot.on_evict(&evicted);
                (served, evicted)
            }
            None => self.engine.serve(
                req,
                &Prompt::baseline(req),
                corpus,
                &self.quality,
                self.decode_tokens,
            ),
        };
        self.metrics.record(&served);
        (served, evicted)
    }

    /// Telemetry snapshot (sorts the latency samples for percentiles).
    pub(crate) fn stats(&mut self) -> ShardStats {
        ShardStats {
            shard: self.id,
            served: self.metrics.len(),
            max_queue_depth: self.max_queue_depth,
            hit_ratio: self.metrics.hit_ratio(),
            p50_ttft: self.metrics.ttft.p50(),
            p99_ttft: self.metrics.ttft.p99(),
            index_nodes: self.pilot.as_ref().map_or(0, |p| p.index_size()),
            resident_tokens: self.engine.cache.resident_tokens(),
            sessions: self.engine.session_count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::costmodel::ModelSku;
    use crate::types::{BlockId, QueryId};

    fn req(id: u64, session: u32, ids: &[u32]) -> Request {
        Request {
            id: RequestId(id),
            session: SessionId(session),
            turn: 0,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(id),
        }
    }

    fn corpus() -> Corpus {
        use crate::corpus::CorpusConfig;
        use crate::tokenizer::Tokenizer;
        Corpus::generate(
            &CorpusConfig {
                n_docs: 40,
                ..Default::default()
            },
            &Tokenizer::default(),
        )
    }

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        for n in [1usize, 2, 5, 8, 64] {
            for s in 0..200u32 {
                let a = shard_of(SessionId(s), n);
                let b = shard_of(SessionId(s), n);
                assert_eq!(a, b);
                assert!(a < n);
            }
        }
    }

    #[test]
    fn shard_of_spreads_sequential_sessions() {
        let n = 8;
        let mut counts = vec![0usize; n];
        for s in 0..800u32 {
            counts[shard_of(SessionId(s), n)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!((50..200).contains(&c), "shard {i} got {c} of 800");
        }
    }

    #[test]
    fn queue_and_singleton_paths_agree() {
        let corpus = corpus();
        let cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        let batch = vec![req(1, 1, &[1, 2, 3]), req(2, 2, &[1, 2, 9])];
        let mut as_queue = Shard::new(0, &cfg);
        let (q, _) = as_queue.serve_queue(&batch, &corpus);
        let mut one_by_one = Shard::new(0, &cfg);
        // serve in the same execution order the queue chose
        for served in &q {
            let (s, _) = one_by_one.serve_one(&served.request, &corpus);
            assert_eq!(s.cached_tokens, served.cached_tokens);
            assert_eq!(s.prompt_tokens, served.prompt_tokens);
        }
    }

    #[test]
    fn baseline_shard_orders_by_longest_prefix() {
        let corpus = corpus();
        let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        cfg.pilot = None;
        let mut shard = Shard::new(0, &cfg);
        // warm the cache with {1,2,3}
        shard.serve_queue(&[req(1, 1, &[1, 2, 3])], &corpus);
        // a queue where the second request shares the cached prefix: LPM
        // must serve it first
        let (out, _) = shard.serve_queue(&[req(2, 2, &[7, 8]), req(3, 3, &[1, 2, 5])], &corpus);
        assert_eq!(out[0].request.id, RequestId(3));
        assert!(out[0].cached_tokens > 0);
    }

    #[test]
    fn stats_reflect_served_traffic() {
        let corpus = corpus();
        let cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        let mut shard = Shard::new(3, &cfg);
        let batch = vec![
            req(1, 1, &[1, 2, 3]),
            req(2, 2, &[1, 2, 9]),
            req(3, 3, &[4, 5]),
        ];
        shard.serve_queue(&batch, &corpus);
        let st = shard.stats();
        assert_eq!(st.shard, 3);
        assert_eq!(st.served, 3);
        assert_eq!(st.max_queue_depth, 3);
        assert_eq!(st.sessions, 3);
        assert!(st.index_nodes > 1, "pilot index should hold leaves");
        assert!(st.resident_tokens > 0);
        assert!(st.p99_ttft >= st.p50_ttft);
    }
}
