//! Concurrent sharded serving layer — the production-scale engine room of
//! the reproduction (ROADMAP north star; paper §4/Fig. 3 at serving
//! scale). Since the facade redesign it is **crate-private**: every
//! caller reaches it through [`crate::api::Server`], which owns the
//! session/ticket request lifecycle and the typed error surface; this
//! module keeps the sharding, placement, admission and tiering machinery.
//!
//! There is exactly **one** serving pipeline in the repo: the sequential
//! experiment runner ([`crate::experiments::runner`]) is a single-shard,
//! single-worker instance of this module, and every layer programs
//! against the [`crate::engine::InferenceEngine`] trait rather than a
//! concrete engine:
//!
//! ```text
//!   api::Server (sessions / tickets / typed errors — the front door)
//!        │ serve_batch / flush / submit_at / build_offline / on_evict
//!        ▼
//!   sched::Scheduler<E> ── long-lived per-shard scheduler loops
//!        │ waves become per-shard WaveJobs (bit-identical to the worker
//!        │ pool by construction); open-loop arrivals (submit_at) step
//!        │ chunk-by-chunk on each shard's run-queue clock with SLO-aware
//!        │ backpressure (queue_bound / deadline / OverloadPolicy);
//!        │ lifecycle: spawn / pause / resume / drain / shutdown
//!        ▼
//!   ServingEngine<E>  ── lock-striped Vec<Mutex<Shard<E>>> + worker pool
//!        │ placement::PlacementPolicy picks each session's first-turn
//!        │ shard (session-hash / round-robin / context-aware votes read
//!        │ from probe::ProbeDirectory — per-shard snapshots published at
//!        │ wave boundaries, zero shard locks on the probe path); later
//!        │ turns reuse the pin; queues preserve arrival order
//!        ▼
//!   Shard<E>          ── ContextPilot proxy + chunked-prefill admission
//!        │ serve(request, rewritten prompt)   ▲ evicted RequestIds (§4.1,
//!        ▼                                    │  final-discard only when
//!   trait InferenceEngine                     │  tiering is on)
//!        │
//!        ├──► SimEngine ── RadixCache (HBM tier)
//!        │        │  evict = demote ▼   ▲ promote @ reload cost
//!        │        └─── cache::TierStore (DRAM ⇄ SSD, --tiers)
//!        ├──► RealEngine (pjrt)
//!        └──► MockEngine (tests)
//! ```
//!
//! * **Sharding & placement** — each shard owns a full pipeline
//!   instance: a [`crate::pilot::ContextPilot`] (context index,
//!   conversation records) and an engine `E`. A session's **first-turn**
//!   shard is chosen by the configured [`placement::PlacementPolicy`]
//!   ([`ServeConfig::placement`], CLI `--placement session|rr|context`):
//!   the deterministic session hash ([`shard_of`], the default),
//!   round-robin spreading, or context-aware block-overlap voting against
//!   each shard's real context index with a least-loaded tie-break (§7.2
//!   / Table 6 routing, folded into this layer). Every later turn reuses
//!   the first-turn pin, whatever the policy — pinning keeps multi-turn
//!   history, §6 dedup records and §4.1 eviction callbacks shard-local,
//!   so no cross-shard coordination is ever needed on the hot path.
//!   Placement decisions happen at enqueue time, in arrival order, before
//!   workers run, so they are invariant in `n_workers`.
//! * **Probe fast path** — context-aware votes never lock shards: each
//!   shard publishes a probe snapshot (its index's distinct block set +
//!   cache residency) into the [`probe`] directory whenever its state
//!   mutates (end of a serve wave, offline build, eviction, checkpoint,
//!   restore), while already holding the shard lock. `probe_shards` then
//!   reads the directory under the placement lock — O(request blocks)
//!   lookups per shard (counted by `placement_probe_ops`), zero
//!   shard-lock acquisitions (`placement_probe_shard_locks` is a
//!   tripwire pinned at 0) — and decisions stay bit-identical because
//!   probes run at wave boundaries, where live state equals published
//!   state.
//! * **Lock striping** — the serving engine holds one mutex per shard;
//!   concurrent callers contend only when they hit the same shard.
//! * **Worker pool** — `serve_batch` partitions a batch
//!   into per-shard queues and drives them with
//!   [`crate::util::threadpool::par_map_tasks`] workers. Each queue runs
//!   the full pipeline (Alg.-1 search/insert, §5 alignment, §6 dedup,
//!   §5.3 annotation, Alg.-5 scheduling, engine serve, §4.1 eviction sync)
//!   in arrival order.
//! * **Continuous batching** — the facade no longer runs a flush
//!   barrier: [`sched`] keeps one long-lived scheduler loop per shard.
//!   Wave submissions arrive as per-shard jobs executed through the same
//!   per-shard wave body the worker pool uses
//!   (`ServingEngine::serve_shard_queue`), so batch results are
//!   bit-identical to the pre-scheduler path; open-loop arrivals
//!   ([`crate::api::Server::submit_at`]) are admitted mid-flight into
//!   per-shard run queues and their chunked prefills interleave with
//!   whatever is already active. Backpressure
//!   ([`ServeConfig::queue_bound`], [`ServeConfig::deadline`],
//!   [`OverloadPolicy`]) sheds or delays overload deterministically.
//! * **Chunked-prefill admission** — with [`ServeConfig::prefill_chunk`]
//!   set, a request whose uncached prefill exceeds the budget is split at
//!   radix-node boundaries and round-robined across its shard queue, so
//!   short requests are not head-of-line blocked behind giant prefills.
//!   Cache semantics are provably unchanged; only the queue-aware TTFT
//!   ([`crate::types::ServedRequest::queued_ttft`]) moves. Promoted
//!   (cold-tier) tokens count toward the chunkable region — they occupy
//!   the engine while loading, unlike hot hits. See [`admission`].
//! * **KV tiering** — with [`ServeConfig::tiers`] set (CLI `--tiers
//!   hbm=N,dram=N,ssd=N`), each shard's engine runs a
//!   [`crate::cache::TierStore`] behind its radix cache: capacity eviction
//!   *demotes* KV to DRAM (overflowing to SSD) instead of discarding it,
//!   and a later prefix match landing in a cold tier *promotes* at that
//!   tier's reload cost instead of re-prefilling. Admission and promotion
//!   are cost-gated ([`crate::cache::AdmissionPolicy::CostAware`]): spans
//!   cheaper to recompute than to reload are discarded, so demote-mode
//!   TTFT is never worse than discard-mode. §4.1 index pruning fires only
//!   on *final* discard (content in a cold tier is still servable).
//!   Per-request hit tokens split hot/warm/cold
//!   ([`crate::types::TierHits`], [`crate::metrics::ShardStats`]).
//! * **Durability** — on the durable path
//!   ([`crate::api::ServerBuilder::state_dir`]) each shard's SSD shelf is
//!   write-through mirrored into a [`crate::cache::Storage`] backend
//!   ([`ServeConfig::sim_engine_with_storage`]), and
//!   `ServingEngine::checkpoint_snapshot` spills every resident span cold
//!   and captures the warm state (context indices, placement book,
//!   request ownership) as one versioned JSON value that
//!   `restore_snapshot` rehydrates all-or-nothing on resume. Pinned
//!   end-to-end by `rust/tests/recovery.rs`.
//! * **Determinism** — shard state (including the tier store) is
//!   session-local and queues preserve arrival order, so hit/miss results
//!   and the hot/warm/cold split are independent of `n_workers` (and of
//!   `prefill_chunk`) and equal a single-shard ground-truth run of the
//!   same queue (pinned by `rust/tests/serve_stress.rs` and
//!   `rust/tests/engine_trait.rs`).
//! * **Observability** — every shard shares an always-on
//!   [`crate::obs::Registry`] of atomic counters, and with
//!   [`ServeConfig::obs`]`.trace` set additionally owns a
//!   [`crate::obs::Tracer`] that stamps per-request lifecycle events
//!   (`admitted → placed → queued → prefill_chunk* → tier* → resolved`,
//!   plus `storage` flushes) on the same virtual clock the admission
//!   simulator runs on. Because placement and queue order are decided
//!   before workers run, the merged trace is bit-identical across worker
//!   counts (pinned by `rust/tests/obs.rs`); with tracing off the serving
//!   hot path allocates nothing extra.
//!
//! ```text
//!   ServingEngine ── obs::Registry (atomic counters, always on)
//!        └─ Shard ── obs::Tracer (virtual-clock events, --trace-out)
//!                      └─► obs::export (chrome_trace / run_telemetry)
//! ```
//!
//! Per-shard hit rate, tier residency, placement/affinity counters, queue
//! depth and latency percentiles surface through
//! [`crate::metrics::ShardStats`]; `benches/bench_serving.rs` reports
//! whole-batch throughput across worker counts and chunk settings
//! (`BENCH_serving.json`), `benches/bench_tiering.rs` sweeps HBM capacity
//! x tier config (`BENCH_tiering.json`), and `benches/bench_routing.rs`
//! sweeps placement x shards x workers on the recurring-context workload
//! (`BENCH_routing.json`).

pub mod admission;
mod engine;
pub mod placement;
mod probe;
pub mod sched;
mod shard;

pub(crate) use engine::{shard_guard, ServingEngine};
pub use placement::{PlacementKind, PlacementPolicy, ShardProbe};
pub use sched::OverloadPolicy;
pub use shard::shard_of;

use std::collections::HashMap;

use crate::cache::{Storage, StorageError, TierConfig};
use crate::obs::ObsConfig;
use crate::engine::costmodel::{CostProfile, ModelSku};
use crate::engine::sim::{ReusePolicy, SimEngine};
use crate::pilot::PilotConfig;
use crate::quality::ModelEra;
use crate::types::RequestId;

/// Knobs of the sharded serving layer.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Independent shards; each owns a context index, a radix prefix cache
    /// and an engine. Sessions are pinned to shards.
    pub n_shards: usize,
    /// Worker threads driving shard queues in parallel.
    pub n_workers: usize,
    /// KV budget per shard, in tokens.
    pub capacity_tokens: usize,
    /// Engine latency model.
    pub profile: CostProfile,
    /// Engine reuse mechanism under test.
    pub policy: ReusePolicy,
    /// ContextPilot proxy configuration; `None` serves baseline prompts
    /// (engine-only, LPM-ordered within each shard queue when the engine
    /// prefers it).
    pub pilot: Option<PilotConfig>,
    pub era: ModelEra,
    pub multi_hop: bool,
    pub decode_tokens: usize,
    /// Chunked-prefill admission budget in tokens: requests whose uncached
    /// prefill exceeds this are split at radix-node boundaries and
    /// interleaved across their shard queue ([`admission`]). `None`
    /// disables chunking (monolithic prefills, FIFO accounting).
    pub prefill_chunk: Option<usize>,
    /// Per-request decode-length overrides (trace replay); requests not in
    /// the map use `decode_tokens`.
    pub decode_override: Option<HashMap<RequestId, usize>>,
    /// Per-shard DRAM/SSD tier store behind the radix cache (CLI
    /// `--tiers`): eviction demotes instead of discarding, cold-tier
    /// prefix matches promote at reload cost. `None` = classic discard
    /// eviction. Only effective for the radix reuse policy.
    pub tiers: Option<TierConfig>,
    /// First-turn session → shard placement policy (CLI `--placement`):
    /// session hash (default, the pre-placement behaviour bit-for-bit),
    /// round-robin, or context-aware block-overlap voting over the real
    /// per-shard index/cache state. See [`placement`].
    pub placement: PlacementKind,
    /// Observability knobs ([`crate::obs`], CLI `--trace-out`): with
    /// `obs.trace` set each shard records lifecycle events on its virtual
    /// clock into a bounded ring buffer. Off by default — the disabled
    /// path allocates nothing and serving output is bit-identical.
    pub obs: ObsConfig,
    /// Backpressure: per-shard run-queue bound for open-loop arrivals
    /// (CLI `--queue-bound`). An arrival that would push a shard's active
    /// queue past the bound is handled per [`ServeConfig::on_overload`].
    /// `None` (default) = unbounded. Wave submissions
    /// ([`crate::api::Server::serve_batch`]) are never bounded.
    pub queue_bound: Option<usize>,
    /// Backpressure: admission deadline in simulated seconds (CLI
    /// `--deadline`). An open-loop arrival whose queueing delay (shard
    /// clock minus virtual arrival time) already exceeds this at
    /// admission is shed regardless of [`ServeConfig::on_overload`] —
    /// its SLO is unrecoverable. `None` (default) = no deadline.
    pub deadline: Option<f64>,
    /// What the scheduler does with an arrival that hits
    /// [`ServeConfig::queue_bound`] (CLI `--overload shed|delay`). See
    /// [`OverloadPolicy`]. Inert unless a bound is set.
    pub on_overload: OverloadPolicy,
}

impl ServeConfig {
    /// Defaults mirroring [`crate::experiments::RunConfig`]: radix reuse,
    /// ContextPilot on, modern era.
    pub fn new(sku: ModelSku) -> ServeConfig {
        ServeConfig {
            n_shards: 4,
            n_workers: crate::util::threadpool::default_threads(),
            capacity_tokens: 60_000,
            profile: sku.profile(),
            policy: ReusePolicy::RadixPrefix,
            pilot: Some(PilotConfig::default()),
            era: ModelEra::Modern,
            multi_hop: false,
            decode_tokens: 32,
            prefill_chunk: None,
            decode_override: None,
            tiers: None,
            placement: PlacementKind::SessionHash,
            obs: ObsConfig::default(),
            queue_bound: None,
            deadline: None,
            on_overload: OverloadPolicy::Shed,
        }
    }

    /// The default engine for this config: a [`SimEngine`] built from the
    /// profile / reuse policy / per-shard KV budget (plus the tier store
    /// when configured). The factory behind
    /// [`crate::api::ServerBuilder::build`] and the one place the serving
    /// layer names the concrete simulated engine.
    pub fn sim_engine(&self) -> SimEngine {
        match &self.tiers {
            Some(t) => SimEngine::with_tiers(self.profile, self.policy, self.capacity_tokens, t),
            None => SimEngine::new(self.profile, self.policy, self.capacity_tokens),
        }
    }

    /// Like [`ServeConfig::sim_engine`], but the cold (SSD) shelf is
    /// mirrored into `store` — the durable path behind
    /// [`crate::api::ServerBuilder::state_dir`]. `rehydrate` re-seeds the
    /// shelf from whatever the backend already holds (resume). Without a
    /// tier config there is no cold shelf to mirror: the store is dropped
    /// and only the warm-state snapshot carries across restarts.
    pub fn sim_engine_with_storage(
        &self,
        store: Box<dyn Storage>,
        rehydrate: bool,
    ) -> Result<SimEngine, StorageError> {
        match &self.tiers {
            Some(t) => SimEngine::with_tiers_storage(
                self.profile,
                self.policy,
                self.capacity_tokens,
                t,
                store,
                rehydrate,
            ),
            None => Ok(SimEngine::new(self.profile, self.policy, self.capacity_tokens)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        assert!(cfg.n_shards >= 1);
        assert!(cfg.n_workers >= 1);
        assert!(cfg.pilot.is_some());
        assert!(cfg.capacity_tokens > 0);
        assert!(cfg.prefill_chunk.is_none());
        assert!(cfg.decode_override.is_none());
        assert!(cfg.tiers.is_none());
        assert_eq!(cfg.placement, PlacementKind::SessionHash);
        assert!(!cfg.obs.trace, "tracing must default off");
        assert!(cfg.queue_bound.is_none(), "backpressure must default off");
        assert!(cfg.deadline.is_none());
        assert_eq!(cfg.on_overload, OverloadPolicy::Shed);
    }

    #[test]
    fn engine_and_config_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ServeConfig>();
        assert_send_sync::<ServingEngine>();
    }

    #[test]
    fn sim_engine_factory_respects_config() {
        let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        cfg.capacity_tokens = 1234;
        let engine = cfg.sim_engine();
        assert_eq!(engine.cache.capacity(), 1234);
        assert!(!engine.cache.demotion_enabled());
    }

    #[test]
    fn sim_engine_factory_wires_tier_store() {
        let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        cfg.capacity_tokens = 1234;
        cfg.tiers = Some(TierConfig::new(10_000, 40_000));
        let engine = cfg.sim_engine();
        assert_eq!(engine.cache.capacity(), 1234, "hbm budget unchanged");
        assert!(engine.cache.demotion_enabled());
    }
}
