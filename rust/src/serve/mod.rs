//! Concurrent sharded serving layer — the production-scale front of the
//! reproduction (ROADMAP north star; paper §4/Fig. 3 at serving scale).
//!
//! The single-threaded pipeline ([`crate::pilot`] → [`crate::engine::sim`])
//! serves one request at a time. This module scales it out while keeping
//! every result bit-identical to the sequential pipeline:
//!
//! * **Sharding** — sessions are pinned to shards by a deterministic hash
//!   ([`shard_of`]). Each [`Shard`] owns a full pipeline instance: a
//!   [`crate::pilot::ContextPilot`] (context index, conversation records)
//!   and a [`crate::engine::sim::SimEngine`] (radix prefix cache, history).
//!   Pinning keeps multi-turn history, §6 dedup records and §4.1 eviction
//!   callbacks shard-local, so no cross-shard coordination is ever needed
//!   on the hot path.
//! * **Lock striping** — the [`ServingEngine`] holds one mutex per shard;
//!   concurrent callers contend only when they hit the same shard.
//! * **Worker pool** — [`ServingEngine::serve_batch`] partitions a batch
//!   into per-shard queues and drives them with
//!   [`crate::util::threadpool::par_map_tasks`] workers. Each queue runs
//!   the full pipeline (Alg.-1 search/insert, §5 alignment, §6 dedup,
//!   §5.3 annotation, Alg.-5 scheduling, engine serve, §4.1 eviction sync)
//!   in arrival order.
//! * **Determinism** — shard state is session-local and queues preserve
//!   arrival order, so hit/miss results are independent of `n_workers`
//!   and equal to a single-shard ground-truth run of the same queue
//!   (pinned by `rust/tests/serve_stress.rs`).
//!
//! Per-shard hit rate, queue depth and latency percentiles surface through
//! [`crate::metrics::ShardStats`]; `benches/bench_serving.rs` reports
//! whole-batch throughput across worker counts.

mod engine;
mod shard;

pub use engine::ServingEngine;
pub use shard::{shard_of, Shard};

use crate::engine::costmodel::{CostProfile, ModelSku};
use crate::engine::sim::ReusePolicy;
use crate::pilot::PilotConfig;
use crate::quality::ModelEra;

/// Knobs of the sharded serving layer.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Independent shards; each owns a context index, a radix prefix cache
    /// and an engine. Sessions are pinned to shards.
    pub n_shards: usize,
    /// Worker threads driving shard queues in parallel.
    pub n_workers: usize,
    /// KV budget per shard, in tokens.
    pub capacity_tokens: usize,
    /// Engine latency model.
    pub profile: CostProfile,
    /// Engine reuse mechanism under test.
    pub policy: ReusePolicy,
    /// ContextPilot proxy configuration; `None` serves baseline prompts
    /// (engine-only, LPM-ordered within each shard queue).
    pub pilot: Option<PilotConfig>,
    pub era: ModelEra,
    pub multi_hop: bool,
    pub decode_tokens: usize,
}

impl ServeConfig {
    /// Defaults mirroring [`crate::experiments::RunConfig`]: radix reuse,
    /// ContextPilot on, modern era.
    pub fn new(sku: ModelSku) -> ServeConfig {
        ServeConfig {
            n_shards: 4,
            n_workers: crate::util::threadpool::default_threads(),
            capacity_tokens: 60_000,
            profile: sku.profile(),
            policy: ReusePolicy::RadixPrefix,
            pilot: Some(PilotConfig::default()),
            era: ModelEra::Modern,
            multi_hop: false,
            decode_tokens: 32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        assert!(cfg.n_shards >= 1);
        assert!(cfg.n_workers >= 1);
        assert!(cfg.pilot.is_some());
        assert!(cfg.capacity_tokens > 0);
    }

    #[test]
    fn engine_and_config_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ServeConfig>();
        assert_send_sync::<ServingEngine>();
    }
}
