//! Reuse-aware shard placement (§7.2 / Table 6 context-aware routing,
//! folded into the serving layer).
//!
//! Sessions must be *pinned* to shards — multi-turn history, §6 dedup
//! records and §4.1 eviction callbacks are shard-local — but the choice of
//! the **first-turn** shard is a policy decision, and it is exactly where
//! ContextPilot's cross-user reuse meets multi-worker scale: two users
//! sharing a RAG corpus only share KV if their sessions land on the same
//! shard. A blind session hash scatters them; the paper's context-aware
//! routing sends recurring context blocks to the shard already holding
//! their KV.
//!
//! The [`PlacementPolicy`] trait captures that decision point:
//!
//! * [`SessionHash`] — the classic [`crate::serve::shard_of`] hash
//!   (default; reproduces pre-placement behaviour bit-for-bit).
//! * [`RoundRobin`] — new sessions cycle over shards (vanilla load
//!   spreading, the Table 6 baseline).
//! * [`ContextAware`] — block-overlap voting with a least-loaded
//!   tie-break, lifted from the retired `engine::Router` but probing the
//!   **real** per-shard state ([`ShardProbe`]: context-index block
//!   overlap + prefix-cache residency) instead of a shadow block-home
//!   map, so votes stay synchronized with §4.1 eviction pruning. Within
//!   one admission wave — where placed requests have not reached their
//!   shard's index yet — a wave-local block-home overlay supplies the
//!   votes; it is cleared at every wave boundary precisely so it can
//!   never go stale the way the router's persistent map could.
//!
//! Placement happens at **enqueue time**, deterministically, in arrival
//! order, before any worker runs — so hit/miss results stay invariant in
//! `n_workers` for every policy (pinned by `rust/tests/placement.rs`).
//! Later turns of a session always reuse the first-turn pin, whatever the
//! policy decided.

use std::collections::{HashMap, HashSet};

use crate::serve::shard::shard_of;
use crate::types::{BlockId, Request, RequestId, ServedRequest, SessionId};
use crate::util::json::Json;

/// Which placement policy the serving layer runs (CLI `--placement`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementKind {
    /// Deterministic session hash ([`crate::serve::shard_of`]) — the
    /// pre-placement behaviour, bit-for-bit.
    SessionHash,
    /// New sessions cycle over shards in arrival order.
    RoundRobin,
    /// Block-overlap voting against each shard's real context index,
    /// least-loaded tie-break.
    ContextAware,
}

impl PlacementKind {
    /// Parse the CLI shape: `session` | `rr` | `context`. Unknown names
    /// are an [`crate::api::Error::InvalidConfig`], so CLI argument
    /// errors flow through the same typed error surface as builder
    /// validation.
    pub fn parse(s: &str) -> Result<PlacementKind, crate::api::Error> {
        match s.to_ascii_lowercase().as_str() {
            "session" | "session-hash" | "hash" => Ok(PlacementKind::SessionHash),
            "rr" | "round-robin" | "roundrobin" => Ok(PlacementKind::RoundRobin),
            "context" | "context-aware" | "aware" => Ok(PlacementKind::ContextAware),
            other => Err(crate::api::Error::InvalidConfig(format!(
                "unknown placement '{other}' (try session | rr | context)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementKind::SessionHash => "session-hash",
            PlacementKind::RoundRobin => "round-robin",
            PlacementKind::ContextAware => "context-aware",
        }
    }
}

impl std::fmt::Display for PlacementKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One shard's state snapshot at a placement decision, probed from the
/// live shard (not a shadow map): how much of the request's context its
/// pilot index knows, how full its prefix cache is, and how much work
/// placement has already sent it.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardProbe {
    pub shard: usize,
    /// Blocks of the request's context present in this shard's context
    /// index (side-effect-free probe,
    /// [`crate::index::tree::ContextIndex::known_blocks`]); 0 for shards
    /// serving baseline prompts without a pilot.
    pub index_blocks: usize,
    /// Tokens resident in the shard engine's prefix cache
    /// ([`crate::engine::CacheStats::resident_tokens`]).
    pub resident_tokens: usize,
    /// Requests placed on this shard so far (pinned turns included) — the
    /// load signal for tie-breaking.
    pub placed_requests: usize,
}

/// Outcome of placing one first-turn session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub shard: usize,
    /// True when the shard won by context affinity (a positive block
    /// vote), not by load balancing — what the affinity-hit-token
    /// telemetry attributes reuse to.
    pub affinity: bool,
}

/// The first-turn shard choice. Implementations must be deterministic in
/// the sequence of `place` calls (arrival order): the serving layer
/// guarantees it never calls `place` concurrently or out of order.
pub trait PlacementPolicy: Send {
    fn kind(&self) -> PlacementKind;

    /// Whether `place` wants real shard probes (index/cache state). Cheap
    /// policies skip the per-shard probing pass entirely.
    fn needs_probes(&self) -> bool {
        false
    }

    /// Choose a shard for the first request of a session. `probes` holds
    /// one entry per shard, in shard order.
    fn place(&mut self, req: &Request, probes: &[ShardProbe]) -> Placement;

    /// Wave boundary: the serving layer starts a new admission wave
    /// (batch) or a streaming singleton. Wave-local state resets here.
    fn begin_wave(&mut self) {}

    /// Durable cross-wave state, for checkpoint/restore. Only
    /// [`RoundRobin`] has any (its cursor); wave-local state like
    /// [`ContextAware`]'s block-home overlay is cleared at every wave
    /// boundary and must NOT be snapshotted.
    fn snapshot_state(&self) -> u64 {
        0
    }

    /// Restore [`PlacementPolicy::snapshot_state`]. Policies without
    /// durable state ignore it, which also makes restoring a snapshot
    /// taken under a *different* configured policy well-defined: the pins
    /// are policy-independent, the foreign counter is dropped.
    fn restore_state(&mut self, _state: u64) {}
}

/// Today's behaviour, verbatim: [`shard_of`] on the session id.
pub struct SessionHash;

impl PlacementPolicy for SessionHash {
    fn kind(&self) -> PlacementKind {
        PlacementKind::SessionHash
    }

    fn place(&mut self, req: &Request, probes: &[ShardProbe]) -> Placement {
        Placement {
            shard: shard_of(req.session, probes.len()),
            affinity: false,
        }
    }
}

/// Vanilla spreading: new sessions cycle over shards in arrival order.
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { next: 0 }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        RoundRobin::new()
    }
}

impl PlacementPolicy for RoundRobin {
    fn kind(&self) -> PlacementKind {
        PlacementKind::RoundRobin
    }

    fn place(&mut self, _req: &Request, probes: &[ShardProbe]) -> Placement {
        let shard = self.next % probes.len().max(1);
        self.next = (self.next + 1) % probes.len().max(1);
        Placement {
            shard,
            affinity: false,
        }
    }

    fn snapshot_state(&self) -> u64 {
        self.next as u64
    }

    fn restore_state(&mut self, state: u64) {
        self.next = state as usize;
    }
}

/// ContextPilot's §7.2 routing at the placement layer: each shard's vote
/// is the number of the request's blocks its real context index already
/// holds, plus the blocks placed onto it earlier in the *current wave*
/// (those requests have not been served yet, so the index cannot know
/// them). Highest vote wins; ties — and the no-affinity case — fall back
/// to least-loaded (fewest placed requests, then fewest resident cache
/// tokens, then lowest shard id).
pub struct ContextAware {
    /// block → shard chosen earlier in this wave (cleared per wave, so it
    /// can never drift from the real index across waves).
    wave_home: HashMap<BlockId, usize>,
}

impl ContextAware {
    pub fn new() -> ContextAware {
        ContextAware {
            wave_home: HashMap::new(),
        }
    }
}

impl Default for ContextAware {
    fn default() -> Self {
        ContextAware::new()
    }
}

impl PlacementPolicy for ContextAware {
    fn kind(&self) -> PlacementKind {
        PlacementKind::ContextAware
    }

    fn needs_probes(&self) -> bool {
        true
    }

    fn place(&mut self, req: &Request, probes: &[ShardProbe]) -> Placement {
        let mut votes = vec![0usize; probes.len()];
        for p in probes {
            votes[p.shard] = p.index_blocks;
        }
        for b in &req.context {
            if let Some(&s) = self.wave_home.get(b) {
                votes[s] += 1;
            }
        }
        // highest vote wins; the no-affinity case degenerates to the same
        // least-loaded rule over every shard (all votes equal at 0)
        let max = votes.iter().copied().max().unwrap_or(0);
        let shard = probes
            .iter()
            .filter(|p| votes[p.shard] == max)
            .min_by_key(|p| (p.placed_requests, p.resident_tokens, p.shard))
            .map_or(0, |p| p.shard);
        for b in &req.context {
            self.wave_home.insert(*b, shard);
        }
        Placement {
            shard,
            affinity: max > 0,
        }
    }

    fn begin_wave(&mut self) {
        self.wave_home.clear();
    }
}

fn build_policy(kind: PlacementKind) -> Box<dyn PlacementPolicy> {
    match kind {
        PlacementKind::SessionHash => Box::new(SessionHash),
        PlacementKind::RoundRobin => Box::new(RoundRobin::new()),
        PlacementKind::ContextAware => Box::new(ContextAware::new()),
    }
}

struct Pin {
    shard: usize,
    affinity: bool,
}

/// The serving engine's placement ledger: the policy plus the session →
/// shard pins and the per-shard placement/affinity telemetry. One per
/// serving engine, behind its own mutex, always locked
/// *before* any shard mutex (strict placement → shard lock order).
///
/// Pins (one entry per session) and the counted-request-id set (one per
/// request) are never dropped — the same retention trade-off as the
/// request → shard eviction map (a retention bound is the first thing to
/// add if this layer ever fronts an unbounded stream).
pub(crate) struct PlacementBook {
    policy: Box<dyn PlacementPolicy>,
    pins: HashMap<SessionId, Pin>,
    /// Request ids already counted into `placed_requests`, so a request
    /// that flows through placement twice — once in `build_offline`, once
    /// when actually served — contributes to the load signal exactly once.
    counted: HashSet<RequestId>,
    placed_requests: Vec<usize>,
    placed_sessions: Vec<usize>,
    affinity_hit_tokens: Vec<u64>,
}

impl PlacementBook {
    pub(crate) fn new(kind: PlacementKind, n_shards: usize) -> PlacementBook {
        PlacementBook {
            policy: build_policy(kind),
            pins: HashMap::new(),
            counted: HashSet::new(),
            placed_requests: vec![0; n_shards],
            placed_sessions: vec![0; n_shards],
            affinity_hit_tokens: vec![0; n_shards],
        }
    }

    /// The shard this session is pinned to, if it has been placed.
    pub(crate) fn pinned(&self, session: SessionId) -> Option<usize> {
        self.pins.get(&session).map(|p| p.shard)
    }

    /// Whether the next unpinned `assign` wants real shard probes.
    pub(crate) fn wants_probe(&self, session: SessionId) -> bool {
        self.policy.needs_probes() && !self.pins.contains_key(&session)
    }

    pub(crate) fn begin_wave(&mut self) {
        self.policy.begin_wave();
    }

    /// Place one request: pinned sessions reuse their first-turn shard;
    /// unpinned sessions go through the policy (with `probes`, or
    /// load-only synthetic probes when the policy does not need real
    /// ones) and are pinned to its choice.
    pub(crate) fn assign(&mut self, req: &Request, probes: Option<&[ShardProbe]>) -> usize {
        self.assign_placed(req, probes).shard
    }

    /// [`PlacementBook::assign`] that also reports *how* the shard was
    /// chosen: pinned sessions return their first-turn placement (shard +
    /// affinity flag) so the tracing layer can stamp `placed` events with
    /// the affinity attribution every turn.
    pub(crate) fn assign_placed(
        &mut self,
        req: &Request,
        probes: Option<&[ShardProbe]>,
    ) -> Placement {
        if let Some(pin) = self.pins.get(&req.session) {
            let placed = Placement {
                shard: pin.shard,
                affinity: pin.affinity,
            };
            if self.counted.insert(req.id) {
                self.placed_requests[placed.shard] += 1;
            }
            return placed;
        }
        let owned: Vec<ShardProbe>;
        let probes = match probes {
            Some(p) => p,
            None => {
                owned = self.load_probes();
                &owned
            }
        };
        let placed = self.policy.place(req, probes);
        debug_assert!(placed.shard < self.placed_requests.len());
        self.pins.insert(
            req.session,
            Pin {
                shard: placed.shard,
                affinity: placed.affinity,
            },
        );
        self.placed_sessions[placed.shard] += 1;
        if self.counted.insert(req.id) {
            self.placed_requests[placed.shard] += 1;
        }
        placed
    }

    /// Load-only probes (no shard locks) for policies that do not inspect
    /// index/cache state.
    pub(crate) fn load_probes(&self) -> Vec<ShardProbe> {
        self.placed_requests
            .iter()
            .enumerate()
            .map(|(shard, &placed_requests)| ShardProbe {
                shard,
                index_blocks: 0,
                resident_tokens: 0,
                placed_requests,
            })
            .collect()
    }

    /// Requests placed on this shard so far (for probe construction).
    pub(crate) fn placed_requests_on(&self, shard: usize) -> usize {
        self.placed_requests[shard]
    }

    /// Attribute served reuse to affinity placements: cached tokens of
    /// requests whose session was placed by a positive context vote.
    pub(crate) fn record_served(&mut self, served: &[ServedRequest]) {
        for s in served {
            if let Some(pin) = self.pins.get(&s.request.session) {
                if pin.affinity {
                    self.affinity_hit_tokens[pin.shard] += s.cached_tokens as u64;
                }
            }
        }
    }

    pub(crate) fn placed_sessions(&self) -> &[usize] {
        &self.placed_sessions
    }

    pub(crate) fn affinity_hit_tokens(&self) -> &[u64] {
        &self.affinity_hit_tokens
    }

    // ---------------------------------------------------------------------
    // snapshot / restore (durability)
    // ---------------------------------------------------------------------

    /// Serialize every durable ledger: pins (with their affinity flag),
    /// the counted-request set, the per-shard counters, and the policy's
    /// cross-wave state. Hash-set iteration order is canonicalized by
    /// sorting, so identical books snapshot to identical strings.
    pub(crate) fn to_snapshot(&self) -> Json {
        let mut pins: Vec<(u32, usize, bool)> = self
            .pins
            .iter()
            .map(|(s, p)| (s.0, p.shard, p.affinity))
            .collect();
        pins.sort_unstable();
        let mut counted: Vec<u64> = self.counted.iter().map(|r| r.0).collect();
        counted.sort_unstable();
        Json::obj(vec![
            ("policy", Json::str(self.policy.kind().name())),
            ("policy_state", Json::u64(self.policy.snapshot_state())),
            (
                "pins",
                Json::Arr(
                    pins.into_iter()
                        .map(|(s, shard, affinity)| {
                            Json::Arr(vec![
                                Json::Num(s as f64),
                                Json::Num(shard as f64),
                                Json::Bool(affinity),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "counted",
                Json::Arr(counted.into_iter().map(Json::u64).collect()),
            ),
            (
                "placed_requests",
                Json::Arr(self.placed_requests.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            (
                "placed_sessions",
                Json::Arr(self.placed_sessions.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            (
                "affinity_hit_tokens",
                Json::Arr(self.affinity_hit_tokens.iter().map(|&n| Json::u64(n)).collect()),
            ),
        ])
    }

    /// Rebuild a book under the *configured* policy `kind` (which may
    /// differ from the snapshot's — pins are policy-independent, and a
    /// foreign policy counter is dropped by the default
    /// [`PlacementPolicy::restore_state`]). Pins to shards the resumed
    /// server does not have are a structural error, never a panic.
    pub(crate) fn from_snapshot(
        kind: PlacementKind,
        n_shards: usize,
        j: &Json,
    ) -> Result<PlacementBook, String> {
        let mut book = PlacementBook::new(kind, n_shards);
        let snap_kind = j.get("policy").as_str().ok_or("placement policy missing")?;
        let state = j
            .get("policy_state")
            .as_u64()
            .ok_or("placement policy state missing")?;
        if snap_kind == kind.name() {
            book.policy.restore_state(state);
        }
        for pin in j.get("pins").as_arr().ok_or("pins missing")? {
            let p = pin.as_arr().filter(|p| p.len() == 3).ok_or("bad pin")?;
            let session = p[0]
                .as_usize()
                .filter(|&s| s <= u32::MAX as usize)
                .map(|s| SessionId(s as u32))
                .ok_or("bad pin session")?;
            let shard = p[1].as_usize().ok_or("bad pin shard")?;
            if shard >= n_shards {
                return Err(format!(
                    "pin to shard {shard}, but the resumed server has {n_shards}"
                ));
            }
            let affinity = p[2].as_bool().ok_or("bad pin affinity flag")?;
            if book.pins.insert(session, Pin { shard, affinity }).is_some() {
                return Err(format!("session {} pinned twice", session.0));
            }
        }
        for r in j.get("counted").as_arr().ok_or("counted set missing")? {
            book.counted
                .insert(RequestId(r.as_u64().ok_or("bad counted request id")?));
        }
        for (name, dst) in [
            ("placed_requests", &mut book.placed_requests),
            ("placed_sessions", &mut book.placed_sessions),
        ] {
            let arr = j.get(name).as_arr().ok_or_else(|| format!("{name} missing"))?;
            if arr.len() != n_shards {
                return Err(format!(
                    "{name} has {} shards, the resumed server {n_shards}",
                    arr.len()
                ));
            }
            *dst = arr
                .iter()
                .map(Json::as_usize)
                .collect::<Option<Vec<usize>>>()
                .ok_or_else(|| format!("bad {name} counter"))?;
        }
        let hits = j
            .get("affinity_hit_tokens")
            .as_arr()
            .ok_or("affinity_hit_tokens missing")?;
        if hits.len() != n_shards {
            return Err(format!(
                "affinity_hit_tokens has {} shards, the resumed server {n_shards}",
                hits.len()
            ));
        }
        book.affinity_hit_tokens = hits
            .iter()
            .map(Json::as_u64)
            .collect::<Option<Vec<u64>>>()
            .ok_or("bad affinity_hit_tokens counter")?;
        Ok(book)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{QueryId, RequestId};

    fn req(id: u64, session: u32, ids: &[u32]) -> Request {
        Request {
            id: RequestId(id),
            session: SessionId(session),
            turn: 0,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(id),
        }
    }

    fn probes(n: usize) -> Vec<ShardProbe> {
        (0..n)
            .map(|shard| ShardProbe {
                shard,
                ..ShardProbe::default()
            })
            .collect()
    }

    #[test]
    fn parse_cli_spec() {
        assert_eq!(
            PlacementKind::parse("session").unwrap(),
            PlacementKind::SessionHash
        );
        assert_eq!(PlacementKind::parse("rr").unwrap(), PlacementKind::RoundRobin);
        assert_eq!(
            PlacementKind::parse("Context-Aware").unwrap(),
            PlacementKind::ContextAware
        );
        assert!(matches!(
            PlacementKind::parse("nearest"),
            Err(crate::api::Error::InvalidConfig(msg)) if msg.contains("nearest")
        ));
    }

    #[test]
    fn session_hash_matches_shard_of() {
        let mut p = SessionHash;
        for s in 0..200u32 {
            let placed = p.place(&req(s as u64, s, &[1]), &probes(5));
            assert_eq!(placed.shard, shard_of(SessionId(s), 5));
            assert!(!placed.affinity);
        }
    }

    #[test]
    fn round_robin_cycles_over_new_sessions() {
        let mut p = RoundRobin::new();
        let shards: Vec<usize> = (0..8)
            .map(|i| p.place(&req(i, i as u32, &[1]), &probes(4)).shard)
            .collect();
        assert_eq!(shards, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn context_aware_votes_follow_index_probes() {
        let mut p = ContextAware::new();
        let mut ps = probes(4);
        ps[2].index_blocks = 3; // shard 2 already holds 3 of the blocks
        let placed = p.place(&req(1, 1, &[5, 6, 7]), &ps);
        assert_eq!(placed.shard, 2);
        assert!(placed.affinity);
    }

    #[test]
    fn context_aware_groups_a_wave_before_any_serve() {
        // all-new shards (empty indexes): the first session of a block
        // group lands by load, every later group member follows it via the
        // wave-local home map
        let mut p = ContextAware::new();
        p.begin_wave();
        let first = p.place(&req(1, 1, &[5, 6, 7]), &probes(4));
        assert!(!first.affinity, "empty indexes cannot vote");
        let mut ps = probes(4);
        for probe in ps.iter_mut() {
            probe.placed_requests = usize::from(probe.shard == first.shard);
        }
        let second = p.place(&req(2, 2, &[5, 6, 9]), &ps);
        assert_eq!(second.shard, first.shard, "group member not co-placed");
        assert!(second.affinity);
        // a fresh wave forgets the overlay (the real index takes over)
        p.begin_wave();
        let third = p.place(&req(3, 3, &[5, 6, 7]), &probes(4));
        assert!(!third.affinity, "wave overlay must not leak across waves");
    }

    #[test]
    fn context_aware_no_affinity_falls_back_to_least_loaded() {
        let mut p = ContextAware::new();
        let mut ps = probes(3);
        ps[0].placed_requests = 2;
        ps[1].placed_requests = 1;
        ps[2].placed_requests = 2;
        let placed = p.place(&req(1, 1, &[1]), &ps);
        assert_eq!(placed.shard, 1);
        assert!(!placed.affinity);
        // equal load: fewer resident cache tokens wins, then shard id
        let mut ps = probes(3);
        ps[0].resident_tokens = 500;
        assert_eq!(p.place(&req(2, 2, &[2]), &ps).shard, 1);
    }

    #[test]
    fn book_pins_sessions_and_counts_load() {
        let mut book = PlacementBook::new(PlacementKind::RoundRobin, 3);
        let a = book.assign(&req(1, 7, &[1]), None);
        let b = book.assign(&req(2, 7, &[2]), None); // same session, later turn
        assert_eq!(a, b, "session must stick to its first-turn shard");
        assert_eq!(book.pinned(SessionId(7)), Some(a));
        assert_eq!(book.pinned(SessionId(8)), None);
        assert_eq!(book.placed_requests_on(a), 2);
        assert_eq!(book.placed_sessions()[a], 1);
    }

    #[test]
    fn reassigning_the_same_request_counts_load_once() {
        // a request flows through placement twice when build_offline runs
        // before serving: the load signal must not double-count it
        let mut book = PlacementBook::new(PlacementKind::RoundRobin, 2);
        let r = req(5, 3, &[1]);
        let a = book.assign(&r, None); // offline-build pass
        let b = book.assign(&r, None); // serve pass (pinned)
        assert_eq!(a, b);
        assert_eq!(book.placed_requests_on(a), 1, "request double-counted");
        assert_eq!(book.placed_sessions()[a], 1);
    }

    #[test]
    fn book_attributes_affinity_hits() {
        use crate::types::{Prompt, TierHits};
        let mut book = PlacementBook::new(PlacementKind::ContextAware, 2);
        let warm = req(1, 1, &[1, 2]);
        book.assign(&warm, Some(&probes(2)));
        let mut ps = probes(2);
        ps[0].index_blocks = 2;
        let follow = req(2, 2, &[1, 2]);
        let s = book.assign(&follow, Some(&ps));
        assert_eq!(s, 0);
        let served = ServedRequest {
            prompt: Prompt::baseline(&follow),
            request: follow,
            prompt_tokens: 100,
            cached_tokens: 40,
            ttft: 0.1,
            wall: 0.2,
            quality: 0.5,
            queued_ttft: 0.1,
            prefill_chunks: 1,
            tier_hits: TierHits::hot(40),
        };
        book.record_served(std::slice::from_ref(&served));
        assert_eq!(book.affinity_hit_tokens(), &[40, 0]);
    }

    #[test]
    fn assign_placed_reports_affinity_on_every_turn() {
        let mut book = PlacementBook::new(PlacementKind::ContextAware, 2);
        book.assign(&req(1, 1, &[1, 2]), Some(&probes(2)));
        let mut ps = probes(2);
        ps[0].index_blocks = 2;
        let first = book.assign_placed(&req(2, 2, &[1, 2]), Some(&ps));
        assert!(first.affinity, "context vote should win");
        // a later turn of the same session replays the pinned placement,
        // affinity flag included, and agrees with plain assign
        let later = book.assign_placed(&req(3, 2, &[1, 2]), Some(&probes(2)));
        assert_eq!(later, first);
        assert_eq!(book.assign(&req(4, 2, &[1]), None), first.shard);
    }

    #[test]
    fn book_snapshot_restores_pins_counters_and_rr_cursor() {
        let mut book = PlacementBook::new(PlacementKind::RoundRobin, 3);
        for i in 0..5u64 {
            book.assign(&req(i, i as u32, &[1]), None);
        }
        let snap = book.to_snapshot();
        let restored =
            PlacementBook::from_snapshot(PlacementKind::RoundRobin, 3, &snap).unwrap();
        // identical ledgers snapshot to identical strings (canonical order)
        assert_eq!(restored.to_snapshot().to_string(), snap.to_string());
        for s in 0..5u32 {
            assert_eq!(restored.pins.get(&SessionId(s)).map(|p| p.shard),
                       book.pins.get(&SessionId(s)).map(|p| p.shard));
        }
        // the round-robin cursor resumed where it left off: the next NEW
        // session continues the cycle instead of restarting at shard 0
        let mut a = book;
        let mut b = restored;
        assert_eq!(
            a.assign(&req(90, 90, &[1]), None),
            b.assign(&req(90, 90, &[1]), None)
        );
        // already-counted requests stay counted after restore
        let before = b.placed_requests_on(0);
        b.assign(&req(0, 0, &[1]), None);
        assert_eq!(b.placed_requests_on(0), before, "request re-counted");
    }

    #[test]
    fn book_snapshot_rejects_foreign_shard_counts() {
        let mut book = PlacementBook::new(PlacementKind::SessionHash, 4);
        book.assign(&req(1, 1, &[1]), None);
        let snap = book.to_snapshot();
        // shrinking the shard count orphans pins: structural error
        let err = PlacementBook::from_snapshot(PlacementKind::SessionHash, 1, &snap);
        assert!(err.is_err(), "orphaned pin accepted");
        assert!(PlacementBook::from_snapshot(PlacementKind::SessionHash, 4, &Json::Null).is_err());
    }

    #[test]
    fn book_snapshot_across_policies_keeps_pins_drops_state() {
        let mut book = PlacementBook::new(PlacementKind::RoundRobin, 2);
        book.assign(&req(1, 1, &[1]), None);
        let pinned = book.pinned(SessionId(1)).unwrap();
        let restored =
            PlacementBook::from_snapshot(PlacementKind::SessionHash, 2, &book.to_snapshot())
                .unwrap();
        assert_eq!(restored.pinned(SessionId(1)), Some(pinned), "pin lost");
        assert_eq!(restored.policy.kind(), PlacementKind::SessionHash);
        assert_eq!(restored.policy.snapshot_state(), 0, "foreign state kept");
    }
}
