//! Lock-light placement probes: the `ProbeDirectory`.
//!
//! Context-aware placement (§7.2) votes with each shard's live state —
//! how many of the request's blocks the shard's context index knows, and
//! how full its prefix cache is. Reading that state used to mean taking
//! **every shard mutex in sequence while holding the global placement
//! lock**: correct (strict placement → shard order) but a whole-system
//! serialization point that scales with the fleet.
//!
//! The directory breaks that coupling with published snapshots:
//!
//! * [`ProbeDirectory::publish`] — called at every point a shard's index
//!   or cache mutates (end of a serve wave, offline build, eviction,
//!   checkpoint spill, snapshot restore), **while the shard lock is
//!   already held**, copying the index's inverted block directory
//!   ([`ContextIndex::copy_block_set_into`](crate::index::tree::ContextIndex::copy_block_set_into))
//!   and the engine's resident-token gauge into a per-shard entry.
//! * [`ProbeDirectory::probe`] — called under the placement lock, reads
//!   the entries instead of the shards: one directory lookup per distinct
//!   request block per shard, **zero shard-lock acquisitions**.
//!
//! Placement decisions stay bit-identical to probing the live shards:
//! probes run in `place_batch` before any worker touches a queue, so the
//! live state a lock-taking probe would observe is exactly the state the
//! last mutation published. Entry mutexes are strict leaves — publish
//! nests shard → entry, probe nests placement → entry, and no path holds
//! an entry lock while taking anything else — so the existing
//! placement → shard order is preserved trivially: the probe path no
//! longer touches shard locks at all.
//!
//! Probe work is counted deterministically in the [`crate::obs`]
//! registry: `placement_probe_ops` counts block lookups (proportional to
//! Σ request blocks × shards, *not* alive leaves), and
//! `placement_probe_shard_locks` is a tripwire pinned at zero by
//! `bench_routing` and CI — any future fallback that must lock a shard
//! from the probe path must bump it, making the regression measurable.

use std::collections::HashSet;
use std::sync::Mutex;

use crate::api::Error;
use crate::engine::iface::InferenceEngine;
use crate::obs::{Counter, Registry};
use crate::serve::engine::shard_guard;
use crate::serve::placement::{PlacementBook, ShardProbe};
use crate::serve::shard::Shard;
use crate::types::{BlockId, Context};

/// One shard's published probe state. `Default` — an empty block set and
/// zero resident tokens — is exactly a freshly built shard, so entries
/// need no construction-time publish.
#[derive(Default)]
struct ProbeSnapshot {
    /// The shard index's distinct known blocks at publish time.
    blocks: HashSet<BlockId>,
    /// The engine's HBM-resident token count at publish time.
    resident_tokens: usize,
}

/// Per-shard probe snapshots behind leaf mutexes (one per shard, locked
/// only for the duration of one copy or one read — never while holding
/// another entry).
pub(crate) struct ProbeDirectory {
    entries: Vec<Mutex<ProbeSnapshot>>,
}

impl ProbeDirectory {
    pub(crate) fn new(n_shards: usize) -> ProbeDirectory {
        ProbeDirectory {
            entries: (0..n_shards).map(|_| Mutex::default()).collect(),
        }
    }

    /// Refresh `shard`'s entry from its live state. The caller holds the
    /// shard lock (every call site mutated the shard just before), so the
    /// snapshot can never be newer or older than the state a lock-taking
    /// probe would have seen.
    pub(crate) fn publish<E: InferenceEngine>(&self, shard: &Shard<E>) -> Result<(), Error> {
        let mut snap = shard_guard(&self.entries[shard.id], "probe directory")?;
        snap.resident_tokens = shard.engine.cache_stats().resident_tokens;
        match &shard.pilot {
            Some(p) => p.index.copy_block_set_into(&mut snap.blocks),
            None => snap.blocks.clear(),
        }
        Ok(())
    }

    /// One placement decision's worth of probes: `context`'s distinct
    /// blocks against every shard's published block set, plus the
    /// published residency and the book's load telemetry. O(shards ×
    /// distinct context blocks); every lookup is counted under
    /// `placement_probe_ops`, and no shard lock is taken (the
    /// `placement_probe_shard_locks` tripwire stays zero).
    pub(crate) fn probe(
        &self,
        context: &Context,
        book: &PlacementBook,
        registry: &Registry,
    ) -> Result<Vec<ShardProbe>, Error> {
        let mut out = Vec::with_capacity(self.entries.len());
        for (s, entry) in self.entries.iter().enumerate() {
            let snap = shard_guard(entry, "probe directory")?;
            let mut ops = 0u64;
            let mut found = 0usize;
            for (i, b) in context.iter().enumerate() {
                if context[..i].contains(b) {
                    continue; // duplicate within the request: one lookup
                }
                ops += 1;
                if snap.blocks.contains(b) {
                    found += 1;
                }
            }
            registry.add(Counter::PlacementProbeOps, ops);
            out.push(ShardProbe {
                shard: s,
                index_blocks: found,
                resident_tokens: snap.resident_tokens,
                placed_requests: book.placed_requests_on(s),
            });
        }
        Ok(out)
    }
}
