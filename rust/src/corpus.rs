//! Synthetic document corpus substrate.
//!
//! Substitutes the paper's datasets (MultihopRAG / NarrativeQA / QASPER /
//! MT-RAG / LoCoMo / claw-tasks) per DESIGN.md §5: documents are built from
//! deterministic sentence lines with two sources of redundancy the paper
//! exploits —
//!
//!  * **cross-document shared facts**: a pool of "fact" sentences sampled
//!    into many documents (the Kennedy-death-date example of Fig. 2b),
//!    which is what content-defined-chunking dedup (§6) harvests;
//!  * **templated sections**: documents of the same template family start
//!    with identical boilerplate lines (contracts / filings / code repos).
//!
//! Text is deterministic in (seed, doc id, line no), so token sequences are
//! stable across processes — a requirement for prefix caching.

use crate::tokenizer::Tokenizer;
use crate::types::BlockId;
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub n_docs: usize,
    /// Shared fact pool size; smaller => more cross-doc duplication.
    pub fact_pool: usize,
    /// Lines per shared fact *paragraph* — real documents share multi-line
    /// spans (quoted passages, boilerplate sections), which is what
    /// content-defined-chunking dedup harvests.
    pub fact_lines: usize,
    /// Probability a line position starts a shared fact paragraph.
    pub shared_line_prob: f64,
    /// Number of template families; 0 disables boilerplate headers.
    pub templates: usize,
    /// Boilerplate lines per template.
    pub template_lines: usize,
    pub lines_per_doc: usize,
    pub words_per_line: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self {
            n_docs: 200,
            fact_pool: 64,
            fact_lines: 3,
            shared_line_prob: 0.12,
            templates: 4,
            template_lines: 4,
            lines_per_doc: 10,
            words_per_line: 12,
            seed: 7,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Doc {
    pub id: BlockId,
    pub lines: Vec<String>,
}

impl Doc {
    pub fn text(&self) -> String {
        self.lines.join("\n")
    }
}

#[derive(Clone, Debug)]
pub struct Corpus {
    pub docs: Vec<Doc>,
    token_counts: Vec<usize>,
}

fn sentence(rng: &mut Rng, words: usize, prefix: &str) -> String {
    let mut s = String::with_capacity(words * 6 + prefix.len());
    s.push_str(prefix);
    for _ in 0..words {
        s.push(' ');
        let len = rng.range(3, 9);
        for _ in 0..len {
            s.push((b'a' + rng.below(26) as u8) as char);
        }
    }
    s
}

impl Corpus {
    pub fn generate(cfg: &CorpusConfig, tokenizer: &Tokenizer) -> Corpus {
        let mut master = Rng::new(cfg.seed);

        // Shared fact paragraphs — identical wherever they appear.
        let facts: Vec<Vec<String>> = (0..cfg.fact_pool)
            .map(|f| {
                let mut r = master.fork(0x0FAC_0000 + f as u64);
                (0..cfg.fact_lines.max(1))
                    .map(|l| sentence(&mut r, cfg.words_per_line, &format!("fact{f}p{l}")))
                    .collect()
            })
            .collect();

        // Template boilerplate headers.
        let templates: Vec<Vec<String>> = (0..cfg.templates)
            .map(|t| {
                let mut r = master.fork(0x7E4C_0000 + t as u64);
                (0..cfg.template_lines)
                    .map(|l| sentence(&mut r, cfg.words_per_line, &format!("tmpl{t}h{l}")))
                    .collect()
            })
            .collect();

        let mut docs = Vec::with_capacity(cfg.n_docs);
        for d in 0..cfg.n_docs {
            let mut r = master.fork(0xD0C_0000 + d as u64);
            let mut lines = Vec::with_capacity(cfg.lines_per_doc);
            if cfg.templates > 0 {
                let t = r.below(cfg.templates);
                lines.extend(templates[t].iter().cloned());
            }
            while lines.len() < cfg.lines_per_doc {
                if r.chance(cfg.shared_line_prob) && !facts.is_empty() {
                    // splice in a whole shared paragraph
                    let fact = &facts[r.below(facts.len())];
                    for l in fact {
                        if lines.len() >= cfg.lines_per_doc {
                            break;
                        }
                        lines.push(l.clone());
                    }
                } else {
                    let l = lines.len();
                    lines.push(sentence(&mut r, cfg.words_per_line, &format!("d{d}l{l}")));
                }
            }
            docs.push(Doc {
                id: BlockId(d as u32),
                lines,
            });
        }

        let token_counts = docs.iter().map(|d| tokenizer.count(&d.text())).collect();
        Corpus { docs, token_counts }
    }

    pub fn len(&self) -> usize {
        self.docs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }

    pub fn doc(&self, id: BlockId) -> &Doc {
        &self.docs[id.0 as usize]
    }

    /// Cached token count of a whole block.
    pub fn doc_tokens(&self, id: BlockId) -> usize {
        self.token_counts[id.0 as usize]
    }

    /// Average tokens per document (used by cost-model setup).
    pub fn mean_doc_tokens(&self) -> f64 {
        if self.docs.is_empty() {
            return 0.0;
        }
        self.token_counts.iter().sum::<usize>() as f64 / self.docs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (Corpus, Tokenizer) {
        let tok = Tokenizer::default();
        let cfg = CorpusConfig {
            n_docs: 50,
            ..Default::default()
        };
        (Corpus::generate(&cfg, &tok), tok)
    }

    #[test]
    fn deterministic_generation() {
        let tok = Tokenizer::default();
        let cfg = CorpusConfig::default();
        let a = Corpus::generate(&cfg, &tok);
        let b = Corpus::generate(&cfg, &tok);
        assert_eq!(a.docs.len(), b.docs.len());
        for (x, y) in a.docs.iter().zip(&b.docs) {
            assert_eq!(x.lines, y.lines);
        }
    }

    #[test]
    fn docs_have_requested_shape() {
        let (c, _) = small();
        assert_eq!(c.len(), 50);
        for d in &c.docs {
            assert_eq!(d.lines.len(), CorpusConfig::default().lines_per_doc);
        }
    }

    #[test]
    fn shared_facts_create_cross_doc_duplicate_lines() {
        let (c, _) = small();
        let mut line_owners: std::collections::HashMap<&str, Vec<u32>> =
            std::collections::HashMap::new();
        for d in &c.docs {
            for l in &d.lines {
                line_owners.entry(l.as_str()).or_default().push(d.id.0);
            }
        }
        let shared = line_owners.values().filter(|v| v.len() > 1).count();
        assert!(shared > 5, "expected cross-doc duplicate lines, got {shared}");
    }

    #[test]
    fn unique_lines_are_unique() {
        let (c, _) = small();
        // lines with the d{d}l{l} prefix appear exactly once
        let mut seen = std::collections::HashSet::new();
        for d in &c.docs {
            for l in &d.lines {
                if l.starts_with('d') && l.contains('l') && !l.starts_with("fact") {
                    assert!(seen.insert(l.clone()), "duplicate unique line: {l}");
                }
            }
        }
    }

    #[test]
    fn token_counts_cached_correctly() {
        let (c, tok) = small();
        for d in &c.docs {
            assert_eq!(c.doc_tokens(d.id), tok.count(&d.text()));
        }
        assert!(c.mean_doc_tokens() > 0.0);
    }

    #[test]
    fn template_headers_shared_within_family() {
        let tok = Tokenizer::default();
        let cfg = CorpusConfig {
            n_docs: 40,
            templates: 2,
            template_lines: 3,
            ..Default::default()
        };
        let c = Corpus::generate(&cfg, &tok);
        // first line of every doc comes from one of 2 templates
        let firsts: std::collections::HashSet<&str> =
            c.docs.iter().map(|d| d.lines[0].as_str()).collect();
        assert!(firsts.len() <= 2);
    }
}
