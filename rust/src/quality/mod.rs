//! Reasoning-quality simulator — the documented substitution for LLM
//! answer accuracy (DESIGN.md §5).
//!
//! The model encodes exactly the phenomena the paper's accuracy story
//! rests on:
//!
//!  * **gold facts** live in the top-ranked retrieved blocks; answer
//!    quality is the mean extraction probability over gold blocks;
//!  * **lost-in-the-middle** (Liu et al. 2023): extraction decays for
//!    blocks placed mid-list, scaled by the model era's order
//!    sensitivity (§3.2, Table 1: modern LLMs are near-insensitive);
//!  * **order annotations** re-point the model at the original relevance
//!    ranking (attention analysis, App. B), cancelling the positional
//!    penalty and adding a multi-hop chaining bonus on multi-hop datasets
//!    (§5.3: +4.0 F1 on MultihopRAG);
//!  * **location annotations** recover nearly all quality for deduped
//!    blocks whose content sits in the conversation history (§6);
//!    silently dropping blocks instead is heavily penalized;
//!  * **approximate KV matching** (CacheBlend) perturbs all extraction
//!    probabilities (§2.3: 9–11% absolute accuracy drop).
//!
//! All scores are deterministic expectations — no sampling noise.

pub mod ordering;

use std::collections::HashSet;

use crate::types::{BlockId, Prompt, Request, Segment};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelEra {
    /// GPT-3.5-era: strongly order-sensitive (DEmO study).
    Legacy,
    /// Modern (Qwen3 / Llama3.3 / GPT-5.1): near order-insensitive.
    Modern,
}

#[derive(Clone, Debug)]
pub struct QualityModel {
    pub era: ModelEra,
    /// Dataset requires chaining evidence across blocks (MultihopRAG).
    pub multi_hop: bool,
    /// Number of top-ranked blocks holding gold facts.
    pub gold_k: usize,
    /// Base extraction probability for a well-placed block.
    pub base: f64,
}

impl QualityModel {
    pub fn new(era: ModelEra, multi_hop: bool) -> Self {
        Self {
            era,
            multi_hop,
            gold_k: 3,
            base: 0.92,
        }
    }

    /// Lost-in-the-middle positional weight for position `i` of `n`:
    /// U-shaped, worst mid-list. Depth scales with era sensitivity.
    pub fn position_weight(&self, i: usize, n: usize) -> f64 {
        if n <= 1 {
            return 1.0;
        }
        let depth = match self.era {
            ModelEra::Legacy => 0.30,
            // Table 1: modern LLMs show negligible ordering gaps
            ModelEra::Modern => 0.02,
        };
        let x = i as f64 / (n - 1) as f64; // 0 at head, 1 at tail
        // parabola peaking at x=0.5; ends keep weight 1 and 1-0.2*depth
        let middle = 4.0 * x * (1.0 - x); // 0 at ends, 1 at middle
        let tail = 0.2 * depth * x; // slight recency penalty at the tail
        (1.0 - depth * middle - tail).max(0.0)
    }

    /// Score a served prompt for `req` in [0, 1].
    ///
    /// `history_blocks`: blocks whose content is available from earlier
    /// turns of the conversation (location annotations point there).
    /// `kv_noise`: approximate-KV perturbation (CacheBlend), 0 for exact.
    pub fn score(
        &self,
        req: &Request,
        prompt: &Prompt,
        history_blocks: &HashSet<BlockId>,
        kv_noise: f64,
    ) -> f64 {
        let gold: Vec<BlockId> = req.context.iter().take(self.gold_k).copied().collect();
        if gold.is_empty() {
            return 0.0;
        }
        // layout of context-bearing segments in prompt order
        let placed: Vec<&Segment> = prompt
            .segments
            .iter()
            .filter(|s| {
                matches!(
                    s,
                    Segment::Block(_) | Segment::LocationRef(_) | Segment::PartialBlock { .. }
                )
            })
            .collect();
        let n = placed.len();
        let annotated = prompt.has_order_annotation();

        let mut total = 0.0;
        for &g in &gold {
            let orig_rank = req.context.iter().position(|&b| b == g).unwrap();
            let pos = placed.iter().position(|s| match s {
                Segment::Block(b)
                | Segment::LocationRef(b)
                | Segment::PartialBlock { block: b, .. } => *b == g,
                _ => false,
            });
            let p = match pos {
                None => 0.05, // gold block dropped without any annotation
                Some(i) => {
                    let seg = placed[i];
                    let presence = match seg {
                        Segment::Block(_) => 1.0,
                        Segment::PartialBlock { .. } => {
                            // elided spans are referenced: near-full recovery
                            0.985
                        }
                        Segment::LocationRef(b) => {
                            if history_blocks.contains(b) {
                                0.97 // content reachable via history + pointer
                            } else {
                                0.15 // dangling reference
                            }
                        }
                        _ => unreachable!(),
                    };
                    // with an order annotation the model attends by the
                    // *original* rank; otherwise by prompt position
                    let w = if annotated {
                        self.position_weight(orig_rank, req.context.len())
                    } else {
                        self.position_weight(i, n)
                    };
                    let hop_bonus = if annotated && self.multi_hop {
                        // explicit priority cues aid evidence chaining
                        1.07
                    } else if annotated {
                        1.015
                    } else {
                        1.0
                    };
                    (self.base * presence * w * hop_bonus).min(0.99)
                }
            };
            total += p * (1.0 - kv_noise);
        }
        (total / gold.len() as f64).clamp(0.0, 1.0)
    }

    /// Score the unmodified baseline prompt (blocks in retrieval order).
    pub fn score_baseline(&self, req: &Request) -> f64 {
        let prompt = Prompt::baseline(req);
        self.score(req, &prompt, &HashSet::new(), 0.0)
    }
}

/// Map a [0,1] quality score onto a dataset/model F1 scale by anchoring
/// the baseline prompt's score to the paper's reported baseline F1.
pub fn to_f1(quality: f64, baseline_quality: f64, baseline_f1: f64) -> f64 {
    if baseline_quality <= 0.0 {
        return 0.0;
    }
    (quality / baseline_quality * baseline_f1).clamp(0.0, 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{QueryId, RequestId, SessionId};

    fn req(ids: &[u32]) -> Request {
        Request {
            id: RequestId(1),
            session: SessionId(0),
            turn: 0,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(9),
        }
    }

    fn prompt_with_order(r: &Request, order: &[u32], annotate: bool) -> Prompt {
        let mut segments = vec![Segment::System];
        segments.extend(order.iter().map(|&b| Segment::Block(BlockId(b))));
        if annotate {
            segments.push(Segment::OrderAnnotation(r.context.clone()));
        }
        segments.push(Segment::Question(r.query));
        Prompt { segments }
    }

    #[test]
    fn baseline_prompt_scores_high() {
        let m = QualityModel::new(ModelEra::Modern, false);
        let r = req(&[1, 2, 3, 4, 5]);
        let q = m.score_baseline(&r);
        assert!(q > 0.8, "baseline quality {q}");
    }

    #[test]
    fn modern_era_barely_cares_about_order() {
        let m = QualityModel::new(ModelEra::Modern, false);
        let r = req(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let base = m.score_baseline(&r);
        let scrambled = prompt_with_order(&r, &[8, 7, 6, 5, 4, 3, 2, 1], false);
        let q = m.score(&r, &scrambled, &HashSet::new(), 0.0);
        assert!((base - q).abs() < 0.05, "modern gap too big: {base} vs {q}");
    }

    #[test]
    fn legacy_era_is_order_sensitive() {
        let legacy = QualityModel::new(ModelEra::Legacy, false);
        let modern = QualityModel::new(ModelEra::Modern, false);
        let r = req(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let scrambled_order = [4u32, 5, 6, 1, 2, 3, 7, 8]; // gold in the middle
        let p = prompt_with_order(&r, &scrambled_order, false);
        let gap_legacy = legacy.score_baseline(&r) - legacy.score(&r, &p, &HashSet::new(), 0.0);
        let gap_modern = modern.score_baseline(&r) - modern.score(&r, &p, &HashSet::new(), 0.0);
        assert!(
            gap_legacy > 2.0 * gap_modern.max(0.001),
            "legacy {gap_legacy} vs modern {gap_modern}"
        );
    }

    #[test]
    fn annotation_recovers_aligned_order() {
        let m = QualityModel::new(ModelEra::Modern, false);
        let r = req(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let aligned = [4u32, 5, 6, 1, 2, 3, 7, 8];
        let plain = prompt_with_order(&r, &aligned, false);
        let annotated = prompt_with_order(&r, &aligned, true);
        let q_plain = m.score(&r, &plain, &HashSet::new(), 0.0);
        let q_ann = m.score(&r, &annotated, &HashSet::new(), 0.0);
        assert!(q_ann >= q_plain, "annotation hurt: {q_ann} < {q_plain}");
    }

    #[test]
    fn multi_hop_annotation_beats_baseline() {
        // §5.3: on multi-hop tasks annotations *improve* over no-alignment.
        let m = QualityModel::new(ModelEra::Modern, true);
        let r = req(&[1, 2, 3, 4, 5, 6]);
        let base = m.score_baseline(&r);
        let aligned = [6u32, 5, 1, 2, 3, 4];
        let annotated = prompt_with_order(&r, &aligned, true);
        let q = m.score(&r, &annotated, &HashSet::new(), 0.0);
        assert!(q > base, "multi-hop annotated {q} <= baseline {base}");
    }

    #[test]
    fn location_annotation_with_history_is_nearly_free() {
        let m = QualityModel::new(ModelEra::Modern, false);
        let r = req(&[1, 2, 3]);
        let mut segs = vec![Segment::System];
        segs.push(Segment::LocationRef(BlockId(1)));
        segs.push(Segment::Block(BlockId(2)));
        segs.push(Segment::Block(BlockId(3)));
        segs.push(Segment::Question(r.query));
        let p = Prompt { segments: segs };
        let hist: HashSet<BlockId> = [BlockId(1)].into_iter().collect();
        let with_hist = m.score(&r, &p, &hist, 0.0);
        let without = m.score(&r, &p, &HashSet::new(), 0.0);
        let base = m.score_baseline(&r);
        assert!(base - with_hist < 0.03, "dedup w/ history cost too much");
        assert!(without < with_hist - 0.15, "dangling ref not penalized");
    }

    #[test]
    fn dropping_gold_block_hurts_badly() {
        let m = QualityModel::new(ModelEra::Modern, false);
        let r = req(&[1, 2, 3]);
        let p = prompt_with_order(&r, &[2, 3], false); // block 1 silently gone
        let q = m.score(&r, &p, &HashSet::new(), 0.0);
        assert!(q < m.score_baseline(&r) - 0.2);
    }

    #[test]
    fn kv_noise_degrades_multiplicatively() {
        let m = QualityModel::new(ModelEra::Modern, false);
        let r = req(&[1, 2, 3, 4, 5]);
        let p = Prompt::baseline(&r);
        let clean = m.score(&r, &p, &HashSet::new(), 0.0);
        let noisy = m.score(&r, &p, &HashSet::new(), 0.17);
        assert!((noisy - clean * 0.83).abs() < 1e-9);
    }

    #[test]
    fn f1_anchoring() {
        assert!((to_f1(0.85, 0.85, 60.4) - 60.4).abs() < 1e-9);
        assert!(to_f1(0.90, 0.85, 60.4) > 60.4);
        assert_eq!(to_f1(0.5, 0.0, 60.0), 0.0);
    }

    #[test]
    fn position_weight_u_shape() {
        let m = QualityModel::new(ModelEra::Legacy, false);
        let n = 11;
        let head = m.position_weight(0, n);
        let mid = m.position_weight(5, n);
        let tail = m.position_weight(10, n);
        assert!(head > mid && tail > mid, "not U-shaped: {head} {mid} {tail}");
        assert!(head >= tail, "head should beat tail slightly");
    }
}
