//! Reproduction of the DEmO ordering study (Table 1, §3.2): in-context
//! example ordering mattered for GPT-3.5-era models and is negligible for
//! modern ones — the observation that makes alignment safe.
//!
//! We simulate a 4-way classification probe: accuracy = dataset base
//! accuracy + the era's order sensitivity × the ordering's quality
//! (random ≈ 0, DEmO-curated ≈ 1), evaluated with the same
//! lost-in-the-middle machinery as the main quality model.

use crate::quality::{ModelEra, QualityModel};
use crate::util::prng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrderingStrategy {
    Random,
    DEmO,
}

#[derive(Clone, Copy, Debug)]
pub struct ProbeDataset {
    pub name: &'static str,
    /// Base accuracy per era (gpt35, gpt51) under a *good* ordering.
    pub base_gpt35: f64,
    pub base_gpt51: f64,
    /// How much this task's label depends on example placement (tasks in
    /// the original study differ: SUBJ showed gaps, SST2 did not).
    pub order_dependence: f64,
}

/// The four probes of Table 1 with the paper's GPT-3.5/GPT-5.1 anchors.
pub const PROBES: [ProbeDataset; 4] = [
    ProbeDataset {
        name: "SST2",
        base_gpt35: 93.8,
        base_gpt51: 93.8,
        order_dependence: 0.0,
    },
    ProbeDataset {
        name: "SNLI",
        base_gpt35: 72.6,
        base_gpt51: 83.2,
        order_dependence: 0.0,
    },
    ProbeDataset {
        name: "SUBJ",
        base_gpt35: 71.6,
        base_gpt51: 77.3,
        order_dependence: 1.0,
    },
    ProbeDataset {
        name: "CR",
        base_gpt35: 93.8,
        base_gpt51: 93.8,
        order_dependence: 0.6,
    },
];

/// Accuracy of one (dataset, era, strategy) cell, averaged over `trials`
/// random example orderings (DEmO always places demonstrations head/tail).
pub fn probe_accuracy(
    probe: &ProbeDataset,
    era: ModelEra,
    strategy: OrderingStrategy,
    trials: usize,
    seed: u64,
) -> f64 {
    let qm = QualityModel::new(era, false);
    let base = match era {
        ModelEra::Legacy => probe.base_gpt35,
        ModelEra::Modern => probe.base_gpt51,
    };
    let n_examples = 8usize;
    let mut rng = Rng::new(seed);
    let mut acc = 0.0;
    for _ in 0..trials.max(1) {
        // position of the decisive demonstration
        let pos = match strategy {
            OrderingStrategy::DEmO => 0, // curated: most-informative first
            OrderingStrategy::Random => rng.below(n_examples),
        };
        let w = qm.position_weight(pos, n_examples);
        // accuracy shrinks toward chance (25% for 4-way) with lost weight
        let chance = 25.0;
        let effective = chance + (base - chance) * (1.0 - probe.order_dependence * (1.0 - w));
        acc += effective;
    }
    acc / trials.max(1) as f64
}

/// The full Table-1 grid: rows = probes, cells = (random, demo) per era.
pub fn demo_study(trials: usize, seed: u64) -> Vec<(String, f64, f64, f64, f64)> {
    PROBES
        .iter()
        .map(|p| {
            (
                p.name.to_string(),
                probe_accuracy(p, ModelEra::Legacy, OrderingStrategy::Random, trials, seed),
                probe_accuracy(p, ModelEra::Legacy, OrderingStrategy::DEmO, trials, seed ^ 1),
                probe_accuracy(p, ModelEra::Modern, OrderingStrategy::Random, trials, seed ^ 2),
                probe_accuracy(p, ModelEra::Modern, OrderingStrategy::DEmO, trials, seed ^ 3),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_gap_exceeds_modern_gap_on_order_dependent_tasks() {
        let subj = &PROBES[2];
        let t = 2000;
        let gap_legacy = probe_accuracy(subj, ModelEra::Legacy, OrderingStrategy::DEmO, t, 1)
            - probe_accuracy(subj, ModelEra::Legacy, OrderingStrategy::Random, t, 2);
        let gap_modern = probe_accuracy(subj, ModelEra::Modern, OrderingStrategy::DEmO, t, 3)
            - probe_accuracy(subj, ModelEra::Modern, OrderingStrategy::Random, t, 4);
        assert!(gap_legacy > 1.0, "legacy SUBJ gap {gap_legacy}");
        assert!(gap_modern < 1.0, "modern SUBJ gap {gap_modern}");
        assert!(gap_legacy > 3.0 * gap_modern.max(0.05));
    }

    #[test]
    fn order_independent_tasks_show_no_gap() {
        let sst2 = &PROBES[0];
        for era in [ModelEra::Legacy, ModelEra::Modern] {
            let g = probe_accuracy(sst2, era, OrderingStrategy::DEmO, 500, 5)
                - probe_accuracy(sst2, era, OrderingStrategy::Random, 500, 6);
            assert!(g.abs() < 0.5, "SST2 gap {g} for {era:?}");
        }
    }

    #[test]
    fn table_shape() {
        let rows = demo_study(200, 42);
        assert_eq!(rows.len(), 4);
        for (name, r35, d35, r51, d51) in &rows {
            assert!(!name.is_empty());
            for v in [r35, d35, r51, d51] {
                assert!((20.0..=100.0).contains(v), "{name}: {v}");
            }
        }
        // averages echo the paper's story: modern avg >= legacy avg,
        // and DEmO-vs-random deltas are small for modern
        let avg = |f: fn(&(String, f64, f64, f64, f64)) -> f64| {
            rows.iter().map(f).sum::<f64>() / rows.len() as f64
        };
        let modern_gap = (avg(|r| r.4) - avg(|r| r.3)).abs();
        assert!(modern_gap < 1.5, "modern avg gap {modern_gap}");
    }
}
