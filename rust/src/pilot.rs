//! The ContextPilot proxy (Fig. 3 / Fig. 14): takes user requests with
//! retrieval-ranked context blocks, rewrites them for maximum KV-cache
//! reuse (alignment §5 + de-duplication §6 + annotations), schedules the
//! batch (Alg. 5), and keeps its context index synchronized with the
//! engine's prefix cache via request-id eviction callbacks (§4.1).
//!
//! Two operating modes, matching the paper's evaluation setup:
//!  * **offline** (multi-session): [`ContextPilot::build_offline`] cluster-
//!    builds the index over the whole batch before serving; initialization
//!    contexts inherit their aligned prefix from their parent nodes.
//!  * **online** (multi-turn / Mem0): the index starts cold and every
//!    request is searched + inserted incrementally.
//!
//! At serving scale one `ContextPilot` instance runs per shard inside
//! the serving engine behind [`crate::api::Server`]; sessions are pinned to shards, so the
//! conversation records and the eviction callbacks stay consistent
//! without any cross-instance coordination.

use std::collections::HashMap;

use crate::align::{align_context, order_annotation, Alignment};
use crate::corpus::Corpus;
use crate::dedup::{dedup_context, DedupConfig, DedupStats};
use crate::index::build::build_clustered;
use crate::index::tree::ContextIndex;
use crate::index::DEFAULT_ALPHA;
use crate::schedule::schedule_by_paths;
use crate::types::{Context, Prompt, Request, RequestId, Segment};

#[derive(Clone, Debug)]
pub struct PilotConfig {
    /// Eq.-1 positional weight (paper default 0.001).
    pub alpha: f64,
    /// Context alignment (§5.1).
    pub align: bool,
    /// Order annotations (§5.3).
    pub annotate: bool,
    /// De-duplication (§6); None disables.
    pub dedup: Option<DedupConfig>,
    /// Alg.-5 batch scheduling (§5.2).
    pub schedule: bool,
}

impl Default for PilotConfig {
    fn default() -> Self {
        Self {
            alpha: DEFAULT_ALPHA,
            align: true,
            annotate: true,
            dedup: Some(DedupConfig::default()),
            schedule: true,
        }
    }
}

impl PilotConfig {
    /// Ablation helper (Table 7 / Fig. 7 variants).
    pub fn with(align: bool, annotate: bool, dedup: bool, schedule: bool) -> Self {
        Self {
            alpha: DEFAULT_ALPHA,
            align,
            annotate,
            dedup: dedup.then(DedupConfig::default),
            schedule,
        }
    }
}

/// One processed request: the rewritten prompt plus the metadata the
/// engine/scheduler/metrics need. Convenience shape for callers that want
/// the request carried along; the serving hot path uses [`Rewrite`] (via
/// [`ContextPilot::rewrite_batch`]) to avoid the owned `Request` copy.
#[derive(Clone, Debug)]
pub struct PilotOutput {
    pub request: Request,
    pub prompt: Prompt,
    /// Index search path (drives Alg.-5 grouping).
    pub path: Vec<usize>,
    pub aligned: Context,
    pub dedup_stats: DedupStats,
}

/// The rewrite of one request, without an owned copy of the request
/// itself — what a serving shard consumes on the hot path (the
/// original `Request` stays borrowed from the caller's batch).
#[derive(Clone, Debug)]
pub struct Rewrite {
    pub prompt: Prompt,
    /// Index search path (drives Alg.-5 grouping).
    pub path: Vec<usize>,
    pub aligned: Context,
    pub dedup_stats: DedupStats,
}

pub struct ContextPilot {
    pub cfg: PilotConfig,
    pub index: ContextIndex,
    /// Offline-build placements: request -> (aligned context, path).
    placements: HashMap<RequestId, (Context, Vec<usize>)>,
}

impl ContextPilot {
    pub fn new(cfg: PilotConfig) -> Self {
        let alpha = cfg.alpha;
        Self {
            cfg,
            index: ContextIndex::new(alpha),
            placements: HashMap::new(),
        }
    }

    /// Offline mode: pre-build the context index over the whole batch via
    /// hierarchical clustering (Alg. 4). Subsequent `process` calls for
    /// these requests reuse their recorded aligned placement.
    pub fn build_offline(&mut self, requests: &[Request]) {
        let inputs: Vec<(RequestId, Context)> = requests
            .iter()
            .map(|r| (r.id, r.context.clone()))
            .collect();
        let built = build_clustered(&inputs, self.cfg.alpha);
        self.index = built.index;
        self.placements = requests
            .iter()
            .zip(built.placed)
            .map(|(r, (_, aligned, path))| (r.id, (aligned, path)))
            .collect();
    }

    /// Alive nodes in the context index — serving-layer telemetry
    /// ([`crate::metrics::ShardStats`]).
    pub fn index_size(&self) -> usize {
        self.index.len_alive()
    }

    /// Side-effect-free placement probe ([`crate::serve::placement`]): how
    /// many of `context`'s blocks this pilot's index already knows —
    /// i.e. how much of the request the shard behind this pilot could
    /// reuse. Delegates to [`ContextIndex::known_blocks`], which answers
    /// from the index's inverted block directory in O(context blocks)
    /// (no leaf scan, no allocation).
    pub fn known_blocks(&self, context: &Context) -> usize {
        self.index.known_blocks(context)
    }

    /// Engine eviction callback (§4.1).
    pub fn on_evict(&mut self, reqs: &[RequestId]) {
        self.index.on_evict(reqs);
        for r in reqs {
            self.placements.remove(r);
        }
    }

    /// Process one request: align → de-duplicate → annotate.
    /// Thin wrapper over [`ContextPilot::rewrite`] that carries an owned
    /// copy of the request (tests / sequential drivers).
    pub fn process(&mut self, req: &Request, corpus: &Corpus) -> PilotOutput {
        let rw = self.rewrite(req, corpus);
        PilotOutput {
            request: req.clone(),
            prompt: rw.prompt,
            path: rw.path,
            aligned: rw.aligned,
            dedup_stats: rw.dedup_stats,
        }
    }

    /// Rewrite one request (align → de-duplicate → annotate) without
    /// cloning it — the serving hot path.
    pub fn rewrite(&mut self, req: &Request, corpus: &Corpus) -> Rewrite {
        // ---- 1. alignment (§5) ------------------------------------------
        let (aligned, path) = if let Some((aligned, path)) = self.placements.get(&req.id) {
            (aligned.clone(), path.clone())
        } else if self.cfg.align {
            let Alignment { aligned, path, .. } =
                align_context(&mut self.index, &req.context, req.id);
            (aligned, path)
        } else {
            // no alignment: still search (so scheduling has paths and the
            // index tracks the cache), but keep the original order.
            let found = self.index.search(&req.context);
            let (_, path) = self
                .index
                .insert_at(&found, req.context.clone(), req.id);
            (req.context.clone(), path)
        };

        // ---- 2. de-duplication (§6) --------------------------------------
        let (mut segments, dedup_stats) = match &self.cfg.dedup {
            Some(dcfg) => {
                let dcfg = *dcfg;
                dedup_context(&mut self.index, req.session, &aligned, corpus, &dcfg)
            }
            None => (
                aligned.iter().map(|&b| Segment::Block(b)).collect(),
                DedupStats {
                    blocks_in: aligned.len(),
                    ..Default::default()
                },
            ),
        };

        // ---- 3. order annotation (§5.3) ----------------------------------
        let mut all = Vec::with_capacity(segments.len() + 3);
        all.push(Segment::System);
        all.append(&mut segments);
        if self.cfg.annotate {
            if let Some(ranking) = order_annotation(&req.context, &aligned) {
                all.push(Segment::OrderAnnotation(ranking));
            }
        }
        all.push(Segment::Question(req.query));

        Rewrite {
            prompt: Prompt { segments: all },
            path,
            aligned,
            dedup_stats,
        }
    }

    /// Rewrite a batch and schedule it (Alg. 5): returns `(input index,
    /// rewrite)` pairs in execution order. No `Request` or path clones —
    /// scheduling borrows the search paths in place.
    pub fn rewrite_batch(
        &mut self,
        reqs: &[Request],
        corpus: &Corpus,
    ) -> Vec<(usize, Rewrite)> {
        let rewrites: Vec<Rewrite> = reqs.iter().map(|r| self.rewrite(r, corpus)).collect();
        if !self.cfg.schedule {
            return rewrites.into_iter().enumerate().collect();
        }
        let order = {
            let paths: Vec<&[usize]> = rewrites.iter().map(|r| r.path.as_slice()).collect();
            schedule_by_paths(&paths)
        };
        let mut slots: Vec<Option<Rewrite>> = rewrites.into_iter().map(Some).collect();
        order
            .into_iter()
            .map(|i| (i, slots[i].take().expect("schedule emitted duplicate index")))
            .collect()
    }

    /// Process a batch and schedule it (Alg. 5): returns outputs in
    /// execution order. Wrapper over [`ContextPilot::rewrite_batch`] that
    /// clones each request into its output.
    pub fn process_batch(&mut self, reqs: &[Request], corpus: &Corpus) -> Vec<PilotOutput> {
        self.rewrite_batch(reqs, corpus)
            .into_iter()
            .map(|(i, rw)| PilotOutput {
                request: reqs[i].clone(),
                prompt: rw.prompt,
                path: rw.path,
                aligned: rw.aligned,
                dedup_stats: rw.dedup_stats,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{Corpus, CorpusConfig};
    use crate::tokenizer::Tokenizer;
    use crate::types::{BlockId, QueryId, SessionId};

    fn corpus() -> Corpus {
        Corpus::generate(
            &CorpusConfig {
                n_docs: 64,
                ..Default::default()
            },
            &Tokenizer::default(),
        )
    }

    fn req(id: u64, session: u32, turn: u32, ids: &[u32]) -> Request {
        Request {
            id: RequestId(id),
            session: SessionId(session),
            turn,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(id),
        }
    }

    #[test]
    fn offline_batch_reproduces_paper_flow() {
        // Fig. 5/6 composite: init C1..C3, then C6, C7, C8.
        let corpus = corpus();
        let mut pilot = ContextPilot::new(PilotConfig::default());
        let init = vec![
            req(1, 1, 0, &[2, 1, 3]),
            req(2, 2, 0, &[2, 6, 1]),
            req(3, 3, 0, &[4, 1, 0]),
        ];
        pilot.build_offline(&init);
        let batch = vec![
            req(6, 6, 0, &[2, 1, 4]),
            req(7, 7, 0, &[5, 7, 8]),
            req(8, 8, 0, &[1, 2, 9]),
        ];
        let out = pilot.process_batch(&batch, &corpus);
        // C6 and C8 share the {1,2} prefix and must run consecutively
        let pos6 = out.iter().position(|o| o.request.id == RequestId(6)).unwrap();
        let pos8 = out.iter().position(|o| o.request.id == RequestId(8)).unwrap();
        assert_eq!(pos6.abs_diff(pos8), 1, "C6/C8 not adjacent: {pos6} vs {pos8}");
        // C6 aligned to {1,2,4}
        let o6 = &out[pos6];
        let want: Context = [1u32, 2, 4].iter().map(|&i| BlockId(i)).collect();
        assert_eq!(o6.aligned, want);
        // reordered => order annotation present
        assert!(o6.prompt.has_order_annotation());
        pilot.index.check_invariants().unwrap();
    }

    #[test]
    fn online_multi_turn_dedups_history() {
        let corpus = corpus();
        let mut pilot = ContextPilot::new(PilotConfig::default());
        let t0 = pilot.process(&req(1, 5, 0, &[1, 2, 4]), &corpus);
        assert_eq!(t0.dedup_stats.blocks_deduped, 0);
        let t1 = pilot.process(&req(2, 5, 1, &[1, 5, 2]), &corpus);
        assert_eq!(t1.dedup_stats.blocks_deduped, 2);
        let loc_refs = t1
            .prompt
            .segments
            .iter()
            .filter(|s| matches!(s, Segment::LocationRef(_)))
            .count();
        assert_eq!(loc_refs, 2);
    }

    #[test]
    fn annotation_absent_when_order_preserved() {
        let corpus = corpus();
        let mut pilot = ContextPilot::new(PilotConfig::default());
        let out = pilot.process(&req(1, 1, 0, &[3, 4, 5]), &corpus);
        assert!(!out.prompt.has_order_annotation());
    }

    #[test]
    fn ablation_config_disables_components() {
        let corpus = corpus();
        let mut pilot = ContextPilot::new(PilotConfig::with(false, false, false, false));
        let a = pilot.process(&req(1, 1, 0, &[2, 1, 3]), &corpus);
        assert_eq!(a.aligned, a.request.context, "align disabled");
        let b = pilot.process(&req(2, 1, 1, &[2, 1, 3]), &corpus);
        assert_eq!(b.dedup_stats.blocks_deduped, 0, "dedup disabled");
        assert!(!b.prompt.has_order_annotation(), "annotate disabled");
    }

    #[test]
    fn eviction_callback_prunes_index_and_placements() {
        let corpus = corpus();
        let mut pilot = ContextPilot::new(PilotConfig::default());
        let batch = vec![req(1, 1, 0, &[1, 2, 3]), req(2, 2, 0, &[1, 2, 9])];
        pilot.build_offline(&batch);
        pilot.process_batch(&batch, &corpus);
        pilot.on_evict(&[RequestId(1)]);
        assert!(pilot.index.leaf_of_request(RequestId(1)).is_none());
        assert!(pilot.index.leaf_of_request(RequestId(2)).is_some());
        pilot.index.check_invariants().unwrap();
    }

    #[test]
    fn known_blocks_tracks_serving_and_eviction() {
        let corpus = corpus();
        let mut pilot = ContextPilot::new(PilotConfig::default());
        let probe: Context = [1u32, 2, 9].iter().map(|&i| BlockId(i)).collect();
        assert_eq!(pilot.known_blocks(&probe), 0, "cold index knows nothing");
        pilot.process(&req(1, 1, 0, &[1, 2, 3]), &corpus);
        assert_eq!(pilot.known_blocks(&probe), 2);
        pilot.on_evict(&[RequestId(1)]);
        assert_eq!(pilot.known_blocks(&probe), 0, "§4.1 pruning must be seen");
    }

    #[test]
    fn batch_is_permutation_of_input() {
        let corpus = corpus();
        let mut pilot = ContextPilot::new(PilotConfig::default());
        let batch: Vec<Request> = (0..20)
            .map(|i| {
                let mut rng = crate::util::prng::Rng::new(i);
                let ids: Vec<u32> = rng
                    .sample_indices(40, 5)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect();
                req(i, i as u32, 0, &ids)
            })
            .collect();
        let out = pilot.process_batch(&batch, &corpus);
        assert_eq!(out.len(), batch.len());
        let mut ids: Vec<u64> = out.iter().map(|o| o.request.id.0).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn aligned_is_always_permutation_of_context() {
        let corpus = corpus();
        let mut pilot = ContextPilot::new(PilotConfig::default());
        for i in 0..50u64 {
            let mut rng = crate::util::prng::Rng::new(i ^ 0xABC);
            let ids: Vec<u32> = rng
                .sample_indices(40, 1 + (i as usize % 8))
                .into_iter()
                .map(|x| x as u32)
                .collect();
            let r = req(i, (i % 7) as u32, (i / 7) as u32, &ids);
            let out = pilot.process(&r, &corpus);
            let mut a = out.aligned.clone();
            let mut b = r.context.clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "request {i}");
        }
        pilot.index.check_invariants().unwrap();
    }
}
