//! TinyLM runtime: artifact loading, weight literals, chunked prefill with
//! KV-cache threading.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context as _, Result};

use crate::util::json::Json;

/// Parsed `model_meta.json` — the contract with the AOT compile path.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    /// chunk length -> HLO file name
    pub variants: BTreeMap<usize, String>,
    /// (name, shape) in weights.bin order
    pub weights: Vec<(String, Vec<usize>)>,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(dir.join("model_meta.json"))
            .with_context(|| format!("reading model_meta.json in {dir:?} (run `make artifacts`)"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("model_meta.json: {e}"))?;
        let cfg = v.get("config");
        let get = |k: &str| -> Result<usize> {
            cfg.get(k)
                .as_usize()
                .ok_or_else(|| anyhow!("missing config.{k}"))
        };
        let mut variants = BTreeMap::new();
        for item in v.get("variants").as_arr().unwrap_or(&[]) {
            let chunk = item
                .get("chunk")
                .as_usize()
                .ok_or_else(|| anyhow!("variant missing chunk"))?;
            let file = item
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("variant missing file"))?;
            variants.insert(chunk, file.to_string());
        }
        if variants.is_empty() {
            bail!("no variants in model_meta.json");
        }
        let mut weights = Vec::new();
        for w in v.get("weights").as_arr().unwrap_or(&[]) {
            let name = w.get("name").as_str().unwrap_or_default().to_string();
            let shape: Vec<usize> = w
                .get("shape")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect();
            weights.push((name, shape));
        }
        Ok(ModelMeta {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            head_dim: get("head_dim")?,
            max_seq: get("max_seq")?,
            variants,
            weights,
        })
    }

    pub fn kv_elements(&self) -> usize {
        self.n_layers * 2 * self.max_seq * self.n_heads * self.head_dim
    }

    pub fn kv_dims(&self) -> [i64; 5] {
        [
            self.n_layers as i64,
            2,
            self.max_seq as i64,
            self.n_heads as i64,
            self.head_dim as i64,
        ]
    }
}

/// KV-cache state threaded between prefill chunks.
pub struct KvState {
    pub literal: xla::Literal,
    /// Number of valid cached positions.
    pub len: usize,
}

pub struct TinyLmRuntime {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    execs: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    weights: Vec<xla::Literal>,
}

impl TinyLmRuntime {
    /// Load and compile every variant in `dir` (default `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<TinyLmRuntime> {
        let dir = dir.as_ref();
        let meta = ModelMeta::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut execs = BTreeMap::new();
        for (&chunk, file) in &meta.variants {
            let path: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            execs.insert(chunk, client.compile(&comp)?);
        }
        // weights.bin: flat f32 LE in artifact order
        let blob = std::fs::read(dir.join("weights.bin"))?;
        let mut weights = Vec::with_capacity(meta.weights.len());
        let mut off = 0usize;
        for (name, shape) in &meta.weights {
            let n: usize = shape.iter().product();
            let bytes = blob
                .get(off..off + n * 4)
                .ok_or_else(|| anyhow!("weights.bin truncated at {name}"))?;
            let vals: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            weights.push(xla::Literal::vec1(&vals).reshape(&dims)?);
            off += n * 4;
        }
        if off != blob.len() {
            bail!("weights.bin has {} trailing bytes", blob.len() - off);
        }
        Ok(TinyLmRuntime {
            meta,
            client,
            execs,
            weights,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn empty_kv(&self) -> Result<KvState> {
        let zeros = vec![0f32; self.meta.kv_elements()];
        Ok(KvState {
            literal: xla::Literal::vec1(&zeros).reshape(&self.meta.kv_dims())?,
            len: 0,
        })
    }

    /// Largest variant <= n, else the smallest variant (tail gets padded).
    fn pick_variant(&self, n: usize) -> usize {
        self.execs
            .keys()
            .rev()
            .find(|&&c| c <= n)
            .or_else(|| self.execs.keys().next())
            .copied()
            .expect("at least one variant")
    }

    /// Run one compiled chunk. `tokens` must have exactly `chunk` entries.
    fn run_chunk(
        &self,
        chunk: usize,
        tokens: &[i32],
        kv: &xla::Literal,
        cache_len: usize,
    ) -> Result<(xla::Literal, xla::Literal)> {
        debug_assert_eq!(tokens.len(), chunk);
        let exe = &self.execs[&chunk];
        let tok = xla::Literal::vec1(tokens);
        let cl = xla::Literal::vec1(&[cache_len as i32]);
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 + self.weights.len());
        args.push(&tok);
        args.push(kv);
        args.push(&cl);
        for w in &self.weights {
            args.push(w);
        }
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (logits, new_kv) = result.to_tuple2()?;
        Ok((logits, new_kv))
    }

    /// Prefill `tokens` starting from `kv` (consumed), returning the new KV
    /// state and the logits of the **last real token**.
    ///
    /// Chunks greedily with the compiled variants; the tail chunk is padded
    /// with zeros (garbage KV rows beyond the real tokens stay outside
    /// `kv.len` and are overwritten by any continuation).
    pub fn prefill(&self, tokens: &[u32], kv: KvState) -> Result<(Vec<f32>, KvState)> {
        if tokens.is_empty() {
            bail!("prefill of zero tokens");
        }
        if kv.len + tokens.len() > self.meta.max_seq {
            bail!(
                "sequence overflow: {} cached + {} new > max_seq {}",
                kv.len,
                tokens.len(),
                self.meta.max_seq
            );
        }
        let mut cur_kv = kv.literal;
        let mut cache_len = kv.len;
        let mut off = 0usize;
        let mut last_logits: Option<(xla::Literal, usize, usize)> = None; // (logits, chunk, real)
        while off < tokens.len() {
            let remaining = tokens.len() - off;
            let chunk = self.pick_variant(remaining);
            let real = remaining.min(chunk);
            let mut buf: Vec<i32> = Vec::with_capacity(chunk);
            buf.extend(tokens[off..off + real].iter().map(|&t| t as i32));
            buf.resize(chunk, 0); // pad
            let (logits, new_kv) = self.run_chunk(chunk, &buf, &cur_kv, cache_len)?;
            cur_kv = new_kv;
            cache_len += real;
            off += real;
            last_logits = Some((logits, chunk, real));
        }
        let (logits, chunk, real) = last_logits.unwrap();
        // logits: [chunk, vocab]; take row real-1
        let flat = logits.to_vec::<f32>()?;
        let v = self.meta.vocab;
        debug_assert_eq!(flat.len(), chunk * v);
        let row = flat[(real - 1) * v..real * v].to_vec();
        Ok((
            row,
            KvState {
                literal: cur_kv,
                len: cache_len,
            },
        ))
    }

    /// Greedy decode of `n` tokens starting from `kv` and the logits of
    /// the previous position.
    pub fn decode(
        &self,
        mut logits: Vec<f32>,
        mut kv: KvState,
        n: usize,
    ) -> Result<(Vec<u32>, KvState)> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if kv.len >= self.meta.max_seq {
                break;
            }
            let next = argmax(&logits);
            out.push(next);
            let (lg, new_kv) = self.prefill(&[next], kv)?;
            logits = lg;
            kv = new_kv;
        }
        Ok((out, kv))
    }
}

pub fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0); // first wins ties
    }

    // Runtime tests that need built artifacts live in
    // rust/tests/runtime_real_model.rs (integration).
}
