//! Real PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + weights.bin + model_meta.json) and
//! serves TinyLM prefill from Rust — Python is never on the request path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. One compiled
//! executable per chunk-length variant; the engine picks the largest
//! variant that fits the remaining tokens and pads the tail chunk
//! (pad-safety is proven by `python/tests/test_model.py::test_padding_is_harmless`).
//!
//! The whole module is gated on the `pjrt` cargo feature: it needs the
//! external `xla` and `anyhow` crates, which the offline build image does
//! not carry (and which therefore cannot be declared in Cargo.toml, even
//! as optional dependencies — the image has no registry to resolve them).
//! Without the feature the crate (and the simulated serving stack,
//! including [`crate::serve`]) builds dependency-free. To restore the real
//! engine and the `runtime_real_model` integration tests on a networked
//! host: add `anyhow` and `xla` to `[dependencies]` in Cargo.toml, then
//! build with `--features pjrt`.

#[cfg(feature = "pjrt")]
pub mod model;
#[cfg(feature = "pjrt")]
pub mod real_engine;

#[cfg(feature = "pjrt")]
pub use model::{ModelMeta, TinyLmRuntime};
#[cfg(feature = "pjrt")]
pub use real_engine::RealEngine;
