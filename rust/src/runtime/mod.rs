//! Real PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + weights.bin + model_meta.json) and
//! serves TinyLM prefill from Rust — Python is never on the request path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. One compiled
//! executable per chunk-length variant; the engine picks the largest
//! variant that fits the remaining tokens and pads the tail chunk
//! (pad-safety is proven by `python/tests/test_model.py::test_padding_is_harmless`).

pub mod model;
pub mod real_engine;

pub use model::{ModelMeta, TinyLmRuntime};
pub use real_engine::RealEngine;
