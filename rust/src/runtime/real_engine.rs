//! Real serving engine: TinyLM via PJRT with an actual KV-reusing radix
//! prefix cache. This is the end-to-end validation path (examples/
//! e2e_serving.rs): ContextPilot's prompt rewriting must translate into
//! *measured* wall-clock prefill savings on real model execution.
//!
//! KV snapshots (full KV literals + length) are attached to radix-cache
//! nodes at prompt boundaries; a new request resumes prefill from the
//! deepest snapshot whose token prefix matches.

use std::sync::Arc;

use anyhow::Result;

use crate::cache::RadixCache;
use crate::corpus::Corpus;
use crate::engine::iface::{CacheStats, InferenceEngine};
use crate::engine::render::Renderer;
use crate::quality::QualityModel;
use crate::runtime::model::{KvState, TinyLmRuntime};
use crate::tokenizer::Tokenizer;
use crate::types::{Prompt, Request, RequestId, ServedRequest};

/// KV snapshot stored in the cache: state after prefilling a token prefix.
pub struct KvSnapshot {
    pub literal: xla::Literal,
    pub len: usize,
}

pub struct RealEngine {
    pub runtime: TinyLmRuntime,
    pub cache: RadixCache<Arc<KvSnapshot>>,
    pub renderer: Renderer,
    /// Tokens actually prefilled (uncached) across all requests.
    pub stat_prefilled_tokens: u64,
    pub stat_reused_tokens: u64,
}

impl RealEngine {
    pub fn new(runtime: TinyLmRuntime, capacity_tokens: usize) -> Self {
        Self {
            runtime,
            cache: RadixCache::new(capacity_tokens),
            renderer: Renderer::new(Tokenizer::new(2048)),
            stat_prefilled_tokens: 0,
            stat_reused_tokens: 0,
        }
    }

    /// Token offsets of snapshot boundaries: after the system segment and
    /// after each context block — the positions future requests can share.
    /// The annotation/question tail is prefilled as one run with no
    /// snapshot (it is request-specific, so caching it buys nothing and
    /// each snapshot costs a full KV-literal clone).
    fn boundaries(&mut self, prompt: &Prompt, corpus: &Corpus) -> Vec<usize> {
        use crate::types::Segment;
        let mut out = Vec::with_capacity(prompt.segments.len());
        let mut acc = 0usize;
        for seg in &prompt.segments {
            let mut buf = Vec::new();
            let one = Prompt {
                segments: vec![seg.clone()],
            };
            self.renderer.render_into(&one, corpus, &mut buf);
            acc += buf.len();
            if matches!(
                seg,
                Segment::System
                    | Segment::Block(_)
                    | Segment::PartialBlock { .. }
                    | Segment::LocationRef(_)
            ) {
                out.push(acc);
            }
        }
        // final boundary = full prompt (needed so cached_len==total is
        // detectable for identical prompts)
        if out.last() != Some(&acc) {
            out.push(acc);
        }
        out
    }

    /// Serve a prompt: resume from the deepest cached KV snapshot, prefill
    /// the remainder segment-by-segment (snapshotting KV at each segment
    /// boundary so later requests can reuse any shared *block prefix*, not
    /// just identical prompts), decode greedily, and return the record plus
    /// evicted request ids.
    pub fn serve(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
        decode_tokens: usize,
    ) -> Result<(ServedRequest, Vec<RequestId>, Vec<u32>)> {
        let tokens = self.renderer.render(prompt, corpus);
        let boundaries = self.boundaries(prompt, corpus);
        let total = tokens.len();
        debug_assert_eq!(boundaries.last().copied(), Some(total));
        let t0 = std::time::Instant::now();

        // deepest reusable KV snapshot (snapshots live at boundaries)
        let (cached_len, kv) = match self.cache.deepest_payload(&tokens) {
            Some((len, snap)) => (
                len,
                KvState {
                    literal: snap.literal.clone(),
                    len: snap.len,
                },
            ),
            None => (0, self.runtime.empty_kv()?),
        };
        debug_assert_eq!(cached_len, kv.len);

        let mut evicted: Vec<RequestId> = Vec::new();
        let mut kv_cur = kv;
        let mut logits: Option<Vec<f32>> = None;
        let mut prefill_runs = 0u32;
        if cached_len < total {
            // prefill segment-wise from the resume point, snapshotting at
            // every boundary
            let mut pos = cached_len;
            for &b in boundaries.iter().filter(|&&b| b > cached_len) {
                let (lg, kv_next) = self.runtime.prefill(&tokens[pos..b], kv_cur)?;
                kv_cur = kv_next;
                logits = Some(lg);
                prefill_runs += 1;
                let snap = Arc::new(KvSnapshot {
                    literal: kv_cur.literal.clone(),
                    len: kv_cur.len,
                });
                evicted.extend(self.cache.set_payload(&tokens[..b], req.id, snap));
                pos = b;
            }
        } else {
            // full prompt cached: re-run the last token to recover logits
            let resume = KvState {
                literal: kv_cur.literal,
                len: kv_cur.len - 1,
            };
            let (lg, kv_next) = self.runtime.prefill(&tokens[total - 1..], resume)?;
            kv_cur = kv_next;
            logits = Some(lg);
        }
        let ttft = t0.elapsed().as_secs_f64();
        self.stat_prefilled_tokens += (total - cached_len) as u64;
        self.stat_reused_tokens += cached_len as u64;

        // decode
        let (answer, _kv_final) =
            self.runtime
                .decode(logits.expect("at least one chunk ran"), kv_cur, decode_tokens)?;
        let wall = t0.elapsed().as_secs_f64();
        evicted.sort_unstable();
        evicted.dedup();

        Ok((
            ServedRequest {
                request: req.clone(),
                prompt: prompt.clone(),
                prompt_tokens: total,
                cached_tokens: cached_len,
                ttft,
                wall,
                quality: 0.0, // real engine measures latency, not the proxy
                queued_ttft: ttft,
                prefill_chunks: prefill_runs.max(1),
                // no tier store behind the real engine (yet): all hot
                tier_hits: crate::types::TierHits::hot(cached_len),
            },
            evicted,
            answer,
        ))
    }
}

/// The §4.1 proxy↔engine contract for the PJRT-backed engine, so the
/// generic serving layer behind [`crate::api::Server`] can drive real
/// model execution through the exact pipeline the simulated engine uses
/// (`ctxpilot serve --engine real`). The quality model is a proxy-side
/// concern, so it is ignored here; PJRT failures are fatal (the serving
/// layer has no error channel, and a dead accelerator is not recoverable
/// per-request).
impl InferenceEngine for RealEngine {
    fn serve(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
        _quality: &QualityModel,
        decode_tokens: usize,
    ) -> (ServedRequest, Vec<RequestId>) {
        let (served, evicted, _answer) = RealEngine::serve(self, req, prompt, corpus, decode_tokens)
            .expect("PJRT engine failure");
        (served, evicted)
    }

    fn peek_cached(&mut self, _req: &Request, prompt: &Prompt, corpus: &Corpus) -> usize {
        let tokens = self.renderer.render(prompt, corpus);
        self.cache.peek_prefix_len(&tokens)
    }

    fn chunk_boundaries(
        &mut self,
        _req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
    ) -> Vec<usize> {
        self.boundaries(prompt, corpus)
    }

    fn cache_stats(&self) -> CacheStats {
        CacheStats {
            resident_tokens: self.cache.resident_tokens(),
            capacity_tokens: self.cache.capacity(),
            lookup_tokens: self.cache.stat_lookup_tokens,
            matched_tokens: self.cache.stat_matched_tokens,
            inserted_tokens: self.cache.stat_inserted_tokens,
            evicted_tokens: self.cache.stat_evicted_tokens,
            hot_hit_tokens: self.stat_reused_tokens,
            // no tier store: residency/demotion/promotion counters stay 0
            ..CacheStats::default()
        }
    }
}
