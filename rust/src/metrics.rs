//! Serving metrics: TTFT, prefill throughput, cache hit ratios, the
//! per-experiment aggregates every bench table reports, and the per-shard
//! snapshots the concurrent serving layer ([`crate::serve`]) emits.

use crate::types::ServedRequest;
use crate::util::histogram::Summary;

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub ttft: Summary,
    /// Queue-aware TTFT: completion on the shard's virtual clock, counting
    /// time spent waiting behind (or interleaved with) the rest of the
    /// admission wave — the metric chunked-prefill admission moves.
    pub queued_ttft: Summary,
    pub wall: Summary,
    pub quality: Summary,
    pub prompt_tokens: Summary,
    pub total_prompt_tokens: u64,
    pub total_cached_tokens: u64,
    /// Per-tier breakdown of `total_cached_tokens` (hot = HBM radix hits,
    /// warm = DRAM promotions, cold = SSD promotions); the three always
    /// sum to `total_cached_tokens`.
    pub total_hot_hit_tokens: u64,
    pub total_warm_hit_tokens: u64,
    pub total_cold_hit_tokens: u64,
    pub total_prefill_seconds: f64,
    /// Prefill chunks issued (== requests served when chunking is off).
    pub total_prefill_chunks: u64,
    /// Cached tokens served to requests whose session was placed on its
    /// shard by a positive context-affinity vote
    /// ([`crate::serve::placement`]); 0 under session-hash / round-robin
    /// placement. Filled at the serving-engine level (the per-shard
    /// recorder cannot see placement decisions), so it is 0 on the raw
    /// per-shard `RunMetrics` and set on the aggregate.
    pub total_affinity_hit_tokens: u64,
    /// (progress fraction of requests, cumulative hit ratio) samples for
    /// the Fig. 12 time series.
    pub hit_series: Vec<(f64, f64)>,
    /// cumulative cached tokens over progress (Fig. 13).
    pub cached_series: Vec<(f64, u64)>,
    n: usize,
    series_every: usize,
}

impl RunMetrics {
    pub fn new() -> Self {
        Self {
            series_every: 16,
            ..Default::default()
        }
    }

    pub fn with_series_stride(stride: usize) -> Self {
        Self {
            series_every: stride.max(1),
            ..Default::default()
        }
    }

    pub fn record(&mut self, s: &ServedRequest) {
        self.ttft.record(s.ttft);
        self.queued_ttft.record(s.queued_ttft);
        self.wall.record(s.wall);
        self.quality.record(s.quality);
        self.prompt_tokens.record(s.prompt_tokens as f64);
        self.total_prompt_tokens += s.prompt_tokens as u64;
        self.total_cached_tokens += s.cached_tokens as u64;
        self.total_hot_hit_tokens += s.tier_hits.hbm as u64;
        self.total_warm_hit_tokens += s.tier_hits.dram as u64;
        self.total_cold_hit_tokens += s.tier_hits.ssd as u64;
        self.total_prefill_seconds += s.ttft;
        self.total_prefill_chunks += s.prefill_chunks as u64;
        self.n += 1;
        if self.n % self.series_every == 0 {
            self.hit_series.push((self.n as f64, self.hit_ratio()));
            self.cached_series.push((self.n as f64, self.total_cached_tokens));
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Aggregate KV-cache hit ratio (cached / total prompt tokens).
    pub fn hit_ratio(&self) -> f64 {
        if self.total_prompt_tokens == 0 {
            0.0
        } else {
            self.total_cached_tokens as f64 / self.total_prompt_tokens as f64
        }
    }

    /// Prefill throughput in tokens/second: total prompt tokens over the
    /// summed prefill time (the paper's Table 2 metric).
    pub fn prefill_throughput(&self) -> f64 {
        if self.total_prefill_seconds <= 0.0 {
            0.0
        } else {
            self.total_prompt_tokens as f64 / self.total_prefill_seconds
        }
    }

    pub fn mean_quality(&self) -> f64 {
        self.quality.mean()
    }

    pub fn mean_ttft(&mut self) -> f64 {
        self.ttft.mean()
    }

    pub fn p99_ttft(&mut self) -> f64 {
        self.ttft.p99()
    }

    pub fn p99_queued_ttft(&mut self) -> f64 {
        self.queued_ttft.p99()
    }

    /// The progress series with a final partial-stride sample appended.
    ///
    /// [`RunMetrics::record`] samples the series every `series_every`
    /// requests, so a run whose length is not a stride multiple ends
    /// mid-stride and its last `n % series_every` requests never appear.
    /// Exporters want the curve to end at the run's final state, so this
    /// returns clones of both series with an `(n, hit_ratio)` /
    /// `(n, total_cached_tokens)` tail appended when the run ended
    /// off-stride. The recorded series themselves are untouched (their
    /// exact stride is pinned by `series_sampled_on_stride`).
    pub fn series_with_tail(&self) -> (Vec<(f64, f64)>, Vec<(f64, u64)>) {
        let mut hits = self.hit_series.clone();
        let mut cached = self.cached_series.clone();
        if self.n > 0 && self.n % self.series_every != 0 {
            hits.push((self.n as f64, self.hit_ratio()));
            cached.push((self.n as f64, self.total_cached_tokens));
        }
        (hits, cached)
    }

    /// Fold another run's samples into this one (shard aggregation).
    ///
    /// Summaries and token totals combine exactly; the progress series are
    /// concatenated as-is, so after a merge their x-coordinates remain
    /// relative to the *source* run — callers that need a global series
    /// should read it per shard before merging.
    pub fn merge(&mut self, other: &RunMetrics) {
        self.ttft.merge(&other.ttft);
        self.queued_ttft.merge(&other.queued_ttft);
        self.wall.merge(&other.wall);
        self.quality.merge(&other.quality);
        self.prompt_tokens.merge(&other.prompt_tokens);
        self.total_prompt_tokens += other.total_prompt_tokens;
        self.total_cached_tokens += other.total_cached_tokens;
        self.total_hot_hit_tokens += other.total_hot_hit_tokens;
        self.total_warm_hit_tokens += other.total_warm_hit_tokens;
        self.total_cold_hit_tokens += other.total_cold_hit_tokens;
        self.total_prefill_seconds += other.total_prefill_seconds;
        self.total_prefill_chunks += other.total_prefill_chunks;
        self.total_affinity_hit_tokens += other.total_affinity_hit_tokens;
        self.hit_series.extend(other.hit_series.iter().copied());
        self.cached_series.extend(other.cached_series.iter().copied());
        self.n += other.n;
    }
}

/// One serving shard's telemetry snapshot ([`crate::serve`]): request
/// volume, cache effectiveness, latency percentiles and structure sizes.
#[derive(Clone, Debug)]
pub struct ShardStats {
    pub shard: usize,
    /// Requests served by this shard so far.
    pub served: usize,
    /// Largest per-batch queue this shard has absorbed.
    pub max_queue_depth: usize,
    /// Cached / total prompt tokens for this shard's requests.
    pub hit_ratio: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    /// p99 of queue-aware TTFT (waiting included) — what chunked-prefill
    /// admission improves for short requests.
    pub p99_queued_ttft: f64,
    /// Prefill chunks issued by this shard (== served when chunking off).
    pub prefill_chunks: u64,
    /// Alive nodes in the shard's context index (0 when serving baseline
    /// prompts without a pilot).
    pub index_nodes: usize,
    /// Distinct context blocks in the shard index's inverted block
    /// directory ([`crate::index::tree::ContextIndex::distinct_blocks`])
    /// — the published probe set placement votes against; 0 without a
    /// pilot.
    pub index_blocks: usize,
    /// Sessions the placement layer pinned to this shard
    /// ([`crate::serve::placement`]) — counts placement decisions, unlike
    /// `sessions` which counts conversations the engine has served.
    pub placed_sessions: usize,
    /// Cached tokens served here to affinity-placed sessions (0 under
    /// session-hash / round-robin placement).
    pub affinity_hit_tokens: u64,
    /// Tokens resident in the shard's radix prefix cache (the HBM tier).
    pub resident_tokens: usize,
    /// Tokens resident in the shard's DRAM tier (0 without a tier store).
    pub dram_resident_tokens: usize,
    /// Tokens resident in the shard's SSD tier.
    pub ssd_resident_tokens: usize,
    /// Cumulative hit tokens promoted from DRAM (warm).
    pub warm_hit_tokens: u64,
    /// Cumulative hit tokens promoted from SSD (cold).
    pub cold_hit_tokens: u64,
    /// Conversation sessions pinned to this shard so far.
    pub sessions: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::*;

    fn served(prompt_tokens: usize, cached: usize, ttft: f64, q: f64) -> ServedRequest {
        let req = Request {
            id: RequestId(0),
            session: SessionId(0),
            turn: 0,
            context: vec![],
            query: QueryId(0),
        };
        ServedRequest {
            prompt: Prompt::baseline(&req),
            request: req,
            prompt_tokens,
            cached_tokens: cached,
            ttft,
            wall: ttft + 0.1,
            quality: q,
            queued_ttft: ttft * 2.0,
            prefill_chunks: 1,
            tier_hits: TierHits::hot(cached),
        }
    }

    #[test]
    fn hit_ratio_aggregates() {
        let mut m = RunMetrics::new();
        m.record(&served(100, 50, 0.1, 0.8));
        m.record(&served(100, 0, 0.2, 0.6));
        assert!((m.hit_ratio() - 0.25).abs() < 1e-9);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn throughput_is_tokens_over_time() {
        let mut m = RunMetrics::new();
        m.record(&served(1000, 0, 0.5, 1.0));
        m.record(&served(1000, 0, 0.5, 1.0));
        assert!((m.prefill_throughput() - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn series_sampled_on_stride() {
        let mut m = RunMetrics::with_series_stride(2);
        for _ in 0..10 {
            m.record(&served(10, 5, 0.1, 0.5));
        }
        assert_eq!(m.hit_series.len(), 5);
        assert_eq!(m.cached_series.last().unwrap().1, 50);
    }

    #[test]
    fn series_with_tail_appends_final_partial_stride() {
        let mut m = RunMetrics::with_series_stride(4);
        for _ in 0..10 {
            m.record(&served(10, 5, 0.1, 0.5));
        }
        // the recorded series stops at the last full stride (n = 8)...
        assert_eq!(m.hit_series.len(), 2);
        // ...but the exported view ends at the run's final state (n = 10)
        let (hits, cached) = m.series_with_tail();
        assert_eq!(hits.len(), 3);
        assert_eq!(hits.last().unwrap().0, 10.0);
        assert!((hits.last().unwrap().1 - m.hit_ratio()).abs() < 1e-12);
        assert_eq!(cached.last().unwrap(), &(10.0, 50));
        assert_eq!(m.hit_series.len(), 2, "recorded series must not grow");

        // on-stride and empty runs gain no tail
        let mut even = RunMetrics::with_series_stride(5);
        for _ in 0..10 {
            even.record(&served(10, 5, 0.1, 0.5));
        }
        assert_eq!(even.series_with_tail().0.len(), even.hit_series.len());
        assert!(RunMetrics::new().series_with_tail().0.is_empty());
    }

    #[test]
    fn merge_of_splits_equals_whole_run() {
        use crate::util::prop::{self, CaseResult};

        fn sorted(s: &Summary) -> Vec<f64> {
            let mut v = s.samples().to_vec();
            v.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            v
        }

        prop::quickcheck("metrics: merge of splits == whole run", |rng, size| {
            let n = rng.range(1, size.max(2));
            let samples: Vec<ServedRequest> = (0..n)
                .map(|_| {
                    let prompt = rng.range(1, 500);
                    let cached = rng.below(prompt + 1);
                    let dram = rng.below(cached + 1);
                    let ssd = rng.below(cached - dram + 1);
                    let mut s = served(prompt, cached, rng.f64(), rng.f64());
                    s.prefill_chunks = rng.range(1, 4) as u32;
                    s.tier_hits = TierHits {
                        hbm: cached - dram - ssd,
                        dram,
                        ssd,
                    };
                    s
                })
                .collect();

            let mut whole = RunMetrics::new();
            for s in &samples {
                whole.record(s);
            }

            // split the run at random points, record each part separately,
            // then merge the parts back together
            let mut merged = RunMetrics::new();
            let mut rest: &[ServedRequest] = &samples;
            while !rest.is_empty() {
                let take = rng.range(1, rest.len() + 1);
                let mut part = RunMetrics::new();
                for s in &rest[..take] {
                    part.record(s);
                }
                merged.merge(&part);
                rest = &rest[take..];
            }

            if merged.len() != whole.len() {
                return CaseResult::Fail(format!("len {} != {}", merged.len(), whole.len()));
            }
            let exact = [
                (
                    "prompt_tokens",
                    merged.total_prompt_tokens,
                    whole.total_prompt_tokens,
                ),
                (
                    "cached_tokens",
                    merged.total_cached_tokens,
                    whole.total_cached_tokens,
                ),
                ("hot", merged.total_hot_hit_tokens, whole.total_hot_hit_tokens),
                ("warm", merged.total_warm_hit_tokens, whole.total_warm_hit_tokens),
                ("cold", merged.total_cold_hit_tokens, whole.total_cold_hit_tokens),
                ("chunks", merged.total_prefill_chunks, whole.total_prefill_chunks),
            ];
            for (name, a, b) in exact {
                if a != b {
                    return CaseResult::Fail(format!("{name}: {a} != {b}"));
                }
            }
            // float accumulation order differs between the two paths, so
            // totals agree only to rounding
            if (merged.total_prefill_seconds - whole.total_prefill_seconds).abs() > 1e-9 {
                return CaseResult::Fail("prefill seconds diverged".into());
            }
            if (merged.hit_ratio() - whole.hit_ratio()).abs() > 1e-12 {
                return CaseResult::Fail("hit ratio diverged".into());
            }
            // summaries hold the same sample multiset
            for (name, a, b) in [
                ("ttft", &merged.ttft, &whole.ttft),
                ("queued_ttft", &merged.queued_ttft, &whole.queued_ttft),
                ("prompt", &merged.prompt_tokens, &whole.prompt_tokens),
            ] {
                if sorted(a) != sorted(b) {
                    return CaseResult::Fail(format!("{name} samples diverged"));
                }
            }
            CaseResult::Pass
        });
    }

    #[test]
    fn queued_ttft_and_chunks_accumulate() {
        let mut m = RunMetrics::new();
        let mut s = served(100, 0, 0.2, 0.5);
        s.prefill_chunks = 3;
        m.record(&s);
        m.record(&served(50, 0, 0.1, 0.5));
        assert_eq!(m.total_prefill_chunks, 4);
        // queued samples are tracked independently of raw ttft
        assert!((m.queued_ttft.mean() - 0.3).abs() < 1e-9);
        assert!((m.ttft.mean() - 0.15).abs() < 1e-9);
        let mut other = RunMetrics::new();
        other.record(&served(10, 0, 0.05, 0.5));
        m.merge(&other);
        assert_eq!(m.total_prefill_chunks, 5);
        assert_eq!(m.queued_ttft.len(), 3);
    }

    #[test]
    fn tier_hit_totals_track_and_merge() {
        let mut m = RunMetrics::new();
        let mut s = served(100, 60, 0.1, 0.5);
        s.tier_hits = TierHits {
            hbm: 40,
            dram: 15,
            ssd: 5,
        };
        m.record(&s);
        m.record(&served(50, 10, 0.1, 0.5)); // all-hot
        assert_eq!(m.total_hot_hit_tokens, 50);
        assert_eq!(m.total_warm_hit_tokens, 15);
        assert_eq!(m.total_cold_hit_tokens, 5);
        // the three tiers partition the cached total
        assert_eq!(
            m.total_hot_hit_tokens + m.total_warm_hit_tokens + m.total_cold_hit_tokens,
            m.total_cached_tokens
        );
        let mut other = RunMetrics::new();
        let mut s2 = served(10, 4, 0.1, 0.5);
        s2.tier_hits = TierHits {
            hbm: 0,
            dram: 0,
            ssd: 4,
        };
        other.record(&s2);
        m.merge(&other);
        assert_eq!(m.total_cold_hit_tokens, 9);
        assert_eq!(
            m.total_hot_hit_tokens + m.total_warm_hit_tokens + m.total_cold_hit_tokens,
            m.total_cached_tokens
        );
    }

    #[test]
    fn affinity_tokens_merge_and_default_to_zero() {
        let mut a = RunMetrics::new();
        a.record(&served(100, 50, 0.1, 0.8));
        assert_eq!(a.total_affinity_hit_tokens, 0, "record never attributes");
        a.total_affinity_hit_tokens = 10;
        let mut b = RunMetrics::new();
        b.total_affinity_hit_tokens = 5;
        a.merge(&b);
        assert_eq!(a.total_affinity_hit_tokens, 15);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = RunMetrics::new();
        assert_eq!(m.hit_ratio(), 0.0);
        assert_eq!(m.prefill_throughput(), 0.0);
        assert!(m.is_empty());
    }

    #[test]
    fn merge_combines_totals_and_samples() {
        let mut a = RunMetrics::new();
        a.record(&served(100, 50, 0.1, 0.8));
        let mut b = RunMetrics::new();
        b.record(&served(300, 50, 0.3, 0.6));
        b.record(&served(100, 0, 0.2, 0.4));
        a.merge(&b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.total_prompt_tokens, 500);
        assert_eq!(a.total_cached_tokens, 100);
        assert!((a.hit_ratio() - 0.2).abs() < 1e-9);
        assert!((a.mean_ttft() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn merge_into_empty_equals_source() {
        let mut src = RunMetrics::new();
        for i in 0..5usize {
            src.record(&served(10 * (i + 1), i, 0.01 * i as f64, 0.5));
        }
        let mut dst = RunMetrics::new();
        dst.merge(&src);
        assert_eq!(dst.len(), src.len());
        assert_eq!(dst.total_prompt_tokens, src.total_prompt_tokens);
        assert!((dst.hit_ratio() - src.hit_ratio()).abs() < 1e-12);
    }
}
