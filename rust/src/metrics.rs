//! Serving metrics: TTFT, prefill throughput, cache hit ratios, and the
//! per-experiment aggregates every bench table reports.

use crate::types::ServedRequest;
use crate::util::histogram::Summary;

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub ttft: Summary,
    pub wall: Summary,
    pub quality: Summary,
    pub prompt_tokens: Summary,
    pub total_prompt_tokens: u64,
    pub total_cached_tokens: u64,
    pub total_prefill_seconds: f64,
    /// (progress fraction of requests, cumulative hit ratio) samples for
    /// the Fig. 12 time series.
    pub hit_series: Vec<(f64, f64)>,
    /// cumulative cached tokens over progress (Fig. 13).
    pub cached_series: Vec<(f64, u64)>,
    n: usize,
    series_every: usize,
}

impl RunMetrics {
    pub fn new() -> Self {
        Self {
            series_every: 16,
            ..Default::default()
        }
    }

    pub fn with_series_stride(stride: usize) -> Self {
        Self {
            series_every: stride.max(1),
            ..Default::default()
        }
    }

    pub fn record(&mut self, s: &ServedRequest) {
        self.ttft.record(s.ttft);
        self.wall.record(s.wall);
        self.quality.record(s.quality);
        self.prompt_tokens.record(s.prompt_tokens as f64);
        self.total_prompt_tokens += s.prompt_tokens as u64;
        self.total_cached_tokens += s.cached_tokens as u64;
        self.total_prefill_seconds += s.ttft;
        self.n += 1;
        if self.n % self.series_every == 0 {
            self.hit_series.push((self.n as f64, self.hit_ratio()));
            self.cached_series.push((self.n as f64, self.total_cached_tokens));
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Aggregate KV-cache hit ratio (cached / total prompt tokens).
    pub fn hit_ratio(&self) -> f64 {
        if self.total_prompt_tokens == 0 {
            0.0
        } else {
            self.total_cached_tokens as f64 / self.total_prompt_tokens as f64
        }
    }

    /// Prefill throughput in tokens/second: total prompt tokens over the
    /// summed prefill time (the paper's Table 2 metric).
    pub fn prefill_throughput(&self) -> f64 {
        if self.total_prefill_seconds <= 0.0 {
            0.0
        } else {
            self.total_prompt_tokens as f64 / self.total_prefill_seconds
        }
    }

    pub fn mean_quality(&self) -> f64 {
        self.quality.mean()
    }

    pub fn mean_ttft(&mut self) -> f64 {
        self.ttft.mean()
    }

    pub fn p99_ttft(&mut self) -> f64 {
        self.ttft.p99()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::*;

    fn served(prompt_tokens: usize, cached: usize, ttft: f64, q: f64) -> ServedRequest {
        let req = Request {
            id: RequestId(0),
            session: SessionId(0),
            turn: 0,
            context: vec![],
            query: QueryId(0),
        };
        ServedRequest {
            prompt: Prompt::baseline(&req),
            request: req,
            prompt_tokens,
            cached_tokens: cached,
            ttft,
            wall: ttft + 0.1,
            quality: q,
        }
    }

    #[test]
    fn hit_ratio_aggregates() {
        let mut m = RunMetrics::new();
        m.record(&served(100, 50, 0.1, 0.8));
        m.record(&served(100, 0, 0.2, 0.6));
        assert!((m.hit_ratio() - 0.25).abs() < 1e-9);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn throughput_is_tokens_over_time() {
        let mut m = RunMetrics::new();
        m.record(&served(1000, 0, 0.5, 1.0));
        m.record(&served(1000, 0, 0.5, 1.0));
        assert!((m.prefill_throughput() - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn series_sampled_on_stride() {
        let mut m = RunMetrics::with_series_stride(2);
        for _ in 0..10 {
            m.record(&served(10, 5, 0.1, 0.5));
        }
        assert_eq!(m.hit_series.len(), 5);
        assert_eq!(m.cached_series.last().unwrap().1, 50);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = RunMetrics::new();
        assert_eq!(m.hit_ratio(), 0.0);
        assert_eq!(m.prefill_throughput(), 0.0);
        assert!(m.is_empty());
    }
}
