//! Scoped parallel-map substrate (no rayon/tokio in the offline image).
//!
//! The context-index build parallelizes its O(N^2) distance matrix across
//! cores (the paper builds it on CPUs/GPUs, §4.1); the sharded serving
//! layer (Table 6) drives one engine per shard from a worker pool.
//! `std::thread::scope` gives us borrow-safe fork-join without a
//! persistent pool.

/// Parallel map over `items`, preserving order. Splits into at most
/// `threads` contiguous chunks. Falls back to serial for tiny inputs.
pub fn par_map<T: Sync, R: Send, F: Fn(&T) -> R + Sync>(
    items: &[T],
    threads: usize,
    f: F,
) -> Vec<R> {
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() < 32 {
        return items.iter().map(|x| f(x)).collect();
    }
    let chunk = items.len().div_ceil(threads);
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    let out_chunks: Vec<&mut [Option<R>]> = out.chunks_mut(chunk).collect();
    std::thread::scope(|s| {
        for (ci, out_chunk) in out_chunks.into_iter().enumerate() {
            let start = ci * chunk;
            let f = &f;
            let items = &items[start..(start + out_chunk.len())];
            s.spawn(move || {
                for (slot, item) in out_chunk.iter_mut().zip(items) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Parallel for over index ranges: calls `f(lo, hi)` per shard.
pub fn par_shards<F: Fn(usize, usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1);
    if threads <= 1 || n < 32 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        for t in 0..threads {
            let lo = t * chunk;
            if lo >= n {
                break;
            }
            let hi = (lo + chunk).min(n);
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Worker-pool map over task indices `0..n`: `threads` workers repeatedly
/// claim the next unclaimed index from a shared counter, so a slow task
/// never idles the other workers (dynamic load balancing, vs `par_map`'s
/// static chunking). Results come back in task order.
///
/// Unlike [`par_map`] there is no serial fallback for small inputs: the
/// serving layer hands this a handful of *heavy* shard queues, exactly the
/// shape the `items.len() < 32` heuristic would wrongly serialize.
pub fn par_map_tasks<R: Send, F: Fn(usize) -> R + Sync>(
    n: usize,
    threads: usize,
    f: F,
) -> Vec<R> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let done = &done;
            let f = &f;
            s.spawn(move || {
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                if !local.is_empty() {
                    // infallible lock ON PURPOSE (not `api::shard_guard`):
                    // this mutex only poisons if a sibling worker panicked,
                    // and that panic is about to resurface from the scope
                    // join anyway — the facade converts it to a typed error
                    // at its own boundary, one layer up.
                    done.lock().expect("worker poisoned").extend(local);
                }
            });
        }
    });
    let mut out = done.into_inner().expect("worker poisoned");
    out.sort_unstable_by_key(|&(i, _)| i);
    out.into_iter().map(|(_, r)| r).collect()
}

/// Default parallelism: available cores (minus one to keep the box
/// responsive), at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let items: Vec<u64> = (0..1000).collect();
        let serial: Vec<u64> = items.iter().map(|x| x * x).collect();
        for threads in [1, 2, 4, 7] {
            let par = par_map(&items, threads, |x| x * x);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn par_map_empty_and_tiny() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, 4, |x| *x).is_empty());
        assert_eq!(par_map(&[5u32], 4, |x| x + 1), vec![6]);
    }

    #[test]
    fn par_shards_covers_everything() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_shards(n, 4, |lo, hi| {
            for slot in &hits[lo..hi] {
                slot.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn par_map_tasks_matches_serial_and_preserves_order() {
        for n in [0usize, 1, 3, 7, 100] {
            let serial: Vec<usize> = (0..n).map(|i| i * i).collect();
            for threads in [1, 2, 4, 9] {
                let par = par_map_tasks(n, threads, |i| i * i);
                assert_eq!(par, serial, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn par_map_tasks_parallelizes_small_inputs() {
        // 4 tasks, 4 threads: every task must run exactly once even though
        // the input is far below par_map's serial-fallback threshold.
        use std::sync::atomic::{AtomicU64, Ordering};
        let hits: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        let out = par_map_tasks(4, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
