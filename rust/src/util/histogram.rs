//! Latency statistics substrate: streaming summary + exact percentiles.
//!
//! The experiment harness reports Avg and P99 latencies (Table 4) and
//! percentile TTFT (Table 3b); sample counts are small enough (≤ a few
//! hundred thousand) that exact sorted-sample percentiles are fine.

#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum() / self.samples.len() as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self
            .samples
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
    }

    /// Exact percentile with linear interpolation, q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q), "percentile {q} out of range");
        self.ensure_sorted();
        if self.samples.is_empty() {
            return 0.0;
        }
        let n = self.samples.len();
        if n == 1 {
            return self.samples[0];
        }
        let rank = q / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn merge(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }
}

/// Fixed-bucket counter histogram for hit-ratio/time-series plots.
#[derive(Clone, Debug)]
pub struct Buckets {
    edges: Vec<f64>,
    counts: Vec<u64>,
}

impl Buckets {
    /// `edges` are the upper bounds of each bucket; a final overflow bucket
    /// is appended automatically.
    pub fn new(edges: Vec<f64>) -> Self {
        let n = edges.len();
        Self {
            edges,
            counts: vec![0; n + 1],
        }
    }

    pub fn linear(lo: f64, hi: f64, n: usize) -> Self {
        assert!(n > 0 && hi > lo);
        let step = (hi - lo) / n as f64;
        Self::new((1..=n).map(|i| lo + step * i as f64).collect())
    }

    pub fn record(&mut self, x: f64) {
        let idx = self
            .edges
            .iter()
            .position(|&e| x <= e)
            .unwrap_or(self.edges.len());
        self.counts[idx] += 1;
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_sum() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.record(x);
        }
        assert_eq!(s.sum(), 10.0);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    /// An empty summary reports 0.0 for every percentile instead of
    /// panicking — load reports lean on this when a cell serves nothing
    /// (e.g. a fully shed backpressure run still emits p50/p99 rows).
    #[test]
    fn empty_summary_percentile_is_zero() {
        let mut s = Summary::new();
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.p99(), 0.0);
        assert_eq!(s.percentile(100.0), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut s = Summary::new();
        for x in 1..=100 {
            s.record(x as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p95() - 95.05).abs() < 0.02);
        assert!((s.p99() - 99.01).abs() < 0.02);
    }

    #[test]
    fn percentile_accessors_agree_and_are_monotone() {
        let mut s = Summary::new();
        for x in [5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0, 4.0, 6.0, 10.0] {
            s.record(x);
        }
        assert_eq!(s.p50(), s.percentile(50.0));
        assert_eq!(s.p95(), s.percentile(95.0));
        assert_eq!(s.p99(), s.percentile(99.0));
        assert!(s.p50() <= s.p95() && s.p95() <= s.p99());
    }

    #[test]
    fn percentile_single_sample() {
        let mut s = Summary::new();
        s.record(7.0);
        assert_eq!(s.p50(), 7.0);
        assert_eq!(s.p99(), 7.0);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let mut s = Summary::new();
        for _ in 0..10 {
            s.record(3.0);
        }
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn record_after_percentile_resorts() {
        let mut s = Summary::new();
        s.record(10.0);
        s.record(1.0);
        let _ = s.p50();
        s.record(0.5);
        assert_eq!(s.percentile(0.0), 0.5);
    }

    #[test]
    fn merge_combines() {
        let mut a = Summary::new();
        a.record(1.0);
        let mut b = Summary::new();
        b.record(3.0);
        a.merge(&b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.mean(), 2.0);
    }

    #[test]
    fn buckets_overflow() {
        let mut b = Buckets::linear(0.0, 10.0, 5);
        b.record(1.0);
        b.record(9.9);
        b.record(100.0); // overflow
        assert_eq!(b.total(), 3);
        assert_eq!(*b.counts().last().unwrap(), 1);
    }
}
