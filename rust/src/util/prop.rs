//! Mini property-testing substrate (proptest is not in the offline image).
//!
//! `check` runs a predicate over many seeded-random cases; on failure it
//! reports the case seed so the exact input can be replayed (`Rng::new(seed)`
//! regenerates it). A light "shrinking" pass retries with smaller size
//! hints to report the smallest failing size.

use crate::util::prng::Rng;

pub struct Config {
    pub cases: u64,
    pub base_seed: u64,
    /// Size hint passed to the generator (collections scale with it).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 256,
            base_seed: 0xC0FFEE,
            max_size: 64,
        }
    }
}

/// Outcome of a single property case.
pub enum CaseResult {
    Pass,
    /// Discard (precondition not met) — does not count toward `cases`.
    Discard,
    Fail(String),
}

impl From<bool> for CaseResult {
    fn from(ok: bool) -> Self {
        if ok {
            CaseResult::Pass
        } else {
            CaseResult::Fail("property returned false".to_string())
        }
    }
}

impl From<Result<(), String>> for CaseResult {
    fn from(r: Result<(), String>) -> Self {
        match r {
            Ok(()) => CaseResult::Pass,
            Err(e) => CaseResult::Fail(e),
        }
    }
}

/// Run `prop(rng, size)` for `cfg.cases` non-discarded cases.
/// Panics with a replayable seed + smallest failing size on failure.
pub fn check<R: Into<CaseResult>, F: FnMut(&mut Rng, usize) -> R>(
    name: &str,
    cfg: Config,
    mut prop: F,
) {
    let mut ran = 0u64;
    let mut attempts = 0u64;
    while ran < cfg.cases {
        attempts += 1;
        if attempts > cfg.cases * 20 {
            panic!("property '{name}': too many discards ({attempts} attempts)");
        }
        let seed = cfg.base_seed.wrapping_add(attempts.wrapping_mul(0x9E3779B97F4A7C15));
        // size grows with the case index so early failures are small
        let size = 1 + (ran as usize * cfg.max_size) / (cfg.cases as usize).max(1);
        let mut rng = Rng::new(seed);
        match prop(&mut rng, size).into() {
            CaseResult::Pass => ran += 1,
            CaseResult::Discard => {}
            CaseResult::Fail(msg) => {
                // shrink: retry the same seed with smaller sizes to find the
                // smallest size that still fails
                let mut smallest = size;
                let mut smallest_msg = msg;
                let mut s = size / 2;
                while s >= 1 {
                    let mut rng2 = Rng::new(seed);
                    if let CaseResult::Fail(m) = prop(&mut rng2, s).into() {
                        smallest = s;
                        smallest_msg = m;
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    } else {
                        break;
                    }
                }
                panic!(
                    "property '{name}' failed (seed={seed:#x}, size={smallest}): {smallest_msg}"
                );
            }
        }
    }
}

/// `check` with default config.
pub fn quickcheck<R: Into<CaseResult>, F: FnMut(&mut Rng, usize) -> R>(name: &str, prop: F) {
    check(name, Config::default(), prop);
}

// ---- common generators -----------------------------------------------------

/// Random vec of usize ids drawn from [0, universe).
pub fn gen_ids(rng: &mut Rng, size: usize, universe: usize) -> Vec<usize> {
    let len = rng.range(0, size.max(1) + 1);
    (0..len).map(|_| rng.below(universe.max(1))).collect()
}

/// Random vec of *distinct* ids (like a retrieval result).
pub fn gen_distinct_ids(rng: &mut Rng, size: usize, universe: usize) -> Vec<usize> {
    let universe = universe.max(1);
    let len = rng.range(0, size.max(1).min(universe) + 1);
    rng.sample_indices(universe, len)
}

/// Random lowercase word.
pub fn gen_word(rng: &mut Rng, max_len: usize) -> String {
    let len = rng.range(1, max_len.max(2));
    (0..len)
        .map(|_| (b'a' + rng.below(26) as u8) as char)
        .collect()
}

/// Random text of `words` words.
pub fn gen_text(rng: &mut Rng, words: usize) -> String {
    (0..words)
        .map(|_| gen_word(rng, 8))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Random retrieval-shaped context: up to `size` distinct `BlockId`s drawn
/// from `[0, universe)` (may be empty, like `gen_distinct_ids`).
pub fn gen_context(rng: &mut Rng, size: usize, universe: usize) -> Vec<crate::types::BlockId> {
    gen_distinct_ids(rng, size, universe)
        .into_iter()
        .map(|i| crate::types::BlockId(i as u32))
        .collect()
}

/// Random request batch spread over `sessions` sessions with per-session
/// turn counters and non-empty contexts of up to `k` blocks — the shape
/// the serving layer ([`crate::serve`]) consumes. Request ids are the
/// batch indices, hence unique.
pub fn gen_requests(
    rng: &mut Rng,
    n: usize,
    sessions: usize,
    k: usize,
    universe: usize,
) -> Vec<crate::types::Request> {
    use crate::types::{BlockId, QueryId, Request, RequestId, SessionId};
    let sessions = sessions.max(1);
    let universe = universe.max(1);
    let mut turn = vec![0u32; sessions];
    (0..n)
        .map(|i| {
            let s = rng.below(sessions);
            let t = turn[s];
            turn[s] += 1;
            let mut context = gen_context(rng, k.max(1), universe);
            if context.is_empty() {
                context.push(BlockId(rng.below(universe) as u32));
            }
            Request {
                id: RequestId(i as u64),
                session: SessionId(s as u32),
                turn: t,
                context,
                query: QueryId(i as u64),
            }
        })
        .collect()
}

// ---- serving-layer test engines --------------------------------------------

use std::collections::{HashSet, VecDeque};
use std::sync::{Arc, Mutex};

use crate::corpus::Corpus;
use crate::engine::iface::{CacheStats, InferenceEngine};
use crate::quality::QualityModel;
use crate::types::{Prompt, Request, RequestId, ServedRequest, SessionId};

/// Hit/miss determinism fingerprint: `(request id, prompt tokens, cached
/// tokens)` per served request. Worker count, chunking and backend choice
/// must never change it — shared by the serving bench and the
/// engine-trait integration tests.
pub fn hit_miss_fingerprint(served: &[ServedRequest]) -> Vec<(u64, usize, usize)> {
    served
        .iter()
        .map(|s| (s.request.id.0, s.prompt_tokens, s.cached_tokens))
        .collect()
}

/// Tier-aware determinism fingerprint: [`hit_miss_fingerprint`] plus the
/// per-request hot/warm/cold hit split. Worker count must never change it
/// either — the per-shard tier store evolves in shard serve order, which
/// is worker-independent (pinned by `tests/serve_stress.rs` and
/// `benches/bench_tiering.rs`).
#[allow(clippy::type_complexity)]
pub fn reuse_fingerprint(
    served: &[ServedRequest],
) -> Vec<(u64, usize, usize, usize, usize, usize)> {
    served
        .iter()
        .map(|s| {
            (
                s.request.id.0,
                s.prompt_tokens,
                s.cached_tokens,
                s.tier_hits.hbm,
                s.tier_hits.dram,
                s.tier_hits.ssd,
            )
        })
        .collect()
}

/// One proxy→engine interaction, as observed by [`RecordingEngine`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EngineCall {
    /// Which shard's engine instance served it.
    pub shard: usize,
    pub request: RequestId,
    /// Eviction callback the engine returned for this serve (§4.1).
    pub evicted: Vec<RequestId>,
}

/// Shared interaction log, appendable from engines owned by shard mutexes.
pub type EngineLog = Arc<Mutex<Vec<EngineCall>>>;

/// Scripted [`InferenceEngine`] for serving-layer tests: deterministic
/// token accounting (a fixed cost per prompt segment, no corpus access)
/// and FIFO eviction under a token capacity — just enough behaviour to
/// exercise the proxy↔engine contract without the simulated latency model.
pub struct MockEngine {
    pub tokens_per_segment: usize,
    pub capacity_tokens: usize,
    resident: VecDeque<(RequestId, usize)>,
    resident_tokens: usize,
    sessions: HashSet<SessionId>,
    served: u64,
}

impl MockEngine {
    pub fn new(tokens_per_segment: usize, capacity_tokens: usize) -> MockEngine {
        MockEngine {
            tokens_per_segment: tokens_per_segment.max(1),
            capacity_tokens: capacity_tokens.max(1),
            resident: VecDeque::new(),
            resident_tokens: 0,
            sessions: HashSet::new(),
            served: 0,
        }
    }
}

impl InferenceEngine for MockEngine {
    fn serve(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        _corpus: &Corpus,
        _quality: &QualityModel,
        decode_tokens: usize,
    ) -> (ServedRequest, Vec<RequestId>) {
        let total = prompt.segments.len() * self.tokens_per_segment;
        let ttft = 1e-3 + total as f64 * 1e-6;
        self.sessions.insert(req.session);
        self.served += 1;
        self.resident.push_back((req.id, total));
        self.resident_tokens += total;
        let mut evicted = Vec::new();
        while self.resident_tokens > self.capacity_tokens && self.resident.len() > 1 {
            let (victim, len) = self.resident.pop_front().expect("non-empty queue");
            self.resident_tokens -= len;
            evicted.push(victim);
        }
        (
            ServedRequest {
                request: req.clone(),
                prompt: prompt.clone(),
                prompt_tokens: total,
                cached_tokens: 0,
                ttft,
                wall: ttft + decode_tokens as f64 * 1e-6,
                quality: 0.0,
                queued_ttft: ttft,
                prefill_chunks: 1,
                tier_hits: crate::types::TierHits::default(),
            },
            evicted,
        )
    }

    fn peek_cached(&mut self, _req: &Request, _prompt: &Prompt, _corpus: &Corpus) -> usize {
        0
    }

    fn prefers_lpm(&self) -> bool {
        false
    }

    fn chunk_boundaries(
        &mut self,
        _req: &Request,
        prompt: &Prompt,
        _corpus: &Corpus,
    ) -> Vec<usize> {
        (1..=prompt.segments.len())
            .map(|i| i * self.tokens_per_segment)
            .collect()
    }

    fn session_count(&self) -> usize {
        self.sessions.len()
    }

    fn cache_stats(&self) -> CacheStats {
        CacheStats {
            resident_tokens: self.resident_tokens,
            capacity_tokens: self.capacity_tokens,
            lookup_tokens: self.served,
            ..CacheStats::default()
        }
    }
}

/// Transparent [`InferenceEngine`] wrapper that appends every `serve`
/// interaction (request id + eviction callback) to a shared [`EngineLog`].
/// Used to assert that the serving layer issues *identical* engine-call
/// sequences regardless of the backend behind the trait.
pub struct RecordingEngine<E> {
    pub inner: E,
    pub shard_tag: usize,
    pub log: EngineLog,
}

impl<E: InferenceEngine> InferenceEngine for RecordingEngine<E> {
    fn serve(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
        quality: &QualityModel,
        decode_tokens: usize,
    ) -> (ServedRequest, Vec<RequestId>) {
        let (served, evicted) = self.inner.serve(req, prompt, corpus, quality, decode_tokens);
        self.log.lock().expect("engine log poisoned").push(EngineCall {
            shard: self.shard_tag,
            request: req.id,
            evicted: evicted.clone(),
        });
        (served, evicted)
    }

    fn peek_cached(&mut self, req: &Request, prompt: &Prompt, corpus: &Corpus) -> usize {
        self.inner.peek_cached(req, prompt, corpus)
    }

    fn lpm_order(&mut self, batch: &[Request], corpus: &Corpus) -> Vec<usize> {
        self.inner.lpm_order(batch, corpus)
    }

    fn prefers_lpm(&self) -> bool {
        self.inner.prefers_lpm()
    }

    fn chunk_boundaries(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
    ) -> Vec<usize> {
        self.inner.chunk_boundaries(req, prompt, corpus)
    }

    fn session_count(&self) -> usize {
        self.inner.session_count()
    }

    fn cache_stats(&self) -> CacheStats {
        self.inner.cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        quickcheck("always true", |_rng, _size| {
            count += 1;
            true
        });
        assert_eq!(count, Config::default().cases);
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_panics_with_seed() {
        quickcheck("always false", |_rng, _size| false);
    }

    #[test]
    fn discards_do_not_count() {
        let mut passes = 0;
        check(
            "discard half",
            Config {
                cases: 50,
                ..Default::default()
            },
            |rng, _size| {
                if rng.chance(0.5) {
                    CaseResult::Discard
                } else {
                    passes += 1;
                    CaseResult::Pass
                }
            },
        );
        assert_eq!(passes, 50);
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let ids = gen_ids(&mut rng, 10, 50);
            assert!(ids.len() <= 10);
            assert!(ids.iter().all(|&i| i < 50));
            let distinct = gen_distinct_ids(&mut rng, 10, 50);
            let set: std::collections::HashSet<_> = distinct.iter().collect();
            assert_eq!(set.len(), distinct.len());
            let w = gen_word(&mut rng, 8);
            assert!(!w.is_empty() && w.len() < 8);
        }
    }

    #[test]
    fn request_generator_respects_shape() {
        let mut rng = Rng::new(77);
        for _ in 0..50 {
            let reqs = gen_requests(&mut rng, 20, 5, 6, 40);
            assert_eq!(reqs.len(), 20);
            let mut ids = std::collections::HashSet::new();
            let mut turns: std::collections::HashMap<u32, u32> = Default::default();
            for r in &reqs {
                assert!(ids.insert(r.id), "duplicate request id");
                assert!(!r.context.is_empty());
                assert!(r.context.len() <= 6);
                assert!(r.context.iter().all(|b| b.0 < 40));
                let distinct: std::collections::HashSet<_> = r.context.iter().collect();
                assert_eq!(distinct.len(), r.context.len(), "dup blocks in context");
                assert!(r.session.0 < 5);
                // turns count up per session in arrival order
                let t = turns.entry(r.session.0).or_default();
                assert_eq!(r.turn, *t);
                *t += 1;
            }
        }
    }

    #[test]
    fn mock_engine_evicts_fifo_and_tracks_sessions() {
        use crate::corpus::CorpusConfig;
        use crate::quality::{ModelEra, QualityModel};
        use crate::tokenizer::Tokenizer;
        use crate::types::{BlockId, QueryId};

        let corpus = Corpus::generate(
            &CorpusConfig {
                n_docs: 8,
                ..Default::default()
            },
            &Tokenizer::default(),
        );
        let qm = QualityModel::new(ModelEra::Modern, false);
        // 3 segments x 10 tokens per request, capacity 70 -> the 3rd serve
        // overflows and evicts the oldest resident
        let mut eng = MockEngine::new(10, 70);
        let mk = |id: u64, session: u32| Request {
            id: RequestId(id),
            session: SessionId(session),
            turn: 0,
            context: vec![BlockId(1)],
            query: QueryId(id),
        };
        let mut evictions = Vec::new();
        for i in 0..3u64 {
            let r = mk(i, i as u32);
            let (served, ev) = eng.serve(&r, &Prompt::baseline(&r), &corpus, &qm, 4);
            assert_eq!(served.prompt_tokens, 30);
            evictions.extend(ev);
        }
        assert_eq!(evictions, vec![RequestId(0)]);
        assert_eq!(eng.session_count(), 3);
        assert!(eng.cache_stats().resident_tokens <= 70);
        // boundaries are per-segment multiples
        let r = mk(9, 9);
        assert_eq!(
            eng.chunk_boundaries(&r, &Prompt::baseline(&r), &corpus),
            vec![10, 20, 30]
        );
    }

    #[test]
    fn recording_engine_logs_serves_and_evictions() {
        use crate::corpus::CorpusConfig;
        use crate::quality::{ModelEra, QualityModel};
        use crate::tokenizer::Tokenizer;
        use crate::types::{BlockId, QueryId};

        let corpus = Corpus::generate(
            &CorpusConfig {
                n_docs: 8,
                ..Default::default()
            },
            &Tokenizer::default(),
        );
        let qm = QualityModel::new(ModelEra::Modern, false);
        let log = EngineLog::default();
        let mut eng = RecordingEngine {
            inner: MockEngine::new(10, 1_000_000),
            shard_tag: 7,
            log: log.clone(),
        };
        let r = Request {
            id: RequestId(42),
            session: SessionId(1),
            turn: 0,
            context: vec![BlockId(2)],
            query: QueryId(42),
        };
        eng.serve(&r, &Prompt::baseline(&r), &corpus, &qm, 4);
        let calls = log.lock().unwrap();
        assert_eq!(calls.len(), 1);
        assert_eq!(
            calls[0],
            EngineCall {
                shard: 7,
                request: RequestId(42),
                evicted: vec![]
            }
        );
    }

    #[test]
    fn shrinking_reports_small_size() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            quickcheck("fails at any size", |_rng, size| size == 0)
        }));
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("size=1"), "{msg}");
    }
}
