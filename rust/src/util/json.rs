//! Minimal JSON substrate (parser + writer).
//!
//! The offline image has no `serde`/`serde_json`, so we implement the small
//! JSON surface the repo needs: reading `artifacts/model_meta.json` written
//! by the AOT compile path, and writing benchmark/experiment result files.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Read a `u64` stored via [`Json::u64`] (a decimal string). Small
    /// plain numbers are accepted too, as long as they survive the f64
    /// round-trip exactly.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Str(s) => s.parse::<u64>().ok(),
            Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x <= 9_007_199_254_740_992.0 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; returns Null for missing keys on non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Encode a `u64` losslessly. `Json::Num` is an f64, which silently
    /// rounds integers above 2^53 — request ids, content hashes and
    /// frequency clocks must survive a snapshot bit-exactly, so they ride
    /// as decimal strings instead ([`Json::as_u64`] reads them back).
    pub fn u64(x: u64) -> Json {
        Json::Str(x.to_string())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // BMP only; surrogate pairs unsupported (not
                            // needed for our metadata files).
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(*v.get("d"), Json::Null);
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"k":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t".to_string());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".to_string())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn u64_roundtrips_above_f64_precision() {
        for x in [0u64, 1, 1 << 53, u64::MAX, 0xDEAD_BEEF_DEAD_BEEF] {
            let j = Json::u64(x);
            assert_eq!(j.as_u64(), Some(x));
            let reparsed = Json::parse(&j.to_string()).unwrap();
            assert_eq!(reparsed.as_u64(), Some(x));
        }
        // small plain numbers are accepted, imprecise/negative ones not
        assert_eq!(Json::Num(42.0).as_u64(), Some(42));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(2.5).as_u64(), None);
        assert_eq!(Json::Str("not a number".into()).as_u64(), None);
    }
}
