//! Micro-benchmark harness substrate (criterion is not in the offline
//! image). Provides warmup + timed iterations with basic statistics, used
//! by the `bench_*` targets and the §Perf hot-path measurements.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn report(&self) -> String {
        format!(
            "{:<42} {:>10.3} us/iter  (p50 {:>8.3}, p99 {:>8.3}, min {:>8.3}, n={})",
            self.name,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p99_ns / 1e3,
            self.min_ns / 1e3,
            self.iters
        )
    }
}

/// Run `f` repeatedly: `warmup` untimed iterations, then timed iterations
/// until `budget` elapses (at least `min_iters`). Each iteration is timed
/// individually so percentiles are meaningful.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, budget: Duration, min_iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || (samples.len() as u64) < min_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() > 5_000_000 {
            break; // hard cap
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let pick = |q: f64| samples[((q * (n - 1) as f64).round() as usize).min(n - 1)];
    BenchResult {
        name: name.to_string(),
        iters: n as u64,
        mean_ns: mean,
        p50_ns: pick(0.50),
        p99_ns: pick(0.99),
        min_ns: samples[0],
    }
}

/// Convenience wrapper with repo-default settings (quick but stable).
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 3, Duration::from_millis(300), 10, f)
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Time a single closure run, returning (result, seconds).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop", 1, Duration::from_millis(20), 10, || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 10);
        assert!(r.min_ns <= r.p50_ns);
        assert!(r.p50_ns <= r.p99_ns);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn time_once_returns_result() {
        let (v, secs) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn report_formats() {
        let r = quick("fmt", || {
            black_box(());
        });
        assert!(r.report().contains("fmt"));
    }
}
