//! Deterministic PRNG substrate.
//!
//! The offline build image has no `rand` crate, so we implement the small
//! set of generators the repo needs: SplitMix64 for seeding, xoshiro256**
//! as the workhorse, plus the distribution helpers used by the workload
//! generators (uniform ranges, shuffles, Zipf, Gaussian).
//!
//! Everything here is deterministic given a seed — experiments and property
//! tests print their seeds so any run can be replayed exactly.

/// SplitMix64: used to expand a single u64 seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a seed; the seed is expanded via SplitMix64 as
    /// recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (e.g. one per session/worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's nearly-divisionless method.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform usize in [lo, hi). Panics if lo >= hi.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range: empty range {lo}..{hi}");
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        if k * 4 >= n {
            // dense: shuffle a full index vec
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // sparse: rejection sample
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }
}

/// Zipf(s) sampler over ranks {0, .., n-1}, rank 0 most popular.
///
/// Precomputes the CDF (O(n) build, O(log n) sample). The workload
/// generators use this for document popularity; the exponent is calibrated
/// per dataset profile so the top-20% access coverage matches the paper
/// (Fig. 11: 79.2% / 57.4% / 49.6%).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Fraction of probability mass held by the top `frac` of ranks —
    /// used to calibrate exponents against the paper's CDF numbers.
    pub fn top_mass(&self, frac: f64) -> f64 {
        let cut = ((self.cdf.len() as f64 * frac).ceil() as usize)
            .clamp(1, self.cdf.len());
        self.cdf[cut - 1]
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_streams_differ_by_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_uniform_ish() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.range(5, 9);
            assert!((5..9).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for &(n, k) in &[(100, 5), (10, 10), (1000, 30)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(19);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_rank0_most_popular() {
        let z = Zipf::new(100, 1.0);
        let mut r = Rng::new(23);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn zipf_top_mass_monotone_in_s() {
        let lo = Zipf::new(1000, 0.5).top_mass(0.2);
        let hi = Zipf::new(1000, 1.5).top_mass(0.2);
        assert!(hi > lo);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(99);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
