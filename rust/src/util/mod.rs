//! Shared substrates built in-repo because the offline image carries no
//! tokio/clap/serde/criterion/proptest/rand: deterministic PRNG, JSON,
//! CLI parsing, histograms, a bench harness, a scoped thread pool, and a
//! mini property-test framework.

pub mod bench;
pub mod cli;
pub mod histogram;
pub mod json;
pub mod prng;
pub mod prop;
pub mod table;
pub mod threadpool;
