//! Tiny CLI argument parser substrate (no `clap` in the offline image).
//!
//! Supports the shapes the `ctxpilot` binary and bench harnesses need:
//! a positional subcommand followed by `--key value` / `--flag` options.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .is_some_and(|n| !n.starts_with("--"))
                {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects a number, got '{v}'")
                })
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["serve", "--workers", "4", "--verbose"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.get_usize("workers", 1), 4);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["bench", "--k=15", "--alpha=0.001"]);
        assert_eq!(a.get_usize("k", 0), 15);
        assert!((a.get_f64("alpha", 0.0) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn flag_before_value_option() {
        let a = parse(&["--dry-run", "--seed", "42"]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.get_u64("seed", 0), 42);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.subcommand(), None);
        assert_eq!(a.get_or("mode", "real"), "real");
        assert_eq!(a.get_usize("n", 7), 7);
    }

    #[test]
    fn multiple_positionals() {
        let a = parse(&["run", "table2", "--fast"]);
        assert_eq!(a.positional, vec!["run", "table2"]);
    }
}
