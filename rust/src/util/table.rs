//! Markdown/ASCII table printer for experiment output.
//!
//! Every bench target prints the paper's rows through this, and also writes
//! them to `target/bench_results/<id>.md` so EXPERIMENTS.md can reference
//! stable artifacts.

use std::fmt::Write as _;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Render as a GitHub-flavored markdown table with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}", self.title);
        let _ = writeln!(out);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                let _ = write!(line, " {:<w$} |", cells[i], w = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Print to stdout and persist under `target/bench_results/<id>.md`.
    pub fn emit(&self, id: &str) {
        let rendered = self.render();
        println!("{rendered}");
        let dir = Path::new("target/bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(format!("{id}.md"));
            // append: one bench may emit several tables
            use std::io::Write as _;
            if let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
            {
                let _ = f.write_all(rendered.as_bytes());
                let _ = f.write_all(b"\n");
            }
        }
    }
}

/// Truncate/overwrite a bench result file at the start of a bench run.
pub fn reset_result_file(id: &str) {
    let dir = Path::new("target/bench_results");
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join(format!("{id}.md")), "");
}

/// Formatting helpers used across experiment tables.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn speedup(ours: f64, base: f64) -> String {
    if base <= 0.0 {
        "-".to_string()
    } else {
        format!("{:.2}x", ours / base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let r = t.render();
        assert!(r.contains("### demo"));
        assert!(r.contains("| long-name | 2.5   |"));
        assert!(r.contains("| a         | 1     |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f1(3.14159), "3.1");
        assert_eq!(f2(3.14159), "3.14");
        assert_eq!(pct(0.345), "34.5%");
        assert_eq!(speedup(2.0, 1.0), "2.00x");
        assert_eq!(speedup(1.0, 0.0), "-");
    }
}
