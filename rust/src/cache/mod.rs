//! Prefix-cache substrate: the KV reuse layer of the inference engine.

pub mod radix;

pub use radix::{PrefixMatch, RadixCache};
