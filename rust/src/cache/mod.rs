//! Prefix-cache substrate: the KV reuse layer of the inference engine.
//!
//! [`RadixCache`] is the GPU-resident (HBM) tier; [`TierStore`] adds the
//! DRAM/SSD tiers behind it so capacity eviction demotes KV instead of
//! discarding it, with cost-aware admission and promotion ([`policy`]).
//! The SSD shelf is mirrored into a pluggable [`Storage`] backend
//! ([`storage`]) so a durable run survives process restarts.

pub mod policy;
pub mod radix;
pub mod storage;
pub mod tier;

pub use policy::{AdmissionPolicy, TierCosts};
pub use radix::{EvictedEntry, PrefixMatch, RadixCache};
pub use storage::{ColdPayload, FileStorage, MemStorage, Record, Storage, StorageError};
pub use tier::{Promotion, Tier, TierConfig, TierStore};
