//! Cost-aware tiering policy: when is a KV block worth *keeping* in a
//! colder tier (demotion admission), and when is a cold block worth
//! *reloading* instead of recomputing (promotion profitability)?
//!
//! The decision mirrors the latency model ([`crate::engine::costmodel`]):
//! recomputing `n` tokens costs `n / prefill_rate` seconds of engine
//! occupancy, while reloading them from a tier costs a fixed per-entry
//! overhead plus a per-token transfer cost. A tier whose reload is slower
//! than recompute is worse than a discard — caching there would *add*
//! latency on every future hit — so [`AdmissionPolicy::CostAware`] refuses
//! it. The same comparison gates promotion: a stored prefix is reloaded
//! only when the load beats recomputing the promoted span.

/// Per-tier reload cost model: what it takes to bring KV for `n` tokens
/// back into HBM from this tier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierCosts {
    /// Per-token transfer cost (seconds/token).
    pub load_s_per_tok: f64,
    /// Fixed per-entry cost (lookup, page-in, kernel launch) in seconds —
    /// this is what makes tiny entries not worth demoting.
    pub load_overhead_s: f64,
}

impl TierCosts {
    /// DRAM (CPU-offload) defaults: the per-token cost matches the LMCache
    /// offload penalty the experiment runner charges
    /// ([`crate::experiments::SystemKind::LMCache`], 6 µs/token).
    pub fn dram_default() -> TierCosts {
        TierCosts {
            load_s_per_tok: 6e-6,
            load_overhead_s: 5e-4,
        }
    }

    /// SSD (NVMe) defaults: ~3x DRAM per-token, larger fixed cost. Sits
    /// below recompute for large dense models (Qwen3-32B: 50 µs/token)
    /// and *above* it for small fast ones (Qwen3-4B: ~17 µs/token), so
    /// the cost-aware policy genuinely bites per SKU.
    pub fn ssd_default() -> TierCosts {
        TierCosts {
            load_s_per_tok: 2e-5,
            load_overhead_s: 2e-3,
        }
    }

    /// Seconds to reload an `n`-token entry from this tier.
    pub fn reload_s(&self, n: usize) -> f64 {
        self.load_overhead_s + n as f64 * self.load_s_per_tok
    }
}

/// Demotion-admission / promotion-profitability policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit every evicted block (capacity permitting). Useful as the
    /// ablation baseline: shows what naive tiering costs.
    Always,
    /// Admit only blocks cheaper to reload than to recompute:
    /// `reload_s(n) < n / prefill_rate`.
    CostAware,
}

impl AdmissionPolicy {
    /// Is an `n`-token span worth holding in (or reloading from) a tier
    /// with the given costs, when recompute runs at
    /// `recompute_s_per_tok` seconds/token?
    pub fn admits(&self, costs: &TierCosts, recompute_s_per_tok: f64, n: usize) -> bool {
        match self {
            AdmissionPolicy::Always => true,
            AdmissionPolicy::CostAware => costs.reload_s(n) < n as f64 * recompute_s_per_tok,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reload_cost_is_affine() {
        let c = TierCosts {
            load_s_per_tok: 1e-5,
            load_overhead_s: 1e-3,
        };
        assert!((c.reload_s(0) - 1e-3).abs() < 1e-12);
        assert!((c.reload_s(1000) - 11e-3).abs() < 1e-12);
    }

    #[test]
    fn cost_aware_refuses_tiny_entries() {
        // overhead dominates small spans: reload 10 tokens from DRAM
        // (0.5 ms + 60 µs) vs recompute at 50 µs/token (0.5 ms) -> refuse
        let dram = TierCosts::dram_default();
        let recompute = 5e-5; // Qwen3-32B
        assert!(!AdmissionPolicy::CostAware.admits(&dram, recompute, 10));
        assert!(AdmissionPolicy::CostAware.admits(&dram, recompute, 1000));
        // Always admits anything
        assert!(AdmissionPolicy::Always.admits(&dram, recompute, 1));
    }

    #[test]
    fn cost_aware_is_sku_sensitive() {
        let ssd = TierCosts::ssd_default();
        // 32B dense: recompute 50 µs/token -> SSD (20 µs/token) wins
        assert!(AdmissionPolicy::CostAware.admits(&ssd, 5e-5, 10_000));
        // 4B: recompute ~17 µs/token -> SSD reload is slower, refuse
        assert!(!AdmissionPolicy::CostAware.admits(&ssd, 1.0 / 60_000.0, 10_000));
    }

    #[test]
    fn zero_tokens_never_admitted_cost_aware() {
        let dram = TierCosts::dram_default();
        assert!(!AdmissionPolicy::CostAware.admits(&dram, 1e-3, 0));
    }
}
