//! Hierarchical KV-cache tier store: HBM → DRAM → SSD.
//!
//! The GPU-resident [`crate::cache::RadixCache`] is the **hot** (HBM)
//! tier. Under capacity pressure its LRU eviction normally *discards* KV —
//! recurring context blocks then pay full prefill again. With a
//! `TierStore` attached (see [`crate::cache::RadixCache::enable_demotion`]),
//! eviction becomes **demotion**: the evicted node's root-anchored token
//! prefix (plus its request-id tags and payload) moves down to DRAM;
//! DRAM overflow spills to SSD; SSD overflow finally discards — and only
//! that final discard reports request ids for §4.1 context-index pruning,
//! because until then the content is still servable.
//!
//! A prefix match that lands in a cold tier triggers **promotion**: the
//! stored prefix is reloaded at the owning tier's transfer rate
//! ([`crate::cache::policy::TierCosts`]) instead of recomputed at the
//! prefill rate. Both directions are cost-gated by
//! [`crate::cache::policy::AdmissionPolicy`]: blocks cheaper to recompute
//! than to reload are never demoted, and unprofitable promotions are left
//! in place.
//!
//! Determinism: the store is engine-local (one per shard), every operation
//! is driven by the shard's serve order, and LRU stamps come from a local
//! counter — so serving results are bit-identical for any worker count,
//! exactly like the radix cache itself (pinned by `tests/serve_stress.rs`
//! and `benches/bench_tiering.rs`).

use crate::cache::policy::{AdmissionPolicy, TierCosts};
use crate::cache::radix::EvictedEntry;
use crate::types::RequestId;

/// Which tier served (or holds) a token span. `Hbm` is the radix cache;
/// the store itself only holds `Dram` and `Ssd` entries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Hbm,
    Dram,
    Ssd,
}

/// Longest common prefix of two token sequences.
fn lcp(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Tier::Hbm => "hbm",
            Tier::Dram => "dram",
            Tier::Ssd => "ssd",
        })
    }
}

/// Tier-store shape: per-tier capacities in tokens plus reload costs and
/// the admission policy. `dram_tokens`/`ssd_tokens` of 0 disable a tier.
#[derive(Clone, Debug)]
pub struct TierConfig {
    pub dram_tokens: usize,
    pub ssd_tokens: usize,
    pub dram: TierCosts,
    pub ssd: TierCosts,
    pub admission: AdmissionPolicy,
}

impl TierConfig {
    /// Default costs ([`TierCosts::dram_default`]/[`TierCosts::ssd_default`])
    /// and cost-aware admission.
    pub fn new(dram_tokens: usize, ssd_tokens: usize) -> TierConfig {
        TierConfig {
            dram_tokens,
            ssd_tokens,
            dram: TierCosts::dram_default(),
            ssd: TierCosts::ssd_default(),
            admission: AdmissionPolicy::CostAware,
        }
    }

    /// Parse the CLI shape `hbm=N,dram=N,ssd=N` (token counts, optionally
    /// suffixed `k`/`m` for 10³/10⁶ — `hbm=64k` is 64 000 tokens; `hbm`
    /// is required — it sizes the radix cache — `dram`/`ssd` default to
    /// 0 = disabled). Returns `(hbm_tokens, config)`. Malformed specs are
    /// an [`crate::api::Error::InvalidConfig`], the same typed error the
    /// facade's builder validation raises.
    pub fn parse(spec: &str) -> Result<(usize, TierConfig), crate::api::Error> {
        use crate::api::Error;
        fn tokens(key: &str, val: &str) -> Result<usize, Error> {
            let t = val.trim().to_ascii_lowercase();
            let (digits, mult) = match (t.strip_suffix('k'), t.strip_suffix('m')) {
                (Some(d), _) => (d, 1_000usize),
                (_, Some(d)) => (d, 1_000_000),
                _ => (t.as_str(), 1),
            };
            digits
                .trim()
                .parse::<usize>()
                .ok()
                .and_then(|n| n.checked_mul(mult))
                .ok_or_else(|| {
                    Error::InvalidConfig(format!(
                        "tier '{key}' expects a token count (plain or k/m-suffixed), got '{val}'"
                    ))
                })
        }
        let mut hbm: Option<usize> = None;
        let mut dram = 0usize;
        let mut ssd = 0usize;
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                Error::InvalidConfig(format!("tier spec expects key=tokens, got '{part}'"))
            })?;
            let key = key.trim();
            let n = tokens(key, val)?;
            match key {
                "hbm" => hbm = Some(n),
                "dram" => dram = n,
                "ssd" => ssd = n,
                other => {
                    return Err(Error::InvalidConfig(format!(
                        "unknown tier '{other}' (try hbm/dram/ssd)"
                    )))
                }
            }
        }
        let hbm = hbm.ok_or_else(|| {
            Error::InvalidConfig("tier spec is missing hbm=<tokens> (sizes the radix cache)".into())
        })?;
        if hbm == 0 {
            return Err(Error::InvalidConfig("hbm capacity must be > 0".into()));
        }
        Ok((hbm, TierConfig::new(dram, ssd)))
    }

    /// Split total tier budgets across `n` shards (each shard owns an
    /// independent store, mirroring how `--capacity` is divided).
    pub fn per_shard(&self, n: usize) -> TierConfig {
        let n = n.max(1);
        TierConfig {
            dram_tokens: self.dram_tokens / n,
            ssd_tokens: self.ssd_tokens / n,
            ..self.clone()
        }
    }
}

/// A successful promotion: the consumed entry's tokens, the request ids
/// that own it, its payload, and the modeled load cost of bringing the
/// promoted span back into HBM.
///
/// `matched` is the longest common prefix of the entry and the probe key;
/// when the entry diverges from the key past `matched` (demoted entries
/// carry request-specific tails, e.g. the previous owner's question
/// tokens), only the shared span is promoted — the tail is dropped and
/// `payload` (a snapshot at the entry's *end*) is `None` because it is
/// not valid at the divergence point.
#[derive(Debug)]
pub struct Promotion<V> {
    pub tier: Tier,
    /// Longest common prefix of the stored entry and the probe key.
    pub matched: usize,
    /// The consumed entry's full token sequence (`matched <= tokens.len()`).
    pub tokens: Vec<u32>,
    pub request_ids: Vec<RequestId>,
    /// Present only when the entry matched in full (`matched ==
    /// tokens.len()`), i.e. the end-of-entry KV snapshot is usable.
    pub payload: Option<V>,
    /// Seconds to reload the promoted span `[min_len, matched)`.
    pub load_s: f64,
}

#[derive(Debug)]
struct Entry<V> {
    tokens: Vec<u32>,
    request_ids: Vec<RequestId>,
    payload: Option<V>,
    /// LRU stamp (from the store's local counter; unique, deterministic).
    stamp: u64,
}

#[derive(Debug)]
struct Shelf<V> {
    capacity: usize,
    resident: usize,
    entries: Vec<Entry<V>>,
}

impl<V> Shelf<V> {
    fn new(capacity: usize) -> Shelf<V> {
        Shelf {
            capacity,
            resident: 0,
            entries: Vec::new(),
        }
    }

    /// Entry with the longest common prefix against `key`, strictly beyond
    /// `min_len`. Returns `(index, lcp)`. Deterministic tie-breaking:
    /// longer lcp wins, then a fully-matched entry beats a diverging one
    /// (no tail to waste), then the older stamp.
    fn best_match(&self, key: &[u32], min_len: usize) -> Option<(usize, usize)> {
        // A qualifying entry needs lcp > min_len, which requires agreeing
        // with `key` at position min_len — an O(1) necessary condition
        // that rejects most entries without the full lcp scan (and bails
        // out entirely when the hot match already covers the whole key,
        // the common case on the serve hot path).
        let probe = *key.get(min_len)?;
        let mut best: Option<(usize, usize)> = None; // (idx, lcp)
        for (i, e) in self.entries.iter().enumerate() {
            if e.tokens.len() <= min_len || e.tokens[min_len] != probe {
                continue;
            }
            let l = lcp(&e.tokens, key);
            if l <= min_len {
                continue;
            }
            let better = match best {
                None => true,
                Some((bi, bl)) => {
                    let b = &self.entries[bi];
                    let full = l == e.tokens.len();
                    let b_full = bl == b.tokens.len();
                    l > bl || (l == bl && ((full && !b_full) || (full == b_full && e.stamp < b.stamp)))
                }
            };
            if better {
                best = Some((i, l));
            }
        }
        best
    }

    /// Remove and return the LRU entry (min stamp). `None` when empty.
    fn pop_lru(&mut self) -> Option<Entry<V>> {
        let idx = self
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.stamp)
            .map(|(i, _)| i)?;
        let e = self.entries.remove(idx);
        self.resident -= e.tokens.len();
        Some(e)
    }

    /// Insert, merging into an existing entry with *identical* tokens
    /// (newest payload wins, request ids union, stamp refreshed).
    fn insert(&mut self, e: Entry<V>) {
        if let Some(existing) = self.entries.iter_mut().find(|x| x.tokens == e.tokens) {
            for r in e.request_ids {
                if !existing.request_ids.contains(&r) {
                    existing.request_ids.push(r);
                }
            }
            if e.payload.is_some() {
                existing.payload = e.payload;
            }
            existing.stamp = e.stamp;
            return;
        }
        self.resident += e.tokens.len();
        self.entries.push(e);
    }
}

/// The DRAM + SSD shelves behind a demotion-enabled radix cache. `V` is
/// the payload type carried by the radix nodes (`()` for the simulated
/// engine, KV snapshots for a real one) — demotion and promotion move it
/// through the hierarchy untouched (round-trip pinned by the
/// `demote_then_promote_roundtrips_*` properties below).
///
/// Accounting caveat: shelf entries are **root-anchored** (a demoted leaf
/// carries its full prefix from the radix root, because a bare edge label
/// would be unpromotable without its ancestors), so entries evicted from
/// a shared subtree repeat their common ancestors and shelf residency
/// over-counts relative to the HBM tokens actually freed. Tier budgets
/// are therefore approximate working-set bounds, not exact KV footprints
/// — size them generously relative to `RadixCache` capacity (the
/// defaults and benches use 16x/64x).
#[derive(Debug)]
pub struct TierStore<V> {
    dram: Shelf<V>,
    ssd: Shelf<V>,
    dram_costs: TierCosts,
    ssd_costs: TierCosts,
    admission: AdmissionPolicy,
    /// Engine recompute cost (1 / prefill rate), the admission comparator.
    recompute_s_per_tok: f64,
    clock: u64,
    /// Tokens admitted into the store by demotion.
    pub stat_demoted_tokens: u64,
    /// Tokens reloaded into HBM by promotion (the span beyond the hot match).
    pub stat_promoted_tokens: u64,
    /// Tokens that left the hierarchy entirely (admission refusal or SSD
    /// overflow).
    pub stat_discarded_tokens: u64,
}

impl<V> TierStore<V> {
    pub fn new(cfg: &TierConfig, recompute_s_per_tok: f64) -> TierStore<V> {
        TierStore {
            dram: Shelf::new(cfg.dram_tokens),
            ssd: Shelf::new(cfg.ssd_tokens),
            dram_costs: cfg.dram,
            ssd_costs: cfg.ssd,
            admission: cfg.admission,
            recompute_s_per_tok,
            clock: 0,
            stat_demoted_tokens: 0,
            stat_promoted_tokens: 0,
            stat_discarded_tokens: 0,
        }
    }

    pub fn dram_resident_tokens(&self) -> usize {
        self.dram.resident
    }

    pub fn ssd_resident_tokens(&self) -> usize {
        self.ssd.resident
    }

    pub fn entry_count(&self) -> usize {
        self.dram.entries.len() + self.ssd.entries.len()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn admits(&self, tier: Tier, n: usize) -> bool {
        let (costs, capacity) = match tier {
            Tier::Dram => (&self.dram_costs, self.dram.capacity),
            Tier::Ssd => (&self.ssd_costs, self.ssd.capacity),
            Tier::Hbm => return false,
        };
        n > 0 && n <= capacity && self.admission.admits(costs, self.recompute_s_per_tok, n)
    }

    /// Demote one evicted radix entry into the hierarchy (DRAM first, LRU
    /// spill to SSD, SSD overflow discards). Returns the request ids whose
    /// content left the hierarchy entirely — the caller feeds them to the
    /// §4.1 context-index pruning exactly as it would plain evictions.
    pub fn demote(&mut self, entry: EvictedEntry<V>) -> Vec<RequestId> {
        let mut discarded: Vec<RequestId> = Vec::new();
        let len = entry.tokens.len();
        let mut e = Entry {
            tokens: entry.tokens,
            request_ids: entry.request_ids,
            payload: entry.payload,
            stamp: self.tick(),
        };
        // cross-shelf dedup: an identical key may already sit in SSD from
        // an earlier demote-spill cycle (Shelf::insert only dedups within
        // one shelf). Absorb it so at most ONE copy of a key exists in the
        // hierarchy — otherwise the stale copy's eventual discard would
        // prune §4.1 ids whose content is still servable from the fresh
        // copy. (Admission is deterministic in the key length, so the
        // merged entry is placeable wherever the old copy was.)
        if let Some(pos) = self.ssd.entries.iter().position(|x| x.tokens == e.tokens) {
            let old = self.ssd.entries.remove(pos);
            self.ssd.resident -= old.tokens.len();
            for r in old.request_ids {
                if !e.request_ids.contains(&r) {
                    e.request_ids.push(r);
                }
            }
            if e.payload.is_none() {
                e.payload = old.payload;
            }
        }
        // (entry, already counted as demoted?) — DRAM spills were counted
        // on their original admission; DRAM-refused entries were not
        let mut to_ssd: Vec<(Entry<V>, bool)> = Vec::new();
        if self.admits(Tier::Dram, len) {
            self.stat_demoted_tokens += len as u64;
            self.dram.insert(e);
            while self.dram.resident > self.dram.capacity {
                let victim = self.dram.pop_lru().expect("resident > 0 implies entries");
                to_ssd.push((victim, true));
            }
        } else {
            to_ssd.push((e, false));
        }
        for (e, counted) in to_ssd {
            let n = e.tokens.len();
            if self.admits(Tier::Ssd, n) {
                if !counted {
                    self.stat_demoted_tokens += n as u64;
                }
                self.ssd.insert(e);
                while self.ssd.resident > self.ssd.capacity {
                    let victim = self.ssd.pop_lru().expect("resident > 0 implies entries");
                    self.stat_discarded_tokens += victim.tokens.len() as u64;
                    discarded.extend(victim.request_ids);
                }
            } else {
                self.stat_discarded_tokens += n as u64;
                discarded.extend(e.request_ids);
            }
        }
        discarded.sort_unstable();
        discarded.dedup();
        discarded
    }

    /// Observably side-effect-free probe (`&self` — provably no LRU or
    /// stat perturbation, mirroring
    /// [`crate::cache::RadixCache::peek_prefix_len`]): the longest common
    /// prefix any stored entry shares with `key` strictly beyond
    /// `min_len`, or `min_len` when no tier extends the match.
    pub fn peek_longest(&self, key: &[u32], min_len: usize) -> usize {
        let d = self.dram.best_match(key, min_len).map_or(min_len, |(_, l)| l);
        let s = self.ssd.best_match(key, min_len).map_or(min_len, |(_, l)| l);
        d.max(s)
    }

    /// Promote the stored entry sharing the longest prefix with `key`
    /// beyond `min_len` (the hot match): the entry is removed from its
    /// shelf and returned with the modeled load cost for the span
    /// `[min_len, matched)`. Any entry tail past the divergence point is
    /// dropped (counted in `stat_discarded_tokens`; its ids are NOT
    /// reported for pruning — the caller re-tags them onto the promoted
    /// prefix, which is real resident content again). Prefers the longer
    /// match; at equal length the cheaper tier (DRAM). Under
    /// [`AdmissionPolicy::CostAware`], promotions that would cost more
    /// than recomputing the span are refused and the entry left in place.
    pub fn promote(&mut self, key: &[u32], min_len: usize) -> Option<Promotion<V>> {
        let d_match = self
            .dram
            .best_match(key, min_len)
            .map(|(i, l)| (Tier::Dram, i, l, l == self.dram.entries[i].tokens.len()));
        let s_match = self
            .ssd
            .best_match(key, min_len)
            .map(|(i, l)| (Tier::Ssd, i, l, l == self.ssd.entries[i].tokens.len()));
        // the same comparison that gates demotion admission gates
        // promotion profitability (one rule, both directions — the basis
        // of the "demote-mode TTFT never worse" guarantee)
        let gate = |m: Option<(Tier, usize, usize, bool)>, costs: &TierCosts| {
            let (tier, idx, matched, full) = m?;
            let span = matched - min_len;
            self.admission
                .admits(costs, self.recompute_s_per_tok, span)
                .then(|| (tier, idx, matched, full, costs.reload_s(span)))
        };
        let d = gate(d_match, &self.dram_costs);
        let s = gate(s_match, &self.ssd_costs);
        let (tier, idx, matched, _full, load_s) = match (d, s) {
            (Some(d), Some(s)) => {
                // longer match wins; at equal length a fully-matched entry
                // beats a diverging one (same rule as Shelf::best_match —
                // no tail or payload to waste); then DRAM (cheaper load)
                if s.2 > d.2 || (s.2 == d.2 && s.3 && !d.3) {
                    s
                } else {
                    d
                }
            }
            (Some(d), None) => d,
            (None, Some(s)) => s,
            (None, None) => return None,
        };
        let shelf = match tier {
            Tier::Dram => &mut self.dram,
            Tier::Ssd => &mut self.ssd,
            Tier::Hbm => unreachable!("store holds no HBM entries"),
        };
        let e = shelf.entries.remove(idx);
        shelf.resident -= e.tokens.len();
        debug_assert!(matched <= e.tokens.len());
        self.stat_promoted_tokens += (matched - min_len) as u64;
        let full = matched == e.tokens.len();
        if !full {
            // the diverged tail leaves the hierarchy
            self.stat_discarded_tokens += (e.tokens.len() - matched) as u64;
        }
        Some(Promotion {
            tier,
            matched,
            tokens: e.tokens,
            request_ids: e.request_ids,
            payload: if full { e.payload } else { None },
            load_s,
        })
    }

    /// Structural invariants (tests / failure injection).
    pub fn check_invariants(&self) -> Result<(), String> {
        for (name, shelf) in [("dram", &self.dram), ("ssd", &self.ssd)] {
            let counted: usize = shelf.entries.iter().map(|e| e.tokens.len()).sum();
            if counted != shelf.resident {
                return Err(format!(
                    "{name}: counted {counted} != tracked {}",
                    shelf.resident
                ));
            }
            if shelf.resident > shelf.capacity {
                return Err(format!("{name} over capacity"));
            }
            for e in &shelf.entries {
                if e.tokens.is_empty() {
                    return Err(format!("{name}: empty entry"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, Config};

    fn entry(tokens: &[u32], req: u64) -> EvictedEntry<Vec<u8>> {
        EvictedEntry {
            tokens: tokens.to_vec(),
            request_ids: vec![RequestId(req)],
            payload: Some(tokens.iter().map(|&t| t as u8).collect()),
        }
    }

    fn roomy() -> TierConfig {
        let mut cfg = TierConfig::new(1 << 20, 1 << 20);
        cfg.admission = AdmissionPolicy::Always;
        cfg
    }

    #[test]
    fn parse_cli_spec() {
        let (hbm, cfg) = TierConfig::parse("hbm=4000,dram=16000,ssd=64000").unwrap();
        assert_eq!(hbm, 4000);
        assert_eq!(cfg.dram_tokens, 16_000);
        assert_eq!(cfg.ssd_tokens, 64_000);
        assert_eq!(cfg.admission, AdmissionPolicy::CostAware);
        // subset: missing tiers disabled
        let (hbm, cfg) = TierConfig::parse("hbm=500").unwrap();
        assert_eq!((hbm, cfg.dram_tokens, cfg.ssd_tokens), (500, 0, 0));
        // k/m suffixes scale by 10^3 / 10^6
        let (hbm, cfg) = TierConfig::parse("hbm=64k,dram=256K,ssd=1m").unwrap();
        assert_eq!(hbm, 64_000);
        assert_eq!((cfg.dram_tokens, cfg.ssd_tokens), (256_000, 1_000_000));
        // errors — every rejection is the facade's typed InvalidConfig
        // (incl. a suffixed count that would overflow usize)
        for bad in [
            "dram=10",
            "hbm=0",
            "hbm=x",
            "vram=10,hbm=1",
            "hbm",
            "hbm=4q",
            "hbm=18446744073709551615k",
        ] {
            assert!(
                matches!(
                    TierConfig::parse(bad),
                    Err(crate::api::Error::InvalidConfig(_))
                ),
                "spec '{bad}' must be rejected as InvalidConfig"
            );
        }
    }

    #[test]
    fn per_shard_divides_budgets() {
        let cfg = TierConfig::new(1000, 4000).per_shard(4);
        assert_eq!((cfg.dram_tokens, cfg.ssd_tokens), (250, 1000));
    }

    #[test]
    fn demote_then_promote_returns_entry() {
        let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
        let discarded = store.demote(entry(&[1, 2, 3, 4], 7));
        assert!(discarded.is_empty());
        assert_eq!(store.dram_resident_tokens(), 4);
        let p = store.promote(&[1, 2, 3, 4, 5, 6], 0).expect("promoted");
        assert_eq!(p.tier, Tier::Dram);
        assert_eq!(p.matched, 4);
        assert_eq!(p.tokens, vec![1, 2, 3, 4]);
        assert_eq!(p.request_ids, vec![RequestId(7)]);
        assert_eq!(p.payload.unwrap(), vec![1, 2, 3, 4]);
        assert_eq!(store.entry_count(), 0);
        store.check_invariants().unwrap();
    }

    #[test]
    fn partial_divergence_promotes_common_prefix_and_drops_tail() {
        let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
        store.demote(entry(&[1, 2, 3, 4], 1));
        // already covered by the hot match: nothing to promote
        assert!(store.promote(&[1, 2, 3, 4], 4).is_none());
        assert_eq!(store.entry_count(), 1, "refused probes leave entries");
        // diverges at position 2: the shared span promotes, the {3,4} tail
        // (a snapshot past the divergence) is dropped without its payload
        let p = store.promote(&[1, 2, 9, 9], 0).expect("common prefix");
        assert_eq!(p.matched, 2);
        assert_eq!(p.tokens, vec![1, 2, 3, 4]);
        assert_eq!(p.request_ids, vec![RequestId(1)]);
        assert!(p.payload.is_none(), "end-of-entry KV invalid at divergence");
        assert_eq!(store.entry_count(), 0);
        assert_eq!(store.stat_promoted_tokens, 2);
        assert_eq!(store.stat_discarded_tokens, 2);
        store.check_invariants().unwrap();
    }

    #[test]
    fn equal_lcp_prefers_fully_matched_entry() {
        let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
        store.demote(entry(&[1, 2, 3, 8, 8], 1)); // diverging tail
        store.demote(entry(&[1, 2, 3], 2)); // exact
        let p = store.promote(&[1, 2, 3, 4], 0).unwrap();
        assert_eq!(p.request_ids, vec![RequestId(2)], "full match preferred");
        assert!(p.payload.is_some());
        assert_eq!(store.entry_count(), 1, "diverging entry left in place");
    }

    #[test]
    fn dram_overflow_spills_lru_to_ssd_and_ssd_overflow_discards() {
        let mut cfg = TierConfig::new(6, 6);
        cfg.admission = AdmissionPolicy::Always;
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        assert!(store.demote(entry(&[1, 2, 3], 1)).is_empty());
        assert!(store.demote(entry(&[4, 5, 6], 2)).is_empty());
        // third demotion overflows DRAM: entry 1 (LRU) spills to SSD
        assert!(store.demote(entry(&[7, 8, 9], 3)).is_empty());
        assert_eq!(store.dram_resident_tokens(), 6);
        assert_eq!(store.ssd_resident_tokens(), 3);
        assert_eq!(store.peek_longest(&[1, 2, 3], 0), 3, "spilled, not lost");
        // two more: SSD fills, then the oldest SSD entry is discarded
        assert!(store.demote(entry(&[10, 11, 12], 4)).is_empty());
        let discarded = store.demote(entry(&[13, 14, 15], 5));
        assert_eq!(discarded, vec![RequestId(1)]);
        assert_eq!(store.peek_longest(&[1, 2, 3], 0), 0, "finally discarded");
        assert!(store.stat_discarded_tokens >= 3);
        store.check_invariants().unwrap();
    }

    #[test]
    fn cost_aware_admission_refuses_and_reports_ids() {
        // CostAware + tiny entries: reload overhead beats recompute, so
        // demotion must discard immediately and report the ids for pruning
        let cfg = TierConfig::new(1 << 20, 1 << 20); // CostAware default
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        let discarded = store.demote(entry(&[1, 2], 9));
        assert_eq!(discarded, vec![RequestId(9)]);
        assert_eq!(store.entry_count(), 0);
        assert_eq!(store.stat_demoted_tokens, 0);
        assert_eq!(store.stat_discarded_tokens, 2);
    }

    #[test]
    fn cost_aware_promotion_skips_unprofitable_spans() {
        let cfg = TierConfig::new(1 << 20, 1 << 20);
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        let long: Vec<u32> = (0..1000).collect();
        assert!(store.demote(entry(&long, 1)).is_empty(), "1000 tok admits");
        // hot match already covers 995 of 1000: reloading 5 tokens costs
        // more than recomputing them -> leave the entry in place
        assert!(store.promote(&long, 995).is_none());
        assert_eq!(store.entry_count(), 1);
        // a cold probe promotes the full kilotoken span profitably
        let p = store.promote(&long, 0).expect("profitable");
        assert!(p.load_s < 1000.0 * 5e-5);
    }

    #[test]
    fn cross_shelf_duplicates_are_absorbed_on_demote() {
        // an entry spilled to SSD, then the same key demoted again: the
        // stale SSD copy must be absorbed into the fresh one, so its
        // eventual discard can never prune ids with servable content
        let mut cfg = TierConfig::new(6, 1 << 20);
        cfg.admission = AdmissionPolicy::Always;
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        store.demote(entry(&[1, 2, 3], 1));
        store.demote(entry(&[4, 5, 6], 2)); // DRAM now full
        store.demote(entry(&[7, 8, 9], 3)); // spills [1,2,3] to SSD
        assert_eq!(store.ssd_resident_tokens(), 3);
        // the same key comes back down (re-hot, then re-evicted)
        assert!(store.demote(entry(&[1, 2, 3], 4)).is_empty());
        let p = store.promote(&[1, 2, 3], 0).expect("merged copy");
        let mut ids = p.request_ids.clone();
        ids.sort_unstable();
        assert!(
            ids.contains(&RequestId(1)) && ids.contains(&RequestId(4)),
            "old ids not absorbed: {ids:?}"
        );
        assert!(
            store.promote(&[1, 2, 3], 0).is_none(),
            "duplicate copy survived in a shelf"
        );
        store.check_invariants().unwrap();
    }

    #[test]
    fn cross_shelf_tie_prefers_full_match_over_dram() {
        // equal lcp in both shelves: the fully-matched SSD entry (usable
        // payload, no tail waste) must beat the diverging DRAM entry,
        // mirroring the in-shelf tie rule
        let mut cfg = TierConfig::new(6, 1 << 20);
        cfg.admission = AdmissionPolicy::Always;
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        store.demote(entry(&[1, 2, 3], 1));
        store.demote(entry(&[9, 9, 9], 2)); // DRAM full
        store.demote(entry(&[1, 2, 3, 8, 8], 3)); // spills both to SSD
        let p = store.promote(&[1, 2, 3, 4], 0).expect("tie candidate");
        assert_eq!(p.tier, Tier::Ssd);
        assert_eq!(p.request_ids, vec![RequestId(1)]);
        assert!(p.payload.is_some(), "full match keeps its snapshot");
        store.check_invariants().unwrap();
    }

    #[test]
    fn identical_tokens_merge_instead_of_duplicating() {
        let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
        store.demote(entry(&[1, 2, 3], 1));
        let mut second = entry(&[1, 2, 3], 2);
        second.payload = Some(vec![9, 9, 9]);
        store.demote(second);
        assert_eq!(store.entry_count(), 1);
        assert_eq!(store.dram_resident_tokens(), 3);
        let p = store.promote(&[1, 2, 3], 0).unwrap();
        let mut ids = p.request_ids.clone();
        ids.sort_unstable();
        assert_eq!(ids, vec![RequestId(1), RequestId(2)]);
        assert_eq!(p.payload.unwrap(), vec![9, 9, 9], "newest payload wins");
    }

    #[test]
    fn peek_longest_is_side_effect_free_and_agrees_with_promote() {
        let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
        store.demote(entry(&[1, 2, 3], 1));
        store.demote(entry(&[1, 2, 3, 4, 5], 2));
        let before = format!("{store:?}");
        for _ in 0..10 {
            assert_eq!(store.peek_longest(&[1, 2, 3, 4, 5, 6], 0), 5);
            assert_eq!(store.peek_longest(&[1, 2, 3, 9], 0), 3);
            assert_eq!(store.peek_longest(&[7], 0), 0);
            assert_eq!(store.peek_longest(&[1, 2, 3], 3), 3, "min_len respected");
        }
        assert_eq!(format!("{store:?}"), before, "peek mutated the store");
        let p = store.promote(&[1, 2, 3, 4, 5, 6], 0).unwrap();
        assert_eq!(p.matched, 5, "promote takes the longest prefix");
    }

    /// Satellite: demote-then-promote round-trips payloads byte-identically
    /// for arbitrary entry populations (the eviction→demotion→promotion
    /// chain may never corrupt KV).
    #[test]
    fn prop_demote_then_promote_roundtrips_payloads_byte_identically() {
        check(
            "tier demote/promote round-trip",
            Config {
                cases: 96,
                base_seed: 0x71E2,
                max_size: 24,
            },
            |rng: &mut Rng, size| {
                let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
                // distinct first tokens -> no entry is a prefix of another,
                // so every demoted entry must survive verbatim
                let n = size.clamp(1, 24);
                let mut keys: Vec<Vec<u32>> = Vec::new();
                for i in 0..n {
                    let len = 1 + rng.below(12);
                    let mut key = vec![i as u32 + 1];
                    key.extend((0..len).map(|_| rng.below(50) as u32 + 100));
                    keys.push(key);
                }
                for (i, key) in keys.iter().enumerate() {
                    let payload: Vec<u8> = key.iter().map(|&t| (t % 251) as u8).collect();
                    let discarded = store.demote(EvictedEntry {
                        tokens: key.clone(),
                        request_ids: vec![RequestId(i as u64)],
                        payload: Some(payload),
                    });
                    if !discarded.is_empty() {
                        return Err("roomy store discarded an entry".to_string());
                    }
                }
                store.check_invariants().map_err(|e| e.to_string())?;
                for (i, key) in keys.iter().enumerate() {
                    let p = store
                        .promote(key, 0)
                        .ok_or_else(|| format!("entry {i} lost"))?;
                    if p.tokens != *key {
                        return Err(format!("entry {i}: tokens corrupted"));
                    }
                    let want: Vec<u8> = key.iter().map(|&t| (t % 251) as u8).collect();
                    if p.payload.as_deref() != Some(want.as_slice()) {
                        return Err(format!("entry {i}: payload corrupted"));
                    }
                    if p.request_ids != vec![RequestId(i as u64)] {
                        return Err(format!("entry {i}: request ids corrupted"));
                    }
                }
                if store.entry_count() != 0 {
                    return Err("promotion left stale entries".to_string());
                }
                Ok(())
            },
        );
    }
}
