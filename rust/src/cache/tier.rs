//! Hierarchical KV-cache tier store: HBM → DRAM → SSD.
//!
//! The GPU-resident [`crate::cache::RadixCache`] is the **hot** (HBM)
//! tier. Under capacity pressure its LRU eviction normally *discards* KV —
//! recurring context blocks then pay full prefill again. With a
//! `TierStore` attached (see [`crate::cache::RadixCache::enable_demotion`]),
//! eviction becomes **demotion**: the evicted node's root-anchored token
//! prefix (plus its request-id tags and payload) moves down to DRAM;
//! DRAM overflow spills to SSD; SSD overflow finally discards — and only
//! that final discard reports request ids for §4.1 context-index pruning,
//! because until then the content is still servable.
//!
//! A prefix match that lands in a cold tier triggers **promotion**: the
//! stored prefix is reloaded at the owning tier's transfer rate
//! ([`crate::cache::policy::TierCosts`]) instead of recomputed at the
//! prefill rate. Both directions are cost-gated by
//! [`crate::cache::policy::AdmissionPolicy`]: blocks cheaper to recompute
//! than to reload are never demoted, and unprofitable promotions are left
//! in place.
//!
//! Determinism: the store is engine-local (one per shard), every operation
//! is driven by the shard's serve order, and LRU stamps come from a local
//! counter — so serving results are bit-identical for any worker count,
//! exactly like the radix cache itself (pinned by `tests/serve_stress.rs`
//! and `benches/bench_tiering.rs`).
//!
//! Durability: the SSD shelf is write-through mirrored into a pluggable
//! [`Storage`] backend ([`crate::cache::storage`]). The default
//! ([`MemStorage`], via [`TierStore::new`]) keeps everything in memory —
//! bit-identical to the pre-durability behaviour because the mirror never
//! feeds back into a live run. A durable run passes a
//! [`crate::cache::FileStorage`] to [`TierStore::with_storage`], which can
//! also *rehydrate* the shelf from the backend on resume. Mirror I/O
//! errors are sticky ([`TierStore::storage_flush`] surfaces the first one
//! at checkpoint time) rather than perturbing the serve path.

use crate::cache::policy::{AdmissionPolicy, TierCosts};
use crate::cache::radix::EvictedEntry;
use crate::cache::storage::{ColdPayload, MemStorage, Record, Storage, StorageError};
use crate::types::RequestId;
use crate::util::json::Json;

/// Which tier served (or holds) a token span. `Hbm` is the radix cache;
/// the store itself only holds `Dram` and `Ssd` entries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Hbm,
    Dram,
    Ssd,
}

/// Longest common prefix of two token sequences.
fn lcp(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Tier::Hbm => "hbm",
            Tier::Dram => "dram",
            Tier::Ssd => "ssd",
        })
    }
}

/// Tier-store shape: per-tier capacities in tokens plus reload costs and
/// the admission policy. `dram_tokens`/`ssd_tokens` of 0 disable a tier.
#[derive(Clone, Debug)]
pub struct TierConfig {
    pub dram_tokens: usize,
    pub ssd_tokens: usize,
    pub dram: TierCosts,
    pub ssd: TierCosts,
    pub admission: AdmissionPolicy,
}

impl TierConfig {
    /// Default costs ([`TierCosts::dram_default`]/[`TierCosts::ssd_default`])
    /// and cost-aware admission.
    pub fn new(dram_tokens: usize, ssd_tokens: usize) -> TierConfig {
        TierConfig {
            dram_tokens,
            ssd_tokens,
            dram: TierCosts::dram_default(),
            ssd: TierCosts::ssd_default(),
            admission: AdmissionPolicy::CostAware,
        }
    }

    /// Parse the CLI shape `hbm=N,dram=N,ssd=N` (token counts, optionally
    /// suffixed `k`/`m` for 10³/10⁶ — `hbm=64k` is 64 000 tokens; `hbm`
    /// is required — it sizes the radix cache — `dram`/`ssd` default to
    /// 0 = disabled). Returns `(hbm_tokens, config)`. Malformed specs are
    /// an [`crate::api::Error::InvalidConfig`], the same typed error the
    /// facade's builder validation raises.
    pub fn parse(spec: &str) -> Result<(usize, TierConfig), crate::api::Error> {
        use crate::api::Error;
        fn tokens(key: &str, val: &str) -> Result<usize, Error> {
            let t = val.trim().to_ascii_lowercase();
            let (digits, mult) = match (t.strip_suffix('k'), t.strip_suffix('m')) {
                (Some(d), _) => (d, 1_000usize),
                (_, Some(d)) => (d, 1_000_000),
                _ => (t.as_str(), 1),
            };
            digits
                .trim()
                .parse::<usize>()
                .ok()
                .and_then(|n| n.checked_mul(mult))
                .ok_or_else(|| {
                    Error::InvalidConfig(format!(
                        "tier '{key}' expects a token count (plain or k/m-suffixed), got '{val}'"
                    ))
                })
        }
        let mut hbm: Option<usize> = None;
        let mut dram: Option<usize> = None;
        let mut ssd: Option<usize> = None;
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                Error::InvalidConfig(format!("tier spec expects key=tokens, got '{part}'"))
            })?;
            let key = key.trim();
            let n = tokens(key, val)?;
            // a repeated key is ambiguous (which budget did the caller
            // mean?) — reject instead of silently letting the last one win
            let slot = match key {
                "hbm" => &mut hbm,
                "dram" => &mut dram,
                "ssd" => &mut ssd,
                other => {
                    return Err(Error::InvalidConfig(format!(
                        "unknown tier '{other}' (try hbm/dram/ssd)"
                    )))
                }
            };
            if slot.is_some() {
                return Err(Error::InvalidConfig(format!(
                    "tier '{key}' specified more than once"
                )));
            }
            *slot = Some(n);
        }
        let (dram, ssd) = (dram.unwrap_or(0), ssd.unwrap_or(0));
        let hbm = hbm.ok_or_else(|| {
            Error::InvalidConfig("tier spec is missing hbm=<tokens> (sizes the radix cache)".into())
        })?;
        if hbm == 0 {
            return Err(Error::InvalidConfig("hbm capacity must be > 0".into()));
        }
        Ok((hbm, TierConfig::new(dram, ssd)))
    }

    /// Split total tier budgets across `n` shards (each shard owns an
    /// independent store, mirroring how `--capacity` is divided).
    pub fn per_shard(&self, n: usize) -> TierConfig {
        let n = n.max(1);
        TierConfig {
            dram_tokens: self.dram_tokens / n,
            ssd_tokens: self.ssd_tokens / n,
            ..self.clone()
        }
    }
}

/// A successful promotion: the consumed entry's tokens, the request ids
/// that own it, its payload, and the modeled load cost of bringing the
/// promoted span back into HBM.
///
/// `matched` is the longest common prefix of the entry and the probe key;
/// when the entry diverges from the key past `matched` (demoted entries
/// carry request-specific tails, e.g. the previous owner's question
/// tokens), only the shared span is promoted — the tail is dropped and
/// `payload` (a snapshot at the entry's *end*) is `None` because it is
/// not valid at the divergence point.
#[derive(Debug)]
pub struct Promotion<V> {
    pub tier: Tier,
    /// Longest common prefix of the stored entry and the probe key.
    pub matched: usize,
    /// The consumed entry's full token sequence (`matched <= tokens.len()`).
    pub tokens: Vec<u32>,
    pub request_ids: Vec<RequestId>,
    /// Present only when the entry matched in full (`matched ==
    /// tokens.len()`), i.e. the end-of-entry KV snapshot is usable.
    pub payload: Option<V>,
    /// Seconds to reload the promoted span `[min_len, matched)`.
    pub load_s: f64,
}

#[derive(Debug)]
struct Entry<V> {
    tokens: Vec<u32>,
    request_ids: Vec<RequestId>,
    payload: Option<V>,
    /// LRU stamp (from the store's local counter; unique, deterministic).
    stamp: u64,
}

#[derive(Debug)]
struct Shelf<V> {
    capacity: usize,
    resident: usize,
    entries: Vec<Entry<V>>,
}

impl<V> Shelf<V> {
    fn new(capacity: usize) -> Shelf<V> {
        Shelf {
            capacity,
            resident: 0,
            entries: Vec::new(),
        }
    }

    /// Entry with the longest common prefix against `key`, strictly beyond
    /// `min_len`. Returns `(index, lcp)`. Deterministic tie-breaking:
    /// longer lcp wins, then a fully-matched entry beats a diverging one
    /// (no tail to waste), then the older stamp.
    fn best_match(&self, key: &[u32], min_len: usize) -> Option<(usize, usize)> {
        // A qualifying entry needs lcp > min_len, which requires agreeing
        // with `key` at position min_len — an O(1) necessary condition
        // that rejects most entries without the full lcp scan (and bails
        // out entirely when the hot match already covers the whole key,
        // the common case on the serve hot path).
        let probe = *key.get(min_len)?;
        let mut best: Option<(usize, usize)> = None; // (idx, lcp)
        for (i, e) in self.entries.iter().enumerate() {
            if e.tokens.len() <= min_len || e.tokens[min_len] != probe {
                continue;
            }
            let l = lcp(&e.tokens, key);
            if l <= min_len {
                continue;
            }
            let better = match best {
                None => true,
                Some((bi, bl)) => {
                    let b = &self.entries[bi];
                    let full = l == e.tokens.len();
                    let b_full = bl == b.tokens.len();
                    l > bl || (l == bl && ((full && !b_full) || (full == b_full && e.stamp < b.stamp)))
                }
            };
            if better {
                best = Some((i, l));
            }
        }
        best
    }

    /// Remove and return the LRU entry (min stamp). `None` when empty.
    fn pop_lru(&mut self) -> Option<Entry<V>> {
        let idx = self
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.stamp)
            .map(|(i, _)| i)?;
        let e = self.entries.remove(idx);
        self.resident -= e.tokens.len();
        Some(e)
    }

    /// Insert, merging into an existing entry with *identical* tokens
    /// (newest payload wins, request ids union, stamp refreshed).
    fn insert(&mut self, e: Entry<V>) {
        if let Some(existing) = self.entries.iter_mut().find(|x| x.tokens == e.tokens) {
            for r in e.request_ids {
                if !existing.request_ids.contains(&r) {
                    existing.request_ids.push(r);
                }
            }
            if e.payload.is_some() {
                existing.payload = e.payload;
            }
            existing.stamp = e.stamp;
            return;
        }
        self.resident += e.tokens.len();
        self.entries.push(e);
    }
}

/// The DRAM + SSD shelves behind a demotion-enabled radix cache. `V` is
/// the payload type carried by the radix nodes (`()` for the simulated
/// engine, KV snapshots for a real one) — demotion and promotion move it
/// through the hierarchy untouched (round-trip pinned by the
/// `demote_then_promote_roundtrips_*` properties below).
///
/// Accounting caveat: shelf entries are **root-anchored** (a demoted leaf
/// carries its full prefix from the radix root, because a bare edge label
/// would be unpromotable without its ancestors), so entries evicted from
/// a shared subtree repeat their common ancestors and shelf residency
/// over-counts relative to the HBM tokens actually freed. Tier budgets
/// are therefore approximate working-set bounds, not exact KV footprints
/// — size them generously relative to `RadixCache` capacity (the
/// defaults and benches use 16x/64x).
#[derive(Debug)]
pub struct TierStore<V> {
    dram: Shelf<V>,
    ssd: Shelf<V>,
    /// Durable write-through mirror of the SSD shelf (`MemStorage` by
    /// default, so the mirror is invisible unless a file backend is
    /// plugged in via [`TierStore::with_storage`]).
    store: Box<dyn Storage>,
    /// First mirror failure observed on the serve path. Serving must stay
    /// deterministic regardless of disk health, so errors are remembered
    /// here and surfaced by [`TierStore::storage_flush`] at checkpoint.
    storage_error: Option<StorageError>,
    dram_costs: TierCosts,
    ssd_costs: TierCosts,
    admission: AdmissionPolicy,
    /// Engine recompute cost (1 / prefill rate), the admission comparator.
    recompute_s_per_tok: f64,
    clock: u64,
    /// Tokens admitted into the store by demotion.
    pub stat_demoted_tokens: u64,
    /// Tokens reloaded into HBM by promotion (the span beyond the hot match).
    pub stat_promoted_tokens: u64,
    /// Tokens that left the hierarchy entirely (admission refusal or SSD
    /// overflow).
    pub stat_discarded_tokens: u64,
}

impl<V> TierStore<V> {
    pub fn new(cfg: &TierConfig, recompute_s_per_tok: f64) -> TierStore<V> {
        TierStore {
            dram: Shelf::new(cfg.dram_tokens),
            ssd: Shelf::new(cfg.ssd_tokens),
            store: Box::new(MemStorage::new()),
            storage_error: None,
            dram_costs: cfg.dram,
            ssd_costs: cfg.ssd,
            admission: cfg.admission,
            recompute_s_per_tok,
            clock: 0,
            stat_demoted_tokens: 0,
            stat_promoted_tokens: 0,
            stat_discarded_tokens: 0,
        }
    }

    pub fn dram_resident_tokens(&self) -> usize {
        self.dram.resident
    }

    pub fn ssd_resident_tokens(&self) -> usize {
        self.ssd.resident
    }

    pub fn entry_count(&self) -> usize {
        self.dram.entries.len() + self.ssd.entries.len()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn admits(&self, tier: Tier, n: usize) -> bool {
        let (costs, capacity) = match tier {
            Tier::Dram => (&self.dram_costs, self.dram.capacity),
            Tier::Ssd => (&self.ssd_costs, self.ssd.capacity),
            Tier::Hbm => return false,
        };
        n > 0 && n <= capacity && self.admission.admits(costs, self.recompute_s_per_tok, n)
    }

    /// The first mirror failure observed on the serve path, if any.
    pub fn storage_error(&self) -> Option<&StorageError> {
        self.storage_error.as_ref()
    }

    /// Checkpoint hook: surface the first sticky mirror failure, then
    /// flush/compact the storage backend.
    pub fn storage_flush(&mut self) -> Result<(), StorageError> {
        if let Some(e) = self.storage_error.clone() {
            return Err(e);
        }
        self.store.flush()
    }

    fn note_storage(&mut self, r: Result<(), StorageError>) {
        if let Err(e) = r {
            self.storage_error.get_or_insert(e);
        }
    }

    /// Mirror: the key left the SSD shelf for good.
    fn mirror_del(&mut self, key: &[u32]) {
        let r = self.store.delete(key);
        self.note_storage(r);
    }
}

impl<V: ColdPayload> TierStore<V> {
    /// Build a store whose SSD shelf is mirrored into `store`. With
    /// `rehydrate`, the shelf is first seeded from [`Storage::scan`]
    /// (records arrive in ascending stamp order, so the LRU ordering
    /// survives the restart) and the clock resumes past the newest stamp.
    /// Records over the configured SSD budget are shed oldest-first —
    /// resuming with a smaller `ssd=` budget silently drops LRU cold
    /// entries. A record whose payload does not decode is a
    /// corrupt-flagged [`StorageError`], never a panic.
    pub fn with_storage(
        cfg: &TierConfig,
        recompute_s_per_tok: f64,
        store: Box<dyn Storage>,
        rehydrate: bool,
    ) -> Result<TierStore<V>, StorageError> {
        let mut ts = TierStore::new(cfg, recompute_s_per_tok);
        ts.store = store;
        if rehydrate {
            for rec in ts.store.scan()? {
                if rec.tokens.is_empty() {
                    return Err(StorageError::corrupt("cold-tier record with empty key"));
                }
                let payload = match &rec.payload {
                    Json::Null => None,
                    j => Some(V::from_json(j).ok_or_else(|| {
                        StorageError::corrupt("cold-tier record payload does not decode")
                    })?),
                };
                ts.clock = ts.clock.max(rec.stamp);
                ts.ssd.insert(Entry {
                    tokens: rec.tokens,
                    request_ids: rec.request_ids.into_iter().map(RequestId).collect(),
                    payload,
                    stamp: rec.stamp,
                });
            }
            while ts.ssd.resident > ts.ssd.capacity {
                let victim = ts.ssd.pop_lru().expect("resident > 0 implies entries");
                ts.store.delete(&victim.tokens)?;
            }
        }
        Ok(ts)
    }

    /// Mirror: write-through the *current* shelf state of `key` (after a
    /// `Shelf::insert`, which may have merged ids/payload into an
    /// existing entry — the record must reflect the merge result).
    fn mirror_put(&mut self, key: &[u32]) {
        let rec = match self.ssd.entries.iter().find(|e| e.tokens == key) {
            Some(e) => Record {
                tokens: e.tokens.clone(),
                request_ids: e.request_ids.iter().map(|r| r.0).collect(),
                stamp: e.stamp,
                payload: e.payload.as_ref().map_or(Json::Null, ColdPayload::to_json),
            },
            None => return,
        };
        let r = self.store.put(rec);
        self.note_storage(r);
    }

    /// Demote one evicted radix entry into the hierarchy (DRAM first, LRU
    /// spill to SSD, SSD overflow discards). Returns the request ids whose
    /// content left the hierarchy entirely — the caller feeds them to the
    /// §4.1 context-index pruning exactly as it would plain evictions.
    pub fn demote(&mut self, entry: EvictedEntry<V>) -> Vec<RequestId> {
        let mut discarded: Vec<RequestId> = Vec::new();
        let len = entry.tokens.len();
        let mut e = Entry {
            tokens: entry.tokens,
            request_ids: entry.request_ids,
            payload: entry.payload,
            stamp: self.tick(),
        };
        // cross-shelf dedup: an identical key may already sit in SSD from
        // an earlier demote-spill cycle (Shelf::insert only dedups within
        // one shelf). Absorb it so at most ONE copy of a key exists in the
        // hierarchy — otherwise the stale copy's eventual discard would
        // prune §4.1 ids whose content is still servable from the fresh
        // copy. (Admission is deterministic in the key length, so the
        // merged entry is placeable wherever the old copy was.)
        if let Some(pos) = self.ssd.entries.iter().position(|x| x.tokens == e.tokens) {
            let old = self.ssd.entries.remove(pos);
            self.ssd.resident -= old.tokens.len();
            for r in old.request_ids {
                if !e.request_ids.contains(&r) {
                    e.request_ids.push(r);
                }
            }
            if e.payload.is_none() {
                e.payload = old.payload;
            }
            // the merged entry may land in DRAM; until it re-enters the
            // SSD shelf the key has no durable copy
            self.mirror_del(&e.tokens);
        }
        // (entry, already counted as demoted?) — DRAM spills were counted
        // on their original admission; DRAM-refused entries were not
        let mut to_ssd: Vec<(Entry<V>, bool)> = Vec::new();
        if self.admits(Tier::Dram, len) {
            self.stat_demoted_tokens += len as u64;
            self.dram.insert(e);
            while self.dram.resident > self.dram.capacity {
                let victim = self.dram.pop_lru().expect("resident > 0 implies entries");
                to_ssd.push((victim, true));
            }
        } else {
            to_ssd.push((e, false));
        }
        for (e, counted) in to_ssd {
            let n = e.tokens.len();
            if self.admits(Tier::Ssd, n) {
                if !counted {
                    self.stat_demoted_tokens += n as u64;
                }
                let key = e.tokens.clone();
                self.ssd.insert(e);
                self.mirror_put(&key);
                while self.ssd.resident > self.ssd.capacity {
                    let victim = self.ssd.pop_lru().expect("resident > 0 implies entries");
                    self.mirror_del(&victim.tokens);
                    self.stat_discarded_tokens += victim.tokens.len() as u64;
                    discarded.extend(victim.request_ids);
                }
            } else {
                self.stat_discarded_tokens += n as u64;
                discarded.extend(e.request_ids);
            }
        }
        discarded.sort_unstable();
        discarded.dedup();
        discarded
    }

    /// Observably side-effect-free probe (`&self` — provably no LRU or
    /// stat perturbation, mirroring
    /// [`crate::cache::RadixCache::peek_prefix_len`]): the longest common
    /// prefix any stored entry shares with `key` strictly beyond
    /// `min_len`, or `min_len` when no tier extends the match.
    pub fn peek_longest(&self, key: &[u32], min_len: usize) -> usize {
        let d = self.dram.best_match(key, min_len).map_or(min_len, |(_, l)| l);
        let s = self.ssd.best_match(key, min_len).map_or(min_len, |(_, l)| l);
        d.max(s)
    }

    /// Promote the stored entry sharing the longest prefix with `key`
    /// beyond `min_len` (the hot match): the entry is removed from its
    /// shelf and returned with the modeled load cost for the span
    /// `[min_len, matched)`. Any entry tail past the divergence point is
    /// dropped (counted in `stat_discarded_tokens`; its ids are NOT
    /// reported for pruning — the caller re-tags them onto the promoted
    /// prefix, which is real resident content again). Prefers the longer
    /// match; at equal length the cheaper tier (DRAM). Under
    /// [`AdmissionPolicy::CostAware`], promotions that would cost more
    /// than recomputing the span are refused and the entry left in place.
    pub fn promote(&mut self, key: &[u32], min_len: usize) -> Option<Promotion<V>> {
        let d_match = self
            .dram
            .best_match(key, min_len)
            .map(|(i, l)| (Tier::Dram, i, l, l == self.dram.entries[i].tokens.len()));
        let s_match = self
            .ssd
            .best_match(key, min_len)
            .map(|(i, l)| (Tier::Ssd, i, l, l == self.ssd.entries[i].tokens.len()));
        // the same comparison that gates demotion admission gates
        // promotion profitability (one rule, both directions — the basis
        // of the "demote-mode TTFT never worse" guarantee)
        let gate = |m: Option<(Tier, usize, usize, bool)>, costs: &TierCosts| {
            let (tier, idx, matched, full) = m?;
            let span = matched - min_len;
            self.admission
                .admits(costs, self.recompute_s_per_tok, span)
                .then(|| (tier, idx, matched, full, costs.reload_s(span)))
        };
        let d = gate(d_match, &self.dram_costs);
        let s = gate(s_match, &self.ssd_costs);
        let (tier, idx, matched, _full, load_s) = match (d, s) {
            (Some(d), Some(s)) => {
                // longer match wins; at equal length a fully-matched entry
                // beats a diverging one (same rule as Shelf::best_match —
                // no tail or payload to waste); then DRAM (cheaper load)
                if s.2 > d.2 || (s.2 == d.2 && s.3 && !d.3) {
                    s
                } else {
                    d
                }
            }
            (Some(d), None) => d,
            (None, Some(s)) => s,
            (None, None) => return None,
        };
        let shelf = match tier {
            Tier::Dram => &mut self.dram,
            Tier::Ssd => &mut self.ssd,
            Tier::Hbm => unreachable!("store holds no HBM entries"),
        };
        let e = shelf.entries.remove(idx);
        shelf.resident -= e.tokens.len();
        if tier == Tier::Ssd {
            self.mirror_del(&e.tokens);
        }
        debug_assert!(matched <= e.tokens.len());
        self.stat_promoted_tokens += (matched - min_len) as u64;
        let full = matched == e.tokens.len();
        if !full {
            // the diverged tail leaves the hierarchy
            self.stat_discarded_tokens += (e.tokens.len() - matched) as u64;
        }
        Some(Promotion {
            tier,
            matched,
            tokens: e.tokens,
            request_ids: e.request_ids,
            payload: if full { e.payload } else { None },
            load_s,
        })
    }

    /// Checkpoint spill: move everything still warm — the (volatile)
    /// DRAM shelf plus the radix cache's freshly evicted hot entries —
    /// into the durable SSD shelf. The admission cost gate is bypassed
    /// (this is a shutdown, not a steady-state demotion: content not
    /// spilled now is simply gone after the restart); capacity is still
    /// enforced. Returns the ids whose content left the hierarchy, for
    /// §4.1 pruning, exactly like [`TierStore::demote`].
    pub fn spill_for_checkpoint(&mut self, hot: Vec<EvictedEntry<V>>) -> Vec<RequestId> {
        let mut discarded: Vec<RequestId> = Vec::new();
        // DRAM first, oldest stamps first, keeping the stamps: the warm
        // shelf's LRU order stays intact under the hot entries about to
        // arrive with fresh (newer) stamps
        let mut dram_entries = std::mem::take(&mut self.dram.entries);
        self.dram.resident = 0;
        dram_entries.sort_by_key(|e| e.stamp);
        for e in dram_entries {
            self.spill_into_ssd(e, false, &mut discarded);
        }
        for entry in hot {
            let stamp = self.tick();
            let e = Entry {
                tokens: entry.tokens,
                request_ids: entry.request_ids,
                payload: entry.payload,
                stamp,
            };
            self.spill_into_ssd(e, true, &mut discarded);
        }
        discarded.sort_unstable();
        discarded.dedup();
        discarded
    }

    fn spill_into_ssd(&mut self, e: Entry<V>, count_demoted: bool, discarded: &mut Vec<RequestId>) {
        let n = e.tokens.len();
        if n == 0 {
            return;
        }
        if n > self.ssd.capacity {
            self.stat_discarded_tokens += n as u64;
            discarded.extend(e.request_ids);
            return;
        }
        if count_demoted {
            self.stat_demoted_tokens += n as u64;
        }
        let key = e.tokens.clone();
        self.ssd.insert(e);
        self.mirror_put(&key);
        while self.ssd.resident > self.ssd.capacity {
            let victim = self.ssd.pop_lru().expect("resident > 0 implies entries");
            self.mirror_del(&victim.tokens);
            self.stat_discarded_tokens += victim.tokens.len() as u64;
            discarded.extend(victim.request_ids);
        }
    }

    /// Structural invariants (tests / failure injection).
    pub fn check_invariants(&self) -> Result<(), String> {
        for (name, shelf) in [("dram", &self.dram), ("ssd", &self.ssd)] {
            let counted: usize = shelf.entries.iter().map(|e| e.tokens.len()).sum();
            if counted != shelf.resident {
                return Err(format!(
                    "{name}: counted {counted} != tracked {}",
                    shelf.resident
                ));
            }
            if shelf.resident > shelf.capacity {
                return Err(format!("{name} over capacity"));
            }
            for e in &shelf.entries {
                if e.tokens.is_empty() {
                    return Err(format!("{name}: empty entry"));
                }
            }
        }
        // mirror coherence: unless a sticky I/O error already explains a
        // divergence, the storage backend holds exactly the SSD shelf
        if self.storage_error.is_none() {
            let scanned = self.store.scan().map_err(|e| e.to_string())?;
            if scanned.len() != self.ssd.entries.len() {
                return Err(format!(
                    "storage mirror holds {} records, ssd shelf {}",
                    scanned.len(),
                    self.ssd.entries.len()
                ));
            }
            for rec in &scanned {
                let e = self
                    .ssd
                    .entries
                    .iter()
                    .find(|e| e.tokens == rec.tokens)
                    .ok_or("storage mirror holds a key missing from the ssd shelf")?;
                let ids: Vec<u64> = e.request_ids.iter().map(|r| r.0).collect();
                let payload = e.payload.as_ref().map_or(Json::Null, ColdPayload::to_json);
                if rec.request_ids != ids || rec.stamp != e.stamp || rec.payload != payload {
                    return Err(format!("storage mirror diverges on key {:?}", rec.tokens));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, Config};

    fn entry(tokens: &[u32], req: u64) -> EvictedEntry<Vec<u8>> {
        EvictedEntry {
            tokens: tokens.to_vec(),
            request_ids: vec![RequestId(req)],
            payload: Some(tokens.iter().map(|&t| t as u8).collect()),
        }
    }

    fn roomy() -> TierConfig {
        let mut cfg = TierConfig::new(1 << 20, 1 << 20);
        cfg.admission = AdmissionPolicy::Always;
        cfg
    }

    #[test]
    fn parse_cli_spec() {
        let (hbm, cfg) = TierConfig::parse("hbm=4000,dram=16000,ssd=64000").unwrap();
        assert_eq!(hbm, 4000);
        assert_eq!(cfg.dram_tokens, 16_000);
        assert_eq!(cfg.ssd_tokens, 64_000);
        assert_eq!(cfg.admission, AdmissionPolicy::CostAware);
        // subset: missing tiers disabled
        let (hbm, cfg) = TierConfig::parse("hbm=500").unwrap();
        assert_eq!((hbm, cfg.dram_tokens, cfg.ssd_tokens), (500, 0, 0));
        // k/m suffixes scale by 10^3 / 10^6
        let (hbm, cfg) = TierConfig::parse("hbm=64k,dram=256K,ssd=1m").unwrap();
        assert_eq!(hbm, 64_000);
        assert_eq!((cfg.dram_tokens, cfg.ssd_tokens), (256_000, 1_000_000));
        // errors — every rejection is the facade's typed InvalidConfig
        // (incl. a suffixed count that would overflow usize)
        for bad in [
            "dram=10",
            "hbm=0",
            "hbm=x",
            "vram=10,hbm=1",
            "hbm",
            "hbm=4q",
            "hbm=18446744073709551615k",
            // duplicate keys are ambiguous, not last-wins
            "hbm=64k,hbm=1",
            "hbm=1,dram=2,dram=3",
            "hbm=1,ssd=2,ssd=2",
        ] {
            assert!(
                matches!(
                    TierConfig::parse(bad),
                    Err(crate::api::Error::InvalidConfig(_))
                ),
                "spec '{bad}' must be rejected as InvalidConfig"
            );
        }
        let msg = match TierConfig::parse("hbm=64k,hbm=1") {
            Err(crate::api::Error::InvalidConfig(m)) => m,
            other => panic!("expected InvalidConfig, got {other:?}"),
        };
        assert!(msg.contains("more than once"), "got: {msg}");
    }

    #[test]
    fn per_shard_divides_budgets() {
        let cfg = TierConfig::new(1000, 4000).per_shard(4);
        assert_eq!((cfg.dram_tokens, cfg.ssd_tokens), (250, 1000));
    }

    #[test]
    fn demote_then_promote_returns_entry() {
        let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
        let discarded = store.demote(entry(&[1, 2, 3, 4], 7));
        assert!(discarded.is_empty());
        assert_eq!(store.dram_resident_tokens(), 4);
        let p = store.promote(&[1, 2, 3, 4, 5, 6], 0).expect("promoted");
        assert_eq!(p.tier, Tier::Dram);
        assert_eq!(p.matched, 4);
        assert_eq!(p.tokens, vec![1, 2, 3, 4]);
        assert_eq!(p.request_ids, vec![RequestId(7)]);
        assert_eq!(p.payload.unwrap(), vec![1, 2, 3, 4]);
        assert_eq!(store.entry_count(), 0);
        store.check_invariants().unwrap();
    }

    #[test]
    fn partial_divergence_promotes_common_prefix_and_drops_tail() {
        let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
        store.demote(entry(&[1, 2, 3, 4], 1));
        // already covered by the hot match: nothing to promote
        assert!(store.promote(&[1, 2, 3, 4], 4).is_none());
        assert_eq!(store.entry_count(), 1, "refused probes leave entries");
        // diverges at position 2: the shared span promotes, the {3,4} tail
        // (a snapshot past the divergence) is dropped without its payload
        let p = store.promote(&[1, 2, 9, 9], 0).expect("common prefix");
        assert_eq!(p.matched, 2);
        assert_eq!(p.tokens, vec![1, 2, 3, 4]);
        assert_eq!(p.request_ids, vec![RequestId(1)]);
        assert!(p.payload.is_none(), "end-of-entry KV invalid at divergence");
        assert_eq!(store.entry_count(), 0);
        assert_eq!(store.stat_promoted_tokens, 2);
        assert_eq!(store.stat_discarded_tokens, 2);
        store.check_invariants().unwrap();
    }

    #[test]
    fn equal_lcp_prefers_fully_matched_entry() {
        let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
        store.demote(entry(&[1, 2, 3, 8, 8], 1)); // diverging tail
        store.demote(entry(&[1, 2, 3], 2)); // exact
        let p = store.promote(&[1, 2, 3, 4], 0).unwrap();
        assert_eq!(p.request_ids, vec![RequestId(2)], "full match preferred");
        assert!(p.payload.is_some());
        assert_eq!(store.entry_count(), 1, "diverging entry left in place");
    }

    #[test]
    fn dram_overflow_spills_lru_to_ssd_and_ssd_overflow_discards() {
        let mut cfg = TierConfig::new(6, 6);
        cfg.admission = AdmissionPolicy::Always;
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        assert!(store.demote(entry(&[1, 2, 3], 1)).is_empty());
        assert!(store.demote(entry(&[4, 5, 6], 2)).is_empty());
        // third demotion overflows DRAM: entry 1 (LRU) spills to SSD
        assert!(store.demote(entry(&[7, 8, 9], 3)).is_empty());
        assert_eq!(store.dram_resident_tokens(), 6);
        assert_eq!(store.ssd_resident_tokens(), 3);
        assert_eq!(store.peek_longest(&[1, 2, 3], 0), 3, "spilled, not lost");
        // two more: SSD fills, then the oldest SSD entry is discarded
        assert!(store.demote(entry(&[10, 11, 12], 4)).is_empty());
        let discarded = store.demote(entry(&[13, 14, 15], 5));
        assert_eq!(discarded, vec![RequestId(1)]);
        assert_eq!(store.peek_longest(&[1, 2, 3], 0), 0, "finally discarded");
        assert!(store.stat_discarded_tokens >= 3);
        store.check_invariants().unwrap();
    }

    #[test]
    fn cost_aware_admission_refuses_and_reports_ids() {
        // CostAware + tiny entries: reload overhead beats recompute, so
        // demotion must discard immediately and report the ids for pruning
        let cfg = TierConfig::new(1 << 20, 1 << 20); // CostAware default
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        let discarded = store.demote(entry(&[1, 2], 9));
        assert_eq!(discarded, vec![RequestId(9)]);
        assert_eq!(store.entry_count(), 0);
        assert_eq!(store.stat_demoted_tokens, 0);
        assert_eq!(store.stat_discarded_tokens, 2);
    }

    #[test]
    fn cost_aware_promotion_skips_unprofitable_spans() {
        let cfg = TierConfig::new(1 << 20, 1 << 20);
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        let long: Vec<u32> = (0..1000).collect();
        assert!(store.demote(entry(&long, 1)).is_empty(), "1000 tok admits");
        // hot match already covers 995 of 1000: reloading 5 tokens costs
        // more than recomputing them -> leave the entry in place
        assert!(store.promote(&long, 995).is_none());
        assert_eq!(store.entry_count(), 1);
        // a cold probe promotes the full kilotoken span profitably
        let p = store.promote(&long, 0).expect("profitable");
        assert!(p.load_s < 1000.0 * 5e-5);
    }

    #[test]
    fn cross_shelf_duplicates_are_absorbed_on_demote() {
        // an entry spilled to SSD, then the same key demoted again: the
        // stale SSD copy must be absorbed into the fresh one, so its
        // eventual discard can never prune ids with servable content
        let mut cfg = TierConfig::new(6, 1 << 20);
        cfg.admission = AdmissionPolicy::Always;
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        store.demote(entry(&[1, 2, 3], 1));
        store.demote(entry(&[4, 5, 6], 2)); // DRAM now full
        store.demote(entry(&[7, 8, 9], 3)); // spills [1,2,3] to SSD
        assert_eq!(store.ssd_resident_tokens(), 3);
        // the same key comes back down (re-hot, then re-evicted)
        assert!(store.demote(entry(&[1, 2, 3], 4)).is_empty());
        let p = store.promote(&[1, 2, 3], 0).expect("merged copy");
        let mut ids = p.request_ids.clone();
        ids.sort_unstable();
        assert!(
            ids.contains(&RequestId(1)) && ids.contains(&RequestId(4)),
            "old ids not absorbed: {ids:?}"
        );
        assert!(
            store.promote(&[1, 2, 3], 0).is_none(),
            "duplicate copy survived in a shelf"
        );
        store.check_invariants().unwrap();
    }

    #[test]
    fn cross_shelf_tie_prefers_full_match_over_dram() {
        // equal lcp in both shelves: the fully-matched SSD entry (usable
        // payload, no tail waste) must beat the diverging DRAM entry,
        // mirroring the in-shelf tie rule
        let mut cfg = TierConfig::new(6, 1 << 20);
        cfg.admission = AdmissionPolicy::Always;
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        store.demote(entry(&[1, 2, 3], 1));
        store.demote(entry(&[9, 9, 9], 2)); // DRAM full
        store.demote(entry(&[1, 2, 3, 8, 8], 3)); // spills both to SSD
        let p = store.promote(&[1, 2, 3, 4], 0).expect("tie candidate");
        assert_eq!(p.tier, Tier::Ssd);
        assert_eq!(p.request_ids, vec![RequestId(1)]);
        assert!(p.payload.is_some(), "full match keeps its snapshot");
        store.check_invariants().unwrap();
    }

    #[test]
    fn identical_tokens_merge_instead_of_duplicating() {
        let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
        store.demote(entry(&[1, 2, 3], 1));
        let mut second = entry(&[1, 2, 3], 2);
        second.payload = Some(vec![9, 9, 9]);
        store.demote(second);
        assert_eq!(store.entry_count(), 1);
        assert_eq!(store.dram_resident_tokens(), 3);
        let p = store.promote(&[1, 2, 3], 0).unwrap();
        let mut ids = p.request_ids.clone();
        ids.sort_unstable();
        assert_eq!(ids, vec![RequestId(1), RequestId(2)]);
        assert_eq!(p.payload.unwrap(), vec![9, 9, 9], "newest payload wins");
    }

    #[test]
    fn peek_longest_is_side_effect_free_and_agrees_with_promote() {
        let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
        store.demote(entry(&[1, 2, 3], 1));
        store.demote(entry(&[1, 2, 3, 4, 5], 2));
        let before = format!("{store:?}");
        for _ in 0..10 {
            assert_eq!(store.peek_longest(&[1, 2, 3, 4, 5, 6], 0), 5);
            assert_eq!(store.peek_longest(&[1, 2, 3, 9], 0), 3);
            assert_eq!(store.peek_longest(&[7], 0), 0);
            assert_eq!(store.peek_longest(&[1, 2, 3], 3), 3, "min_len respected");
        }
        assert_eq!(format!("{store:?}"), before, "peek mutated the store");
        let p = store.promote(&[1, 2, 3, 4, 5, 6], 0).unwrap();
        assert_eq!(p.matched, 5, "promote takes the longest prefix");
    }

    /// Satellite: demote-then-promote round-trips payloads byte-identically
    /// for arbitrary entry populations (the eviction→demotion→promotion
    /// chain may never corrupt KV).
    #[test]
    fn prop_demote_then_promote_roundtrips_payloads_byte_identically() {
        check(
            "tier demote/promote round-trip",
            Config {
                cases: 96,
                base_seed: 0x71E2,
                max_size: 24,
            },
            |rng: &mut Rng, size| {
                let mut store: TierStore<Vec<u8>> = TierStore::new(&roomy(), 5e-5);
                // distinct first tokens -> no entry is a prefix of another,
                // so every demoted entry must survive verbatim
                let n = size.clamp(1, 24);
                let mut keys: Vec<Vec<u32>> = Vec::new();
                for i in 0..n {
                    let len = 1 + rng.below(12);
                    let mut key = vec![i as u32 + 1];
                    key.extend((0..len).map(|_| rng.below(50) as u32 + 100));
                    keys.push(key);
                }
                for (i, key) in keys.iter().enumerate() {
                    let payload: Vec<u8> = key.iter().map(|&t| (t % 251) as u8).collect();
                    let discarded = store.demote(EvictedEntry {
                        tokens: key.clone(),
                        request_ids: vec![RequestId(i as u64)],
                        payload: Some(payload),
                    });
                    if !discarded.is_empty() {
                        return Err("roomy store discarded an entry".to_string());
                    }
                }
                store.check_invariants().map_err(|e| e.to_string())?;
                for (i, key) in keys.iter().enumerate() {
                    let p = store
                        .promote(key, 0)
                        .ok_or_else(|| format!("entry {i} lost"))?;
                    if p.tokens != *key {
                        return Err(format!("entry {i}: tokens corrupted"));
                    }
                    let want: Vec<u8> = key.iter().map(|&t| (t % 251) as u8).collect();
                    if p.payload.as_deref() != Some(want.as_slice()) {
                        return Err(format!("entry {i}: payload corrupted"));
                    }
                    if p.request_ids != vec![RequestId(i as u64)] {
                        return Err(format!("entry {i}: request ids corrupted"));
                    }
                }
                if store.entry_count() != 0 {
                    return Err("promotion left stale entries".to_string());
                }
                Ok(())
            },
        );
    }

    /// Satellite: a zero-capacity cold tier must behave exactly like
    /// discard mode — every demotion leaves the hierarchy immediately and
    /// reports its ids for §4.1 pruning, with or without the cost gate.
    #[test]
    fn zero_capacity_cold_tier_discards_immediately() {
        for admission in [AdmissionPolicy::Always, AdmissionPolicy::CostAware] {
            let mut cfg = TierConfig::new(0, 0);
            cfg.admission = admission;
            let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
            let discarded = store.demote(entry(&[1, 2, 3, 4], 7));
            assert_eq!(discarded, vec![RequestId(7)], "{admission:?}");
            assert_eq!(store.entry_count(), 0);
            assert_eq!(store.stat_demoted_tokens, 0);
            assert_eq!(store.stat_discarded_tokens, 4);
            assert_eq!(store.peek_longest(&[1, 2, 3, 4], 0), 0);
            assert!(store.promote(&[1, 2, 3, 4], 0).is_none());
            // the checkpoint spill likewise has nowhere durable to go
            let spilled = store.spill_for_checkpoint(vec![entry(&[5, 6], 8)]);
            assert_eq!(spilled, vec![RequestId(8)]);
            assert_eq!(store.entry_count(), 0);
            store.check_invariants().unwrap();
        }
    }

    use crate::cache::storage::{FileStorage, MemStorage, Storage};

    fn file_store(dir: &std::path::Path, resume: bool) -> Box<dyn Storage> {
        Box::new(FileStorage::open(&dir.join("cold.jsonl"), resume).unwrap())
    }

    fn tempdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ctxpilot-tier-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Tentpole: a file-backed store serves bit-identically to the
    /// in-memory default, and after a drop + rehydrate the SSD shelf
    /// comes back verbatim — ids, payloads, and LRU order included.
    #[test]
    fn file_backed_store_matches_memory_and_rehydrates_verbatim() {
        let dir = tempdir("rehydrate");
        let mut cfg = TierConfig::new(6, 9);
        cfg.admission = AdmissionPolicy::Always;
        let mut mem: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        let mut file: TierStore<Vec<u8>> =
            TierStore::with_storage(&cfg, 5e-5, file_store(&dir, false), false).unwrap();
        // a workload that exercises spill, overflow-discard, merge, promote
        let keys: [&[u32]; 5] = [&[1, 2, 3], &[4, 5, 6], &[7, 8, 9], &[1, 2, 3], &[10, 11, 12]];
        for (i, k) in keys.iter().enumerate() {
            let a = mem.demote(entry(k, i as u64));
            let b = file.demote(entry(k, i as u64));
            assert_eq!(a, b, "demote {i} diverged");
        }
        let a = mem.promote(&[4, 5, 6, 7], 0);
        let b = file.promote(&[4, 5, 6, 7], 0);
        assert_eq!(a.is_some(), b.is_some());
        if let (Some(a), Some(b)) = (a, b) {
            assert_eq!((a.tier, a.matched, &a.tokens), (b.tier, b.matched, &b.tokens));
            assert_eq!(a.request_ids, b.request_ids);
            assert_eq!(a.payload, b.payload);
        }
        mem.check_invariants().unwrap();
        file.check_invariants().unwrap();
        assert!(file.storage_error().is_none());
        file.storage_flush().unwrap();
        let ssd_before: usize = file.ssd_resident_tokens();
        let probe = |s: &TierStore<Vec<u8>>| {
            (
                s.peek_longest(&[1, 2, 3], 0),
                s.peek_longest(&[7, 8, 9], 0),
                s.peek_longest(&[10, 11, 12], 0),
            )
        };
        let before = probe(&file);
        drop(file);
        // "restart": only the SSD shelf survives (DRAM is volatile)
        let resumed: TierStore<Vec<u8>> =
            TierStore::with_storage(&cfg, 5e-5, file_store(&dir, true), true).unwrap();
        resumed.check_invariants().unwrap();
        assert_eq!(resumed.ssd_resident_tokens(), ssd_before);
        assert_eq!(resumed.dram_resident_tokens(), 0, "DRAM does not survive");
        // every pre-restart probe answerable from SSD still answers
        let after = probe(&resumed);
        for (b, a) in [(before.0, after.0), (before.1, after.1), (before.2, after.2)] {
            assert!(a == b || a == 0, "rehydrated shelf invented content");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The checkpoint spill drains DRAM and the hot entries into the
    /// durable shelf, bypassing the cost gate (CostAware would refuse
    /// these tiny spans in steady state) while still enforcing capacity.
    #[test]
    fn checkpoint_spill_bypasses_cost_gate_but_not_capacity() {
        let cfg = TierConfig::new(8, 8); // CostAware default
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        // steady-state demotion refuses a 2-token span under CostAware…
        assert_eq!(store.demote(entry(&[1, 2], 1)), vec![RequestId(1)]);
        // …but the shutdown spill must keep it
        let discarded = store.spill_for_checkpoint(vec![entry(&[1, 2], 2), entry(&[3, 4, 5], 3)]);
        assert!(discarded.is_empty());
        assert_eq!(store.ssd_resident_tokens(), 5);
        assert_eq!(store.peek_longest(&[1, 2], 0), 2);
        store.check_invariants().unwrap();
        // capacity still binds: overflow sheds LRU and reports ids
        let discarded = store.spill_for_checkpoint(vec![entry(&[6, 7, 8, 9], 4)]);
        assert_eq!(discarded, vec![RequestId(2)], "LRU spill victim pruned");
        store.check_invariants().unwrap();
    }

    /// The spill also drains the volatile DRAM shelf into SSD, preserving
    /// relative LRU order (DRAM content is older than the hot entries).
    #[test]
    fn checkpoint_spill_drains_dram_before_hot() {
        let mut cfg = TierConfig::new(16, 6);
        cfg.admission = AdmissionPolicy::Always;
        let mut store: TierStore<Vec<u8>> = TierStore::new(&cfg, 5e-5);
        store.demote(entry(&[1, 2, 3], 1)); // DRAM
        store.demote(entry(&[4, 5, 6], 2)); // DRAM
        let discarded = store.spill_for_checkpoint(vec![entry(&[7, 8, 9], 3)]);
        // SSD holds 6 of the 9 spilled tokens: the OLDEST DRAM entry is
        // the overflow victim, not the fresh hot entry
        assert_eq!(discarded, vec![RequestId(1)]);
        assert_eq!(store.dram_resident_tokens(), 0);
        assert_eq!(store.ssd_resident_tokens(), 6);
        assert_eq!(store.peek_longest(&[7, 8, 9], 0), 3);
        assert_eq!(store.peek_longest(&[4, 5, 6], 0), 3);
        store.check_invariants().unwrap();
    }

    /// Identical workloads against MemStorage-backed and FileStorage-backed
    /// stores leave byte-identical storage scans (the wire form is the
    /// backend contract, not an implementation detail).
    #[test]
    fn mem_and_file_backends_scan_identically() {
        let dir = tempdir("scan");
        let mut cfg = TierConfig::new(3, 64);
        cfg.admission = AdmissionPolicy::Always;
        let mut a: TierStore<Vec<u8>> =
            TierStore::with_storage(&cfg, 5e-5, Box::new(MemStorage::new()), false).unwrap();
        let mut b: TierStore<Vec<u8>> =
            TierStore::with_storage(&cfg, 5e-5, file_store(&dir, false), false).unwrap();
        for (i, k) in [&[1u32, 2, 3][..], &[9, 9][..], &[1, 2, 3][..]].iter().enumerate() {
            a.demote(entry(k, i as u64));
            b.demote(entry(k, i as u64));
        }
        a.spill_for_checkpoint(Vec::new());
        b.spill_for_checkpoint(Vec::new());
        assert_eq!(a.store.scan().unwrap(), b.store.scan().unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
