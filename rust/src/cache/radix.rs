//! Token-level radix-tree prefix cache (the RadixCache of SGLang, §2.1).
//!
//! Each node stores a token span (edge label from its parent) plus an
//! optional payload `V` — the simulated engine uses `()`, the real PJRT
//! engine attaches KV-cache snapshots at chunk boundaries. Capacity is
//! counted in resident tokens; eviction is LRU over unlocked leaves,
//! exactly the policy the paper's scheduler (Alg. 5) is designed around.
//!
//! ContextPilot integration (paper §4.1): every insert is tagged with the
//! engine `RequestId`; `evict` returns the request ids of removed nodes so
//! the context index can prune the matching entries.
//!
//! Tiered mode ([`RadixCache::enable_demotion`]): eviction becomes
//! *demotion* — removed leaves are reconstructed into root-anchored
//! [`EvictedEntry`]s (full token prefix + request-id tags + payload) and
//! buffered for a [`crate::cache::TierStore`] to absorb, and the §4.1
//! prune list stays empty until the tier store finally discards an entry
//! (the content is still servable while it sits in DRAM/SSD).

use std::collections::HashMap;

use crate::types::RequestId;

/// A radix entry removed by eviction, reconstructed as a root-anchored
/// token prefix — what a demotion sink ([`crate::cache::TierStore`])
/// consumes. The payload travels with it so demote-then-promote
/// round-trips KV byte-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct EvictedEntry<V> {
    /// Full token prefix from the root through the evicted node.
    pub tokens: Vec<u32>,
    /// Request ids tagged on the evicted node (§4.1 ownership).
    pub request_ids: Vec<RequestId>,
    pub payload: Option<V>,
}

pub type NodeId = usize;
const ROOT: NodeId = 0;

#[derive(Debug)]
struct Node<V> {
    /// Edge label: tokens on the path from the parent to this node.
    tokens: Vec<u32>,
    children: HashMap<u32, NodeId>,
    parent: NodeId,
    last_access: u64,
    /// Pin count: in-flight requests using this prefix; pinned nodes are
    /// not evictable.
    locks: u32,
    /// Request ids whose insert created/extended this node.
    request_ids: Vec<RequestId>,
    payload: Option<V>,
    alive: bool,
}

#[derive(Debug)]
pub struct RadixCache<V> {
    nodes: Vec<Node<V>>,
    free: Vec<NodeId>,
    capacity: usize,
    resident: usize,
    clock: u64,
    /// Demotion mode: evicted leaves are buffered as [`EvictedEntry`]s
    /// instead of reporting their request ids for index pruning.
    demote: bool,
    demoted: Vec<EvictedEntry<V>>,
    /// Cumulative counters for Fig. 12/13 style reporting.
    pub stat_matched_tokens: u64,
    pub stat_lookup_tokens: u64,
    pub stat_inserted_tokens: u64,
    pub stat_evicted_tokens: u64,
}

/// Result of a prefix match.
#[derive(Clone, Debug)]
pub struct PrefixMatch {
    /// Number of leading tokens of the key found in the cache.
    pub len: usize,
    /// Node path from root (exclusive) to the deepest matched node.
    pub path: Vec<NodeId>,
}

impl<V> RadixCache<V> {
    pub fn new(capacity_tokens: usize) -> Self {
        let root = Node {
            tokens: Vec::new(),
            children: HashMap::new(),
            parent: ROOT,
            last_access: 0,
            locks: 0,
            request_ids: Vec::new(),
            payload: None,
            alive: true,
        };
        Self {
            nodes: vec![root],
            free: Vec::new(),
            capacity: capacity_tokens,
            resident: 0,
            clock: 0,
            demote: false,
            demoted: Vec::new(),
            stat_matched_tokens: 0,
            stat_lookup_tokens: 0,
            stat_inserted_tokens: 0,
            stat_evicted_tokens: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn resident_tokens(&self) -> usize {
        self.resident
    }

    /// Switch eviction from discard to demotion: removed leaves are
    /// reconstructed into [`EvictedEntry`]s (drain with
    /// [`RadixCache::take_demotions`]) and the request ids returned by
    /// `insert`/`evict_tokens` no longer include them — the caller prunes
    /// the §4.1 index only when the tier store reports a final discard.
    pub fn enable_demotion(&mut self) {
        self.demote = true;
    }

    pub fn demotion_enabled(&self) -> bool {
        self.demote
    }

    /// Drain the demotion buffer (entries evicted since the last drain, in
    /// eviction order). Observably side-effect-free on cache state: no
    /// clock tick, no recency touch, no stat change.
    pub fn take_demotions(&mut self) -> Vec<EvictedEntry<V>> {
        std::mem::take(&mut self.demoted)
    }

    /// Current LRU clock — exposed so tests can *prove* peek paths never
    /// advance recency (`peek_is_observably_side_effect_free`).
    pub fn lru_clock(&self) -> u64 {
        self.clock
    }

    /// Root-anchored token prefix ending at `id` (demotion reconstruction;
    /// touches nothing).
    fn full_key(&self, id: NodeId) -> Vec<u32> {
        let mut chain = Vec::new();
        let mut cur = id;
        while cur != ROOT {
            chain.push(cur);
            cur = self.nodes[cur].parent;
        }
        let mut out = Vec::new();
        for &n in chain.iter().rev() {
            out.extend_from_slice(&self.nodes[n].tokens);
        }
        out
    }

    /// Append `reqs` to the request-id tags of every node on the matched
    /// path of `key`. Promotion re-attaches ownership after a demoted
    /// prefix returns to the hot tier, without touching recency or stats
    /// (the §4.1 index keeps tracking ids whose content is hot again).
    ///
    /// Returns how many leading tokens of `key` were actually covered by
    /// tagged nodes — under extreme thrash the very insert that reloaded
    /// a promoted span can evict parts of it again before tagging, and
    /// the caller must treat a partial cover as an eviction of `reqs`
    /// (otherwise their eventual discard never reaches the prune chain).
    pub fn tag_requests(&mut self, key: &[u32], reqs: &[RequestId]) -> usize {
        let mut cur = ROOT;
        let mut matched = 0usize;
        while matched < key.len() {
            let next = match self.nodes[cur].children.get(&key[matched]) {
                Some(&n) => n,
                None => break,
            };
            let span_len = self.nodes[next].tokens.len();
            let common = self.nodes[next]
                .tokens
                .iter()
                .zip(&key[matched..])
                .take_while(|(a, b)| a == b)
                .count();
            matched += common;
            for &r in reqs {
                if !self.nodes[next].request_ids.contains(&r) {
                    self.nodes[next].request_ids.push(r);
                }
            }
            if common < span_len {
                break;
            }
            cur = next;
        }
        matched
    }

    fn alloc(&mut self, node: Node<V>) -> NodeId {
        if let Some(id) = self.free.pop() {
            self.nodes[id] = node;
            id
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Longest-prefix match without mutating structure (touches LRU).
    pub fn match_prefix(&mut self, key: &[u32]) -> PrefixMatch {
        let now = self.tick();
        let mut cur = ROOT;
        let mut matched = 0usize;
        let mut path = Vec::new();
        'outer: while matched < key.len() {
            let next = match self.nodes[cur].children.get(&key[matched]) {
                Some(&n) => n,
                None => break,
            };
            let node_len = self.nodes[next].tokens.len();
            let span = &self.nodes[next].tokens;
            let avail = key.len() - matched;
            let common = span
                .iter()
                .zip(&key[matched..])
                .take_while(|(a, b)| a == b)
                .count();
            matched += common;
            if common == node_len {
                // full edge matched; descend
                self.nodes[next].last_access = now;
                path.push(next);
                cur = next;
                if common == avail {
                    break 'outer;
                }
            } else {
                // partial edge match: stop here (node not split on lookup)
                self.nodes[next].last_access = now;
                path.push(next);
                break 'outer;
            }
        }
        self.stat_lookup_tokens += key.len() as u64;
        self.stat_matched_tokens += matched as u64;
        PrefixMatch { len: matched, path }
    }

    /// How many leading tokens are cached, **observably side-effect-free**:
    /// unlike [`RadixCache::match_prefix`] it neither advances the LRU
    /// clock, nor touches `last_access`, nor counts toward
    /// `stat_lookup_tokens`/`stat_matched_tokens`. Schedulers poll this
    /// once per queued request per wave (LPM ordering, admission peeks), so
    /// any stat or recency perturbation here would skew both the Fig. 12/13
    /// counters and the eviction order. Contract pinned by
    /// `peek_is_observably_side_effect_free` / `peek_agrees_with_match`.
    pub fn peek_prefix_len(&self, key: &[u32]) -> usize {
        let mut cur = ROOT;
        let mut matched = 0usize;
        while matched < key.len() {
            let next = match self.nodes[cur].children.get(&key[matched]) {
                Some(&n) => n,
                None => break,
            };
            let span = &self.nodes[next].tokens;
            let common = span
                .iter()
                .zip(&key[matched..])
                .take_while(|(a, b)| a == b)
                .count();
            matched += common;
            if common < span.len() {
                break;
            }
            cur = next;
        }
        matched
    }

    /// Split `node` so its edge label has exactly `keep` tokens; the tail
    /// moves into a new child. Payload stays with the *tail* (it snapshots
    /// state at the node's end position).
    fn split(&mut self, node: NodeId, keep: usize) -> NodeId {
        let tail: Vec<u32> = self.nodes[node].tokens.split_off(keep);
        debug_assert!(!tail.is_empty());
        let child_map = std::mem::take(&mut self.nodes[node].children);
        let payload = self.nodes[node].payload.take();
        let reqs = self.nodes[node].request_ids.clone();
        let new_id = self.alloc(Node {
            tokens: tail,
            children: child_map,
            parent: node,
            last_access: self.nodes[node].last_access,
            locks: self.nodes[node].locks,
            request_ids: reqs,
            payload,
            alive: true,
        });
        // fix parents of moved children
        let moved: Vec<NodeId> = self.nodes[new_id].children.values().copied().collect();
        for m in moved {
            self.nodes[m].parent = new_id;
        }
        let first = self.nodes[new_id].tokens[0];
        self.nodes[node].children.insert(first, new_id);
        new_id
    }

    /// Insert `key`, tagging touched/created nodes with `req`. Evicts LRU
    /// leaves as needed to respect capacity. Returns the request ids whose
    /// cache entries were evicted to make room (ContextPilot consumes these
    /// to prune its context index) and the number of *new* tokens inserted.
    pub fn insert(&mut self, key: &[u32], req: RequestId) -> (usize, Vec<RequestId>) {
        let now = self.tick();
        let mut cur = ROOT;
        let mut matched = 0usize;
        while matched < key.len() {
            let next = self.nodes[cur].children.get(&key[matched]).copied();
            match next {
                Some(n) => {
                    let span_len = self.nodes[n].tokens.len();
                    let common = self.nodes[n]
                        .tokens
                        .iter()
                        .zip(&key[matched..])
                        .take_while(|(a, b)| a == b)
                        .count();
                    matched += common;
                    self.nodes[n].last_access = now;
                    if common < span_len {
                        // diverges inside this edge: split, then either stop
                        // (key exhausted) or fall through to append below.
                        self.split(n, common);
                    }
                    if !self.nodes[n].request_ids.contains(&req) {
                        self.nodes[n].request_ids.push(req);
                    }
                    cur = n;
                    if matched == key.len() {
                        return (0, Vec::new());
                    }
                    if common < span_len {
                        break; // diverged: append remainder as child of n
                    }
                }
                None => break,
            }
        }
        // append remainder as a fresh leaf
        let rest: Vec<u32> = key[matched..].to_vec();
        let added = rest.len();
        if added == 0 {
            return (0, Vec::new());
        }
        let evicted = self.make_room(added);
        let leaf = self.alloc(Node {
            tokens: rest,
            children: HashMap::new(),
            parent: cur,
            last_access: now,
            locks: 0,
            request_ids: vec![req],
            payload: None,
            alive: true,
        });
        let first = key[matched];
        self.nodes[cur].children.insert(first, leaf);
        self.resident += added;
        self.stat_inserted_tokens += added as u64;
        (added, evicted)
    }

    /// Evict LRU unlocked leaves until `need` tokens fit. Returns evicted
    /// request ids (deduplicated).
    fn make_room(&mut self, need: usize) -> Vec<RequestId> {
        let mut evicted_reqs = Vec::new();
        while self.resident + need > self.capacity {
            // find LRU unlocked leaf
            let mut victim: Option<(u64, NodeId)> = None;
            for (id, n) in self.nodes.iter().enumerate() {
                if id == ROOT || !n.alive || n.locks > 0 || !n.children.is_empty() {
                    continue;
                }
                if victim.is_none() || n.last_access < victim.unwrap().0 {
                    victim = Some((n.last_access, id));
                }
            }
            let Some((_, v)) = victim else {
                break; // nothing evictable
            };
            self.remove_leaf(v, &mut evicted_reqs);
        }
        evicted_reqs.sort_unstable();
        evicted_reqs.dedup();
        evicted_reqs
    }

    fn remove_leaf(&mut self, id: NodeId, evicted_reqs: &mut Vec<RequestId>) {
        debug_assert!(self.nodes[id].children.is_empty());
        if self.demote {
            // demotion: reconstruct the root-anchored prefix (before
            // unlinking, while the parent chain is intact) and buffer it;
            // the ids stay out of the prune list — the content lives on in
            // a colder tier until the tier store reports a final discard
            let tokens = self.full_key(id);
            let request_ids = std::mem::take(&mut self.nodes[id].request_ids);
            let payload = self.nodes[id].payload.take();
            self.demoted.push(EvictedEntry {
                tokens,
                request_ids,
                payload,
            });
        } else {
            evicted_reqs.extend(self.nodes[id].request_ids.drain(..));
        }
        let parent = self.nodes[id].parent;
        let first = self.nodes[id].tokens[0];
        self.nodes[parent].children.remove(&first);
        self.resident -= self.nodes[id].tokens.len();
        self.stat_evicted_tokens += self.nodes[id].tokens.len() as u64;
        self.nodes[id].alive = false;
        self.nodes[id].tokens.clear();
        self.nodes[id].payload = None;
        self.free.push(id);
    }

    /// Explicitly evict at least `n` tokens (for tests / capacity churn).
    pub fn evict_tokens(&mut self, n: usize) -> Vec<RequestId> {
        let target = self.resident.saturating_sub(n);
        let mut evicted_reqs = Vec::new();
        while self.resident > target {
            let mut victim: Option<(u64, NodeId)> = None;
            for (id, node) in self.nodes.iter().enumerate() {
                if id == ROOT || !node.alive || node.locks > 0 || !node.children.is_empty() {
                    continue;
                }
                if victim.is_none() || node.last_access < victim.unwrap().0 {
                    victim = Some((node.last_access, id));
                }
            }
            let Some((_, v)) = victim else { break };
            self.remove_leaf(v, &mut evicted_reqs);
        }
        evicted_reqs.sort_unstable();
        evicted_reqs.dedup();
        evicted_reqs
    }

    /// Pin / unpin the deepest node of a matched path.
    pub fn lock_path(&mut self, path: &[NodeId]) {
        for &n in path {
            self.nodes[n].locks += 1;
        }
    }

    pub fn unlock_path(&mut self, path: &[NodeId]) {
        for &n in path {
            debug_assert!(self.nodes[n].locks > 0);
            self.nodes[n].locks -= 1;
        }
    }

    /// Attach a payload (e.g. a KV snapshot) to the deepest node matching
    /// exactly `key` (inserting it first if necessary).
    pub fn set_payload(&mut self, key: &[u32], req: RequestId, payload: V) -> Vec<RequestId> {
        let (_, evicted) = self.insert(key, req);
        // walk to the node ending exactly at key.len()
        let m = self.match_prefix(key);
        debug_assert_eq!(m.len, key.len());
        if let Some(&last) = m.path.last() {
            // ensure node boundary == key end: split if the edge overshoots
            let mut consumed = 0usize;
            for &n in &m.path {
                consumed += self.nodes[n].tokens.len();
            }
            if consumed > key.len() {
                let over = consumed - key.len();
                let keep = self.nodes[last].tokens.len() - over;
                self.split(last, keep);
            }
            self.nodes[last].payload = Some(payload);
        }
        evicted
    }

    /// Deepest payload along `key`: returns (prefix_len, &payload).
    pub fn deepest_payload(&self, key: &[u32]) -> Option<(usize, &V)> {
        let mut cur = ROOT;
        let mut matched = 0usize;
        let mut best: Option<(usize, NodeId)> = None;
        while matched < key.len() {
            let next = match self.nodes[cur].children.get(&key[matched]) {
                Some(&n) => n,
                None => break,
            };
            let span = &self.nodes[next].tokens;
            let common = span
                .iter()
                .zip(&key[matched..])
                .take_while(|(a, b)| a == b)
                .count();
            matched += common;
            if common < span.len() {
                break;
            }
            if self.nodes[next].payload.is_some() {
                best = Some((matched, next));
            }
            cur = next;
        }
        best.map(|(len, id)| (len, self.nodes[id].payload.as_ref().unwrap()))
    }

    /// Total alive nodes (diagnostics).
    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.alive).count()
    }

    /// Structural invariants without the capacity bound (lock-heavy fuzz
    /// sequences can legitimately pin more tokens than capacity).
    pub fn check_invariants_ignoring_capacity(&self) -> Result<(), String> {
        self.check_impl(false)
    }

    /// Verify structural invariants (tests / failure injection).
    pub fn check_invariants(&self) -> Result<(), String> {
        self.check_impl(true)
    }

    fn check_impl(&self, enforce_capacity: bool) -> Result<(), String> {
        let mut resident = 0usize;
        for (id, n) in self.nodes.iter().enumerate() {
            if !n.alive {
                continue;
            }
            if id != ROOT {
                resident += n.tokens.len();
                if n.tokens.is_empty() {
                    return Err(format!("node {id} has empty edge"));
                }
                let p = n.parent;
                if !self.nodes[p].alive {
                    return Err(format!("node {id} has dead parent {p}"));
                }
                match self.nodes[p].children.get(&n.tokens[0]) {
                    Some(&c) if c == id => {}
                    _ => return Err(format!("node {id} not linked from parent")),
                }
            }
            for (&first, &c) in &n.children {
                if !self.nodes[c].alive {
                    return Err(format!("node {id} has dead child {c}"));
                }
                if self.nodes[c].tokens[0] != first {
                    return Err(format!("child key mismatch at {id}->{c}"));
                }
                if self.nodes[c].parent != id {
                    return Err(format!("child {c} parent mismatch"));
                }
            }
        }
        if resident != self.resident {
            return Err(format!(
                "resident mismatch: counted {resident} != tracked {}",
                self.resident
            ));
        }
        if enforce_capacity && self.resident > self.capacity {
            return Err("over capacity".to_string());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(cap: usize) -> RadixCache<()> {
        RadixCache::new(cap)
    }

    #[test]
    fn empty_cache_no_match() {
        let mut c = cache(100);
        let m = c.match_prefix(&[1, 2, 3]);
        assert_eq!(m.len, 0);
        assert!(m.path.is_empty());
    }

    #[test]
    fn insert_then_full_match() {
        let mut c = cache(100);
        c.insert(&[1, 2, 3, 4], RequestId(1));
        let m = c.match_prefix(&[1, 2, 3, 4]);
        assert_eq!(m.len, 4);
        c.check_invariants().unwrap();
    }

    #[test]
    fn partial_match_and_split() {
        let mut c = cache(100);
        c.insert(&[1, 2, 3, 4], RequestId(1));
        c.insert(&[1, 2, 9, 9], RequestId(2));
        assert_eq!(c.match_prefix(&[1, 2, 3, 4]).len, 4);
        assert_eq!(c.match_prefix(&[1, 2, 9, 9]).len, 4);
        assert_eq!(c.match_prefix(&[1, 2, 7]).len, 2);
        assert_eq!(c.resident_tokens(), 6); // {1,2} shared + {3,4} + {9,9}
        c.check_invariants().unwrap();
    }

    #[test]
    fn match_returns_true_prefix_len() {
        let mut c = cache(100);
        c.insert(&[5, 6, 7], RequestId(1));
        let m = c.match_prefix(&[5, 6, 8, 9]);
        assert_eq!(m.len, 2);
    }

    #[test]
    fn reinsert_is_noop() {
        let mut c = cache(100);
        let (a1, _) = c.insert(&[1, 2, 3], RequestId(1));
        let (a2, _) = c.insert(&[1, 2, 3], RequestId(2));
        assert_eq!(a1, 3);
        assert_eq!(a2, 0);
        assert_eq!(c.resident_tokens(), 3);
    }

    #[test]
    fn eviction_respects_capacity_and_reports_request_ids() {
        let mut c = cache(6);
        c.insert(&[1, 2, 3], RequestId(1));
        c.insert(&[4, 5, 6], RequestId(2));
        assert_eq!(c.resident_tokens(), 6);
        // inserting 3 more must evict the LRU leaf (request 1)
        let (_, evicted) = c.insert(&[7, 8, 9], RequestId(3));
        assert_eq!(evicted, vec![RequestId(1)]);
        assert!(c.resident_tokens() <= 6);
        assert_eq!(c.match_prefix(&[1, 2, 3]).len, 0);
        assert_eq!(c.peek_prefix_len(&[7, 8, 9]), 3);
        c.check_invariants().unwrap();
    }

    #[test]
    fn lru_order_follows_access() {
        let mut c = cache(6);
        c.insert(&[1, 2, 3], RequestId(1));
        c.insert(&[4, 5, 6], RequestId(2));
        // touch the first entry so the second becomes LRU
        c.match_prefix(&[1, 2, 3]);
        let (_, evicted) = c.insert(&[7, 8, 9], RequestId(3));
        assert_eq!(evicted, vec![RequestId(2)]);
        assert_eq!(c.peek_prefix_len(&[1, 2, 3]), 3);
    }

    #[test]
    fn locked_nodes_survive_eviction() {
        let mut c = cache(6);
        c.insert(&[1, 2, 3], RequestId(1));
        let m = c.match_prefix(&[1, 2, 3]);
        c.lock_path(&m.path);
        c.insert(&[4, 5, 6], RequestId(2));
        let (added, evicted) = c.insert(&[7, 8, 9], RequestId(3));
        assert_eq!(added, 3);
        // request 1 is pinned; request 2 must be the victim
        assert_eq!(evicted, vec![RequestId(2)]);
        c.unlock_path(&m.path);
        c.check_invariants().unwrap();
    }

    #[test]
    fn evict_tokens_explicit() {
        let mut c = cache(100);
        c.insert(&[1, 2, 3], RequestId(1));
        c.insert(&[1, 2, 4], RequestId(2));
        let before = c.resident_tokens();
        let evicted = c.evict_tokens(1);
        assert!(!evicted.is_empty());
        assert!(c.resident_tokens() < before);
        c.check_invariants().unwrap();
    }

    #[test]
    fn payload_at_boundary() {
        let mut c: RadixCache<String> = RadixCache::new(100);
        c.set_payload(&[1, 2, 3, 4], RequestId(1), "kv@4".to_string());
        c.set_payload(&[1, 2], RequestId(1), "kv@2".to_string());
        let (len, p) = c.deepest_payload(&[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(len, 4);
        assert_eq!(p, "kv@4");
        let (len2, p2) = c.deepest_payload(&[1, 2, 99]).unwrap();
        assert_eq!(len2, 2);
        assert_eq!(p2, "kv@2");
        assert!(c.deepest_payload(&[9]).is_none());
        c.check_invariants().unwrap();
    }

    #[test]
    fn payload_splits_overshooting_edge() {
        let mut c: RadixCache<&'static str> = RadixCache::new(100);
        c.insert(&[1, 2, 3, 4, 5, 6], RequestId(1));
        c.set_payload(&[1, 2, 3], RequestId(1), "mid");
        let (len, p) = c.deepest_payload(&[1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!((len, *p), (3, "mid"));
        // full sequence still matches
        assert_eq!(c.peek_prefix_len(&[1, 2, 3, 4, 5, 6]), 6);
        c.check_invariants().unwrap();
    }

    #[test]
    fn stats_accumulate() {
        let mut c = cache(100);
        c.insert(&[1, 2, 3], RequestId(1));
        c.match_prefix(&[1, 2, 3]);
        c.match_prefix(&[1, 9]);
        assert_eq!(c.stat_inserted_tokens, 3);
        assert_eq!(c.stat_lookup_tokens, 5);
        assert_eq!(c.stat_matched_tokens, 4);
    }

    /// Runs the peek-side-effect-freeness regression in both eviction
    /// modes: `tiered = false` is the original discard path, `tiered =
    /// true` enables the demotion sink — the peeks (and the demotion
    /// bookkeeping itself) must not advance the LRU clock, touch recency,
    /// move a stat counter, or change the eviction victim order.
    fn peek_side_effect_free_case(tiered: bool) {
        let mut c = cache(6);
        if tiered {
            c.enable_demotion();
        }
        c.insert(&[1, 2, 3], RequestId(1));
        c.insert(&[4, 5, 6], RequestId(2));
        let clock = c.lru_clock();
        let (lookups, matched, inserted, evicted_toks) = (
            c.stat_lookup_tokens,
            c.stat_matched_tokens,
            c.stat_inserted_tokens,
            c.stat_evicted_tokens,
        );
        // hammer the LRU entry with peeks: stats must not move and the
        // entry must NOT be refreshed (a match_prefix here would make
        // request 2 the eviction victim instead)
        for _ in 0..10 {
            assert_eq!(c.peek_prefix_len(&[1, 2, 3]), 3);
            assert_eq!(c.peek_prefix_len(&[1, 2, 9]), 2);
            assert_eq!(c.peek_prefix_len(&[7]), 0);
        }
        assert_eq!(c.lru_clock(), clock, "peek advanced the LRU clock");
        assert_eq!(c.stat_lookup_tokens, lookups);
        assert_eq!(c.stat_matched_tokens, matched);
        assert_eq!(c.stat_inserted_tokens, inserted);
        assert_eq!(c.stat_evicted_tokens, evicted_toks);
        let (_, evicted) = c.insert(&[7, 8, 9], RequestId(3));
        if tiered {
            // demotion mode: the victim goes to the sink, not the prune list
            assert!(evicted.is_empty(), "demoted ids must not be pruned");
            let demoted = c.take_demotions();
            assert_eq!(demoted.len(), 1, "exactly one leaf demoted");
            assert_eq!(demoted[0].tokens, vec![1, 2, 3], "peek perturbed LRU recency");
            assert_eq!(demoted[0].request_ids, vec![RequestId(1)]);
            assert!(c.take_demotions().is_empty(), "drain is draining");
        } else {
            assert_eq!(evicted, vec![RequestId(1)], "peek perturbed LRU recency");
        }
        c.check_invariants().unwrap();
    }

    #[test]
    fn peek_is_observably_side_effect_free() {
        peek_side_effect_free_case(false);
    }

    #[test]
    fn peek_is_observably_side_effect_free_tiered() {
        peek_side_effect_free_case(true);
    }

    #[test]
    fn demotion_reconstructs_root_anchored_prefixes() {
        // shared prefix {1,2} with two leaves: evicting a leaf must emit
        // the FULL path from the root, not just the leaf's edge label
        let mut c: RadixCache<String> = RadixCache::new(100);
        c.enable_demotion();
        c.set_payload(&[1, 2, 3, 4], RequestId(1), "kv@4".to_string());
        c.insert(&[1, 2, 9], RequestId(2));
        let before = c.resident_tokens();
        c.evict_tokens(1);
        assert!(c.resident_tokens() < before);
        let demoted = c.take_demotions();
        assert_eq!(demoted.len(), 1);
        let e = &demoted[0];
        // LRU leaf is the {3,4} tail of the first insert: full key 1,2,3,4
        assert_eq!(e.tokens, vec![1, 2, 3, 4]);
        assert_eq!(e.request_ids, vec![RequestId(1)]);
        assert_eq!(e.payload.as_deref(), Some("kv@4"));
        c.check_invariants().unwrap();
    }

    #[test]
    fn demotion_mode_keeps_victim_order_identical_to_discard_mode() {
        // eviction order (who gets removed, when) may not depend on the
        // demotion flag — tiering only changes where victims *go*
        let ops: &[&[u32]] = &[&[1, 2, 3], &[4, 5, 6], &[1, 2, 9], &[7, 8], &[9, 9, 9, 9]];
        let mut plain = cache(8);
        let mut tiered = cache(8);
        tiered.enable_demotion();
        let mut plain_victims: Vec<RequestId> = Vec::new();
        let mut tiered_victims: Vec<RequestId> = Vec::new();
        for (i, key) in ops.iter().enumerate() {
            let (_, ev) = plain.insert(key, RequestId(i as u64));
            plain_victims.extend(ev);
            let (_, ev) = tiered.insert(key, RequestId(i as u64));
            assert!(ev.is_empty());
            tiered_victims.extend(
                tiered
                    .take_demotions()
                    .into_iter()
                    .flat_map(|e| e.request_ids),
            );
        }
        assert!(!plain_victims.is_empty(), "capacity 8 must evict");
        assert_eq!(plain_victims, tiered_victims);
        assert_eq!(plain.resident_tokens(), tiered.resident_tokens());
        plain.check_invariants().unwrap();
        tiered.check_invariants().unwrap();
    }

    #[test]
    fn tag_requests_appends_ownership_without_touching_recency() {
        let mut c = cache(100);
        c.insert(&[1, 2, 3], RequestId(1));
        c.insert(&[1, 2, 9], RequestId(2));
        let clock = c.lru_clock();
        let covered = c.tag_requests(&[1, 2, 3], &[RequestId(7), RequestId(8)]);
        assert_eq!(covered, 3, "resident path must be fully covered");
        assert_eq!(c.lru_clock(), clock, "tagging must not tick the clock");
        // a key whose tail is absent reports partial cover
        assert_eq!(c.tag_requests(&[1, 2, 3, 4, 5], &[RequestId(9)]), 3);
        // evicting the tagged leaf now reports the appended ids too
        let evicted = c.evict_tokens(100);
        let mut ids = evicted;
        ids.sort_unstable();
        assert!(ids.contains(&RequestId(7)) && ids.contains(&RequestId(8)));
        c.check_invariants().unwrap();
    }

    #[test]
    fn peek_agrees_with_match() {
        use crate::util::prop::{check, Config};
        use crate::util::prng::Rng;
        check(
            "peek_prefix_len == match_prefix().len",
            Config {
                cases: 128,
                base_seed: 0x9EEC,
                max_size: 24,
            },
            |rng: &mut Rng, size| {
                let mut c = cache(1 << 16);
                for i in 0..size.max(2) {
                    let len = 1 + rng.below(12);
                    let key: Vec<u32> = (0..len).map(|_| rng.below(6) as u32).collect();
                    c.insert(&key, RequestId(i as u64));
                }
                for _ in 0..8 {
                    let len = 1 + rng.below(14);
                    let probe: Vec<u32> = (0..len).map(|_| rng.below(6) as u32).collect();
                    let peeked = c.peek_prefix_len(&probe);
                    let matched = c.match_prefix(&probe).len;
                    if peeked != matched {
                        return Err(format!(
                            "probe {probe:?}: peek {peeked} != match {matched}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn node_reuse_after_eviction() {
        let mut c = cache(3);
        c.insert(&[1, 2, 3], RequestId(1));
        c.insert(&[4, 5, 6], RequestId(2)); // evicts first
        c.insert(&[7, 8, 9], RequestId(3)); // evicts second, reuses slot
        assert!(c.node_count() <= 2); // root + one leaf
        c.check_invariants().unwrap();
    }
}
