//! Cold-tier storage backends: where the SSD shelf's records actually
//! live.
//!
//! The PR-3 tier store kept its "SSD" shelf purely in memory, so a
//! process restart silently discarded every cold-tier entry and every
//! recurring session paid full prefill again. [`Storage`] is the
//! durability seam that fixes that: the [`crate::cache::TierStore`]
//! mirrors every SSD-shelf mutation into a `Box<dyn Storage>` —
//! `put`/`get`/`delete`/`scan` over [`Record`]s keyed by the entry's
//! root-anchored token sequence — and rebuilds the shelf from
//! [`Storage::scan`] on resume.
//!
//! Two backends:
//!  * [`MemStorage`] — the default; an in-memory map, so tier-1 stays
//!    dependency-free and serving is bit-identical to the pre-durability
//!    behaviour (the mirror never feeds back into a live run).
//!  * [`FileStorage`] — one append-friendly segment file of JSON lines
//!    (`{"op":"put",…}` / `{"op":"del",…}`, via [`crate::util::json`])
//!    with the index rebuilt by replaying the log on open. A torn final
//!    line (crash mid-append) is dropped; damage anywhere earlier is a
//!    [`StorageError`] with `corrupt` set, which the facade surfaces as
//!    [`crate::api::Error::CorruptSnapshot`]. [`Storage::flush`] compacts
//!    the log (rewrite-and-rename), which the checkpoint path invokes.
//!
//! Payloads ride through the backend as JSON via [`ColdPayload`]; the
//! simulated engine's `()` payload and the KV-bytes test payload both
//! implement it.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// A cold-tier payload that can ride through a [`Storage`] backend.
/// Encoding must round-trip exactly: `from_json(&v.to_json()) == Some(v)`.
pub trait ColdPayload: Clone + Send {
    fn to_json(&self) -> Json;
    fn from_json(j: &Json) -> Option<Self>;
}

/// The simulated engine carries no KV bytes; a marker value records that
/// a payload was present at all.
impl ColdPayload for () {
    fn to_json(&self) -> Json {
        Json::Bool(true)
    }

    fn from_json(j: &Json) -> Option<Self> {
        j.as_bool().map(|_| ())
    }
}

/// Raw KV bytes (what a real engine's snapshot reduces to in tests).
impl ColdPayload for Vec<u8> {
    fn to_json(&self) -> Json {
        Json::Arr(self.iter().map(|&b| Json::Num(b as f64)).collect())
    }

    fn from_json(j: &Json) -> Option<Self> {
        j.as_arr()?
            .iter()
            .map(|x| {
                let n = x.as_f64()?;
                (n.fract() == 0.0 && (0.0..=255.0).contains(&n)).then_some(n as u8)
            })
            .collect()
    }
}

/// One cold-tier record in wire form: the root-anchored token key, the
/// §4.1 owner request ids, the LRU stamp (so a rebuilt shelf keeps its
/// eviction order), and the payload serialized via [`ColdPayload`]
/// (`Json::Null` when the entry carried none).
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    pub tokens: Vec<u32>,
    pub request_ids: Vec<u64>,
    pub stamp: u64,
    pub payload: Json,
}

impl Record {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("op", Json::str("put")),
            (
                "tokens",
                Json::Arr(self.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            (
                "ids",
                Json::Arr(self.request_ids.iter().map(|&r| Json::u64(r)).collect()),
            ),
            ("stamp", Json::u64(self.stamp)),
            ("payload", self.payload.clone()),
        ])
    }

    fn from_json(j: &Json) -> Option<Record> {
        let tokens = parse_tokens(j.get("tokens"))?;
        let request_ids = j
            .get("ids")
            .as_arr()?
            .iter()
            .map(Json::as_u64)
            .collect::<Option<Vec<u64>>>()?;
        Some(Record {
            tokens,
            request_ids,
            stamp: j.get("stamp").as_u64()?,
            payload: j.get("payload").clone(),
        })
    }
}

fn parse_tokens(j: &Json) -> Option<Vec<u32>> {
    j.as_arr()?
        .iter()
        .map(|x| {
            let n = x.as_f64()?;
            (n.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&n)).then_some(n as u32)
        })
        .collect()
}

/// A storage failure. `corrupt` distinguishes "the bytes exist but do
/// not decode" (surfaced as [`crate::api::Error::CorruptSnapshot`]) from
/// plain I/O trouble ([`crate::api::Error::Storage`]).
#[derive(Clone, Debug)]
pub struct StorageError {
    pub message: String,
    pub corrupt: bool,
}

impl StorageError {
    pub fn io(message: impl Into<String>) -> StorageError {
        StorageError {
            message: message.into(),
            corrupt: false,
        }
    }

    pub fn corrupt(message: impl Into<String>) -> StorageError {
        StorageError {
            message: message.into(),
            corrupt: true,
        }
    }
}

impl fmt::Display for StorageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for StorageError {}

/// A cold-tier record store keyed by root-anchored token sequence.
///
/// The tier store treats this as a write-through mirror of its SSD
/// shelf: `put` upserts (a re-demoted or merged key overwrites its old
/// record), `delete` is idempotent, and `scan` returns every live record
/// in ascending stamp order — the canonical order a resumed shelf is
/// rebuilt in.
pub trait Storage: Send + fmt::Debug {
    fn put(&mut self, rec: Record) -> Result<(), StorageError>;
    fn get(&self, tokens: &[u32]) -> Result<Option<Record>, StorageError>;
    fn delete(&mut self, tokens: &[u32]) -> Result<(), StorageError>;
    /// Every live record, ascending by stamp.
    fn scan(&self) -> Result<Vec<Record>, StorageError>;
    /// Make everything written so far durable (and compact, for log-
    /// structured backends). The checkpoint path calls this; in-memory
    /// backends are a no-op.
    fn flush(&mut self) -> Result<(), StorageError> {
        Ok(())
    }
}

fn sorted_by_stamp(mut records: Vec<Record>) -> Vec<Record> {
    records.sort_by_key(|r| r.stamp);
    records
}

/// The in-memory backend: keeps the tier store dependency-free and its
/// serving results bit-identical to the pre-durability behaviour. A
/// restart loses it, by definition — use [`FileStorage`] for durability.
#[derive(Debug, Default)]
pub struct MemStorage {
    records: BTreeMap<Vec<u32>, Record>,
}

impl MemStorage {
    pub fn new() -> MemStorage {
        MemStorage::default()
    }
}

impl Storage for MemStorage {
    fn put(&mut self, rec: Record) -> Result<(), StorageError> {
        self.records.insert(rec.tokens.clone(), rec);
        Ok(())
    }

    fn get(&self, tokens: &[u32]) -> Result<Option<Record>, StorageError> {
        Ok(self.records.get(tokens).cloned())
    }

    fn delete(&mut self, tokens: &[u32]) -> Result<(), StorageError> {
        self.records.remove(tokens);
        Ok(())
    }

    fn scan(&self) -> Result<Vec<Record>, StorageError> {
        Ok(sorted_by_stamp(self.records.values().cloned().collect()))
    }
}

/// The file-backed default for durable runs: one append-friendly segment
/// file of JSON lines, index rebuilt by replaying the log on open.
#[derive(Debug)]
pub struct FileStorage {
    path: PathBuf,
    file: fs::File,
    records: BTreeMap<Vec<u32>, Record>,
    /// Log lines since the last compaction (delete tombstones and
    /// overwritten puts accumulate until `flush` rewrites the segment).
    dirty_ops: usize,
}

impl FileStorage {
    /// Open (or create) the segment file at `path`.
    ///
    /// `resume` replays the existing log into the index — a torn final
    /// line (crash mid-append) is dropped, damage anywhere earlier is a
    /// corrupt-flagged error. Without `resume` the segment is truncated:
    /// a fresh durable run starts from an empty cold tier.
    pub fn open(path: &Path, resume: bool) -> Result<FileStorage, StorageError> {
        let mut records = BTreeMap::new();
        if resume && path.exists() {
            let text = fs::read_to_string(path)
                .map_err(|e| StorageError::io(format!("read {}: {e}", path.display())))?;
            let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
            for (i, line) in lines.iter().enumerate() {
                match Self::replay_line(line, &mut records) {
                    Ok(()) => {}
                    Err(e) if i + 1 == lines.len() => {
                        // a torn tail is the one legal form of damage: the
                        // process died mid-append and every complete record
                        // before it is still good
                        let _ = e;
                        break;
                    }
                    Err(e) => {
                        return Err(StorageError::corrupt(format!(
                            "{} line {}: {e}",
                            path.display(),
                            i + 1
                        )))
                    }
                }
            }
        }
        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| StorageError::io(format!("open {}: {e}", path.display())))?;
        if !resume {
            file.set_len(0)
                .map_err(|e| StorageError::io(format!("truncate {}: {e}", path.display())))?;
        }
        Ok(FileStorage {
            path: path.to_path_buf(),
            file,
            records,
            dirty_ops: 0,
        })
    }

    fn replay_line(line: &str, records: &mut BTreeMap<Vec<u32>, Record>) -> Result<(), String> {
        let j = Json::parse(line).map_err(|e| e.to_string())?;
        match j.get("op").as_str() {
            Some("put") => {
                let rec = Record::from_json(&j).ok_or("malformed put record")?;
                records.insert(rec.tokens.clone(), rec);
                Ok(())
            }
            Some("del") => {
                let tokens = parse_tokens(j.get("tokens")).ok_or("malformed del record")?;
                records.remove(&tokens);
                Ok(())
            }
            _ => Err("unknown op".to_string()),
        }
    }

    fn append(&mut self, j: &Json) -> Result<(), StorageError> {
        writeln!(self.file, "{j}")
            .map_err(|e| StorageError::io(format!("append {}: {e}", self.path.display())))?;
        self.dirty_ops += 1;
        Ok(())
    }
}

impl Storage for FileStorage {
    fn put(&mut self, rec: Record) -> Result<(), StorageError> {
        self.append(&rec.to_json())?;
        self.records.insert(rec.tokens.clone(), rec);
        Ok(())
    }

    fn get(&self, tokens: &[u32]) -> Result<Option<Record>, StorageError> {
        Ok(self.records.get(tokens).cloned())
    }

    fn delete(&mut self, tokens: &[u32]) -> Result<(), StorageError> {
        if self.records.remove(tokens).is_none() {
            return Ok(());
        }
        self.append(&Json::obj(vec![
            ("op", Json::str("del")),
            (
                "tokens",
                Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
        ]))
    }

    fn scan(&self) -> Result<Vec<Record>, StorageError> {
        Ok(sorted_by_stamp(self.records.values().cloned().collect()))
    }

    /// Compact: rewrite the segment as one put line per live record
    /// (ascending stamp), rename over the old log, and fsync. Tombstones
    /// and overwritten puts vanish; a crash during compaction leaves
    /// either the old or the new segment intact, never a mix.
    fn flush(&mut self) -> Result<(), StorageError> {
        let tmp = self.path.with_extension("tmp");
        let mut out = String::new();
        for rec in sorted_by_stamp(self.records.values().cloned().collect()) {
            out.push_str(&rec.to_json().to_string());
            out.push('\n');
        }
        fs::write(&tmp, out)
            .map_err(|e| StorageError::io(format!("write {}: {e}", tmp.display())))?;
        fs::rename(&tmp, &self.path)
            .map_err(|e| StorageError::io(format!("rename {}: {e}", self.path.display())))?;
        self.file = fs::OpenOptions::new()
            .append(true)
            .open(&self.path)
            .map_err(|e| StorageError::io(format!("reopen {}: {e}", self.path.display())))?;
        self.file
            .sync_all()
            .map_err(|e| StorageError::io(format!("sync {}: {e}", self.path.display())))?;
        self.dirty_ops = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(tokens: &[u32], ids: &[u64], stamp: u64) -> Record {
        Record {
            tokens: tokens.to_vec(),
            request_ids: ids.to_vec(),
            stamp,
            payload: vec![1u8, 2, 3].to_json(),
        }
    }

    fn tempdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ctxpilot-storage-{name}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn cold_payload_roundtrips() {
        let v: Vec<u8> = vec![0, 7, 255];
        assert_eq!(Vec::<u8>::from_json(&v.to_json()), Some(v));
        assert_eq!(<()>::from_json(&().to_json()), Some(()));
        assert_eq!(Vec::<u8>::from_json(&Json::Null), None);
        assert_eq!(<()>::from_json(&Json::Null), None);
    }

    #[test]
    fn mem_storage_put_get_delete_scan() {
        let mut s = MemStorage::new();
        s.put(rec(&[1, 2], &[7], 2)).unwrap();
        s.put(rec(&[3], &[8], 1)).unwrap();
        assert_eq!(s.get(&[1, 2]).unwrap().unwrap().request_ids, vec![7]);
        assert_eq!(s.get(&[9]).unwrap(), None);
        // scan is ascending by stamp, not by key
        let stamps: Vec<u64> = s.scan().unwrap().iter().map(|r| r.stamp).collect();
        assert_eq!(stamps, vec![1, 2]);
        // upsert replaces, delete is idempotent
        s.put(rec(&[1, 2], &[9], 3)).unwrap();
        assert_eq!(s.get(&[1, 2]).unwrap().unwrap().request_ids, vec![9]);
        s.delete(&[1, 2]).unwrap();
        s.delete(&[1, 2]).unwrap();
        assert_eq!(s.scan().unwrap().len(), 1);
    }

    #[test]
    fn file_storage_survives_reopen() {
        let dir = tempdir("reopen");
        let path = dir.join("cold.jsonl");
        {
            let mut s = FileStorage::open(&path, false).unwrap();
            s.put(rec(&[1, 2, 3], &[u64::MAX], 1)).unwrap();
            s.put(rec(&[4], &[2], 2)).unwrap();
            s.delete(&[4]).unwrap();
        }
        let s = FileStorage::open(&path, true).unwrap();
        let scanned = s.scan().unwrap();
        assert_eq!(scanned.len(), 1);
        assert_eq!(scanned[0].tokens, vec![1, 2, 3]);
        assert_eq!(scanned[0].request_ids, vec![u64::MAX], "u64 ids exact");
        assert_eq!(
            Vec::<u8>::from_json(&scanned[0].payload),
            Some(vec![1, 2, 3])
        );
        // opening WITHOUT resume truncates: a fresh run starts cold
        let s = FileStorage::open(&path, false).unwrap();
        assert!(s.scan().unwrap().is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_final_line_is_dropped_earlier_damage_is_corrupt() {
        let dir = tempdir("torn");
        let path = dir.join("cold.jsonl");
        {
            let mut s = FileStorage::open(&path, false).unwrap();
            s.put(rec(&[1], &[1], 1)).unwrap();
            s.put(rec(&[2], &[2], 2)).unwrap();
        }
        // crash mid-append: chop the file inside the last record
        let text = fs::read_to_string(&path).unwrap();
        fs::write(&path, &text[..text.len() - 10]).unwrap();
        let s = FileStorage::open(&path, true).unwrap();
        assert_eq!(s.scan().unwrap().len(), 1, "torn tail dropped, rest kept");
        // damage in the MIDDLE is real corruption, not a crash artifact
        fs::write(&path, "garbage\n{\"op\":\"del\",\"tokens\":[1]}\n").unwrap();
        let err = FileStorage::open(&path, true).unwrap_err();
        assert!(err.corrupt, "mid-log damage must flag corrupt: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_compacts_the_log() {
        let dir = tempdir("compact");
        let path = dir.join("cold.jsonl");
        let mut s = FileStorage::open(&path, false).unwrap();
        for i in 0..20u32 {
            s.put(rec(&[i % 4], &[i as u64], i as u64 + 1)).unwrap();
        }
        let before = fs::metadata(&path).unwrap().len();
        s.flush().unwrap();
        let after = fs::metadata(&path).unwrap().len();
        assert!(after < before, "compaction shrinks the segment");
        drop(s);
        let s = FileStorage::open(&path, true).unwrap();
        assert_eq!(s.scan().unwrap().len(), 4, "live records survive compaction");
        let _ = fs::remove_dir_all(&dir);
    }
}
