//! `contextpilot::api` — the stable, documented front door of the crate.
//!
//! The paper's architectural claim (§5) is a proxy with a *clean
//! interface that integrates with existing inference engines*. This
//! module is that interface: one fluent builder for every serving knob,
//! one typed error enum, and a session/ticket request lifecycle that
//! serves streams the way the engine room serves batches. Everything
//! underneath — the sharded [`crate::serve`] engine, placement, KV
//! tiering, chunked admission — is reached through it; the serving engine
//! itself is crate-private.
//!
//! ```text
//!   Server::builder(sku)                 one fluent config; validation at
//!     .shards(..).workers(..)            build() time → Error::InvalidConfig
//!     .tiers("hbm=64k,dram=256k")        (never a panic, never a clamp)
//!     .placement(..).prefill_chunk(..)
//!     .corpus(corpus)
//!     .build()?                          → Server
//!
//!   server.session(id)                   → SessionHandle (stamps session)
//!       .submit(request)?                → Ticket (joins the pending wave)
//!   ticket.wait()?                       → Response (flushes the wave on
//!                                          first wait; typed errors)
//!
//!   server.serve_batch(&reqs)? / server.serve_one(&req)?
//!                                        thin shims over the same
//!                                        submit → flush → wait lifecycle
//!
//!   server.submit_at(request, t)?        open-loop arrival at virtual time
//!   server.seal_arrivals()?              t — continuous batching through
//!   server.drain()?                      the per-shard scheduler loops,
//!                                        no flush barrier (see Server)
//! ```
//!
//! # End-to-end example
//!
//! Three sessions share context blocks; submissions from different
//! sessions interleave in one admission wave, placement co-locates the
//! overlap, and the prefix cache turns it into KV reuse:
//!
//! ```
//! use contextpilot::api::{PlacementKind, Server};
//! use contextpilot::corpus::{Corpus, CorpusConfig};
//! use contextpilot::engine::ModelSku;
//! use contextpilot::tokenizer::Tokenizer;
//! use contextpilot::types::{BlockId, QueryId, Request, RequestId, SessionId};
//!
//! let corpus = Corpus::generate(
//!     &CorpusConfig { n_docs: 24, ..Default::default() },
//!     &Tokenizer::default(),
//! );
//! let server = Server::builder(ModelSku::Qwen3_4B)
//!     .shards(2)
//!     .workers(2)
//!     .capacity(32_000)
//!     .placement(PlacementKind::ContextAware)
//!     .prefill_chunk(2048)
//!     .corpus(corpus)
//!     .build()?;
//!
//! let req = |id: u64, session: u32, blocks: &[u32]| Request {
//!     id: RequestId(id),
//!     session: SessionId(session),
//!     turn: 0,
//!     context: blocks.iter().map(|&b| BlockId(b)).collect(),
//!     query: QueryId(id),
//! };
//!
//! // Streaming tickets: two sessions submit into the same pending wave;
//! // the first wait() flushes it through the sharded engine.
//! let a = server.session(SessionId(1)).submit(req(1, 1, &[1, 2, 3]))?;
//! let b = server.session(SessionId(2)).submit(req(2, 2, &[1, 2, 9]))?;
//! let first = a.wait()?;
//! let second = b.wait()?; // already resolved by the same flush
//! assert_eq!(first.request.id, RequestId(1));
//! assert!(second.cached_tokens > 0, "overlapping contexts share KV");
//!
//! // Batches run through the same session/ticket lifecycle.
//! let served = server.serve_batch(&[req(3, 3, &[1, 2, 3])])?;
//! assert_eq!(served.len(), 1);
//!
//! // Typed telemetry and session introspection.
//! let (metrics, per_shard) = server.metrics()?;
//! assert_eq!(metrics.len(), 3);
//! assert_eq!(per_shard.len(), 2);
//! assert!(metrics.hit_ratio() > 0.0);
//! let pinned = server.session_shard(SessionId(1))?;
//! assert!(pinned < server.n_shards());
//! # Ok::<(), contextpilot::api::Error>(())
//! ```
//!
//! # Errors
//!
//! Every fallible call returns [`Error`]: configuration problems are
//! rejected at [`ServerBuilder::build`] time ([`Error::InvalidConfig`] —
//! zero shards/workers, a chunk budget of 0, a malformed tier spec); a
//! worker panic surfaces to concurrent waiters and every subsequent call
//! as [`Error::ShardPoisoned`] instead of cascading panics (the call
//! that drove the panicking worker itself still unwinds); duplicate
//! submissions and unplaced-session lookups get their own variants;
//! open-loop arrivals shed by scheduler backpressure resolve their
//! tickets to [`Error::Overloaded`] (deterministically — see
//! [`Server::submit_at`]); the durable path ([`ServerBuilder::state_dir`] /
//! [`ServerBuilder::resume_from`] / [`Server::checkpoint`]) distinguishes
//! I/O trouble ([`Error::Storage`]) from persisted state that exists but
//! does not decode ([`Error::CorruptSnapshot`]) — a damaged state
//! directory fails `build()` cleanly, never as a panic. See [`Error`]
//! for the full catalogue.
//!
//! # Relation to the engine room
//!
//! [`Server`] wraps the crate-private sharded serving engine. The
//! [`ServeConfig`] it resolves to is still public — engine factories
//! receive it ([`ServerBuilder::build_with`]) and harness code may
//! preassemble one ([`ServerBuilder::from_config`]) — but construction
//! and serving always flow through this facade, which is what lets the
//! crate evolve the engine room freely underneath it.

mod builder;
mod error;
mod server;

pub use builder::ServerBuilder;
pub use error::Error;
pub use server::{Response, Server, SessionHandle, Ticket};

// One-stop imports for facade users: the enums and configs that appear in
// builder signatures.
pub use crate::cache::{AdmissionPolicy, TierConfig};
pub use crate::engine::costmodel::ModelSku;
pub use crate::engine::sim::ReusePolicy;
pub use crate::obs::ObsConfig;
pub use crate::pilot::PilotConfig;
pub use crate::serve::{OverloadPolicy, PlacementKind, ServeConfig};
