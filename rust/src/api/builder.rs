//! [`ServerBuilder`]: one fluent entry point for every serving knob.
//!
//! Before the facade, a caller assembled a [`ServeConfig`] by hand, parsed
//! tier and placement specs through separate `Result<_, String>` parsers,
//! and learned about bad values from panics at serve time. The builder
//! subsumes all of it: every knob is a chained method, raw CLI-shaped
//! specs (`.tiers("hbm=64k,dram=256k")`) are parsed at [`build`] time, and
//! validation happens *once*, there, returning [`Error::InvalidConfig`]
//! instead of scattering `max(1)` clamps and panics through the stack.
//!
//! [`build`]: ServerBuilder::build

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::api::{Error, Server};
use crate::cache::{FileStorage, Storage, StorageError, TierConfig};
use crate::corpus::Corpus;
use crate::engine::costmodel::ModelSku;
use crate::engine::iface::InferenceEngine;
use crate::engine::sim::{ReusePolicy, SimEngine};
use crate::obs::ObsConfig;
use crate::pilot::PilotConfig;
use crate::quality::ModelEra;
use crate::serve::{OverloadPolicy, PlacementKind, ServeConfig, ServingEngine};
use crate::types::RequestId;
use crate::util::json::Json;

/// Map a storage-backend failure onto the facade error surface: damaged
/// persisted bytes are [`Error::CorruptSnapshot`], everything else (I/O)
/// is [`Error::Storage`].
fn storage_err(e: StorageError) -> Error {
    if e.corrupt {
        Error::CorruptSnapshot(e.to_string())
    } else {
        Error::Storage(e.to_string())
    }
}

/// Fluent configuration for a [`Server`]. Obtained from
/// [`Server::builder`]; consumed by [`ServerBuilder::build`] (simulated
/// backend) or [`ServerBuilder::build_with`] (any
/// [`crate::engine::InferenceEngine`] factory).
///
/// Capacities (`capacity`, tier budgets) are **per shard**, matching the
/// underlying [`ServeConfig`] semantics; the CLI divides its user-facing
/// total budgets across shards before reaching the builder.
#[derive(Clone, Debug)]
pub struct ServerBuilder {
    cfg: ServeConfig,
    corpus: Option<Arc<Corpus>>,
    /// Unparsed `--tiers`-shaped spec; parsed (and validated) at build
    /// time so a malformed string surfaces as `InvalidConfig`, not a
    /// panic inside a parser.
    raw_tiers: Option<String>,
    /// Durable-state directory (per-shard cold segment files +
    /// `snapshot.json`); `None` = ephemeral server.
    state_dir: Option<PathBuf>,
    /// With a state dir: `true` rehydrates cold KV and restores the warm
    /// snapshot ([`ServerBuilder::resume_from`]); `false` truncates the
    /// segments and starts fresh ([`ServerBuilder::state_dir`]).
    resume: bool,
}

impl ServerBuilder {
    pub(crate) fn new(sku: ModelSku) -> ServerBuilder {
        ServerBuilder {
            cfg: ServeConfig::new(sku),
            corpus: None,
            raw_tiers: None,
            state_dir: None,
            resume: false,
        }
    }

    /// Start from a preassembled [`ServeConfig`] — the escape hatch for
    /// harness code that already maps experiment configs onto the serving
    /// layer ([`crate::experiments::serve_config`]). The config still goes
    /// through the same [`build`](ServerBuilder::build)-time validation as
    /// the fluent path.
    pub fn from_config(cfg: ServeConfig) -> ServerBuilder {
        ServerBuilder {
            cfg,
            corpus: None,
            raw_tiers: None,
            state_dir: None,
            resume: false,
        }
    }

    /// Independent shards (each owns a context index, a prefix cache and
    /// an engine instance). Must be ≥ 1 at build time.
    pub fn shards(mut self, n: usize) -> Self {
        self.cfg.n_shards = n;
        self
    }

    /// Worker threads driving shard queues. Must be ≥ 1 at build time.
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.n_workers = n;
        self
    }

    /// KV (HBM) budget per shard, in tokens. Must be ≥ 1 at build time.
    /// A `hbm=` component in [`tiers`](ServerBuilder::tiers) overrides it.
    pub fn capacity(mut self, tokens_per_shard: usize) -> Self {
        self.cfg.capacity_tokens = tokens_per_shard;
        self
    }

    /// Decode length per request (tokens).
    pub fn decode_tokens(mut self, n: usize) -> Self {
        self.cfg.decode_tokens = n;
        self
    }

    /// Engine reuse mechanism under test (radix / doc-prefix /
    /// approximate).
    pub fn reuse_policy(mut self, p: ReusePolicy) -> Self {
        self.cfg.policy = p;
        self
    }

    /// ContextPilot proxy configuration; `None` serves baseline prompts
    /// (engine-only, LPM-ordered within each shard queue when the engine
    /// prefers it).
    pub fn pilot(mut self, p: impl Into<Option<PilotConfig>>) -> Self {
        self.cfg.pilot = p.into();
        self
    }

    /// Quality-model era.
    pub fn era(mut self, e: ModelEra) -> Self {
        self.cfg.era = e;
        self
    }

    /// Multi-hop quality scoring (MultihopRAG-style workloads).
    pub fn multi_hop(mut self, on: bool) -> Self {
        self.cfg.multi_hop = on;
        self
    }

    /// Chunked-prefill admission budget in tokens; `None` disables
    /// chunking. `Some(0)` is rejected at build time.
    pub fn prefill_chunk(mut self, chunk: impl Into<Option<usize>>) -> Self {
        self.cfg.prefill_chunk = chunk.into();
        self
    }

    /// Per-request decode-length overrides (trace replay).
    pub fn decode_override(mut self, m: impl Into<Option<HashMap<RequestId, usize>>>) -> Self {
        self.cfg.decode_override = m.into();
        self
    }

    /// First-turn session → shard placement policy.
    pub fn placement(mut self, k: PlacementKind) -> Self {
        self.cfg.placement = k;
        self
    }

    /// Bound on a shard's open-loop run queue: an arrival finding this
    /// many requests already mid-prefill is shed or delayed per
    /// [`overload`](ServerBuilder::overload). `None` (the default)
    /// admits without bound. `Some(0)` is rejected at build time
    /// (it would admit nothing).
    pub fn queue_bound(mut self, bound: impl Into<Option<usize>>) -> Self {
        self.cfg.queue_bound = bound.into();
        self
    }

    /// Admission deadline for open-loop arrivals, in virtual seconds: a
    /// request still unadmitted more than this long past its arrival
    /// time is shed (whatever the overload policy — a blown deadline is
    /// unservable by definition). `None` disables. Must be finite and
    /// > 0 at build time.
    pub fn deadline(mut self, seconds: impl Into<Option<f64>>) -> Self {
        self.cfg.deadline = seconds.into();
        self
    }

    /// What to do with arrivals over the
    /// [`queue_bound`](ServerBuilder::queue_bound):
    /// [`OverloadPolicy::Shed`] rejects them
    /// ([`Error::Overloaded`]), [`OverloadPolicy::Delay`] keeps them
    /// queued until the shard drains.
    pub fn overload(mut self, p: OverloadPolicy) -> Self {
        self.cfg.on_overload = p;
        self
    }

    /// Observability configuration ([`crate::obs`]). The counter registry
    /// is always on; this knob opts the server into per-shard lifecycle
    /// tracing (`ObsConfig::tracing()`), read back via
    /// [`Server::trace_events`]. Off by default — the disabled path emits
    /// nothing and allocates nothing.
    pub fn observability(mut self, o: ObsConfig) -> Self {
        self.cfg.obs = o;
        self
    }

    /// KV tier store from a CLI-shaped spec, e.g. `"hbm=64k,dram=256k"`
    /// ([`TierConfig::parse`]; budgets are per shard, `k`/`m` suffixes
    /// scale by 10³/10⁶). The `hbm=` component sizes the radix cache
    /// (overriding [`capacity`](ServerBuilder::capacity)); `dram`/`ssd`
    /// size the demotion shelves. Parsed and validated at build time.
    pub fn tiers(mut self, spec: &str) -> Self {
        self.raw_tiers = Some(spec.to_string());
        self
    }

    /// KV tier store from an already-assembled [`TierConfig`] (per shard);
    /// `None` keeps classic discard-mode eviction.
    pub fn tier_config(mut self, t: impl Into<Option<TierConfig>>) -> Self {
        self.cfg.tiers = t.into();
        self
    }

    /// The corpus every request's context blocks are rendered from. The
    /// server owns (a handle to) it so sessions can submit requests
    /// without threading a corpus through every call. Required.
    pub fn corpus(mut self, c: impl Into<Arc<Corpus>>) -> Self {
        self.corpus = Some(c.into());
        self
    }

    /// Persist durable state under `dir`, starting **fresh**: per-shard
    /// cold segment files (`shard-<i>.cold.jsonl`) are created or
    /// truncated, no snapshot is read, and [`Server::checkpoint`] writes
    /// `snapshot.json` there. The directory is created if missing. With a
    /// tier store configured ([`tiers`](ServerBuilder::tiers)), every SSD
    /// demotion is mirrored into its shard's segment file as it happens;
    /// without one, only the checkpoint-time warm snapshot is durable.
    pub fn state_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.state_dir = Some(dir.into());
        self.resume = false;
        self
    }

    /// Resume from a previous run's state dir: rehydrate each shard's
    /// cold (SSD) shelf from its segment file and restore the warm-state
    /// snapshot (`snapshot.json` — context indices, session → shard pins,
    /// request ownership). The configuration must be compatible (same
    /// shard count). Build-time failures: a missing snapshot or any I/O
    /// problem is [`Error::Storage`]; undecodable or structurally invalid
    /// persisted state is [`Error::CorruptSnapshot`] — never a panic.
    pub fn resume_from(mut self, dir: impl Into<PathBuf>) -> Self {
        self.state_dir = Some(dir.into());
        self.resume = true;
        self
    }

    /// Validate the assembled configuration and build a server over the
    /// default simulated backend. With a state dir configured this is the
    /// durable path: cold segment files open (truncating or rehydrating
    /// per [`state_dir`](ServerBuilder::state_dir) vs
    /// [`resume_from`](ServerBuilder::resume_from)) before any engine is
    /// built, and on resume the warm snapshot is restored before the
    /// server is returned — a resumed server never serves from
    /// half-restored state.
    pub fn build(self) -> Result<Server<SimEngine>, Error> {
        let state = self.state_dir.clone().map(|d| (d, self.resume));
        let (cfg, corpus) = self.finish()?;
        let Some((dir, resume)) = state else {
            return Ok(Server::from_engine(
                ServingEngine::with_engine_factory(cfg, ServeConfig::sim_engine),
                corpus,
                None,
            ));
        };
        // resume reads the snapshot before anything opens, so a missing /
        // damaged state dir fails without touching the segment files
        let snap = if resume {
            let path = dir.join("snapshot.json");
            let text = std::fs::read_to_string(&path)
                .map_err(|e| Error::Storage(format!("reading {}: {e}", path.display())))?;
            Some(Json::parse(&text).map_err(|e| {
                Error::CorruptSnapshot(format!("{}: {e}", path.display()))
            })?)
        } else {
            std::fs::create_dir_all(&dir)
                .map_err(|e| Error::Storage(format!("creating {}: {e}", dir.display())))?;
            None
        };
        let mut stores: Vec<Box<dyn Storage>> = Vec::with_capacity(cfg.n_shards);
        for i in 0..cfg.n_shards {
            let p = dir.join(format!("shard-{i}.cold.jsonl"));
            stores.push(Box::new(FileStorage::open(&p, resume).map_err(storage_err)?));
        }
        // the factory contract is infallible, so rehydration failures are
        // parked and surfaced right after construction
        let mut stores = stores.into_iter();
        let mut failure: Option<StorageError> = None;
        let engine = ServingEngine::with_engine_factory(cfg, |c| {
            let store = stores.next().expect("one cold segment per shard");
            match c.sim_engine_with_storage(store, resume) {
                Ok(e) => e,
                Err(e) => {
                    failure.get_or_insert(e);
                    c.sim_engine()
                }
            }
        });
        if let Some(e) = failure {
            return Err(storage_err(e));
        }
        if let Some(snap) = &snap {
            engine.restore_snapshot(snap)?;
        }
        Ok(Server::from_engine(engine, corpus, Some(dir)))
    }

    /// Validate and build over an arbitrary backend: `factory` is called
    /// once per shard (in shard order) with the resolved config to
    /// construct that shard's engine instance — the CLI's `--engine real`
    /// path hands it a PJRT-backed factory, tests hand it mocks and
    /// recording wrappers.
    ///
    /// Custom factories own their engines' storage, so the durable path
    /// is [`build`](ServerBuilder::build)-only: combining `build_with`
    /// with [`state_dir`](ServerBuilder::state_dir) /
    /// [`resume_from`](ServerBuilder::resume_from) is rejected as
    /// [`Error::InvalidConfig`] rather than silently persisting nothing.
    pub fn build_with<E, F>(self, factory: F) -> Result<Server<E>, Error>
    where
        E: InferenceEngine,
        F: FnMut(&ServeConfig) -> E,
    {
        if self.state_dir.is_some() {
            return Err(Error::InvalidConfig(
                "state_dir/resume_from require the simulated backend (build()); \
                 custom engine factories own their engines' storage"
                    .into(),
            ));
        }
        let (cfg, corpus) = self.finish()?;
        Ok(Server::from_engine(
            ServingEngine::with_engine_factory(cfg, factory),
            corpus,
            None,
        ))
    }

    /// All build-time validation in one place: every rejected value is an
    /// [`Error::InvalidConfig`], never a panic or a silent clamp.
    fn finish(self) -> Result<(ServeConfig, Arc<Corpus>), Error> {
        let ServerBuilder {
            mut cfg,
            corpus,
            raw_tiers,
            ..
        } = self;
        if let Some(spec) = raw_tiers {
            let (hbm, tiers) = TierConfig::parse(&spec)?;
            cfg.capacity_tokens = hbm;
            cfg.tiers = Some(tiers);
        }
        if cfg.n_shards == 0 {
            return Err(Error::InvalidConfig(
                "shards must be >= 1 (each shard owns an index, a cache and an engine)".into(),
            ));
        }
        if cfg.n_workers == 0 {
            return Err(Error::InvalidConfig(
                "workers must be >= 1 (the pool that drives shard queues)".into(),
            ));
        }
        if cfg.capacity_tokens == 0 {
            return Err(Error::InvalidConfig(
                "capacity must be >= 1 token per shard".into(),
            ));
        }
        if cfg.prefill_chunk == Some(0) {
            return Err(Error::InvalidConfig(
                "prefill chunk of 0 tokens admits nothing; use None to disable chunking".into(),
            ));
        }
        if cfg.queue_bound == Some(0) {
            return Err(Error::InvalidConfig(
                "a queue bound of 0 admits nothing; use None for unbounded".into(),
            ));
        }
        if let Some(dl) = cfg.deadline {
            if !dl.is_finite() || dl <= 0.0 {
                return Err(Error::InvalidConfig(format!(
                    "deadline must be finite and > 0 virtual seconds, got {dl}"
                )));
            }
        }
        if cfg.obs.trace && cfg.obs.trace_capacity == 0 {
            return Err(Error::InvalidConfig(
                "trace capacity of 0 events records nothing; disable tracing instead".into(),
            ));
        }
        let corpus = corpus.ok_or_else(|| {
            Error::InvalidConfig("a corpus is required: call .corpus(..) before build()".into())
        })?;
        Ok((cfg, corpus))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusConfig;
    use crate::tokenizer::Tokenizer;

    fn corpus() -> Corpus {
        Corpus::generate(
            &CorpusConfig {
                n_docs: 10,
                ..Default::default()
            },
            &Tokenizer::default(),
        )
    }

    fn builder() -> ServerBuilder {
        Server::builder(ModelSku::Qwen3_4B).corpus(corpus())
    }

    #[test]
    fn defaults_build() {
        let server = builder().build().expect("defaults are valid");
        assert!(server.n_shards() >= 1);
        assert!(server.n_workers() >= 1);
    }

    #[test]
    fn tiers_spec_sets_capacity_and_store() {
        let server = builder()
            .shards(2)
            .tiers("hbm=4k,dram=16k,ssd=1m")
            .build()
            .expect("tier spec is valid");
        let cfg = server.config();
        assert_eq!(cfg.capacity_tokens, 4_000);
        let tiers = cfg.tiers.as_ref().expect("store attached");
        assert_eq!(tiers.dram_tokens, 16_000);
        assert_eq!(tiers.ssd_tokens, 1_000_000);
    }

    #[test]
    fn from_config_goes_through_the_same_validation() {
        let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
        cfg.n_shards = 0;
        let err = ServerBuilder::from_config(cfg)
            .corpus(corpus())
            .build()
            .unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)));
    }

    fn tempdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ctxpilot-api-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn durable_build_checkpoints_and_resumes() {
        use crate::types::{BlockId, QueryId, Request, SessionId};
        let dir = tempdir("resume");
        let c = Arc::new(corpus());
        let req = |id: u64, session: u32| Request {
            id: RequestId(id),
            session: SessionId(session),
            turn: 0,
            context: vec![BlockId(1), BlockId(2)],
            query: QueryId(id),
        };
        let server = Server::builder(ModelSku::Qwen3_4B)
            .shards(2)
            .workers(1)
            .decode_tokens(8)
            .corpus(c.clone())
            .state_dir(&dir)
            .build()
            .expect("durable build");
        server.serve_batch(&[req(1, 5)]).expect("serve");
        let pinned = server.session_shard(SessionId(5)).expect("pinned");
        let path = server.checkpoint().expect("checkpoint");
        assert!(path.ends_with("snapshot.json"));
        assert_eq!(server.state_dir(), Some(dir.as_path()));
        drop(server);
        let resumed = Server::builder(ModelSku::Qwen3_4B)
            .shards(2)
            .workers(1)
            .decode_tokens(8)
            .corpus(c)
            .resume_from(&dir)
            .build()
            .expect("resume");
        assert_eq!(resumed.session_shard(SessionId(5)).unwrap(), pinned);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_from_missing_or_corrupt_state_is_typed() {
        let dir = tempdir("missing");
        let c = Arc::new(corpus());
        let err = Server::builder(ModelSku::Qwen3_4B)
            .corpus(c.clone())
            .resume_from(&dir)
            .build()
            .unwrap_err();
        assert!(matches!(err, Error::Storage(_)), "{err:?}");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("snapshot.json"), "{not json").unwrap();
        let err = Server::builder(ModelSku::Qwen3_4B)
            .corpus(c)
            .resume_from(&dir)
            .build()
            .unwrap_err();
        assert!(matches!(err, Error::CorruptSnapshot(_)), "{err:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_trace_capacity_is_invalid_config() {
        let err = builder()
            .observability(ObsConfig {
                trace: true,
                trace_capacity: 0,
            })
            .build()
            .unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)), "{err:?}");
        // capacity 0 with tracing off is harmless — nothing records anyway
        builder()
            .observability(ObsConfig {
                trace: false,
                trace_capacity: 0,
            })
            .build()
            .expect("tracing off ignores capacity");
    }

    #[test]
    fn backpressure_knobs_validate_at_build_time() {
        let err = builder().queue_bound(0).build().unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)), "{err:?}");
        let err = builder().deadline(0.0).build().unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)), "{err:?}");
        let err = builder().deadline(f64::NAN).build().unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)), "{err:?}");
        let server = builder()
            .queue_bound(4)
            .deadline(2.5)
            .overload(OverloadPolicy::Delay)
            .build()
            .expect("valid backpressure config");
        let cfg = server.config();
        assert_eq!(cfg.queue_bound, Some(4));
        assert_eq!(cfg.deadline, Some(2.5));
        assert_eq!(cfg.on_overload, OverloadPolicy::Delay);
    }

    #[test]
    fn build_with_rejects_durable_state() {
        let c = Arc::new(corpus());
        let err = Server::builder(ModelSku::Qwen3_4B)
            .corpus(c)
            .state_dir(std::env::temp_dir().join("ctxpilot-never-created"))
            .build_with(|cfg: &ServeConfig| cfg.sim_engine())
            .unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)));
    }

    #[test]
    fn checkpoint_without_state_dir_is_invalid_config() {
        let server = builder().build().unwrap();
        let err = server.checkpoint().unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)), "{err:?}");
    }
}
