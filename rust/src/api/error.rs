//! [`Error`]: the typed error surface of the facade.
//!
//! Before the facade existed, failure reporting was scattered: the CLI
//! parsers returned `Result<_, String>`, and a poisoned shard mutex
//! surfaced as an `expect("shard poisoned")` panic deep inside the
//! serving layer. The facade folds every failure a caller can observe
//! into this one enum, so `?`-style composition works end to end. Panic
//! scope: the call whose worker panics still unwinds (the panic
//! propagates through the thread-scope join), but it no longer cascades
//! — concurrent waiters and every *subsequent* call observe
//! [`Error::ShardPoisoned`] instead of hitting further `expect`s.

use std::fmt;

use crate::types::{RequestId, SessionId};

/// Everything `contextpilot::api` can fail with.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Error {
    /// A configuration (or configuration-shaped input, e.g. a `--tiers`
    /// spec or `--placement` name) was rejected by validation. Raised at
    /// [`crate::api::ServerBuilder::build`] time — never as a panic from
    /// deep inside the stack.
    InvalidConfig(String),
    /// A facade-boundary mutex (shard, placement ledger, request map,
    /// ticket wave) was poisoned by a panicking worker thread, or a
    /// flush panicked with tickets outstanding. The payload names the
    /// poisoned component. State behind the mutex may be incomplete, and
    /// calls that need it (including [`crate::api::Server::metrics`])
    /// keep failing with this error until the server is rebuilt.
    ShardPoisoned(&'static str),
    /// The session has never been placed on a shard (no request of it
    /// was ever submitted), so there is no pin to report.
    UnknownSession(SessionId),
    /// A request id was submitted twice. Request ids key the §4.1
    /// eviction plumbing and the ticket ledger, so they must be unique
    /// within a server's lifetime.
    DuplicateRequest(RequestId),
    /// The backend engine violated its contract (e.g. dropped a request
    /// from a batch) or an engine backend is unavailable in this build.
    EngineFailure(String),
    /// The durable storage layer failed on I/O: the state directory could
    /// not be created, a cold-tier segment file or snapshot could not be
    /// read or written. The payload names the path and the OS error.
    Storage(String),
    /// The scheduler's backpressure policy shed this request: its shard's
    /// run queue was at the configured bound (or its admission deadline
    /// had passed) under [`OverloadPolicy::Shed`](crate::serve::OverloadPolicy),
    /// so the arrival was rejected instead of queued. Deterministic — a
    /// replay of the same arrival sequence sheds the same requests. Only
    /// open-loop ([`Server::submit_at`](crate::api::Server::submit_at))
    /// arrivals can be shed; wave submissions never are.
    Overloaded(RequestId),
    /// A snapshot or cold-tier segment file exists but does not decode:
    /// truncated mid-record, malformed JSON, an unknown snapshot version,
    /// or internally inconsistent state (e.g. a pin to a shard the
    /// resumed server does not have). Never a panic — a damaged state
    /// directory must fail [`crate::api::ServerBuilder::build`] cleanly.
    CorruptSnapshot(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidConfig(msg) => write!(f, "invalid configuration: {msg}"),
            Error::ShardPoisoned(what) => write!(
                f,
                "{what} poisoned: a worker thread panicked while holding its lock"
            ),
            Error::UnknownSession(s) => {
                write!(f, "unknown session {}: never placed on a shard", s.0)
            }
            Error::DuplicateRequest(r) => write!(
                f,
                "duplicate request id {}: ids must be unique per server",
                r.0
            ),
            Error::EngineFailure(msg) => write!(f, "engine failure: {msg}"),
            Error::Storage(msg) => write!(f, "storage failure: {msg}"),
            Error::Overloaded(r) => write!(
                f,
                "overloaded: request {} shed by scheduler backpressure",
                r.0
            ),
            Error::CorruptSnapshot(msg) => write!(f, "corrupt snapshot: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_variant_displays_its_payload() {
        let cases: Vec<(Error, &str)> = vec![
            (
                Error::InvalidConfig("shards must be >= 1".into()),
                "invalid configuration: shards must be >= 1",
            ),
            (
                Error::ShardPoisoned("shard"),
                "shard poisoned: a worker thread panicked while holding its lock",
            ),
            (
                Error::UnknownSession(SessionId(7)),
                "unknown session 7: never placed on a shard",
            ),
            (
                Error::DuplicateRequest(RequestId(42)),
                "duplicate request id 42: ids must be unique per server",
            ),
            (
                Error::EngineFailure("request 3 not served".into()),
                "engine failure: request 3 not served",
            ),
            (
                Error::Storage("create dir /tmp/x: permission denied".into()),
                "storage failure: create dir /tmp/x: permission denied",
            ),
            (
                Error::Overloaded(RequestId(9)),
                "overloaded: request 9 shed by scheduler backpressure",
            ),
            (
                Error::CorruptSnapshot("snapshot.json: trailing data".into()),
                "corrupt snapshot: snapshot.json: trailing data",
            ),
        ];
        for (e, want) in cases {
            assert_eq!(e.to_string(), want);
        }
    }

    #[test]
    fn works_as_a_boxed_std_error() {
        let e: Box<dyn std::error::Error> = Box::new(Error::ShardPoisoned("placement ledger"));
        assert!(e.to_string().contains("placement ledger"));
    }
}
