//! [`Server`], [`SessionHandle`] and [`Ticket`]: the request-lifecycle
//! front of the facade.
//!
//! The engine room ([`crate::serve`]) thinks in whole batches; real
//! traffic arrives as *streams* — many sessions submitting concurrently,
//! interleaving arbitrarily. The ticket layer bridges the two: every
//! [`SessionHandle::submit`] appends to the server's **pending wave** (in
//! arrival order, whatever session it came from) and returns a [`Ticket`];
//! [`Server::flush`] — called explicitly or implicitly by the first
//! [`Ticket::wait`] — drains the wave through the per-shard scheduler
//! loops ([`crate::serve::sched`]) as one admission wave and resolves
//! every ticket it contained. Requests from different sessions therefore
//! share waves exactly the way a batch endpoint's callers would, while
//! each caller only ever touches its own ticket.
//!
//! [`Server::serve_batch`] and [`Server::serve_one`] are thin shims over
//! this lifecycle (submit → flush → wait), so the batch path and the
//! streaming path are literally the same code — which is what keeps the
//! worker-count-invariance and placement pins of the test suite valid for
//! both.
//!
//! # Continuous batching (open-loop arrivals)
//!
//! Waves are a *closed-loop* interface: the caller decides when a batch
//! is complete. [`Server::submit_at`] is the *open-loop* one — each
//! request carries a virtual arrival time (seconds, nondecreasing) and
//! is admitted mid-flight into its shard's run queue the moment the
//! shard's virtual clock reaches it, chunked prefills interleaving
//! round-robin. There is no flush barrier on this path: a short request
//! arriving behind a long prefill overtakes it chunk by chunk.
//! [`Server::seal_arrivals`] (or [`Server::advance_arrivals`]) releases
//! the determinism frontier so the queues can run dry;
//! [`Server::drain`] blocks until they have. Backpressure
//! ([`crate::serve::ServeConfig::queue_bound`],
//! [`crate::serve::ServeConfig::deadline`],
//! [`crate::serve::OverloadPolicy`]) sheds or delays overload
//! deterministically on the same virtual clock.

use std::collections::HashSet;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::api::{Error, ServerBuilder};
use crate::corpus::Corpus;
use crate::engine::costmodel::ModelSku;
use crate::engine::iface::InferenceEngine;
use crate::engine::sim::SimEngine;
use crate::metrics::{RunMetrics, ShardStats};
use crate::obs::TraceEvent;
use crate::serve::sched::{ResultCell, Scheduler};
use crate::serve::{shard_guard, ServeConfig, ServingEngine};
use crate::types::{Request, RequestId, ServedRequest, SessionId};

/// What a resolved ticket yields: the full served record (prompt layout,
/// token accounting, latency model outputs, tier split).
pub type Response = ServedRequest;

/// The pending admission wave: submissions (in arrival order) that have
/// not been flushed through the engine yet, plus the all-time request-id
/// ledger that rejects duplicate submissions. The ledger is never pruned
/// — one `RequestId` per served request, the same retention trade-off as
/// the engine room's request → shard eviction map. Open-loop submissions
/// share the ledger (ids are unique across both paths) but bypass the
/// pending wave entirely.
struct Wave {
    reqs: Vec<Request>,
    cells: Vec<Arc<ResultCell>>,
    seen: HashSet<RequestId>,
}

/// Fills every still-unresolved cell of a drained wave with an error when
/// dropped. Armed by [`Server::flush`] the moment it takes ownership of a
/// wave: if the flushing thread panics mid-serve (a worker panic
/// resurfacing through the scheduler's seal), unwinding resolves the
/// cells instead of stranding concurrent [`Ticket::wait`] callers on the
/// condvar forever. On the normal paths every cell is already filled, so
/// the drop is a no-op (cells are first-write-wins).
struct ResolveOnDrop {
    cells: Vec<Arc<ResultCell>>,
}

impl Drop for ResolveOnDrop {
    fn drop(&mut self) {
        for c in &self.cells {
            c.fill(Err(Error::ShardPoisoned("ticket wave")));
        }
    }
}

/// A running ContextPilot serving stack: sharded engine, placement
/// ledger, KV tiers, the per-shard scheduler loops and the ticket front,
/// behind one handle. Built by [`Server::builder`]; safe to share across
/// threads (`&Server` is all any caller needs).
pub struct Server<E: InferenceEngine = SimEngine> {
    engine: Arc<ServingEngine<E>>,
    corpus: Arc<Corpus>,
    wave: Mutex<Wave>,
    /// Where [`Server::checkpoint`] writes `snapshot.json` (and where the
    /// per-shard cold segment files live). `None` = ephemeral server.
    state_dir: Option<PathBuf>,
    /// The continuous-batching scheduler: one long-lived loop per shard,
    /// lazily spawned on first admission, joined on drop.
    sched: Scheduler<E>,
}

impl Server<SimEngine> {
    /// Start configuring a server for the given model SKU. See
    /// [`ServerBuilder`] for the knobs and [`crate::api`] for a worked
    /// end-to-end example.
    pub fn builder(sku: ModelSku) -> ServerBuilder {
        ServerBuilder::new(sku)
    }
}

impl<E: InferenceEngine> Server<E> {
    pub(crate) fn from_engine(
        engine: ServingEngine<E>,
        corpus: Arc<Corpus>,
        state_dir: Option<PathBuf>,
    ) -> Server<E> {
        let engine = Arc::new(engine);
        let sched = Scheduler::new(Arc::clone(&engine), Arc::clone(&corpus));
        Server {
            engine,
            corpus,
            wave: Mutex::new(Wave {
                reqs: Vec::new(),
                cells: Vec::new(),
                seen: HashSet::new(),
            }),
            state_dir,
            sched,
        }
    }

    /// The resolved configuration this server runs with (after builder
    /// validation; shard/worker counts as built).
    pub fn config(&self) -> &ServeConfig {
        self.engine.config()
    }

    pub fn n_shards(&self) -> usize {
        self.engine.n_shards()
    }

    pub fn n_workers(&self) -> usize {
        self.engine.n_workers()
    }

    /// The corpus requests are rendered against.
    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    /// A handle for submitting requests under one session. Cheap —
    /// sessions exist implicitly; their state (pin, history, dedup
    /// records) lives on whichever shard placement chose.
    pub fn session(&self, id: SessionId) -> SessionHandle<'_, E> {
        SessionHandle { server: self, id }
    }

    /// The shard a session is pinned to, or
    /// [`Error::UnknownSession`] if no request of it was ever placed.
    pub fn session_shard(&self, id: SessionId) -> Result<usize, Error> {
        self.engine
            .placed_shard(id)?
            .ok_or(Error::UnknownSession(id))
    }

    /// The shard a session's next request *would* run on: its recorded
    /// pin when placed, otherwise the session-hash prediction (exact
    /// under [`crate::api::PlacementKind::SessionHash`]).
    pub fn predicted_shard(&self, id: SessionId) -> Result<usize, Error> {
        self.engine.shard_of_session(id)
    }

    /// Drain the pending wave through the per-shard scheduler loops as
    /// one admission wave, resolving every ticket it contained. Returns
    /// how many requests were served. A no-op (`Ok(0)`) when nothing is
    /// pending — including when a concurrent caller drained the wave
    /// first; their flush resolves the tickets.
    pub fn flush(&self) -> Result<usize, Error> {
        let (reqs, cells) = {
            let mut wave = shard_guard(&self.wave, "ticket wave")?;
            (
                std::mem::take(&mut wave.reqs),
                std::mem::take(&mut wave.cells),
            )
        };
        if reqs.is_empty() {
            return Ok(0);
        }
        // from here on the drained cells are this thread's responsibility:
        // if the serve below panics, unwinding resolves them (waiters get
        // ShardPoisoned instead of blocking forever)
        let guard = ResolveOnDrop { cells };
        match self.sched.serve_wave(&reqs) {
            Ok(served) => {
                // the scheduler fails with EngineFailure rather than
                // return a partial wave, so Ok is always complete — and
                // output is in arrival order == submission order
                debug_assert_eq!(served.len(), reqs.len());
                for (cell, sr) in guard.cells.iter().zip(served) {
                    cell.fill(Ok(sr));
                }
                Ok(reqs.len())
            }
            Err(e) => {
                for cell in &guard.cells {
                    cell.fill(Err(e.clone()));
                }
                Err(e)
            }
        }
    }

    /// Submit one **open-loop** arrival at virtual time `at` (seconds,
    /// nondecreasing across calls — [`Error::InvalidConfig`] otherwise).
    /// The request is placed and queued on its shard immediately; the
    /// shard's scheduler loop admits it when its virtual clock reaches
    /// `at`, and its chunked prefill interleaves with whatever is
    /// already running — no flush barrier. The returned ticket resolves
    /// when the request completes on the virtual timeline, which
    /// requires the arrival frontier to move past it: keep submitting,
    /// call [`Server::advance_arrivals`], or finish with
    /// [`Server::seal_arrivals`] before waiting on the last tickets.
    ///
    /// Under backpressure the ticket may instead resolve to
    /// [`Error::Overloaded`] (see
    /// [`crate::serve::ServeConfig::queue_bound`] /
    /// [`crate::serve::ServeConfig::deadline`]) — deterministically: a
    /// replay of the same arrival sequence sheds the same requests.
    pub fn submit_at(&self, req: Request, at: f64) -> Result<Ticket<'_, E>, Error> {
        {
            let mut wave = shard_guard(&self.wave, "ticket wave")?;
            if !wave.seen.insert(req.id) {
                return Err(Error::DuplicateRequest(req.id));
            }
        }
        let id = req.id;
        match self.sched.submit_at(req, at) {
            Ok(cell) => Ok(Ticket { server: self, cell }),
            Err(e) => {
                // the arrival was rejected before it was queued: release
                // its id so the caller can resubmit (e.g. at a valid time)
                if let Ok(mut wave) = shard_guard(&self.wave, "ticket wave") {
                    wave.seen.remove(&id);
                }
                Err(e)
            }
        }
    }

    /// Declare the open-loop arrival sequence finished: the scheduler
    /// loops run their queues to completion (the determinism frontier
    /// stops gating execution). Permanent for this server; subsequent
    /// [`Server::submit_at`] calls fail. Wave submissions are unaffected.
    pub fn seal_arrivals(&self) -> Result<(), Error> {
        self.sched.seal_arrivals()
    }

    /// Promise that no open-loop arrival earlier than `upto` will come,
    /// letting the shards run their virtual clocks up to it without a
    /// submission. Useful for driving a live system "to now" without
    /// sealing.
    pub fn advance_arrivals(&self, upto: f64) -> Result<(), Error> {
        self.sched.advance_arrivals(upto)
    }

    /// Block until no scheduler loop has runnable work: every admitted
    /// request ran as far as the arrival frontier allows, and every
    /// queued wave was served. With [`Server::seal_arrivals`] called
    /// first, this means *everything submitted has resolved*.
    pub fn drain(&self) -> Result<(), Error> {
        self.sched.drain()
    }

    /// Pause every scheduler loop at its next step boundary. Submissions
    /// keep queueing; nothing is lost. Idempotent.
    pub fn pause(&self) -> Result<(), Error> {
        self.sched.pause()
    }

    /// Resume paused scheduler loops. Idempotent.
    pub fn resume(&self) -> Result<(), Error> {
        self.sched.resume()
    }

    /// Queue a whole slice atomically: validated first (duplicate ids —
    /// against the ledger *and* within the slice — admit nothing), then
    /// admitted to the pending wave in slice order under one lock, so a
    /// rejected batch leaves no half-queued prefix behind and no ids
    /// burned in the ledger.
    fn submit_all(&self, reqs: &[Request]) -> Result<Vec<Ticket<'_, E>>, Error> {
        let mut wave = shard_guard(&self.wave, "ticket wave")?;
        let mut in_slice: HashSet<RequestId> = HashSet::with_capacity(reqs.len());
        for r in reqs {
            if wave.seen.contains(&r.id) || !in_slice.insert(r.id) {
                return Err(Error::DuplicateRequest(r.id));
            }
        }
        let mut tickets = Vec::with_capacity(reqs.len());
        for r in reqs {
            let cell = Arc::new(ResultCell::new());
            wave.seen.insert(r.id);
            wave.reqs.push(r.clone());
            wave.cells.push(cell.clone());
            tickets.push(Ticket { server: self, cell });
        }
        Ok(tickets)
    }

    /// Serve a whole batch through the session/ticket lifecycle: admit
    /// every request atomically (arrival order = slice order), flush
    /// once, collect in the original order. With no concurrent submitters
    /// this hands the scheduler exactly this slice as one wave — bit-for-
    /// bit the pre-facade `serve_batch` semantics.
    pub fn serve_batch(&self, reqs: &[Request]) -> Result<Vec<Response>, Error> {
        let tickets = self.submit_all(reqs)?;
        self.flush()?;
        tickets.into_iter().map(Ticket::wait).collect()
    }

    /// Serve a single request (the streaming path): submit + wait. Safe
    /// to call concurrently from many threads; a session's requests are
    /// always served in submission order (sessions are pinned to one
    /// shard and waves preserve arrival order).
    ///
    /// Note the wave semantics: concurrent callers' submissions may land
    /// in one admission wave, and *different* sessions racing onto the
    /// same shard are then scheduled together (Alg.-5 ordering, shared
    /// chunked-admission clock) rather than serialized as singletons —
    /// the same freedom the engine has within any batch. Cross-session
    /// arrival order under concurrency was never deterministic; per-
    /// session results for a fixed per-shard arrival order are.
    pub fn serve_one(&self, req: &Request) -> Result<Response, Error> {
        self.session(req.session).submit(req.clone())?.wait()
    }

    /// Offline mode (§5.1): cluster-build each shard's context index over
    /// its slice of the batch. Runs through placement, pinning sessions,
    /// so subsequent serves land where their index was built.
    pub fn build_offline(&self, reqs: &[Request]) -> Result<(), Error> {
        self.engine.build_offline(reqs)
    }

    /// External eviction callback (§4.1): prune each owning shard's
    /// context index. Unknown ids are ignored.
    pub fn on_evict(&self, reqs: &[RequestId]) -> Result<(), Error> {
        self.engine.on_evict(reqs)
    }

    /// Aggregate run metrics plus a per-shard telemetry snapshot.
    pub fn metrics(&self) -> Result<(RunMetrics, Vec<ShardStats>), Error> {
        self.engine.metrics()
    }

    /// Snapshot of the observability counter registry ([`crate::obs`]):
    /// `(name, value)` per counter, in a fixed order. Always available —
    /// the registry runs whether or not tracing is on.
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        self.engine.counters()
    }

    /// The merged per-request lifecycle trace ([`crate::obs::trace`]),
    /// ordered by virtual time (ties broken by shard, then emission
    /// order). Empty unless the server was built with
    /// [`ServerBuilder::observability`] and tracing on; the stream is
    /// deterministic and worker-count invariant, like serving itself.
    pub fn trace_events(&self) -> Result<Vec<TraceEvent>, Error> {
        self.engine.trace_events()
    }

    /// Where this server persists durable state, if anywhere (set by
    /// [`ServerBuilder::state_dir`] / [`ServerBuilder::resume_from`]).
    pub fn state_dir(&self) -> Option<&Path> {
        self.state_dir.as_deref()
    }

    /// Durable checkpoint: flush the pending wave, drain the scheduler
    /// loops (so no in-flight open-loop request is mid-prefill), spill
    /// every shard's hot/warm KV into its cold-tier storage backend
    /// (pruning the context indices with whatever finally overflowed,
    /// §4.1), and write the versioned warm-state snapshot to
    /// `<state_dir>/snapshot.json` atomically (temp file + rename). A
    /// later [`ServerBuilder::resume_from`] on the same directory
    /// rebuilds the warm routing state and cold KV of this server.
    /// Returns the snapshot path.
    ///
    /// The server remains usable afterwards — a checkpoint is a spill,
    /// not a shutdown — but its HBM tier starts cold again, exactly as a
    /// restarted process would.
    ///
    /// Requires a state dir ([`Error::InvalidConfig`] otherwise); storage
    /// backend failures surface as [`Error::Storage`].
    pub fn checkpoint(&self) -> Result<PathBuf, Error> {
        let dir = self.state_dir.as_ref().ok_or_else(|| {
            Error::InvalidConfig(
                "checkpoint requires a state dir: build with .state_dir(..) or .resume_from(..)"
                    .into(),
            )
        })?;
        self.flush()?;
        self.sched.drain()?;
        let snap = self.engine.checkpoint_snapshot()?;
        let path = dir.join("snapshot.json");
        let tmp = dir.join("snapshot.json.tmp");
        std::fs::write(&tmp, format!("{snap}\n"))
            .and_then(|()| std::fs::rename(&tmp, &path))
            .map_err(|e| Error::Storage(format!("writing {}: {e}", path.display())))?;
        Ok(path)
    }
}

/// Summary-only `Debug` (the engine room holds mutexes and engine state
/// that neither derive nor want printing); mainly here so `Result<Server,
/// Error>` / `Result<Ticket, Error>` work with `unwrap_err` in tests.
impl<E: InferenceEngine> fmt::Debug for Server<E> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Server")
            .field("shards", &self.engine.n_shards())
            .field("workers", &self.engine.n_workers())
            .field("state_dir", &self.state_dir)
            .finish_non_exhaustive()
    }
}

/// Submission scope for one session. The handle is the authority on the
/// session identity: requests submitted through it are stamped with its
/// id, so a request built for one session cannot leak into another.
pub struct SessionHandle<'a, E: InferenceEngine> {
    server: &'a Server<E>,
    id: SessionId,
}

impl<'a, E: InferenceEngine> SessionHandle<'a, E> {
    pub fn id(&self) -> SessionId {
        self.id
    }

    /// The shard this session is pinned to ([`Error::UnknownSession`]
    /// until a request of it has been placed).
    pub fn shard(&self) -> Result<usize, Error> {
        self.server.session_shard(self.id)
    }

    /// Queue a request into the server's pending wave and return its
    /// ticket. Fails with [`Error::DuplicateRequest`] if the request id
    /// was ever submitted to this server before; the request is not
    /// queued in that case.
    pub fn submit(&self, mut req: Request) -> Result<Ticket<'a, E>, Error> {
        req.session = self.id;
        let cell = Arc::new(ResultCell::new());
        let mut wave = shard_guard(&self.server.wave, "ticket wave")?;
        if !wave.seen.insert(req.id) {
            return Err(Error::DuplicateRequest(req.id));
        }
        wave.reqs.push(req);
        wave.cells.push(cell.clone());
        Ok(Ticket {
            server: self.server,
            cell,
        })
    }

    /// Open-loop submission under this session: stamp the session id and
    /// forward to [`Server::submit_at`].
    pub fn submit_at(&self, mut req: Request, at: f64) -> Result<Ticket<'a, E>, Error> {
        req.session = self.id;
        self.server.submit_at(req, at)
    }
}

/// A claim on one submitted request's result. [`Ticket::wait`] drives the
/// server if needed (flushing the pending wave) and returns this
/// request's record; dropping a ticket without waiting is allowed — the
/// request is still served by whichever flush (or scheduler loop, for
/// open-loop submissions) resolves its wave.
#[must_use = "a ticket does nothing until waited on (or the server is flushed)"]
pub struct Ticket<'a, E: InferenceEngine> {
    server: &'a Server<E>,
    cell: Arc<ResultCell>,
}

impl<E: InferenceEngine> Ticket<'_, E> {
    /// Non-blocking probe: `Ok(None)` while the request's wave has not
    /// been flushed (or its open-loop admission is still in flight),
    /// `Ok(Some(response))` once it served, `Err` if it failed.
    pub fn try_result(&self) -> Result<Option<Response>, Error> {
        match self.cell.peek()? {
            None => Ok(None),
            Some(Ok(r)) => Ok(Some(r)),
            Some(Err(e)) => Err(e),
        }
    }

    /// Resolve the ticket: if its wave is still pending this flushes it
    /// (serving every pending submission, whatever session they belong
    /// to); if a concurrent caller drained the wave first — or this is an
    /// open-loop submission the scheduler is still running — this blocks
    /// until the cell resolves. For open-loop tickets make sure the
    /// arrival frontier can pass the request
    /// ([`Server::seal_arrivals`] / [`Server::advance_arrivals`]) before
    /// blocking, or the wait never returns.
    pub fn wait(self) -> Result<Response, Error> {
        if let Some(r) = self.cell.take_now()? {
            return r;
        }
        // Either this flush serves our wave, or another thread already
        // drained it and will fill the cell; flush errors that resolved
        // our cell are reported through the cell itself.
        let flushed = self.server.flush();
        if let Some(r) = self.cell.take_now()? {
            return r;
        }
        // the flush failed before our wave was drained (e.g. a poisoned
        // wave lock): nobody will ever fill the cell, so report directly
        // instead of blocking forever
        flushed?;
        self.cell.take_filled()
    }
}

impl<E: InferenceEngine> fmt::Debug for SessionHandle<'_, E> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SessionHandle")
            .field("id", &self.id)
            .finish_non_exhaustive()
    }
}

impl<E: InferenceEngine> fmt::Debug for Ticket<'_, E> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Ticket").finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusConfig;
    use crate::tokenizer::Tokenizer;
    use crate::types::{BlockId, QueryId};

    fn server() -> Server {
        let corpus = Corpus::generate(
            &CorpusConfig {
                n_docs: 30,
                ..Default::default()
            },
            &Tokenizer::default(),
        );
        Server::builder(ModelSku::Qwen3_4B)
            .shards(2)
            .workers(2)
            .decode_tokens(8)
            .corpus(corpus)
            .build()
            .expect("test config is valid")
    }

    fn req(id: u64, session: u32, ids: &[u32]) -> Request {
        Request {
            id: RequestId(id),
            session: SessionId(session),
            turn: 0,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(id),
        }
    }

    #[test]
    fn tickets_resolve_in_submission_order_across_sessions() {
        let server = server();
        let a = server.session(SessionId(1)).submit(req(1, 1, &[1, 2])).unwrap();
        let b = server.session(SessionId(2)).submit(req(2, 2, &[3, 4])).unwrap();
        assert!(a.try_result().unwrap().is_none(), "nothing flushed yet");
        let first = a.wait().expect("serve");
        // a's wait flushed the whole wave: b resolves without serving
        let pending = server.flush().expect("flush");
        assert_eq!(pending, 0, "wave already drained");
        let second = b.wait().expect("serve");
        assert_eq!(first.request.id, RequestId(1));
        assert_eq!(second.request.id, RequestId(2));
    }

    #[test]
    fn duplicate_request_id_is_rejected_without_queueing() {
        let server = server();
        let t = server.session(SessionId(1)).submit(req(7, 1, &[1])).unwrap();
        let err = server
            .session(SessionId(2))
            .submit(req(7, 2, &[2]))
            .unwrap_err();
        assert_eq!(err, Error::DuplicateRequest(RequestId(7)));
        t.wait().expect("original request unaffected");
        let (m, _) = server.metrics().expect("metrics");
        assert_eq!(m.len(), 1, "the duplicate must not have been queued");
    }

    #[test]
    fn handle_stamps_its_session_onto_requests() {
        let server = server();
        // request built with session 9, submitted via session 3
        let t = server.session(SessionId(3)).submit(req(1, 9, &[1])).unwrap();
        let served = t.wait().expect("serve");
        assert_eq!(served.request.session, SessionId(3));
        assert!(server.session_shard(SessionId(3)).is_ok());
        assert_eq!(
            server.session_shard(SessionId(9)).unwrap_err(),
            Error::UnknownSession(SessionId(9))
        );
    }

    #[test]
    fn submit_at_rejects_regressing_and_sealed_arrivals() {
        let server = server();
        let t1 = server.submit_at(req(1, 1, &[1]), 0.5).unwrap();
        let err = server.submit_at(req(2, 2, &[2]), 0.25).unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)), "regressing time");
        server.seal_arrivals().expect("seal");
        let err = server.submit_at(req(3, 3, &[3]), 1.0).unwrap_err();
        assert!(matches!(err, Error::InvalidConfig(_)), "sealed arrivals");
        t1.wait().expect("the valid arrival still serves");
        server.drain().expect("drain");
        // a rejected id is released for resubmission through the wave path
        server.session(SessionId(2)).submit(req(2, 2, &[2])).unwrap().wait().expect("resubmit");
    }

    #[test]
    fn open_loop_duplicate_id_is_rejected() {
        let server = server();
        let t = server.submit_at(req(4, 1, &[1]), 0.0).unwrap();
        let err = server.submit_at(req(4, 2, &[2]), 1.0).unwrap_err();
        assert_eq!(err, Error::DuplicateRequest(RequestId(4)));
        server.seal_arrivals().expect("seal");
        t.wait().expect("original arrival unaffected");
    }

    #[test]
    fn server_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Server>();
    }
}
