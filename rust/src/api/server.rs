//! [`Server`], [`SessionHandle`] and [`Ticket`]: the request-lifecycle
//! front of the facade.
//!
//! The engine room ([`crate::serve`]) thinks in whole batches; real
//! traffic arrives as *streams* — many sessions submitting concurrently,
//! interleaving arbitrarily. The ticket layer bridges the two: every
//! [`SessionHandle::submit`] appends to the server's **pending wave** (in
//! arrival order, whatever session it came from) and returns a [`Ticket`];
//! [`Server::flush`] — called explicitly or implicitly by the first
//! [`Ticket::wait`] — drains the wave through the sharded engine as one
//! admission wave and resolves every ticket it contained. Requests from
//! different sessions therefore share waves exactly the way a batch
//! endpoint's callers would, while each caller only ever touches its own
//! ticket.
//!
//! [`Server::serve_batch`] and [`Server::serve_one`] are thin shims over
//! this lifecycle (submit → flush → wait), so the batch path and the
//! streaming path are literally the same code — which is what keeps the
//! worker-count-invariance and placement pins of the test suite valid for
//! both.

use std::collections::HashSet;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};

use crate::api::{Error, ServerBuilder};
use crate::corpus::Corpus;
use crate::engine::costmodel::ModelSku;
use crate::engine::iface::InferenceEngine;
use crate::engine::sim::SimEngine;
use crate::metrics::{RunMetrics, ShardStats};
use crate::obs::TraceEvent;
use crate::serve::{shard_guard, ServeConfig, ServingEngine};
use crate::types::{Request, RequestId, ServedRequest, SessionId};

/// What a resolved ticket yields: the full served record (prompt layout,
/// token accounting, latency model outputs, tier split).
pub type Response = ServedRequest;

/// One submission's result slot, shared between its [`Ticket`] and the
/// flush that resolves it.
struct TicketCell {
    slot: Mutex<Option<Result<Response, Error>>>,
    ready: Condvar,
}

impl TicketCell {
    fn new() -> TicketCell {
        TicketCell {
            slot: Mutex::new(None),
            ready: Condvar::new(),
        }
    }

    /// Resolve the cell (first write wins). Runs on the flushing thread;
    /// recovers the inner value even from a poisoned slot so a waiter is
    /// never stranded.
    fn fill(&self, r: Result<Response, Error>) {
        let mut slot = self.slot.lock().unwrap_or_else(|p| p.into_inner());
        if slot.is_none() {
            *slot = Some(r);
            self.ready.notify_all();
        }
    }

    /// Non-blocking peek (clones; for the non-consuming
    /// [`Ticket::try_result`]).
    fn peek(&self) -> Result<Option<Result<Response, Error>>, Error> {
        Ok(shard_guard(&self.slot, "ticket slot")?.clone())
    }

    /// Non-blocking take. Only the consuming [`Ticket::wait`] path calls
    /// this: a cell has exactly one ticket, so moving the response out
    /// (instead of cloning it) is safe and saves a full `ServedRequest`
    /// copy per request.
    fn take_now(&self) -> Result<Option<Result<Response, Error>>, Error> {
        Ok(shard_guard(&self.slot, "ticket slot")?.take())
    }

    /// Block until a flush fills the cell (the wave holding this request
    /// was drained by another thread, which will resolve it), then move
    /// the result out.
    fn take_filled(&self) -> Result<Response, Error> {
        let mut slot = shard_guard(&self.slot, "ticket slot")?;
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self
                .ready
                .wait(slot)
                .map_err(|_| Error::ShardPoisoned("ticket slot"))?;
        }
    }
}

/// The pending admission wave: submissions (in arrival order) that have
/// not been flushed through the engine yet, plus the all-time request-id
/// ledger that rejects duplicate submissions. The ledger is never pruned
/// — one `RequestId` per served request, the same retention trade-off as
/// the engine room's request → shard eviction map.
struct Wave {
    reqs: Vec<Request>,
    cells: Vec<Arc<TicketCell>>,
    seen: HashSet<RequestId>,
}

/// Fills every still-unresolved cell of a drained wave with an error when
/// dropped. Armed by [`Server::flush`] the moment it takes ownership of a
/// wave: if the flushing thread panics mid-serve (a worker panic
/// resurfacing through the thread-scope join), unwinding resolves the
/// cells instead of stranding concurrent [`Ticket::wait`] callers on the
/// condvar forever. On the normal paths every cell is already filled, so
/// the drop is a no-op (cells are first-write-wins).
struct ResolveOnDrop {
    cells: Vec<Arc<TicketCell>>,
}

impl Drop for ResolveOnDrop {
    fn drop(&mut self) {
        for c in &self.cells {
            c.fill(Err(Error::ShardPoisoned("ticket wave")));
        }
    }
}

/// A running ContextPilot serving stack: sharded engine, placement
/// ledger, KV tiers and the ticket front, behind one handle. Built by
/// [`Server::builder`]; safe to share across threads (`&Server` is all
/// any caller needs).
pub struct Server<E: InferenceEngine = SimEngine> {
    engine: ServingEngine<E>,
    corpus: Arc<Corpus>,
    wave: Mutex<Wave>,
    /// Where [`Server::checkpoint`] writes `snapshot.json` (and where the
    /// per-shard cold segment files live). `None` = ephemeral server.
    state_dir: Option<PathBuf>,
}

impl Server<SimEngine> {
    /// Start configuring a server for the given model SKU. See
    /// [`ServerBuilder`] for the knobs and [`crate::api`] for a worked
    /// end-to-end example.
    pub fn builder(sku: ModelSku) -> ServerBuilder {
        ServerBuilder::new(sku)
    }
}

impl<E: InferenceEngine> Server<E> {
    pub(crate) fn from_engine(
        engine: ServingEngine<E>,
        corpus: Arc<Corpus>,
        state_dir: Option<PathBuf>,
    ) -> Server<E> {
        Server {
            engine,
            corpus,
            wave: Mutex::new(Wave {
                reqs: Vec::new(),
                cells: Vec::new(),
                seen: HashSet::new(),
            }),
            state_dir,
        }
    }

    /// The resolved configuration this server runs with (after builder
    /// validation; shard/worker counts as built).
    pub fn config(&self) -> &ServeConfig {
        self.engine.config()
    }

    pub fn n_shards(&self) -> usize {
        self.engine.n_shards()
    }

    pub fn n_workers(&self) -> usize {
        self.engine.n_workers()
    }

    /// The corpus requests are rendered against.
    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    /// A handle for submitting requests under one session. Cheap —
    /// sessions exist implicitly; their state (pin, history, dedup
    /// records) lives on whichever shard placement chose.
    pub fn session(&self, id: SessionId) -> SessionHandle<'_, E> {
        SessionHandle { server: self, id }
    }

    /// The shard a session is pinned to, or
    /// [`Error::UnknownSession`] if no request of it was ever placed.
    pub fn session_shard(&self, id: SessionId) -> Result<usize, Error> {
        self.engine
            .placed_shard(id)?
            .ok_or(Error::UnknownSession(id))
    }

    /// The shard a session's next request *would* run on: its recorded
    /// pin when placed, otherwise the session-hash prediction (exact
    /// under [`crate::api::PlacementKind::SessionHash`]).
    pub fn predicted_shard(&self, id: SessionId) -> Result<usize, Error> {
        self.engine.shard_of_session(id)
    }

    /// Drain the pending wave through the sharded engine as one admission
    /// wave, resolving every ticket it contained. Returns how many
    /// requests were served. A no-op (`Ok(0)`) when nothing is pending —
    /// including when a concurrent caller drained the wave first; their
    /// flush resolves the tickets.
    pub fn flush(&self) -> Result<usize, Error> {
        let (reqs, cells) = {
            let mut wave = shard_guard(&self.wave, "ticket wave")?;
            (
                std::mem::take(&mut wave.reqs),
                std::mem::take(&mut wave.cells),
            )
        };
        if reqs.is_empty() {
            return Ok(0);
        }
        // from here on the drained cells are this thread's responsibility:
        // if the serve below panics, unwinding resolves them (waiters get
        // ShardPoisoned instead of blocking forever)
        let guard = ResolveOnDrop { cells };
        match self.engine.serve_batch(&reqs, &self.corpus) {
            Ok(served) => {
                // the engine fails with EngineFailure rather than return a
                // partial batch, so Ok is always complete — and output is
                // in arrival order == submission order
                debug_assert_eq!(served.len(), reqs.len());
                for (cell, sr) in guard.cells.iter().zip(served) {
                    cell.fill(Ok(sr));
                }
                Ok(reqs.len())
            }
            Err(e) => {
                for cell in &guard.cells {
                    cell.fill(Err(e.clone()));
                }
                Err(e)
            }
        }
    }

    /// Queue a whole slice atomically: validated first (duplicate ids —
    /// against the ledger *and* within the slice — admit nothing), then
    /// admitted to the pending wave in slice order under one lock, so a
    /// rejected batch leaves no half-queued prefix behind and no ids
    /// burned in the ledger.
    fn submit_all(&self, reqs: &[Request]) -> Result<Vec<Ticket<'_, E>>, Error> {
        let mut wave = shard_guard(&self.wave, "ticket wave")?;
        let mut in_slice: HashSet<RequestId> = HashSet::with_capacity(reqs.len());
        for r in reqs {
            if wave.seen.contains(&r.id) || !in_slice.insert(r.id) {
                return Err(Error::DuplicateRequest(r.id));
            }
        }
        let mut tickets = Vec::with_capacity(reqs.len());
        for r in reqs {
            let cell = Arc::new(TicketCell::new());
            wave.seen.insert(r.id);
            wave.reqs.push(r.clone());
            wave.cells.push(cell.clone());
            tickets.push(Ticket { server: self, cell });
        }
        Ok(tickets)
    }

    /// Serve a whole batch through the session/ticket lifecycle: admit
    /// every request atomically (arrival order = slice order), flush
    /// once, collect in the original order. With no concurrent submitters
    /// this hands the engine exactly this slice as one wave — bit-for-bit
    /// the pre-facade `serve_batch` semantics.
    pub fn serve_batch(&self, reqs: &[Request]) -> Result<Vec<Response>, Error> {
        let tickets = self.submit_all(reqs)?;
        self.flush()?;
        tickets.into_iter().map(Ticket::wait).collect()
    }

    /// Serve a single request (the streaming path): submit + wait. Safe
    /// to call concurrently from many threads; a session's requests are
    /// always served in submission order (sessions are pinned to one
    /// shard and waves preserve arrival order).
    ///
    /// Note the wave semantics: concurrent callers' submissions may land
    /// in one admission wave, and *different* sessions racing onto the
    /// same shard are then scheduled together (Alg.-5 ordering, shared
    /// chunked-admission clock) rather than serialized as singletons —
    /// the same freedom the engine has within any batch. Cross-session
    /// arrival order under concurrency was never deterministic; per-
    /// session results for a fixed per-shard arrival order are.
    pub fn serve_one(&self, req: &Request) -> Result<Response, Error> {
        self.session(req.session).submit(req.clone())?.wait()
    }

    /// Offline mode (§5.1): cluster-build each shard's context index over
    /// its slice of the batch. Runs through placement, pinning sessions,
    /// so subsequent serves land where their index was built.
    pub fn build_offline(&self, reqs: &[Request]) -> Result<(), Error> {
        self.engine.build_offline(reqs)
    }

    /// External eviction callback (§4.1): prune each owning shard's
    /// context index. Unknown ids are ignored.
    pub fn on_evict(&self, reqs: &[RequestId]) -> Result<(), Error> {
        self.engine.on_evict(reqs)
    }

    /// Aggregate run metrics plus a per-shard telemetry snapshot.
    pub fn metrics(&self) -> Result<(RunMetrics, Vec<ShardStats>), Error> {
        self.engine.metrics()
    }

    /// Snapshot of the observability counter registry ([`crate::obs`]):
    /// `(name, value)` per counter, in a fixed order. Always available —
    /// the registry runs whether or not tracing is on.
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        self.engine.counters()
    }

    /// The merged per-request lifecycle trace ([`crate::obs::trace`]),
    /// ordered by virtual time (ties broken by shard, then emission
    /// order). Empty unless the server was built with
    /// [`ServerBuilder::observability`] and tracing on; the stream is
    /// deterministic and worker-count invariant, like serving itself.
    pub fn trace_events(&self) -> Result<Vec<TraceEvent>, Error> {
        self.engine.trace_events()
    }

    /// Where this server persists durable state, if anywhere (set by
    /// [`ServerBuilder::state_dir`] / [`ServerBuilder::resume_from`]).
    pub fn state_dir(&self) -> Option<&Path> {
        self.state_dir.as_deref()
    }

    /// Durable checkpoint: flush the pending wave, spill every shard's
    /// hot/warm KV into its cold-tier storage backend (pruning the
    /// context indices with whatever finally overflowed, §4.1), and write
    /// the versioned warm-state snapshot to `<state_dir>/snapshot.json`
    /// atomically (temp file + rename). A later
    /// [`ServerBuilder::resume_from`] on the same directory rebuilds the
    /// warm routing state and cold KV of this server. Returns the
    /// snapshot path.
    ///
    /// The server remains usable afterwards — a checkpoint is a spill,
    /// not a shutdown — but its HBM tier starts cold again, exactly as a
    /// restarted process would.
    ///
    /// Requires a state dir ([`Error::InvalidConfig`] otherwise); storage
    /// backend failures surface as [`Error::Storage`].
    pub fn checkpoint(&self) -> Result<PathBuf, Error> {
        let dir = self.state_dir.as_ref().ok_or_else(|| {
            Error::InvalidConfig(
                "checkpoint requires a state dir: build with .state_dir(..) or .resume_from(..)"
                    .into(),
            )
        })?;
        self.flush()?;
        let snap = self.engine.checkpoint_snapshot()?;
        let path = dir.join("snapshot.json");
        let tmp = dir.join("snapshot.json.tmp");
        std::fs::write(&tmp, format!("{snap}\n"))
            .and_then(|()| std::fs::rename(&tmp, &path))
            .map_err(|e| Error::Storage(format!("writing {}: {e}", path.display())))?;
        Ok(path)
    }
}

/// Summary-only `Debug` (the engine room holds mutexes and engine state
/// that neither derive nor want printing); mainly here so `Result<Server,
/// Error>` / `Result<Ticket, Error>` work with `unwrap_err` in tests.
impl<E: InferenceEngine> fmt::Debug for Server<E> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Server")
            .field("shards", &self.engine.n_shards())
            .field("workers", &self.engine.n_workers())
            .field("state_dir", &self.state_dir)
            .finish_non_exhaustive()
    }
}

/// Submission scope for one session. The handle is the authority on the
/// session identity: requests submitted through it are stamped with its
/// id, so a request built for one session cannot leak into another.
pub struct SessionHandle<'a, E: InferenceEngine> {
    server: &'a Server<E>,
    id: SessionId,
}

impl<'a, E: InferenceEngine> SessionHandle<'a, E> {
    pub fn id(&self) -> SessionId {
        self.id
    }

    /// The shard this session is pinned to ([`Error::UnknownSession`]
    /// until a request of it has been placed).
    pub fn shard(&self) -> Result<usize, Error> {
        self.server.session_shard(self.id)
    }

    /// Queue a request into the server's pending wave and return its
    /// ticket. Fails with [`Error::DuplicateRequest`] if the request id
    /// was ever submitted to this server before; the request is not
    /// queued in that case.
    pub fn submit(&self, mut req: Request) -> Result<Ticket<'a, E>, Error> {
        req.session = self.id;
        let cell = Arc::new(TicketCell::new());
        let mut wave = shard_guard(&self.server.wave, "ticket wave")?;
        if !wave.seen.insert(req.id) {
            return Err(Error::DuplicateRequest(req.id));
        }
        wave.reqs.push(req);
        wave.cells.push(cell.clone());
        Ok(Ticket {
            server: self.server,
            cell,
        })
    }
}

/// A claim on one submitted request's result. [`Ticket::wait`] drives the
/// server if needed (flushing the pending wave) and returns this
/// request's record; dropping a ticket without waiting is allowed — the
/// request is still served by whichever flush drains its wave.
#[must_use = "a ticket does nothing until waited on (or the server is flushed)"]
pub struct Ticket<'a, E: InferenceEngine> {
    server: &'a Server<E>,
    cell: Arc<TicketCell>,
}

impl<E: InferenceEngine> Ticket<'_, E> {
    /// Non-blocking probe: `Ok(None)` while the request's wave has not
    /// been flushed, `Ok(Some(response))` once it served, `Err` if the
    /// wave was flushed and failed.
    pub fn try_result(&self) -> Result<Option<Response>, Error> {
        match self.cell.peek()? {
            None => Ok(None),
            Some(Ok(r)) => Ok(Some(r)),
            Some(Err(e)) => Err(e),
        }
    }

    /// Resolve the ticket: if its wave is still pending this flushes it
    /// (serving every pending submission, whatever session they belong
    /// to); if a concurrent caller drained the wave first, this blocks
    /// until that flush resolves the cell.
    pub fn wait(self) -> Result<Response, Error> {
        if let Some(r) = self.cell.take_now()? {
            return r;
        }
        // Either this flush serves our wave, or another thread already
        // drained it and will fill the cell; flush errors that resolved
        // our cell are reported through the cell itself.
        let flushed = self.server.flush();
        if let Some(r) = self.cell.take_now()? {
            return r;
        }
        // the flush failed before our wave was drained (e.g. a poisoned
        // wave lock): nobody will ever fill the cell, so report directly
        // instead of blocking forever
        flushed?;
        self.cell.take_filled()
    }
}

impl<E: InferenceEngine> fmt::Debug for SessionHandle<'_, E> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SessionHandle")
            .field("id", &self.id)
            .finish_non_exhaustive()
    }
}

impl<E: InferenceEngine> fmt::Debug for Ticket<'_, E> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Ticket").finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusConfig;
    use crate::tokenizer::Tokenizer;
    use crate::types::{BlockId, QueryId};

    fn server() -> Server {
        let corpus = Corpus::generate(
            &CorpusConfig {
                n_docs: 30,
                ..Default::default()
            },
            &Tokenizer::default(),
        );
        Server::builder(ModelSku::Qwen3_4B)
            .shards(2)
            .workers(2)
            .decode_tokens(8)
            .corpus(corpus)
            .build()
            .expect("test config is valid")
    }

    fn req(id: u64, session: u32, ids: &[u32]) -> Request {
        Request {
            id: RequestId(id),
            session: SessionId(session),
            turn: 0,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(id),
        }
    }

    #[test]
    fn tickets_resolve_in_submission_order_across_sessions() {
        let server = server();
        let a = server.session(SessionId(1)).submit(req(1, 1, &[1, 2])).unwrap();
        let b = server.session(SessionId(2)).submit(req(2, 2, &[3, 4])).unwrap();
        assert!(a.try_result().unwrap().is_none(), "nothing flushed yet");
        let first = a.wait().expect("serve");
        // a's wait flushed the whole wave: b resolves without serving
        let pending = server.flush().expect("flush");
        assert_eq!(pending, 0, "wave already drained");
        let second = b.wait().expect("serve");
        assert_eq!(first.request.id, RequestId(1));
        assert_eq!(second.request.id, RequestId(2));
    }

    #[test]
    fn duplicate_request_id_is_rejected_without_queueing() {
        let server = server();
        let t = server.session(SessionId(1)).submit(req(7, 1, &[1])).unwrap();
        let err = server
            .session(SessionId(2))
            .submit(req(7, 2, &[2]))
            .unwrap_err();
        assert_eq!(err, Error::DuplicateRequest(RequestId(7)));
        t.wait().expect("original request unaffected");
        let (m, _) = server.metrics().expect("metrics");
        assert_eq!(m.len(), 1, "the duplicate must not have been queued");
    }

    #[test]
    fn handle_stamps_its_session_onto_requests() {
        let server = server();
        // request built with session 9, submitted via session 3
        let t = server.session(SessionId(3)).submit(req(1, 9, &[1])).unwrap();
        let served = t.wait().expect("serve");
        assert_eq!(served.request.session, SessionId(3));
        assert!(server.session_shard(SessionId(3)).is_ok());
        assert_eq!(
            server.session_shard(SessionId(9)).unwrap_err(),
            Error::UnknownSession(SessionId(9))
        );
    }

    #[test]
    fn server_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Server>();
    }
}
