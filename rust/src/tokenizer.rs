//! Deterministic tokenizer substrate.
//!
//! The serving engines the paper integrates with (SGLang/vLLM) cache KV at
//! *token* granularity, so the radix prefix cache needs real token
//! sequences. We use a whitespace word tokenizer with FNV-hashed ids into a
//! fixed vocab — deterministic across runs, collision behaviour is
//! irrelevant (we never detokenize), and identical text always produces
//! identical token ids, which is the property prefix caching requires.

pub const DEFAULT_VOCAB: u32 = 2048;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub vocab: u32,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self {
            vocab: DEFAULT_VOCAB,
        }
    }
}

#[inline]
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl Tokenizer {
    pub fn new(vocab: u32) -> Self {
        assert!(vocab > 0);
        Self { vocab }
    }

    /// Tokenize one word. Reserved ids [0, 16) are avoided so the engine
    /// can use them as sentinels (e.g. padding = 0).
    #[inline]
    pub fn word_id(&self, word: &str) -> u32 {
        let reserved = 16u32.min(self.vocab / 4);
        reserved + (fnv1a(word.as_bytes()) % (self.vocab - reserved) as u64) as u32
    }

    /// Tokenize text: split on whitespace, one token per word.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace().map(|w| self.word_id(w)).collect()
    }

    /// Append-encode into an existing buffer (hot-path variant; avoids the
    /// intermediate Vec in the engine's prompt assembly).
    pub fn encode_into(&self, text: &str, out: &mut Vec<u32>) {
        for w in text.split_whitespace() {
            out.push(self.word_id(w));
        }
    }

    /// Number of tokens `encode` would produce, without allocating.
    pub fn count(&self, text: &str) -> usize {
        text.split_whitespace().count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let t = Tokenizer::default();
        assert_eq!(t.encode("hello world"), t.encode("hello world"));
    }

    #[test]
    fn same_word_same_id_anywhere() {
        let t = Tokenizer::default();
        let a = t.encode("kennedy died in 1963");
        let b = t.encode("in 1963 kennedy died");
        // multiset equal, order differs
        let mut a2 = a.clone();
        let mut b2 = b.clone();
        a2.sort_unstable();
        b2.sort_unstable();
        assert_eq!(a2, b2);
        assert_ne!(a, b);
    }

    #[test]
    fn ids_in_vocab_and_above_reserved() {
        let t = Tokenizer::new(100);
        for w in ["a", "bb", "ccc", "dddd", "テスト"] {
            let id = t.word_id(w);
            assert!(id >= 16.min(25) && id < 100, "{w} -> {id}");
        }
    }

    #[test]
    fn whitespace_handling() {
        let t = Tokenizer::default();
        assert_eq!(t.encode("  a   b  "), t.encode("a b"));
        assert!(t.encode("").is_empty());
        assert_eq!(t.count("one two  three"), 3);
    }

    #[test]
    fn encode_into_matches_encode() {
        let t = Tokenizer::default();
        let mut buf = vec![999];
        t.encode_into("x y z", &mut buf);
        assert_eq!(buf[1..].to_vec(), t.encode("x y z"));
    }
}
