//! Context alignment (§5, Algorithm 2) and order annotations (§5.3).
//!
//! `align_context` queries the index for the best-matching node, reorders
//! the incoming context so the matched shared prefix comes first (in the
//! node's canonical order) followed by the remaining blocks in their
//! original relevance order, inserts the aligned context as a new leaf,
//! and returns the search path the scheduler (Alg. 5) groups by.

use std::collections::HashSet;

use crate::index::tree::{ContextIndex, SearchResult};
use crate::types::{BlockId, Context, RequestId};

#[derive(Clone, Debug)]
pub struct Alignment {
    /// The reordered context handed to the engine.
    pub aligned: Context,
    /// Search path of the inserted leaf (for Alg.-5 scheduling).
    pub path: Vec<usize>,
    /// Whether the order differs from the original retrieval ranking
    /// (if so, an order annotation is required to preserve semantics).
    pub reordered: bool,
}

/// Algorithm 2. `context` is the retrieval-ranked block list.
pub fn align_context(index: &mut ContextIndex, context: &Context, req: RequestId) -> Alignment {
    let found: SearchResult = index.search(context);
    let aligned = align_to_prefix(&index.node(found.node).context, context);
    let reordered = aligned != *context;
    let (_, path) = index.insert_at(&found, aligned.clone(), req);
    Alignment {
        aligned,
        path,
        reordered,
    }
}

/// Reorder `context` to start with the blocks of `prefix` (in prefix
/// order, restricted to blocks actually present in `context` — a virtual
/// node's context may contain blocks this request did not retrieve),
/// followed by the remaining blocks in their original order.
pub fn align_to_prefix(prefix: &Context, context: &Context) -> Context {
    if prefix.is_empty() {
        return context.clone();
    }
    let have: HashSet<BlockId> = context.iter().copied().collect();
    let mut out: Context = prefix.iter().copied().filter(|b| have.contains(b)).collect();
    let taken: HashSet<BlockId> = out.iter().copied().collect();
    out.extend(context.iter().copied().filter(|b| !taken.contains(b)));
    out
}

/// Order annotation (§5.3): the original relevance ranking, rendered by
/// the engine as "Please read the context in the following priority
/// order: [CB_a] > [CB_b] > ... and answer the question."
/// Returns None when the aligned order equals the original (no annotation
/// needed — zero token overhead).
pub fn order_annotation(original: &Context, aligned: &Context) -> Option<Context> {
    if original == aligned {
        None
    } else {
        Some(original.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::build::build_clustered;

    fn ctx(ids: &[u32]) -> Context {
        ids.iter().map(|&i| BlockId(i)).collect()
    }

    #[test]
    fn paper_example_c6_alignment() {
        // Fig. 5: C6{2,1,4} matches C4{1,2} -> aligned {1,2,4}.
        let inputs = vec![
            (RequestId(1), ctx(&[2, 1, 3])),
            (RequestId(2), ctx(&[2, 6, 1])),
            (RequestId(3), ctx(&[4, 1, 0])),
        ];
        let mut r = build_clustered(&inputs, 0.001);
        let a = align_context(&mut r.index, &ctx(&[2, 1, 4]), RequestId(6));
        assert_eq!(a.aligned, ctx(&[1, 2, 4]));
        assert!(a.reordered);
        assert_eq!(a.path, vec![0, 0, 2]); // C4's third child
        r.index.check_invariants().unwrap();
    }

    #[test]
    fn paper_example_c8_alignment() {
        // Fig. 5: C8{1,2,9} also matches C4 -> aligned {1,2,9}, path [0,0,3]
        // after C6 was inserted.
        let inputs = vec![
            (RequestId(1), ctx(&[2, 1, 3])),
            (RequestId(2), ctx(&[2, 6, 1])),
            (RequestId(3), ctx(&[4, 1, 0])),
        ];
        let mut r = build_clustered(&inputs, 0.001);
        align_context(&mut r.index, &ctx(&[2, 1, 4]), RequestId(6));
        let a8 = align_context(&mut r.index, &ctx(&[1, 2, 9]), RequestId(8));
        assert_eq!(a8.aligned, ctx(&[1, 2, 9]));
        assert!(!a8.reordered); // {1,2,9} already starts with the prefix
        assert_eq!(a8.path, vec![0, 0, 3]);
    }

    #[test]
    fn unmatched_context_unchanged() {
        // Fig. 5: C7{5,7,8} matches nothing and stays as-is.
        let inputs = vec![
            (RequestId(1), ctx(&[2, 1, 3])),
            (RequestId(2), ctx(&[2, 6, 1])),
        ];
        let mut r = build_clustered(&inputs, 0.001);
        let a = align_context(&mut r.index, &ctx(&[5, 7, 8]), RequestId(7));
        assert_eq!(a.aligned, ctx(&[5, 7, 8]));
        assert!(!a.reordered);
        assert_eq!(a.path.len(), 1); // standalone branch off the root
    }

    #[test]
    fn alignment_is_permutation() {
        use crate::util::prng::Rng;
        use crate::util::prop;
        prop::quickcheck("align_to_prefix is a permutation", |rng: &mut Rng, size| {
            let ctx_ids: Vec<BlockId> = prop::gen_distinct_ids(rng, size, 128)
                .into_iter()
                .map(|i| BlockId(i as u32))
                .collect();
            let prefix: Vec<BlockId> = prop::gen_distinct_ids(rng, size, 128)
                .into_iter()
                .map(|i| BlockId(i as u32))
                .collect();
            let out = align_to_prefix(&prefix, &ctx_ids);
            let mut a = ctx_ids.clone();
            let mut b = out.clone();
            a.sort_unstable();
            b.sort_unstable();
            a == b
        });
    }

    #[test]
    fn aligned_shared_prefix_comes_first() {
        let prefix = ctx(&[1, 2, 3]);
        let c = ctx(&[9, 3, 1, 7]);
        // shared with prefix: {1,3}; aligned = [1,3] ++ [9,7]
        assert_eq!(align_to_prefix(&prefix, &c), ctx(&[1, 3, 9, 7]));
    }

    #[test]
    fn prefix_blocks_missing_from_context_are_not_invented() {
        let prefix = ctx(&[1, 2, 3]);
        let c = ctx(&[3, 5]);
        let out = align_to_prefix(&prefix, &c);
        assert_eq!(out, ctx(&[3, 5]));
    }

    #[test]
    fn order_annotation_only_when_reordered() {
        assert!(order_annotation(&ctx(&[1, 2]), &ctx(&[1, 2])).is_none());
        assert_eq!(
            order_annotation(&ctx(&[2, 1]), &ctx(&[1, 2])),
            Some(ctx(&[2, 1]))
        );
    }

    #[test]
    fn repeated_alignment_converges_to_shared_prefixes() {
        // many same-cluster contexts: after alignment they share prefixes
        let inputs: Vec<(RequestId, Context)> = vec![
            (RequestId(1), ctx(&[3, 1, 2])),
            (RequestId(2), ctx(&[1, 3, 5])),
        ];
        let mut r = build_clustered(&inputs, 0.001);
        let a1 = align_context(&mut r.index, &ctx(&[2, 3, 1]), RequestId(10));
        let a2 = align_context(&mut r.index, &ctx(&[3, 2, 1, 9]), RequestId(11));
        // both start with the same shared blocks
        assert_eq!(a1.aligned[0], a2.aligned[0]);
        r.index.check_invariants().unwrap();
    }
}
