//! Figure 7: performance breakdown — KV-cache hit ratio from baseline to
//! + aligning to + scheduling, under two engine cache configurations
//! (SGLang-like and vLLM-like capacities).

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_system, RunConfig, SystemKind};
use crate::pilot::PilotConfig;
use crate::util::table::Table;
use crate::workload::{multi_session, Dataset};

pub fn hit_ratios(
    sku: ModelSku,
    capacity: usize,
    sessions: usize,
) -> (f64, f64, f64) {
    let dataset = Dataset::MultihopRag;
    let corpus = corpus_for(dataset);
    let w = multi_session(dataset, sessions, 15, 0xF16);
    let mut cfg = RunConfig::for_dataset(sku, dataset);
    cfg.capacity_tokens = capacity;
    let base = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg).hit_ratio();
    let aligned = run_system(
        &SystemKind::ContextPilot(PilotConfig::with(true, true, false, false)),
        &w,
        &corpus,
        &cfg,
    )
    .hit_ratio();
    let scheduled = run_system(
        &SystemKind::ContextPilot(PilotConfig::with(true, true, false, true)),
        &w,
        &corpus,
        &cfg,
    )
    .hit_ratio();
    (base, aligned, scheduled)
}

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 150 } else { 600 };
    let mut t = Table::new(
        "Fig. 7 — Hit-ratio breakdown: baseline -> +aligning -> +scheduling (MultihopRAG, k=15)",
        &["Engine config", "Model", "Baseline", "+ Aligning", "+ Scheduling"],
    );
    for (engine, sku, cap) in [
        ("SGLang-like", ModelSku::Qwen3_32B, 45_000usize),
        ("vLLM-like", ModelSku::Llama33_70B, 60_000),
    ] {
        let (b, a, s) = hit_ratios(sku, cap, sessions);
        t.row(vec![
            engine.into(),
            sku.name().into(),
            format!("{:.2}%", b * 100.0),
            format!("{:.2}%", a * 100.0),
            format!("{:.2}%", s * 100.0),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn each_component_adds_hit_ratio() {
        let (b, a, s) = hit_ratios(ModelSku::Qwen3_32B, 45_000, 150);
        assert!(a > b, "aligning did not help: {a} <= {b}");
        assert!(s >= a, "scheduling hurt: {s} < {a}");
        assert!(s > 2.0 * b, "total gain too small: {s} vs {b}");
    }
}
