//! Figure 8: prefill throughput under different top-k values
//! (k in {3,5,10,15}) on NarrativeQA and MultihopRAG — ContextPilot's
//! advantage grows with context length.

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_system, RunConfig, SystemKind};
use crate::util::table::Table;
use crate::workload::{multi_session, Dataset};

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 100 } else { 400 };
    let ks = [3usize, 5, 10, 15];
    let mut tables = Vec::new();
    for dataset in [Dataset::MultihopRag, Dataset::NarrativeQa] {
        let corpus = corpus_for(dataset);
        let mut t = Table::new(
            &format!("Fig. 8 — Prefill throughput (tok/s) vs top-k, {}", dataset.name()),
            &["System", "k=3", "k=5", "k=10", "k=15"],
        );
        for system in SystemKind::all_default() {
            let mut cells = vec![system.name().to_string()];
            for &k in &ks {
                let w = multi_session(dataset, sessions, k, 0xF18 + k as u64);
                let cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);
                let m = run_system(&system, &w, &corpus, &cfg);
                cells.push(format!("{:.0}", m.prefill_throughput()));
            }
            t.row(cells);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::PilotConfig;

    #[test]
    fn pilot_wins_at_every_k() {
        let dataset = Dataset::MultihopRag;
        let corpus = corpus_for(dataset);
        for k in [3usize, 15] {
            let w = multi_session(dataset, 80, k, 0xF18 + k as u64);
            let cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);
            let tp_pilot = run_system(
                &SystemKind::ContextPilot(PilotConfig::default()),
                &w,
                &corpus,
                &cfg,
            )
            .prefill_throughput();
            let tp_radix =
                run_system(&SystemKind::RadixCache, &w, &corpus, &cfg).prefill_throughput();
            assert!(tp_pilot > tp_radix, "k={k}: {tp_pilot} <= {tp_radix}");
        }
    }
}
