//! Figure 12 (App. D.1): cache hit ratio over workload progress —
//! ContextPilot sustains a ~5× hit-ratio advantage throughout execution
//! (not a warm-up transient).

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_system, RunConfig, SystemKind};
use crate::metrics::RunMetrics;
use crate::pilot::PilotConfig;
use crate::util::table::Table;
use crate::workload::{multi_session, Dataset};

pub fn series(sku: ModelSku, sessions: usize) -> (RunMetrics, RunMetrics) {
    let dataset = Dataset::MultihopRag;
    let corpus = corpus_for(dataset);
    let w = multi_session(dataset, sessions, 15, 0xF12);
    let mut cfg = RunConfig::for_dataset(sku, dataset);
    cfg.capacity_tokens = 45_000;
    let base = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg);
    let pilot = run_system(
        &SystemKind::ContextPilot(PilotConfig::default()),
        &w,
        &corpus,
        &cfg,
    );
    (base, pilot)
}

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 200 } else { 800 };
    let mut tables = Vec::new();
    for sku in [ModelSku::Llama33_70B, ModelSku::Qwen3_32B] {
        let (base, pilot) = series(sku, sessions);
        let mut t = Table::new(
            &format!("Fig. 12 — Cache hit ratio over progress, {}", sku.name()),
            &["Progress (reqs)", "Baseline", "ContextPilot"],
        );
        for (i, (x, y_pilot)) in pilot.hit_series.iter().enumerate() {
            let y_base = base.hit_series.get(i).map(|(_, y)| *y).unwrap_or(0.0);
            t.row(vec![
                format!("{x:.0}"),
                format!("{:.1}%", y_base * 100.0),
                format!("{:.1}%", y_pilot * 100.0),
            ]);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advantage_is_sustained_not_transient() {
        let (base, pilot) = series(ModelSku::Qwen3_32B, 240);
        // compare the back half of the series
        let half = pilot.hit_series.len() / 2;
        for (i, (_, p)) in pilot.hit_series.iter().enumerate().skip(half) {
            let b = base.hit_series[i].1;
            assert!(
                *p > b * 1.5,
                "advantage collapsed at sample {i}: pilot {p} vs base {b}"
            );
        }
    }
}
