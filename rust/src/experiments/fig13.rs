//! Figure 13 (App. D.1): cumulative cached tokens (radix-tree prefix
//! reuse) over workload progress — ContextPilot ~4× the baseline, with a
//! "w/o Scheduling" variant isolating Alg. 5's contribution.

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_system, RunConfig, SystemKind};
use crate::pilot::PilotConfig;
use crate::util::table::Table;
use crate::workload::{multi_session, Dataset};

pub fn cumulative(sku: ModelSku, sessions: usize) -> (u64, u64, u64) {
    let dataset = Dataset::MultihopRag;
    let corpus = corpus_for(dataset);
    let w = multi_session(dataset, sessions, 15, 0xF13);
    let mut cfg = RunConfig::for_dataset(sku, dataset);
    cfg.capacity_tokens = 45_000;
    let base = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg).total_cached_tokens;
    let no_sched = run_system(
        &SystemKind::ContextPilot(PilotConfig::with(true, true, false, false)),
        &w,
        &corpus,
        &cfg,
    )
    .total_cached_tokens;
    let full = run_system(
        &SystemKind::ContextPilot(PilotConfig::default()),
        &w,
        &corpus,
        &cfg,
    )
    .total_cached_tokens;
    (base, no_sched, full)
}

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 200 } else { 800 };
    let mut t = Table::new(
        "Fig. 13 — Cumulative cached tokens at completion (radix prefix reuse)",
        &["Model", "Baseline", "w/o Scheduling", "ContextPilot", "Pilot/Baseline"],
    );
    for sku in [ModelSku::Llama33_70B, ModelSku::Qwen3_32B] {
        let (b, ns, f) = cumulative(sku, sessions);
        t.row(vec![
            sku.name().into(),
            format!("{b}"),
            format!("{ns}"),
            format!("{f}"),
            format!("{:.2}x", f as f64 / b.max(1) as f64),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pilot_multiplies_cached_tokens() {
        let (b, ns, f) = cumulative(ModelSku::Qwen3_32B, 240);
        assert!(f > b * 2, "full pilot {f} vs baseline {b}");
        // scheduling helps under *tight* KV budgets; at this capacity it
        // must at least not lose more than noise (2%)
        assert!(
            f as f64 >= ns as f64 * 0.98,
            "scheduling lost tokens: {f} < {ns}"
        );
        assert!(ns > b, "alignment alone should beat baseline");
    }
}
