//! Table 1: reproducing the DEmO ordering study with newer models.
//! Modern LLMs show negligible ordering gaps even on datasets with large
//! gaps in the original study — the observation that makes alignment safe.

use crate::quality::ordering::demo_study;
use crate::util::table::{f1, Table};

pub fn run(quick: bool) -> Vec<Table> {
    let trials = if quick { 2_000 } else { 20_000 };
    let rows = demo_study(trials, 0xDE30);
    let mut t = Table::new(
        "Table 1 — DEmO ordering study (accuracy %, Random vs DEmO per era)",
        &["Dataset", "GPT-3.5 Random", "GPT-3.5 DEmO", "GPT-5.1 Random", "GPT-5.1 DEmO"],
    );
    let (mut a35r, mut a35d, mut a51r, mut a51d) = (0.0, 0.0, 0.0, 0.0);
    let n = rows.len() as f64;
    for (name, r35, d35, r51, d51) in &rows {
        t.row(vec![name.clone(), f1(*r35), f1(*d35), f1(*r51), f1(*d51)]);
        a35r += r35 / n;
        a35d += d35 / n;
        a51r += r51 / n;
        a51d += d51 / n;
    }
    t.row(vec!["Avg".into(), f1(a35r), f1(a35d), f1(a51r), f1(a51d)]);
    vec![t]
}

#[cfg(test)]
mod tests {
    #[test]
    fn modern_avg_gap_negligible() {
        let t = &super::run(true)[0];
        let avg = t.rows.last().unwrap();
        let r51: f64 = avg[3].parse().unwrap();
        let d51: f64 = avg[4].parse().unwrap();
        assert!((r51 - d51).abs() < 1.0, "modern avg gap: {r51} vs {d51}");
        let r35: f64 = avg[1].parse().unwrap();
        let d35: f64 = avg[2].parse().unwrap();
        assert!(d35 >= r35, "legacy DEmO should not lose to random");
    }
}
