//! Table 4: OpenClaw + engine with and without ContextPilot on claw-tasks
//! — prompt tokens, prefill latency and wall time (Avg + P99) for
//! document-analysis and coding workloads (single RTX 5090 profile).

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_system, RunConfig, SystemKind};
use crate::metrics::RunMetrics;
use crate::pilot::PilotConfig;
use crate::util::table::{f2, Table};
use crate::workload::{openclaw, Dataset};

struct Cells {
    tokens_avg: f64,
    tokens_p99: f64,
    prefill_avg: f64,
    prefill_p99: f64,
    wall_avg: f64,
    wall_p99: f64,
}

fn measure(m: &mut RunMetrics) -> Cells {
    Cells {
        tokens_avg: m.prompt_tokens.mean(),
        tokens_p99: m.prompt_tokens.p99(),
        prefill_avg: m.ttft.mean(),
        prefill_p99: m.ttft.p99(),
        wall_avg: m.wall.mean(),
        wall_p99: m.wall.p99(),
    }
}

fn delta(a: f64, b: f64) -> String {
    if b == 0.0 {
        "-".into()
    } else {
        format!("{:+.1}%", (a - b) / b * 100.0)
    }
}

pub fn run(quick: bool) -> Vec<Table> {
    let corpus = corpus_for(Dataset::ClawTasks);
    let mut t = Table::new(
        "Table 4 — OpenClaw agent pipeline with and without ContextPilot (claw-tasks)",
        &["Workload", "Metric", "Baseline Avg", "+Pilot Avg", "Δ Avg", "Baseline P99", "+Pilot P99", "Δ P99"],
    );
    for (label, tasks, turns, coding) in [
        ("Document Analysis", if quick { 12 } else { 60 }, if quick { 10 } else { 25 }, false),
        ("Coding", if quick { 4 } else { 10 }, if quick { 8 } else { 20 }, true),
    ] {
        let (w, decode) = openclaw(tasks, turns, 0xC1A3, coding);
        let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_4B_RTX5090, Dataset::ClawTasks);
        cfg.offline = false;
        cfg.capacity_tokens = 400_000;
        cfg.decode_override = Some(decode);
        // "Baseline" = the engine's own radix prefix cache without the proxy
        let mut base = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg);
        let mut pilot = run_system(
            &SystemKind::ContextPilot(PilotConfig::default()),
            &w,
            &corpus,
            &cfg,
        );
        let b = measure(&mut base);
        let p = measure(&mut pilot);
        t.row(vec![
            label.into(),
            "Prompt Tokens".into(),
            format!("{:.0}", b.tokens_avg),
            format!("{:.0}", p.tokens_avg),
            delta(p.tokens_avg, b.tokens_avg),
            format!("{:.0}", b.tokens_p99),
            format!("{:.0}", p.tokens_p99),
            delta(p.tokens_p99, b.tokens_p99),
        ]);
        t.row(vec![
            label.into(),
            "Prefill Latency (s)".into(),
            f2(b.prefill_avg),
            f2(p.prefill_avg),
            delta(p.prefill_avg, b.prefill_avg),
            f2(b.prefill_p99),
            f2(p.prefill_p99),
            delta(p.prefill_p99, b.prefill_p99),
        ]);
        t.row(vec![
            label.into(),
            "Wall Time (s)".into(),
            f2(b.wall_avg),
            f2(p.wall_avg),
            delta(p.wall_avg, b.wall_avg),
            f2(b.wall_p99),
            f2(p.wall_p99),
            delta(p.wall_p99, b.wall_p99),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pilot_reduces_tokens_and_prefill_more_than_wall_on_coding() {
        let corpus = corpus_for(Dataset::ClawTasks);
        let (w, decode) = openclaw(6, 10, 0xC1A3, true);
        let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_4B_RTX5090, Dataset::ClawTasks);
        cfg.offline = false;
        cfg.capacity_tokens = 400_000;
        cfg.decode_override = Some(decode);
        let mut base = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg);
        let mut pilot = run_system(
            &SystemKind::ContextPilot(PilotConfig::default()),
            &w,
            &corpus,
            &cfg,
        );
        // dedup cuts prompt tokens
        assert!(pilot.prompt_tokens.mean() < base.prompt_tokens.mean());
        let prefill_cut = 1.0 - pilot.ttft.mean() / base.ttft.mean();
        let wall_cut = 1.0 - pilot.wall.mean() / base.wall.mean();
        assert!(prefill_cut > 0.0);
        // coding is decode-dominated: wall savings < prefill savings
        assert!(wall_cut < prefill_cut, "wall {wall_cut} !< prefill {prefill_cut}");
    }
}
