//! Experiment reproductions — one module per paper table/figure, each
//! exposing `run(quick) -> Vec<Table>`. The `bench_*` targets are thin
//! wrappers; `quick=true` shrinks workload sizes for CI-speed runs while
//! preserving every qualitative claim (full sizes via `cargo bench` with
//! `CTXPILOT_FULL=1`).

pub mod runner;

pub mod appendix_f;
pub mod appendix_g;
pub mod capacity;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig7;
pub mod fig8;
pub mod table1;
pub mod table2;
pub mod table3a;
pub mod table3b;
pub mod table3c;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod table7;
pub mod table8;

pub use runner::{corpus_for, run_f1, run_system, serve_config, turn_waves, RunConfig, SystemKind};

/// Bench entry helper: true when CTXPILOT_FULL=1 (paper-scale sizes).
pub fn full_mode() -> bool {
    std::env::var("CTXPILOT_FULL").is_ok_and(|v| v == "1")
}
