//! Table 2: multi-session RAG — F1 (%) and prefill throughput for four
//! systems across three models on three datasets (k=15, offline mode).

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_f1, run_system, RunConfig, SystemKind};
use crate::util::table::{f1, Table};
use crate::workload::{multi_session, Dataset};

/// Paper baseline F1 anchors (the exact-reuse LMCache/RadixCache column).
pub fn baseline_f1(dataset: Dataset, sku: ModelSku) -> f64 {
    match (dataset, sku) {
        (Dataset::MultihopRag, ModelSku::Qwen3_4B) => 35.2,
        (Dataset::MultihopRag, ModelSku::Qwen3_32B) => 60.4,
        (Dataset::MultihopRag, ModelSku::Llama33_70B) => 62.9,
        (Dataset::NarrativeQa, ModelSku::Qwen3_4B) => 16.0,
        (Dataset::NarrativeQa, ModelSku::Qwen3_32B) => 28.4,
        (Dataset::NarrativeQa, ModelSku::Llama33_70B) => 37.8,
        (Dataset::Qasper, ModelSku::Qwen3_4B) => 27.9,
        (Dataset::Qasper, ModelSku::Qwen3_32B) => 36.0,
        (Dataset::Qasper, ModelSku::Llama33_70B) => 33.8,
        _ => 50.0,
    }
}

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 120 } else { 600 };
    let k = 15;
    let datasets = [Dataset::MultihopRag, Dataset::NarrativeQa, Dataset::Qasper];
    let models = [ModelSku::Qwen3_4B, ModelSku::Qwen3_32B, ModelSku::Llama33_70B];
    let mut t = Table::new(
        "Table 2 — Multi-session RAG: F1 (%) and prefill throughput (tok/s)",
        &["Dataset", "Model", "System", "F1", "Prefill TP", "Hit ratio"],
    );
    for dataset in datasets {
        let corpus = corpus_for(dataset);
        let w = multi_session(dataset, sessions, k, 0x7AB2);
        for sku in models {
            let cfg = RunConfig::for_dataset(sku, dataset);
            for system in SystemKind::all_default() {
                let m = run_system(&system, &w, &corpus, &cfg);
                let f = run_f1(&m, &w, &cfg, baseline_f1(dataset, sku));
                t.row(vec![
                    dataset.name().into(),
                    sku.name().into(),
                    system.name().into(),
                    f1(f),
                    format!("{:.0}", m.prefill_throughput()),
                    format!("{:.1}%", m.hit_ratio() * 100.0),
                ]);
            }
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_holds_on_multihop_32b() {
        // who wins: ContextPilot throughput > Radix & LMCache; CacheBlend F1 tanks.
        let dataset = Dataset::MultihopRag;
        let corpus = corpus_for(dataset);
        let w = multi_session(dataset, 80, 15, 1);
        let cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);
        let get = |s: &SystemKind| {
            let m = run_system(s, &w, &corpus, &cfg);
            let f = run_f1(&m, &w, &cfg, 60.4);
            (f, m.prefill_throughput())
        };
        let (f_pilot, tp_pilot) =
            get(&SystemKind::ContextPilot(crate::pilot::PilotConfig::default()));
        let (f_radix, tp_radix) = get(&SystemKind::RadixCache);
        let (f_blend, _) = get(&SystemKind::CacheBlend);
        let (_, tp_lm) = get(&SystemKind::LMCache);
        assert!(tp_pilot > tp_radix, "pilot TP {tp_pilot} <= radix {tp_radix}");
        assert!(tp_pilot > tp_lm);
        assert!(f_blend < f_radix - 4.0, "blend F1 {f_blend} vs radix {f_radix}");
        assert!(f_pilot > f_radix - 1.0, "pilot F1 {f_pilot} vs radix {f_radix}");
    }
}
