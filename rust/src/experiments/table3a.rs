//! Table 3a: multi-turn RAG (MT-RAG) — accuracy (%) and TTFT (s) for four
//! systems across three models. ContextPilot runs online with cold start;
//! de-duplication removes cross-turn redundancy. CacheBlend does not
//! support the thinking-mode 30B model (X in the paper).

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_f1, run_system, RunConfig, SystemKind};
use crate::util::table::{f2, Table};
use crate::workload::{multi_turn, Dataset};

fn baseline_acc(sku: ModelSku) -> f64 {
    match sku {
        ModelSku::Qwen3_4B => 62.56,
        ModelSku::Llama31_8B => 68.46,
        ModelSku::Qwen3_30BA3B => 75.12,
        _ => 60.0,
    }
}

pub fn run(quick: bool) -> Vec<Table> {
    let turns = if quick { 24 } else { 80 };
    let sessions = if quick { 4 } else { 10 };
    let models = [ModelSku::Qwen3_4B, ModelSku::Llama31_8B, ModelSku::Qwen3_30BA3B];
    let dataset = Dataset::MtRag;
    let corpus = corpus_for(dataset);
    let mut t = Table::new(
        "Table 3a — MT-RAG: accuracy (%) and TTFT (s)",
        &["System", "Model", "Acc", "TTFT"],
    );
    for sku in models {
        for system in SystemKind::all_default() {
            if matches!(system, SystemKind::CacheBlend) && sku == ModelSku::Qwen3_30BA3B {
                t.row(vec!["CacheBlend".into(), sku.name().into(), "X".into(), "X".into()]);
                continue;
            }
            let mut cfg = RunConfig::for_dataset(sku, dataset);
            cfg.offline = false; // online mode, cold start
            cfg.capacity_tokens = 200_000;
            // aggregate several independent conversations
            let mut acc_sum = 0.0;
            let mut ttft_sum = 0.0;
            for s in 0..sessions {
                let w = multi_turn(dataset, turns, 10, 0x3A + s as u64);
                let mut m = run_system(&system, &w, &corpus, &cfg);
                acc_sum += run_f1(&m, &w, &cfg, baseline_acc(sku));
                ttft_sum += m.mean_ttft();
            }
            t.row(vec![
                system.name().into(),
                sku.name().into(),
                f2(acc_sum / sessions as f64),
                f2(ttft_sum / sessions as f64),
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::PilotConfig;

    #[test]
    fn pilot_cuts_ttft_and_preserves_accuracy() {
        let dataset = Dataset::MtRag;
        let corpus = corpus_for(dataset);
        let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_4B, dataset);
        cfg.offline = false;
        let w = multi_turn(dataset, 24, 10, 0x3A);
        let mut pilot = run_system(
            &SystemKind::ContextPilot(PilotConfig::default()),
            &w,
            &corpus,
            &cfg,
        );
        let mut lm = run_system(&SystemKind::LMCache, &w, &corpus, &cfg);
        assert!(
            pilot.mean_ttft() < lm.mean_ttft(),
            "pilot {} >= lmcache {}",
            pilot.mean_ttft(),
            lm.mean_ttft()
        );
        // dedup shrinks prompts: fewer prompt tokens than baseline
        assert!(pilot.total_prompt_tokens < lm.total_prompt_tokens);
        assert!(pilot.mean_quality() > lm.mean_quality() - 0.03);
    }
}
