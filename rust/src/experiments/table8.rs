//! Table 8 (App. D.3): measured per-request overhead of ContextPilot's
//! components — search, alignment, de-duplication — over 2k requests at
//! k=15. These are *real* measurements of this implementation, the one
//! table where absolute numbers are directly comparable to the paper
//! (~0.7 ms total on an A6000-class host CPU).

use crate::align::align_context;
use crate::corpus::{Corpus, CorpusConfig};
use crate::dedup::{dedup_context, DedupConfig};
use crate::experiments::table3c::synth_contexts;
use crate::index::build::build_clustered;
use crate::index::DEFAULT_ALPHA;
use crate::tokenizer::Tokenizer;
use crate::types::{RequestId, SessionId};
use crate::util::table::Table;

pub struct Overheads {
    pub search_ms: f64,
    pub align_ms: f64,
    pub dedup_ms: f64,
}

pub fn measure(n_requests: usize, k: usize) -> Overheads {
    let base = synth_contexts(2_000, k, 0x0E81);
    let mut built = build_clustered(&base, DEFAULT_ALPHA);
    let queries = synth_contexts(n_requests, k, 0x0E82);
    let corpus = Corpus::generate(
        &CorpusConfig {
            n_docs: 650,
            ..Default::default()
        },
        &Tokenizer::default(),
    );

    // search
    let t0 = std::time::Instant::now();
    for (_, c) in &queries {
        std::hint::black_box(built.index.search(c));
    }
    let search_ms = t0.elapsed().as_secs_f64() * 1e3 / n_requests as f64;

    // alignment (search + reorder + insert)
    let t1 = std::time::Instant::now();
    for (i, (_, c)) in queries.iter().enumerate() {
        std::hint::black_box(align_context(
            &mut built.index,
            c,
            RequestId(1_000_000 + i as u64),
        ));
    }
    let align_ms = t1.elapsed().as_secs_f64() * 1e3 / n_requests as f64;

    // de-duplication (multi-turn: second turn against a seeded record)
    let dcfg = DedupConfig::default();
    for (i, (_, c)) in queries.iter().take(64).enumerate() {
        // seed conversation records
        dedup_context(&mut built.index, SessionId(i as u32), c, &corpus, &dcfg);
    }
    let t2 = std::time::Instant::now();
    for (i, (_, c)) in queries.iter().enumerate() {
        let session = SessionId((i % 64) as u32);
        std::hint::black_box(dedup_context(&mut built.index, session, c, &corpus, &dcfg));
    }
    let dedup_ms = t2.elapsed().as_secs_f64() * 1e3 / n_requests as f64;

    Overheads {
        search_ms,
        align_ms,
        dedup_ms,
    }
}

pub fn run(quick: bool) -> Vec<Table> {
    let n = if quick { 500 } else { 2_000 };
    let o = measure(n, 15);
    let mut t = Table::new(
        "Table 8 — Per-request overhead (ms), measured over real requests (k=15)",
        &["Component", "Latency (ms)", "Paper (ms)"],
    );
    t.row(vec!["Search".into(), format!("{:.3}", o.search_ms), "0.068".into()]);
    t.row(vec!["Alignment".into(), format!("{:.3}", o.align_ms), "0.047".into()]);
    t.row(vec![
        "De-duplication".into(),
        format!("{:.3}", o.dedup_ms),
        "0.600".into(),
    ]);
    t.row(vec![
        "Total".into(),
        format!("{:.3}", o.search_ms + o.align_ms + o.dedup_ms),
        "~0.7".into(),
    ]);
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_negligible_vs_prefill() {
        let o = measure(200, 15);
        let total = o.search_ms + o.align_ms + o.dedup_ms;
        // prefill of a 20k-token prompt on a 32B model is seconds; the
        // proxy must stay under ~5 ms/request even in debug-ish CI runs
        assert!(total < 5.0, "overhead {total} ms");
        assert!(o.search_ms > 0.0 && o.align_ms > 0.0 && o.dedup_ms > 0.0);
    }
}
