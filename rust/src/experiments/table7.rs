//! Table 7 (App. D.2): accuracy breakdown by component — alignment alone
//! costs ≤1% F1; adding annotations recovers it and gains on multi-hop;
//! scheduling does not affect accuracy.

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_f1, run_system, RunConfig, SystemKind};
use crate::experiments::table2::baseline_f1;
use crate::pilot::PilotConfig;
use crate::util::table::{f1, Table};
use crate::workload::{multi_session, Dataset};

pub fn configs() -> Vec<(&'static str, Option<PilotConfig>)> {
    vec![
        ("Baseline", None),
        ("+ Alignment", Some(PilotConfig::with(true, false, false, false))),
        ("+ Annotation", Some(PilotConfig::with(true, true, false, false))),
        ("+ Scheduling", Some(PilotConfig::with(true, true, false, true))),
    ]
}

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 100 } else { 400 };
    let mut t = Table::new(
        "Table 7 — Accuracy breakdown by component (F1 %)",
        &["Model", "Configuration", "MultihopRAG", "NarrativeQA"],
    );
    for sku in [ModelSku::Qwen3_32B, ModelSku::Qwen3_4B] {
        for (label, pc) in configs() {
            let mut cells = vec![sku.name().to_string(), label.to_string()];
            for dataset in [Dataset::MultihopRag, Dataset::NarrativeQa] {
                let corpus = corpus_for(dataset);
                let w = multi_session(dataset, sessions, 15, 0x7AB7);
                let cfg = RunConfig::for_dataset(sku, dataset);
                let system = match &pc {
                    None => SystemKind::RadixCache,
                    Some(p) => SystemKind::ContextPilot(p.clone()),
                };
                let m = run_system(&system, &w, &corpus, &cfg);
                cells.push(f1(run_f1(&m, &w, &cfg, baseline_f1(dataset, sku))));
            }
            t.row(cells);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn component_deltas_match_paper_shape() {
        let dataset = Dataset::MultihopRag;
        let corpus = corpus_for(dataset);
        let w = multi_session(dataset, 120, 15, 0x7AB7);
        let cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);
        let fb = baseline_f1(dataset, ModelSku::Qwen3_32B);
        let score = |system: &SystemKind| {
            let m = run_system(system, &w, &corpus, &cfg);
            run_f1(&m, &w, &cfg, fb)
        };
        let base = score(&SystemKind::RadixCache);
        let aligned = score(&SystemKind::ContextPilot(PilotConfig::with(
            true, false, false, false,
        )));
        let annotated = score(&SystemKind::ContextPilot(PilotConfig::with(
            true, true, false, false,
        )));
        let scheduled = score(&SystemKind::ContextPilot(PilotConfig::with(
            true, true, false, true,
        )));
        // alignment alone: small loss (<= ~1.5 F1)
        assert!(base - aligned < 1.5, "alignment cost {base} -> {aligned}");
        assert!(aligned <= base + 0.2);
        // annotations recover and improve on multi-hop
        assert!(annotated > aligned, "{annotated} !> {aligned}");
        assert!(annotated >= base, "{annotated} < baseline {base}");
        // scheduling leaves accuracy unchanged
        assert!((scheduled - annotated).abs() < 0.6);
    }
}
