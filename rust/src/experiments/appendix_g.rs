//! Appendix G: impact of prefix-cache size — larger KV budgets benefit
//! ContextPilot disproportionately because aligned contexts exploit the
//! extra capacity (A6000 48 GB -> H100 80 GB in the paper; here: token
//! budget sweep).

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_system, RunConfig, SystemKind};
use crate::pilot::PilotConfig;
use crate::util::table::Table;
use crate::workload::{multi_session, Dataset};

pub fn hit_at_capacity(capacity: usize, sessions: usize) -> (f64, f64) {
    let dataset = Dataset::MultihopRag;
    let corpus = corpus_for(dataset);
    let w = multi_session(dataset, sessions, 15, 0xA6);
    let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);
    cfg.capacity_tokens = capacity;
    let base = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg).hit_ratio();
    let pilot = run_system(
        &SystemKind::ContextPilot(PilotConfig::default()),
        &w,
        &corpus,
        &cfg,
    )
    .hit_ratio();
    (base, pilot)
}

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 150 } else { 600 };
    let mut t = Table::new(
        "Appendix G — Prefix-cache size impact on hit ratio (MultihopRAG)",
        &["KV budget (tokens)", "RadixCache", "ContextPilot", "Pilot gain"],
    );
    let caps = [20_000usize, 45_000, 80_000];
    let mut gains = Vec::new();
    for cap in caps {
        let (b, p) = hit_at_capacity(cap, sessions);
        gains.push(p - b);
        t.row(vec![
            format!("{cap}"),
            format!("{:.2}%", b * 100.0),
            format!("{:.2}%", p * 100.0),
            format!("{:+.2}pp", (p - b) * 100.0),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn larger_cache_widens_pilot_advantage() {
        let (b_small, p_small) = hit_at_capacity(20_000, 150);
        let (b_big, p_big) = hit_at_capacity(80_000, 150);
        assert!(p_big > p_small, "pilot should gain from capacity");
        let gain_small = p_small - b_small;
        let gain_big = p_big - b_big;
        assert!(
            gain_big > gain_small * 0.8,
            "advantage should persist: {gain_big} vs {gain_small}"
        );
    }
}
