//! Table 6: DeepSeek-R1 (671B MoE) on 16×/32×H20 — prefill throughput,
//! cache hit ratio and F1 with context-aware routing over engine workers.
//! Vanilla = round-robin placement, no rewriting; ContextPilot adds
//! alignment + context-aware placement (+ annotations for the full
//! system).
//!
//! Since the placement refactor this experiment runs on the production
//! serving stack behind [`crate::api::Server`]: each hardware "worker" of
//! the paper is one serving shard (its own context index, prefix cache
//! and engine), and the routing policy is the serving layer's
//! [`crate::serve::placement::PlacementPolicy`] — the same code path the
//! CLI's `--placement` flag exercises, not a bespoke router.

use crate::api::Server;
use crate::corpus::Corpus;
use crate::engine::costmodel::ModelSku;
use crate::engine::sim::ReusePolicy;
use crate::experiments::runner::{corpus_for, turn_waves};
use crate::pilot::PilotConfig;
use crate::quality::{to_f1, ModelEra, QualityModel};
use crate::serve::PlacementKind;
use crate::util::table::{f2, Table};
use crate::workload::{multi_session, Dataset, Workload};

struct Variant {
    label: &'static str,
    placement: PlacementKind,
    pilot: Option<PilotConfig>,
}

fn run_variant(
    v: &Variant,
    w: &Workload,
    corpus: &Corpus,
    sku: ModelSku,
    shards: usize,
    multi_hop: bool,
    baseline_f1: f64,
) -> (f64, f64, f64) {
    let server = Server::builder(sku)
        .shards(shards)
        .workers(shards)
        .capacity(120_000) // per shard, matching the old per-worker budget
        .reuse_policy(ReusePolicy::RadixPrefix)
        .pilot(v.pilot.clone())
        .era(ModelEra::Modern)
        .multi_hop(multi_hop)
        .decode_tokens(32)
        .placement(v.placement)
        .corpus(corpus.clone())
        .build()
        .expect("table6 serve config is valid");
    if v.pilot.is_some() {
        server.build_offline(&w.requests).expect("offline build");
    }
    for (i, j) in turn_waves(&w.requests) {
        server.serve_batch(&w.requests[i..j]).expect("serve wave");
    }
    let (metrics, _) = server.metrics().expect("metrics snapshot");
    let qm = QualityModel::new(ModelEra::Modern, multi_hop);
    let base_q: f64 = w
        .requests
        .iter()
        .map(|r| qm.score_baseline(r))
        .sum::<f64>()
        / w.requests.len() as f64;
    (
        metrics.prefill_throughput() * shards as f64, // shards prefill in parallel
        metrics.hit_ratio(),
        to_f1(metrics.mean_quality(), base_q, baseline_f1),
    )
}

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 100 } else { 400 };
    let mut t = Table::new(
        "Table 6 — DeepSeek-R1 (MoE) with context-aware routing",
        &["Dataset", "Method", "Hardware", "Prefill TP (tok/s)", "Cache Hit", "F1 (%)"],
    );
    let variants = [
        Variant {
            label: "Vanilla",
            placement: PlacementKind::RoundRobin,
            pilot: None,
        },
        Variant {
            label: "ContextPilot w/o Annotations",
            placement: PlacementKind::ContextAware,
            pilot: Some(PilotConfig::with(true, false, true, true)),
        },
        Variant {
            label: "ContextPilot (Ours)",
            placement: PlacementKind::ContextAware,
            pilot: Some(PilotConfig::default()),
        },
    ];
    for (dataset, baseline_f1) in [(Dataset::MultihopRag, 64.15), (Dataset::NarrativeQa, 40.20)] {
        let corpus = corpus_for(dataset);
        let w = multi_session(dataset, sessions, 15, 0xD5);
        let multi_hop = matches!(dataset, Dataset::MultihopRag);
        for v in &variants {
            for (sku, hw, shards) in [
                (ModelSku::DeepSeekR1_16xH20, "16xH20", 2usize),
                (ModelSku::DeepSeekR1_32xH20, "32xH20", 4usize),
            ] {
                let (tp, hit, f1v) =
                    run_variant(v, &w, &corpus, sku, shards, multi_hop, baseline_f1);
                t.row(vec![
                    dataset.name().into(),
                    v.label.into(),
                    hw.into(),
                    format!("{tp:.0}"),
                    format!("{:.1}%", hit * 100.0),
                    f2(f1v),
                ]);
            }
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pilot_raises_hit_ratio_and_throughput() {
        let dataset = Dataset::MultihopRag;
        let corpus = corpus_for(dataset);
        let w = multi_session(dataset, 80, 15, 0xD5);
        let vanilla = Variant {
            label: "v",
            placement: PlacementKind::RoundRobin,
            pilot: None,
        };
        let ours = Variant {
            label: "p",
            placement: PlacementKind::ContextAware,
            pilot: Some(PilotConfig::default()),
        };
        let (tp_v, hit_v, _) = run_variant(
            &vanilla, &w, &corpus, ModelSku::DeepSeekR1_16xH20, 2, true, 64.15,
        );
        let (tp_p, hit_p, f1_p) = run_variant(
            &ours, &w, &corpus, ModelSku::DeepSeekR1_16xH20, 2, true, 64.15,
        );
        assert!(hit_p > hit_v + 0.05, "hit {hit_p} vs {hit_v}");
        assert!(tp_p > tp_v, "tp {tp_p} vs {tp_v}");
        assert!(f1_p > 60.0);
    }

    #[test]
    fn context_aware_beats_session_hash_for_the_full_system() {
        // the §7.2 claim at the placement layer: with the same pilot and
        // the same 4-shard engine, context-aware placement strictly beats
        // blind session hashing on cached tokens
        let dataset = Dataset::MultihopRag;
        let corpus = corpus_for(dataset);
        let w = multi_session(dataset, 80, 15, 0xD5);
        let run = |placement: PlacementKind| {
            let v = Variant {
                label: "x",
                placement,
                pilot: Some(PilotConfig::default()),
            };
            run_variant(&v, &w, &corpus, ModelSku::DeepSeekR1_32xH20, 4, true, 64.15).1
        };
        let aware = run(PlacementKind::ContextAware);
        let hashed = run(PlacementKind::SessionHash);
        assert!(
            aware > hashed,
            "context-aware {aware} <= session-hash {hashed}"
        );
    }
}
