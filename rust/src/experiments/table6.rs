//! Table 6: DeepSeek-R1 (671B MoE) on 16×/32×H20 — prefill throughput,
//! cache hit ratio and F1 with context-aware routing over engine workers.
//! Vanilla = round-robin routing, no rewriting; ContextPilot adds
//! alignment + context-aware routing (+ annotations for the full system).

use crate::corpus::Corpus;
use crate::engine::costmodel::ModelSku;
use crate::engine::router::{RoutePolicy, Router};
use crate::engine::sim::ReusePolicy;
use crate::experiments::runner::corpus_for;
use crate::metrics::RunMetrics;
use crate::pilot::{ContextPilot, PilotConfig};
use crate::quality::{to_f1, ModelEra, QualityModel};
use crate::types::Prompt;
use crate::util::table::{f2, Table};
use crate::workload::{multi_session, Dataset, Workload};

struct Variant {
    label: &'static str,
    route: RoutePolicy,
    pilot: Option<PilotConfig>,
}

fn run_variant(
    v: &Variant,
    w: &Workload,
    corpus: &Corpus,
    sku: ModelSku,
    workers: usize,
    multi_hop: bool,
    baseline_f1: f64,
) -> (f64, f64, f64) {
    let qm = QualityModel::new(ModelEra::Modern, multi_hop);
    let mut router = Router::new(
        workers,
        sku.profile(),
        ReusePolicy::RadixPrefix,
        120_000,
        v.route,
    );
    let mut pilot = v.pilot.clone().map(|pc| {
        let mut p = ContextPilot::new(pc);
        p.build_offline(&w.requests);
        p
    });
    let mut metrics = RunMetrics::new();
    match &mut pilot {
        Some(p) => {
            let outputs = p.process_batch(&w.requests, corpus);
            for out in outputs {
                let (_, served, evicted) =
                    router.serve(&out.request, &out.prompt, corpus, &qm, 32);
                p.on_evict(&evicted);
                metrics.record(&served);
            }
        }
        None => {
            for r in &w.requests {
                let (_, served, _) = router.serve(r, &Prompt::baseline(r), corpus, &qm, 32);
                metrics.record(&served);
            }
        }
    }
    let base_q: f64 = w
        .requests
        .iter()
        .map(|r| qm.score_baseline(r))
        .sum::<f64>()
        / w.requests.len() as f64;
    (
        metrics.prefill_throughput() * workers as f64, // workers run in parallel
        metrics.hit_ratio(),
        to_f1(metrics.mean_quality(), base_q, baseline_f1),
    )
}

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 100 } else { 400 };
    let mut t = Table::new(
        "Table 6 — DeepSeek-R1 (MoE) with context-aware routing",
        &["Dataset", "Method", "Hardware", "Prefill TP (tok/s)", "Cache Hit", "F1 (%)"],
    );
    let variants = [
        Variant {
            label: "Vanilla",
            route: RoutePolicy::RoundRobin,
            pilot: None,
        },
        Variant {
            label: "ContextPilot w/o Annotations",
            route: RoutePolicy::ContextAware,
            pilot: Some(PilotConfig::with(true, false, true, true)),
        },
        Variant {
            label: "ContextPilot (Ours)",
            route: RoutePolicy::ContextAware,
            pilot: Some(PilotConfig::default()),
        },
    ];
    for (dataset, baseline_f1) in [(Dataset::MultihopRag, 64.15), (Dataset::NarrativeQa, 40.20)] {
        let corpus = corpus_for(dataset);
        let w = multi_session(dataset, sessions, 15, 0xD5);
        let multi_hop = matches!(dataset, Dataset::MultihopRag);
        for v in &variants {
            for (sku, hw, workers) in [
                (ModelSku::DeepSeekR1_16xH20, "16xH20", 2usize),
                (ModelSku::DeepSeekR1_32xH20, "32xH20", 4usize),
            ] {
                let (tp, hit, f1v) =
                    run_variant(v, &w, &corpus, sku, workers, multi_hop, baseline_f1);
                t.row(vec![
                    dataset.name().into(),
                    v.label.into(),
                    hw.into(),
                    format!("{tp:.0}"),
                    format!("{:.1}%", hit * 100.0),
                    f2(f1v),
                ]);
            }
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pilot_raises_hit_ratio_and_throughput() {
        let dataset = Dataset::MultihopRag;
        let corpus = corpus_for(dataset);
        let w = multi_session(dataset, 80, 15, 0xD5);
        let vanilla = Variant {
            label: "v",
            route: RoutePolicy::RoundRobin,
            pilot: None,
        };
        let ours = Variant {
            label: "p",
            route: RoutePolicy::ContextAware,
            pilot: Some(PilotConfig::default()),
        };
        let (tp_v, hit_v, _) = run_variant(
            &vanilla, &w, &corpus, ModelSku::DeepSeekR1_16xH20, 2, true, 64.15,
        );
        let (tp_p, hit_p, f1_p) = run_variant(
            &ours, &w, &corpus, ModelSku::DeepSeekR1_16xH20, 2, true, 64.15,
        );
        assert!(hit_p > hit_v + 0.1, "hit {hit_p} vs {hit_v}");
        assert!(tp_p > tp_v, "tp {tp_p} vs {tp_v}");
        assert!(f1_p > 60.0);
    }
}
