//! Table 3b: hybrid multi-session × multi-turn RAG — TTFT (s) vs
//! concurrency (2–32 sessions) for Qwen3-4B.

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_system, RunConfig, SystemKind};
use crate::util::table::{f2, Table};
use crate::workload::{hybrid, Dataset};

pub fn run(quick: bool) -> Vec<Table> {
    let turns = if quick { 4 } else { 8 };
    let dataset = Dataset::MtRag;
    let corpus = corpus_for(dataset);
    let session_counts = [2usize, 4, 8, 16, 32];
    let mut t = Table::new(
        "Table 3b — Hybrid RAG: TTFT (s) vs concurrent sessions (Qwen3-4B)",
        &["System", "2", "4", "8", "16", "32"],
    );
    for system in SystemKind::all_default() {
        let mut cells = vec![system.name().to_string()];
        for &s in &session_counts {
            let w = hybrid(dataset, s, turns, 10, 0xB0B + s as u64);
            let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_4B, dataset);
            cfg.offline = false;
            cfg.capacity_tokens = 40_000 + 4_000 * s; // scale KV budget w/ load
            let mut m = run_system(&system, &w, &corpus, &cfg);
            cells.push(f2(m.mean_ttft()));
        }
        t.row(cells);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::PilotConfig;

    #[test]
    fn pilot_lowest_ttft_at_low_and_high_concurrency() {
        let dataset = Dataset::MtRag;
        let corpus = corpus_for(dataset);
        for s in [2usize, 16] {
            let w = hybrid(dataset, s, 4, 10, 0xB0B + s as u64);
            let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_4B, dataset);
            cfg.offline = false;
            let mut pilot = run_system(
                &SystemKind::ContextPilot(PilotConfig::default()),
                &w,
                &corpus,
                &cfg,
            );
            let mut radix = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg);
            let mut lm = run_system(&SystemKind::LMCache, &w, &corpus, &cfg);
            assert!(pilot.mean_ttft() <= radix.mean_ttft() + 1e-9, "s={s}");
            assert!(pilot.mean_ttft() < lm.mean_ttft(), "s={s}");
        }
    }
}
