//! Figure 11 (App. C): document access distribution (CDF) across the
//! three RAG datasets — the top 20% most-accessed documents cover 79.2% /
//! 57.4% / 49.6% of retrieval events.

use crate::util::table::Table;
use crate::workload::access::AccessStats;
use crate::workload::{multi_session, Dataset, DatasetProfile};

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 400 } else { 2_000 };
    let mut t = Table::new(
        "Fig. 11 — Document access distribution: top-20% coverage vs paper",
        &["Dataset", "Top-20% coverage (sim)", "Paper"],
    );
    let mut cdf_t = Table::new(
        "Fig. 11 — Access CDF points (doc fraction -> access fraction)",
        &["Dataset", "10%", "20%", "40%", "60%", "80%", "100%"],
    );
    for dataset in [Dataset::MultihopRag, Dataset::NarrativeQa, Dataset::Qasper] {
        let p = DatasetProfile::get(dataset);
        let w = multi_session(dataset, sessions, p.k, 0xF11);
        let s = AccessStats::from_workload(&w);
        t.row(vec![
            dataset.name().into(),
            format!("{:.1}%", s.top_coverage(0.2) * 100.0),
            format!("{:.1}%", p.top20_mass * 100.0),
        ]);
        let cdf = s.cdf(10);
        let at = |frac: f64| {
            cdf.iter()
                .find(|(x, _)| *x >= frac - 1e-9)
                .map(|(_, y)| format!("{:.1}%", y * 100.0))
                .unwrap_or_default()
        };
        cdf_t.row(vec![
            dataset.name().into(),
            at(0.1),
            at(0.2),
            at(0.4),
            at(0.6),
            at(0.8),
            at(1.0),
        ]);
    }
    vec![t, cdf_t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_of_datasets_matches_paper() {
        let sessions = 400;
        let cov = |d: Dataset| {
            let p = DatasetProfile::get(d);
            let w = multi_session(d, sessions, p.k, 0xF11);
            AccessStats::from_workload(&w).top_coverage(0.2)
        };
        let mh = cov(Dataset::MultihopRag);
        let nq = cov(Dataset::NarrativeQa);
        let qa = cov(Dataset::Qasper);
        assert!(mh > nq && nq > qa, "{mh} {nq} {qa}");
    }
}
