//! Table 5: edge devices — Llama-3.2-1B with llama.cpp (batch size 1) on
//! an M3 MacBook Air and a Jetson AGX Orin, MultihopRAG. ContextPilot's
//! context reduction translates directly to wall-clock savings on slow
//! edge prefill.

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_system, RunConfig, SystemKind};
use crate::pilot::PilotConfig;
use crate::util::table::{f2, Table};
use crate::workload::{multi_session, Dataset};

pub fn run(quick: bool) -> Vec<Table> {
    let sessions = if quick { 40 } else { 200 };
    let dataset = Dataset::MultihopRag;
    let corpus = corpus_for(dataset);
    let w = multi_session(dataset, sessions, 8, 0xED6E);
    let mut t = Table::new(
        "Table 5 — Edge devices: avg prefill latency (s), MultihopRAG, bs=1",
        &["Device", "Method", "Avg Latency (s)"],
    );
    for sku in [ModelSku::Edge1B_M3Air, ModelSku::Edge1B_Jetson] {
        let mut cfg = RunConfig::for_dataset(sku, dataset);
        cfg.capacity_tokens = 30_000; // small edge KV budget
        let mut base = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg);
        let mut pilot = run_system(
            &SystemKind::ContextPilot(PilotConfig::default()),
            &w,
            &corpus,
            &cfg,
        );
        t.row(vec![sku.name().into(), "llama.cpp".into(), f2(base.mean_ttft())]);
        t.row(vec![
            sku.name().into(),
            "+ ContextPilot".into(),
            f2(pilot.mean_ttft()),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pilot_speedup_in_paper_range() {
        // paper: 1.5-2.4x latency reduction on edge
        let dataset = Dataset::MultihopRag;
        let corpus = corpus_for(dataset);
        let w = multi_session(dataset, 60, 8, 0xED6E);
        let mut cfg = RunConfig::for_dataset(ModelSku::Edge1B_M3Air, dataset);
        cfg.capacity_tokens = 30_000;
        let mut base = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg);
        let mut pilot = run_system(
            &SystemKind::ContextPilot(PilotConfig::default()),
            &w,
            &corpus,
            &cfg,
        );
        let speedup = base.mean_ttft() / pilot.mean_ttft();
        assert!(speedup > 1.1, "edge speedup {speedup}");
    }
}
