//! Appendix F: system overhead with zero context overlap — the worst case
//! for ContextPilot. With disjoint retrievals there is no reuse benefit;
//! the whole pipeline must add only sub-second total overhead per 1k
//! contexts (the paper: 0.72 s of added prefill latency for 1k contexts).

use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_system, RunConfig, SystemKind};
use crate::pilot::PilotConfig;
use crate::util::bench::time_once;
use crate::util::table::Table;
use crate::workload::{zero_overlap, Dataset};

/// (baseline wall, pilot wall, baseline ttft sum, pilot ttft sum)
pub fn measure(n: usize) -> (f64, f64, f64, f64) {
    let corpus = corpus_for(Dataset::Qasper); // 1585 docs => room for disjoint sets
    let w = zero_overlap(n, 5, 1_500, 0xAF);
    let cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, Dataset::Qasper);
    let (m_base, t_base) = time_once(|| run_system(&SystemKind::RadixCache, &w, &corpus, &cfg));
    let (m_pilot, t_pilot) = time_once(|| {
        run_system(
            &SystemKind::ContextPilot(PilotConfig::default()),
            &w,
            &corpus,
            &cfg,
        )
    });
    (
        t_base,
        t_pilot,
        m_base.total_prefill_seconds,
        m_pilot.total_prefill_seconds,
    )
}

pub fn run(quick: bool) -> Vec<Table> {
    let n = if quick { 300 } else { 1_000 };
    let (wall_b, wall_p, ttft_b, ttft_p) = measure(n);
    let mut t = Table::new(
        "Appendix F — Zero-overlap worst case: pure ContextPilot overhead",
        &["Metric", "Baseline", "+ ContextPilot", "Added"],
    );
    t.row(vec![
        format!("Harness wall time for {n} contexts (s)"),
        format!("{wall_b:.2}"),
        format!("{wall_p:.2}"),
        format!("{:+.2}", wall_p - wall_b),
    ]);
    t.row(vec![
        "Simulated prefill latency sum (s)".into(),
        format!("{ttft_b:.2}"),
        format!("{ttft_p:.2}"),
        format!("{:+.2}", ttft_p - ttft_b),
    ]);
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_is_bounded() {
        let (wall_b, wall_p, ttft_b, ttft_p) = measure(200);
        // pipeline overhead bound (loose: unit tests run unoptimized; the
        // release-mode number reported by bench_appendix_f is ~100x lower)
        assert!(wall_p - wall_b < 6.0, "wall overhead {}", wall_p - wall_b);
        // simulated prefill must not regress materially (annotations add
        // a few tokens; allow 2%)
        assert!(ttft_p < ttft_b * 1.02 + 0.05, "{ttft_p} vs {ttft_b}");
    }
}
