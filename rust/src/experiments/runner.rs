//! Experiment driver: runs one (system, workload) pair through the
//! serving pipeline and collects metrics. Every bench table is produced
//! through this harness so systems differ *only* in their mechanism.
//!
//! Since the engine-generic refactor this module owns **no serve loop of
//! its own**: [`run_system`] maps its [`RunConfig`] onto a single-shard,
//! single-worker [`crate::api::Server`] and submits one batch per
//! arrival wave. The sequential path therefore *is* the sharded path at
//! n = 1 — baseline LPM ordering, Alg.-5 scheduling, §4.1 eviction
//! plumbing and metrics all live in one place (behind [`crate::api`]).

use std::collections::HashMap;

use crate::api::ServerBuilder;
use crate::cache::TierConfig;
use crate::corpus::{Corpus, CorpusConfig};
use crate::engine::costmodel::ModelSku;
use crate::engine::sim::ReusePolicy;
use crate::metrics::RunMetrics;
use crate::pilot::PilotConfig;
use crate::quality::{ModelEra, QualityModel};
use crate::serve::ServeConfig;
use crate::tokenizer::Tokenizer;
use crate::types::{Request, RequestId};
use crate::workload::{Dataset, DatasetProfile, Workload};

/// The four systems of §7.
#[derive(Clone, Debug)]
pub enum SystemKind {
    LMCache,
    CacheBlend,
    RadixCache,
    ContextPilot(PilotConfig),
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::LMCache => "LMCache",
            SystemKind::CacheBlend => "CacheBlend",
            SystemKind::RadixCache => "RadixCache",
            SystemKind::ContextPilot(_) => "ContextPilot",
        }
    }

    pub fn all_default() -> Vec<SystemKind> {
        vec![
            SystemKind::LMCache,
            SystemKind::CacheBlend,
            SystemKind::RadixCache,
            SystemKind::ContextPilot(PilotConfig::default()),
        ]
    }

    /// The engine reuse mechanism this system runs on (also consumed by
    /// the sharded serving path in `main.rs` / [`crate::serve`]).
    pub fn reuse_policy(&self) -> ReusePolicy {
        match self {
            // LMCache: document-granular exact matching + CPU-offload cost
            SystemKind::LMCache => ReusePolicy::DocPrefix {
                offload_s_per_tok: 6e-6,
            },
            // CacheBlend: approximate KV matching, 15% recompute, with the
            // §2.3 accuracy degradation
            SystemKind::CacheBlend => ReusePolicy::Approximate {
                recompute_frac: 0.15,
                kv_noise: 0.17,
            },
            SystemKind::RadixCache => ReusePolicy::RadixPrefix,
            SystemKind::ContextPilot(_) => ReusePolicy::RadixPrefix,
        }
    }

    /// The proxy configuration this system runs with (`None` = baseline
    /// prompts, engine-only).
    pub fn pilot_config(&self) -> Option<PilotConfig> {
        match self {
            SystemKind::ContextPilot(pc) => Some(pc.clone()),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub sku: ModelSku,
    /// Prefix-cache capacity in tokens (the KV budget knob of Fig. 6/App. G).
    pub capacity_tokens: usize,
    pub decode_tokens: usize,
    /// Offline mode: pre-build the context index over the whole workload.
    pub offline: bool,
    pub era: ModelEra,
    pub multi_hop: bool,
    /// Per-request decode override (OpenClaw traces), indexed by workload
    /// position.
    pub decode_override: Option<Vec<usize>>,
    /// DRAM/SSD tier store behind the radix cache (`None` = discard-mode
    /// eviction — the pre-tiering behaviour every table defaults to).
    pub tiers: Option<TierConfig>,
}

impl RunConfig {
    pub fn for_dataset(sku: ModelSku, dataset: Dataset) -> RunConfig {
        RunConfig {
            sku,
            capacity_tokens: 60_000,
            decode_tokens: 48,
            offline: true,
            era: ModelEra::Modern,
            multi_hop: matches!(dataset, Dataset::MultihopRag),
            decode_override: None,
            tiers: None,
        }
    }
}

/// Map an experiment run onto the serving layer: one shard, one worker —
/// the sequential pipeline is literally the sharded pipeline at n = 1.
/// Position-indexed decode overrides are rekeyed by request id (the
/// generators guarantee ids are unique per workload).
pub fn serve_config(system: &SystemKind, workload: &Workload, cfg: &RunConfig) -> ServeConfig {
    let mut s = ServeConfig::new(cfg.sku);
    s.n_shards = 1;
    s.n_workers = 1;
    s.capacity_tokens = cfg.capacity_tokens;
    s.policy = system.reuse_policy();
    s.pilot = system.pilot_config();
    s.era = cfg.era;
    s.multi_hop = cfg.multi_hop;
    s.decode_tokens = cfg.decode_tokens;
    s.decode_override = cfg.decode_override.as_ref().map(|v| {
        workload
            .requests
            .iter()
            .enumerate()
            .map(|(i, r)| (r.id, v.get(i).copied().unwrap_or(cfg.decode_tokens)))
            .collect::<HashMap<RequestId, usize>>()
    });
    s.tiers = cfg.tiers.clone();
    s
}

/// Split a request sequence into its arrival waves — maximal consecutive
/// runs of the same turn number (the structure the generators emit).
/// Returns `(start, end)` index ranges. Shared by the sequential runner
/// and the sharded CLI path so both batch identically.
pub fn turn_waves(requests: &[Request]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < requests.len() {
        let turn = requests[i].turn;
        let mut j = i;
        while j < requests.len() && requests[j].turn == turn {
            j += 1;
        }
        out.push((i, j));
        i = j;
    }
    out
}

/// Corpus matching a dataset profile.
pub fn corpus_for(dataset: Dataset) -> Corpus {
    let p = DatasetProfile::get(dataset);
    Corpus::generate(
        &CorpusConfig {
            n_docs: p.n_docs,
            lines_per_doc: p.doc_lines,
            ..Default::default()
        },
        &Tokenizer::default(),
    )
}

/// Run a workload through a system; returns the metrics.
///
/// Experiment configs are static and known-valid, and the harness has no
/// error channel of its own, so facade errors (which can only be poisoned
/// locks here) abort the run with a message instead of propagating.
pub fn run_system(
    system: &SystemKind,
    workload: &Workload,
    corpus: &Corpus,
    cfg: &RunConfig,
) -> RunMetrics {
    let server = ServerBuilder::from_config(serve_config(system, workload, cfg))
        .corpus(corpus.clone())
        .build()
        .expect("experiment serve config is valid");
    if cfg.offline {
        server
            .build_offline(&workload.requests)
            .expect("offline index build");
    }
    // batches = arrival waves (consecutive same-turn runs)
    for (i, j) in turn_waves(&workload.requests) {
        server
            .serve_batch(&workload.requests[i..j])
            .expect("serve wave");
    }
    server.metrics().expect("metrics snapshot").0
}

/// Baseline-anchored F1 for a run: anchor = the RadixCache/LMCache prompt
/// (exact prefix reuse, unmodified order).
pub fn run_f1(
    metrics: &RunMetrics,
    workload: &Workload,
    cfg: &RunConfig,
    baseline_f1: f64,
) -> f64 {
    let qm = QualityModel::new(cfg.era, cfg.multi_hop);
    let base_q: f64 = workload
        .requests
        .iter()
        .map(|r| qm.score_baseline(r))
        .sum::<f64>()
        / workload.requests.len().max(1) as f64;
    crate::quality::to_f1(metrics.mean_quality(), base_q, baseline_f1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::multi_session;

    fn quick_run(system: SystemKind) -> RunMetrics {
        let dataset = Dataset::MultihopRag;
        let w = multi_session(dataset, 60, 10, 7);
        let corpus = corpus_for(dataset);
        let cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);
        run_system(&system, &w, &corpus, &cfg)
    }

    #[test]
    fn pilot_beats_radix_on_hit_ratio() {
        let pilot = quick_run(SystemKind::ContextPilot(PilotConfig::default()));
        let radix = quick_run(SystemKind::RadixCache);
        assert!(
            pilot.hit_ratio() > radix.hit_ratio(),
            "pilot {} <= radix {}",
            pilot.hit_ratio(),
            radix.hit_ratio()
        );
    }

    #[test]
    fn pilot_throughput_exceeds_lmcache() {
        let pilot = quick_run(SystemKind::ContextPilot(PilotConfig::default()));
        let lm = quick_run(SystemKind::LMCache);
        assert!(pilot.prefill_throughput() > lm.prefill_throughput());
    }

    #[test]
    fn cacheblend_degrades_quality() {
        let blend = quick_run(SystemKind::CacheBlend);
        let radix = quick_run(SystemKind::RadixCache);
        assert!(blend.mean_quality() < radix.mean_quality() - 0.05);
    }

    #[test]
    fn pilot_quality_close_to_exact_baseline() {
        let pilot = quick_run(SystemKind::ContextPilot(PilotConfig::default()));
        let radix = quick_run(SystemKind::RadixCache);
        assert!(
            pilot.mean_quality() > radix.mean_quality() - 0.02,
            "pilot {} vs radix {}",
            pilot.mean_quality(),
            radix.mean_quality()
        );
    }

    #[test]
    fn all_systems_complete_runs() {
        for s in SystemKind::all_default() {
            let m = quick_run(s.clone());
            assert_eq!(m.len(), 60, "{}", s.name());
            assert!(m.prefill_throughput() > 0.0);
        }
    }

    #[test]
    fn decode_override_is_rekeyed_by_request_id() {
        let dataset = Dataset::MultihopRag;
        let w = multi_session(dataset, 10, 5, 3);
        let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_4B, dataset);
        cfg.decode_override = Some((0..w.len()).map(|i| 4 + i).collect());
        let scfg = serve_config(&SystemKind::RadixCache, &w, &cfg);
        let map = scfg.decode_override.expect("override mapped");
        assert_eq!(map.len(), w.len());
        for (i, r) in w.requests.iter().enumerate() {
            assert_eq!(map[&r.id], 4 + i);
        }
        assert_eq!(scfg.n_shards, 1);
        assert_eq!(scfg.n_workers, 1);
        assert!(scfg.pilot.is_none());
    }
}
