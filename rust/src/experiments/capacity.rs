//! Capacity-pressure table (tier-store subsystem): KV reuse and TTFT vs
//! HBM budget, discard-mode vs demote-mode eviction.
//!
//! The paper's capacity axis (Fig. 6 / App. G) only sweeps how much fits
//! in HBM; this table opens the axis the tier store adds — what happens
//! to the reuse that *doesn't* fit. Under pressure (HBM below the
//! workload's working set), discard-mode eviction forfeits every
//! recurring prefix while demote-mode recovers it from DRAM/SSD at reload
//! cost: strictly more total reuse (hot+warm+cold) and strictly lower
//! modeled TTFT, converging to identical results once HBM is roomy enough
//! that nothing evicts. Run sequentially (1 shard, 1 worker), baseline
//! RadixCache system, so the two modes face byte-identical schedules and
//! the comparison isolates the eviction policy.

use crate::cache::TierConfig;
use crate::engine::costmodel::ModelSku;
use crate::experiments::runner::{corpus_for, run_system, RunConfig, SystemKind};
use crate::metrics::RunMetrics;
use crate::util::table::Table;
use crate::workload::{hybrid, Dataset};

/// One sweep cell: the MT-RAG hybrid workload through a RadixCache
/// baseline at the given HBM budget, with (`tiered`) or without a
/// DRAM/SSD store behind it. Tier budgets scale with HBM (4x / 16x).
pub fn pressure_run(hbm: usize, tiered: bool, sessions: usize, turns: usize) -> RunMetrics {
    let dataset = Dataset::MtRag;
    let corpus = corpus_for(dataset);
    let w = hybrid(dataset, sessions, turns, 8, 0x71E55);
    let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);
    cfg.offline = false;
    cfg.capacity_tokens = hbm;
    cfg.tiers = tiered.then(|| TierConfig::new(4 * hbm, 16 * hbm));
    run_system(&SystemKind::RadixCache, &w, &corpus, &cfg)
}

pub fn run(quick: bool) -> Vec<Table> {
    let (sessions, turns) = if quick { (16, 3) } else { (64, 4) };
    let mut t = Table::new(
        &format!(
            "Capacity pressure — reuse + TTFT vs HBM budget, discard vs demote \
             (MT-RAG hybrid, {sessions} sessions x {turns} turns, RadixCache)"
        ),
        &[
            "HBM (tokens)",
            "Discard reuse",
            "Demote reuse (hot/warm/cold)",
            "Discard mean TTFT",
            "Demote mean TTFT",
            "TTFT saved",
        ],
    );
    for hbm in [2_000usize, 8_000, 128_000] {
        let mut discard = pressure_run(hbm, false, sessions, turns);
        let mut demote = pressure_run(hbm, true, sessions, turns);
        let d_ttft = discard.mean_ttft();
        let m_ttft = demote.mean_ttft();
        t.row(vec![
            format!("{hbm}"),
            format!("{:.1}%", discard.hit_ratio() * 100.0),
            format!(
                "{:.1}% ({}/{}/{})",
                demote.hit_ratio() * 100.0,
                demote.total_hot_hit_tokens,
                demote.total_warm_hit_tokens,
                demote.total_cold_hit_tokens
            ),
            format!("{d_ttft:.4}s"),
            format!("{m_ttft:.4}s"),
            format!("{:+.1}%", (d_ttft - m_ttft) / d_ttft.max(1e-12) * 100.0),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pressure_makes_demotion_strictly_better() {
        // HBM far below the working set: recurring session prefixes are
        // evicted between turns, so demote-mode must recover reuse the
        // discard baseline forfeits — and pay less than recompute for it
        let mut discard = pressure_run(2_000, false, 12, 3);
        let mut demote = pressure_run(2_000, true, 12, 3);
        assert!(
            demote.total_cached_tokens > discard.total_cached_tokens,
            "demote reuse {} <= discard reuse {}",
            demote.total_cached_tokens,
            discard.total_cached_tokens
        );
        assert!(
            demote.total_warm_hit_tokens + demote.total_cold_hit_tokens > 0,
            "pressure must trigger promotions"
        );
        assert_eq!(
            demote.total_hot_hit_tokens, discard.total_cached_tokens,
            "tiering must not change hot-tier behaviour"
        );
        assert!(
            demote.mean_ttft() < discard.mean_ttft(),
            "cost-gated promotion must lower TTFT: {} vs {}",
            demote.mean_ttft(),
            discard.mean_ttft()
        );
    }

    #[test]
    fn roomy_hbm_makes_modes_identical() {
        // nothing evicts -> nothing demotes -> the tier store is inert
        let mut discard = pressure_run(1 << 20, false, 12, 3);
        let mut demote = pressure_run(1 << 20, true, 12, 3);
        assert_eq!(demote.total_cached_tokens, discard.total_cached_tokens);
        assert_eq!(demote.total_warm_hit_tokens, 0);
        assert_eq!(demote.total_cold_hit_tokens, 0);
        assert!((demote.mean_ttft() - discard.mean_ttft()).abs() < 1e-12);
    }
}
