//! Table 3c: context-index construction latency (s) as a function of the
//! number of contexts N_ctx and retrieval depth k.
//!
//! Up to 12k contexts we run the paper's O(N^2) hierarchical clustering on
//! CPU threads (the paper's CPU number: 8 s at 2k). The 100k column uses
//! GPU in the paper; we report the incremental (search+insert) build as
//! the CPU-feasible equivalent and mark it with '*' (EXPERIMENTS.md).

use crate::index::build::build_clustered;
use crate::index::tree::ContextIndex;
use crate::index::DEFAULT_ALPHA;
use crate::types::{Context, RequestId};
use crate::util::bench::time_once;
use crate::util::prng::Rng;
use crate::util::table::Table;
use crate::workload::{DatasetProfile, Retriever};

/// Synthesize N contexts of depth k with realistic overlap.
pub fn synth_contexts(n: usize, k: usize, seed: u64) -> Vec<(RequestId, Context)> {
    let retriever = Retriever::new(DatasetProfile::get(crate::workload::Dataset::MultihopRag));
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let topic = retriever.sample_topic(&mut rng);
            (RequestId(i as u64), retriever.retrieve(topic, k, &mut rng))
        })
        .collect()
}

/// Incremental build: search + insert per context (the online path).
pub fn build_incremental(inputs: &[(RequestId, Context)], alpha: f64) -> ContextIndex {
    let mut ix = ContextIndex::new(alpha);
    for (req, ctx) in inputs {
        let found = ix.search(ctx);
        ix.insert_at(&found, ctx.clone(), *req);
    }
    ix
}

pub fn run(quick: bool) -> Vec<Table> {
    let sizes: Vec<usize> = if quick {
        vec![128, 512, 2_000]
    } else {
        vec![128, 512, 4_000, 8_000, 12_000]
    };
    let ks = [3usize, 5, 10, 15, 20];
    let mut t = Table::new(
        "Table 3c — Context index construction latency (s) vs N_ctx and k (CPU, clustered)",
        &{
            let mut h = vec!["k"];
            let labels: Vec<String> = sizes.iter().map(|s| s.to_string()).collect();
            let leaked: Vec<&str> = labels
                .iter()
                .map(|s| Box::leak(s.clone().into_boxed_str()) as &str)
                .collect();
            h.extend(leaked);
            h.push("100k (incremental*)");
            h
        },
    );
    for &k in &ks {
        let mut cells = vec![k.to_string()];
        for &n in &sizes {
            let inputs = synth_contexts(n, k, 0xC0 + n as u64);
            let (_, secs) = time_once(|| build_clustered(&inputs, DEFAULT_ALPHA));
            cells.push(format!("{secs:.2}"));
        }
        // 100k column: incremental
        let n100 = if quick { 10_000 } else { 100_000 };
        let inputs = synth_contexts(n100, k, 0x100);
        let (_, secs) = time_once(|| build_incremental(&inputs, DEFAULT_ALPHA));
        cells.push(format!("{secs:.2} ({n100})"));
        t.row(cells);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incremental_build_is_consistent() {
        let inputs = synth_contexts(300, 10, 1);
        let ix = build_incremental(&inputs, DEFAULT_ALPHA);
        ix.check_invariants().unwrap();
        assert!(ix.len_alive() > 300); // leaves + virtual nodes
    }

    #[test]
    fn construction_scales_superlinearly_but_finishes() {
        let small = synth_contexts(128, 5, 2);
        let big = synth_contexts(512, 5, 3);
        let (_, t_small) = time_once(|| build_clustered(&small, DEFAULT_ALPHA));
        let (_, t_big) = time_once(|| build_clustered(&big, DEFAULT_ALPHA));
        assert!(t_big >= t_small * 0.5, "noise guard");
        assert!(t_big < 30.0, "512 contexts should build fast, took {t_big}");
    }

    #[test]
    fn latency_mildly_sensitive_to_k() {
        // Table 3c: construction latency moves sub-linearly with k (the
        // distance evaluation is O(k^2) worst case but overlap-sparse).
        let a = synth_contexts(384, 3, 4);
        let b = synth_contexts(384, 20, 4);
        let (_, ta) = time_once(|| build_clustered(&a, DEFAULT_ALPHA));
        let (_, tb) = time_once(|| build_clustered(&b, DEFAULT_ALPHA));
        assert!(
            tb < ta * 45.0 + 1.0,
            "k=20 build {tb} vs k=3 {ta} — distance eval regressed"
        );
    }
}
