//! Retrieval simulator: maps queries to ranked context-block lists with the
//! overlap structure real retrievers produce (Fig. 2a/2b).
//!
//! Model: each query targets a *topic* (a document drawn from the dataset's
//! Zipf popularity). Candidates are the documents in a window around the
//! topic; each is scored `popularity(d) * exp(-dist(d,topic)/tau) * noise`
//! and the top-k become the context. Two queries on the same topic
//! therefore retrieve nearly the same set in slightly different orders —
//! exactly the cross-session overlap ContextPilot aligns (Fig. 2a). The
//! aggregate document-access distribution tracks the profile's Zipf
//! (smoothed by the window), reproducing the Fig. 11 CDFs.

use crate::types::{BlockId, Context};
use crate::util::prng::{Rng, Zipf};
use crate::workload::profiles::DatasetProfile;

pub struct Retriever {
    pub profile: DatasetProfile,
    zipf: Zipf,
    /// popularity score per doc (descending by construction)
    popularity: Vec<f64>,
    /// ranking noise magnitude (perturbs per-query order)
    pub noise: f64,
}

impl Retriever {
    pub fn new(profile: DatasetProfile) -> Self {
        let n = profile.n_docs;
        let s = profile.zipf_s;
        let popularity: Vec<f64> = (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(s)).collect();
        Self {
            zipf: profile.zipf(),
            profile,
            popularity,
            noise: 0.25,
        }
    }

    /// Draw a topic doc for a fresh query.
    pub fn sample_topic(&self, rng: &mut Rng) -> usize {
        self.zipf.sample(rng)
    }

    /// A related topic (for multi-turn drift): same cluster, different doc.
    pub fn drift_topic(&self, topic: usize, rng: &mut Rng) -> usize {
        let cs = self.profile.cluster_size.max(1);
        let cluster = topic / cs;
        let base = cluster * cs;
        let span = cs.min(self.profile.n_docs - base);
        base + rng.below(span)
    }

    fn window(&self, k: usize) -> usize {
        self.profile.cluster_size.max(2 * k).min(self.profile.n_docs)
    }

    /// Retrieve top-k ranked docs for `topic`.
    pub fn retrieve(&self, topic: usize, k: usize, rng: &mut Rng) -> Context {
        let n = self.profile.n_docs;
        let k = k.min(n);
        let w = self.window(k);
        let tau = (w as f64 / 4.0).max(1.0);
        // circular window centred on the topic
        let start = (topic + n - w / 2) % n;
        let mut scored: Vec<(f64, usize)> = (0..w)
            .map(|i| {
                let d = (start + i) % n;
                let dist = if i >= w / 2 { i - w / 2 } else { w / 2 - i } as f64;
                let score = self.popularity[d]
                    * (-dist / tau).exp()
                    * (1.0 + self.noise * rng.normal()).max(0.01);
                (score, d)
            })
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        scored
            .into_iter()
            .take(k)
            .map(|(_, d)| BlockId(d as u32))
            .collect()
    }

    /// Multi-turn retrieval (Fig. 2b): composes the turn's context from the
    /// conversation's history at the dataset's `turn_overlap` rate, with
    /// the remainder retrieved fresh around `topic` (excluding history).
    /// §3.1: on MT-RAG ~40% of retrieved docs in any turn overlap earlier
    /// turns of the same session.
    pub fn retrieve_turn(
        &self,
        topic: usize,
        k: usize,
        history: &[BlockId],
        rng: &mut Rng,
    ) -> Context {
        if history.is_empty() {
            return self.retrieve(topic, k, rng);
        }
        let hist_set: std::collections::HashSet<BlockId> = history.iter().copied().collect();
        let fresh_pool: Vec<BlockId> = self
            .retrieve(topic, (2 * k).min(self.profile.n_docs), rng)
            .into_iter()
            .filter(|b| !hist_set.contains(b))
            .collect();
        let mut fresh_iter = fresh_pool.into_iter();
        let mut used: std::collections::HashSet<BlockId> = Default::default();
        let mut out: Context = Vec::with_capacity(k);
        for _slot in 0..k {
            let from_hist = rng.chance(self.profile.turn_overlap);
            let pick = if from_hist {
                // re-retrieve a block from history
                let mut p = *rng.choice(history);
                let mut tries = 0;
                while used.contains(&p) && tries < 8 {
                    p = *rng.choice(history);
                    tries += 1;
                }
                if used.contains(&p) {
                    fresh_iter.next()
                } else {
                    Some(p)
                }
            } else {
                fresh_iter.next()
            };
            if let Some(b) = pick {
                if used.insert(b) {
                    out.push(b);
                }
            }
        }
        // top up with arbitrary unseen docs if we ran dry
        let mut d = topic;
        while out.len() < k && used.len() < self.profile.n_docs {
            d = (d + 1 + rng.below(7)) % self.profile.n_docs;
            let b = BlockId(d as u32);
            if used.insert(b) {
                out.push(b);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::profiles::{Dataset, DatasetProfile};

    fn retriever() -> Retriever {
        Retriever::new(DatasetProfile::get(Dataset::MultihopRag))
    }

    #[test]
    fn retrieve_returns_k_distinct() {
        let r = retriever();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let t = r.sample_topic(&mut rng);
            let ctx = r.retrieve(t, 15, &mut rng);
            assert_eq!(ctx.len(), 15);
            let set: std::collections::HashSet<_> = ctx.iter().collect();
            assert_eq!(set.len(), 15);
        }
    }

    #[test]
    fn same_topic_queries_overlap_heavily() {
        let r = retriever();
        let mut rng = Rng::new(2);
        let t = 3; // popular topic
        let a: std::collections::HashSet<_> = r.retrieve(t, 15, &mut rng).into_iter().collect();
        let b: std::collections::HashSet<_> = r.retrieve(t, 15, &mut rng).into_iter().collect();
        let shared = a.intersection(&b).count();
        assert!(shared >= 10, "same-topic overlap too low: {shared}");
    }

    #[test]
    fn distant_topics_overlap_less() {
        let r = retriever();
        let mut rng = Rng::new(3);
        let a: std::collections::HashSet<_> =
            r.retrieve(100, 15, &mut rng).into_iter().collect();
        let far: std::collections::HashSet<_> =
            r.retrieve(400, 15, &mut rng).into_iter().collect();
        let near: std::collections::HashSet<_> =
            r.retrieve(100, 15, &mut rng).into_iter().collect();
        assert!(a.intersection(&near).count() > a.intersection(&far).count());
    }

    #[test]
    fn turn_retrieval_overlaps_history_at_profile_rate() {
        let r = Retriever::new(DatasetProfile::get(Dataset::MtRag));
        let mut rng = Rng::new(4);
        let mut total = 0usize;
        let mut overlapped = 0usize;
        for _ in 0..300 {
            let t = r.sample_topic(&mut rng);
            let first = r.retrieve(t, 10, &mut rng);
            // jump far away so fresh retrieval is disjoint from history
            let t2 = (t + 300) % r.profile.n_docs;
            let second = r.retrieve_turn(t2, 10, &first, &mut rng);
            let hist: std::collections::HashSet<_> = first.iter().collect();
            total += second.len();
            overlapped += second.iter().filter(|b| hist.contains(b)).count();
        }
        let rate = overlapped as f64 / total as f64;
        // MT-RAG target 0.40
        assert!((0.30..0.50).contains(&rate), "overlap rate {rate}");
    }

    #[test]
    fn drift_stays_in_cluster() {
        let r = retriever();
        let mut rng = Rng::new(5);
        let cs = r.profile.cluster_size;
        for _ in 0..100 {
            let t = r.sample_topic(&mut rng);
            let d = r.drift_topic(t, &mut rng);
            assert_eq!(t / cs, d / cs);
        }
    }

    #[test]
    fn access_distribution_tracks_zipf_ordering() {
        // MultihopRAG (most skewed) must show higher top-20% coverage than
        // QASPER (least skewed) at the access level.
        use crate::workload::access::AccessStats;
        use crate::workload::generators::multi_session;
        let mh = AccessStats::from_workload(&multi_session(Dataset::MultihopRag, 400, 15, 1));
        let qa = AccessStats::from_workload(&multi_session(Dataset::Qasper, 400, 15, 1));
        let (c_mh, c_qa) = (mh.top_coverage(0.2), qa.top_coverage(0.2));
        assert!(c_mh > c_qa, "MultihopRAG {c_mh} <= QASPER {c_qa}");
    }
}
