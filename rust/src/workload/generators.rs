//! Workload generators for every evaluation scenario in the paper:
//! multi-session RAG (§7.1), multi-turn RAG (MT-RAG), hybrid
//! session×turn, agentic memory (Mem0/LoCoMo), Chain-of-Agents, and the
//! OpenClaw agent traces (Table 4).

use crate::types::{BlockId, QueryId, Request, RequestId, SessionId};
use crate::util::prng::Rng;
use crate::workload::profiles::{Dataset, DatasetProfile};
use crate::workload::retrieval::Retriever;

/// A generated workload: an ordered request arrival sequence.
#[derive(Clone, Debug)]
pub struct Workload {
    pub dataset: Dataset,
    pub requests: Vec<Request>,
}

impl Workload {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// A workload plus an open-loop arrival schedule: `arrivals[i]` is the
/// virtual arrival time (seconds) of `workload.requests[i]`. Times are
/// nondecreasing — exactly what [`crate::api::Server::submit_at`]
/// requires — and are generated on the *virtual* clock from a seeded
/// generator, so the schedule is bit-identical across runs, machines and
/// worker counts (it never reads wall time).
#[derive(Clone, Debug)]
pub struct TimedWorkload {
    pub workload: Workload,
    pub arrivals: Vec<f64>,
}

impl TimedWorkload {
    pub fn len(&self) -> usize {
        self.workload.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workload.is_empty()
    }

    /// The schedule's makespan: last arrival time (0 when empty).
    pub fn span(&self) -> f64 {
        self.arrivals.last().copied().unwrap_or(0.0)
    }
}

/// Poisson arrival process at a constant `qps`: seeded exponential
/// inter-arrival gaps `-ln(1-u)/qps`, starting at t=0's first gap.
/// Deterministic in `(n, qps, seed)`.
pub fn poisson_arrivals(n: usize, qps: f64, seed: u64) -> Vec<f64> {
    assert!(qps > 0.0 && qps.is_finite(), "offered qps must be positive");
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += -(1.0 - rng.f64()).ln() / qps;
            t
        })
        .collect()
}

/// Diurnal (time-varying) Poisson arrivals via thinning: the
/// instantaneous rate swings sinusoidally between `(1 - depth)` and
/// `(1 + depth)` times `mean_qps` with the given `period` (virtual
/// seconds). Candidate events are drawn at the peak rate and accepted
/// with probability `rate(t) / peak` — the standard Lewis–Shedler
/// construction, here fully seeded and deterministic.
pub fn diurnal_arrivals(n: usize, mean_qps: f64, depth: f64, period: f64, seed: u64) -> Vec<f64> {
    assert!(
        mean_qps > 0.0 && mean_qps.is_finite(),
        "offered qps must be positive"
    );
    assert!((0.0..1.0).contains(&depth), "depth must be in [0, 1)");
    assert!(period > 0.0 && period.is_finite(), "period must be positive");
    let peak = mean_qps * (1.0 + depth);
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        t += -(1.0 - rng.f64()).ln() / peak;
        let rate = mean_qps * (1.0 + depth * (2.0 * std::f64::consts::PI * t / period).sin());
        if rng.f64() < rate / peak {
            out.push(t);
        }
    }
    out
}

/// Open-loop load: `sessions` single-turn requests arriving as a
/// constant-rate Poisson stream at `qps`. The request sequence and the
/// schedule are forked from one seed, so the pair is reproducible as a
/// unit.
pub fn open_loop(dataset: Dataset, sessions: usize, k: usize, qps: f64, seed: u64) -> TimedWorkload {
    let workload = multi_session(dataset, sessions, k, seed);
    let arrivals = poisson_arrivals(workload.len(), qps, seed ^ 0x9E37_79B9_7F4A_7C15);
    TimedWorkload { workload, arrivals }
}

/// Open-loop load with a diurnal rate swing (see [`diurnal_arrivals`]).
pub fn open_loop_diurnal(
    dataset: Dataset,
    sessions: usize,
    k: usize,
    mean_qps: f64,
    depth: f64,
    period: f64,
    seed: u64,
) -> TimedWorkload {
    let workload = multi_session(dataset, sessions, k, seed);
    let arrivals = diurnal_arrivals(
        workload.len(),
        mean_qps,
        depth,
        period,
        seed ^ 0x9E37_79B9_7F4A_7C15,
    );
    TimedWorkload { workload, arrivals }
}

fn qid(session: u32, turn: u32) -> QueryId {
    QueryId(((session as u64) << 32) | turn as u64)
}

/// Multi-session RAG (§7.1): `sessions` independent single-turn queries,
/// arriving as one batch (ContextPilot runs in *offline* mode).
pub fn multi_session(dataset: Dataset, sessions: usize, k: usize, seed: u64) -> Workload {
    let profile = DatasetProfile::get(dataset);
    let retriever = Retriever::new(profile);
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(sessions);
    for s in 0..sessions {
        let topic = retriever.sample_topic(&mut rng);
        let context = retriever.retrieve(topic, k, &mut rng);
        requests.push(Request {
            id: RequestId(s as u64),
            session: SessionId(s as u32),
            turn: 0,
            context,
            query: qid(s as u32, 0),
        });
    }
    Workload { dataset, requests }
}

/// Multi-turn RAG (MT-RAG, §7.1): one session of `turns` turns; topics
/// drift within a cluster and retrievals overlap earlier turns
/// (ContextPilot runs in *online* mode with cold start).
pub fn multi_turn(dataset: Dataset, turns: usize, k: usize, seed: u64) -> Workload {
    let profile = DatasetProfile::get(dataset);
    let retriever = Retriever::new(profile);
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(turns);
    let mut topic = retriever.sample_topic(&mut rng);
    let mut history: Vec<BlockId> = Vec::new();
    for t in 0..turns {
        if t > 0 {
            // topic dynamics: half the turns jump to a fresh topic (new
            // question), the rest drift within the cluster — keeps the
            // *total* cross-turn overlap near the dataset's measured rate
            // (§3.1: 40% on MT-RAG) instead of compounding per turn.
            if rng.chance(0.5) {
                topic = retriever.sample_topic(&mut rng);
            } else {
                topic = retriever.drift_topic(topic, &mut rng);
            }
        }
        let context = retriever.retrieve_turn(topic, k, &history, &mut rng);
        for &b in &context {
            if !history.contains(&b) {
                history.push(b);
            }
        }
        requests.push(Request {
            id: RequestId(t as u64),
            session: SessionId(0),
            turn: t as u32,
            context,
            query: qid(0, t as u32),
        });
    }
    Workload { dataset, requests }
}

/// Hybrid multi-session × multi-turn (Table 3b): `sessions` concurrent
/// conversations of `turns` turns, arrival interleaved round-robin (the
/// production-conversation pattern).
pub fn hybrid(dataset: Dataset, sessions: usize, turns: usize, k: usize, seed: u64) -> Workload {
    let profile = DatasetProfile::get(dataset);
    let retriever = Retriever::new(profile);
    let mut master = Rng::new(seed);
    struct SessionState {
        rng: Rng,
        topic: usize,
        history: Vec<BlockId>,
    }
    let mut states: Vec<SessionState> = (0..sessions)
        .map(|s| {
            let mut rng = master.fork(s as u64);
            let topic = retriever.sample_topic(&mut rng);
            SessionState {
                rng,
                topic,
                history: Vec::new(),
            }
        })
        .collect();
    let mut requests = Vec::with_capacity(sessions * turns);
    let mut next_id = 0u64;
    for t in 0..turns {
        for (s, st) in states.iter_mut().enumerate() {
            if t > 0 && st.rng.chance(0.6) {
                st.topic = retriever.drift_topic(st.topic, &mut st.rng);
            }
            let context = retriever.retrieve_turn(st.topic, k, &st.history, &mut st.rng);
            for &b in &context {
                if !st.history.contains(&b) {
                    st.history.push(b);
                }
            }
            requests.push(Request {
                id: RequestId(next_id),
                session: SessionId(s as u32),
                turn: t as u32,
                context,
                query: qid(s as u32, t as u32),
            });
            next_id += 1;
        }
    }
    Workload { dataset, requests }
}

/// Agentic memory (Mem0 on LoCoMo, §7.2): per-user memory stores queried
/// with high temporal locality — each request retrieves top-k memories of
/// which most were retrieved before (memories accrete over turns).
pub fn mem0(users: usize, turns_per_user: usize, k: usize, seed: u64) -> Workload {
    let profile = DatasetProfile::get(Dataset::LoCoMo);
    let mut master = Rng::new(seed);
    let mut requests = Vec::new();
    let mut next_id = 0u64;
    let mems_per_user = profile.n_docs / users.max(1);
    for u in 0..users {
        let mut rng = master.fork(u as u64);
        let base = u * mems_per_user;
        // memories accumulate: at turn t the user has `avail` memories
        for t in 0..turns_per_user {
            let avail = ((t + 2) * mems_per_user / (turns_per_user + 1)).clamp(1, mems_per_user);
            let kk = k.min(avail);
            // retrieval is recency+relevance biased: newer memories first,
            // overlapping heavily with the previous turn's retrieval
            let mut ids = rng.sample_indices(avail, kk);
            // bias toward recent: sort descending, then perturb
            ids.sort_unstable_by(|a, b| b.cmp(a));
            for i in 1..ids.len() {
                if rng.chance(0.3) {
                    ids.swap(i - 1, i);
                }
            }
            let context = ids
                .into_iter()
                .map(|m| BlockId((base + m) as u32))
                .collect();
            requests.push(Request {
                id: RequestId(next_id),
                session: SessionId(u as u32),
                turn: t as u32,
                context,
                query: qid(u as u32, t as u32),
            });
            next_id += 1;
        }
    }
    Workload {
        dataset: Dataset::LoCoMo,
        requests,
    }
}

/// Chain-of-Agents (§7.2): `agents` workers each process document segments
/// + a shared instruction header; across `rounds`, recurring documents
/// should be routed to the worker that saw them (agent-aware routing).
/// Session id encodes the worker agent.
pub fn chain_of_agents(
    dataset: Dataset,
    agents: usize,
    rounds: usize,
    k: usize,
    seed: u64,
) -> Workload {
    let profile = DatasetProfile::get(dataset);
    let retriever = Retriever::new(profile);
    let mut rng = Rng::new(seed);
    let mut requests = Vec::new();
    let mut next_id = 0u64;
    for round in 0..rounds {
        // the manager retrieves a large set and shards it over workers
        let topic = retriever.sample_topic(&mut rng);
        let pool = retriever.retrieve(topic, k * agents.min(4), &mut rng);
        for a in 0..agents {
            let mut context: Vec<BlockId> = pool
                .iter()
                .skip(a % pool.len().max(1))
                .step_by(agents.max(1))
                .copied()
                .take(k)
                .collect();
            if context.is_empty() {
                context.push(pool[a % pool.len()]);
            }
            requests.push(Request {
                id: RequestId(next_id),
                session: SessionId(a as u32),
                turn: round as u32,
                context,
                query: qid(a as u32, round as u32),
            });
            next_id += 1;
        }
    }
    Workload { dataset, requests }
}

/// OpenClaw agent trace (Table 4): document-analysis tasks repeatedly read
/// from a small document set over many turns (prefill-heavy); coding tasks
/// have longer decode. Returns (workload, decode_tokens per request).
pub fn openclaw(tasks: usize, turns_per_task: usize, seed: u64, coding: bool) -> (Workload, Vec<usize>) {
    let profile = DatasetProfile::get(Dataset::ClawTasks);
    let mut master = Rng::new(seed);
    let mut requests = Vec::new();
    let mut decode_tokens = Vec::new();
    let mut next_id = 0u64;
    for task in 0..tasks {
        let mut rng = master.fork(task as u64);
        // each task works over a subset of the 22 documents
        let ws_size = rng.range(3, profile.n_docs.min(9));
        let working_set: Vec<BlockId> = rng
            .sample_indices(profile.n_docs, ws_size)
            .into_iter()
            .map(|d| BlockId(d as u32))
            .collect();
        let mut history: Vec<BlockId> = Vec::new();
        for t in 0..turns_per_task {
            // agent re-reads mostly the same files, occasionally opens new
            let mut context: Vec<BlockId> = Vec::new();
            for &b in &working_set {
                if t == 0 || rng.chance(0.8) {
                    context.push(b);
                }
            }
            if context.is_empty() {
                context.push(working_set[0]);
            }
            if rng.chance(0.2) {
                let extra = BlockId(rng.below(profile.n_docs) as u32);
                if !context.contains(&extra) {
                    context.push(extra);
                }
            }
            for &b in &context {
                if !history.contains(&b) {
                    history.push(b);
                }
            }
            requests.push(Request {
                id: RequestId(next_id),
                session: SessionId(task as u32),
                turn: t as u32,
                context,
                query: qid(task as u32, t as u32),
            });
            // doc analysis: ~short answers; coding: long generations
            decode_tokens.push(if coding {
                rng.range(400, 1600)
            } else {
                rng.range(32, 160)
            });
            next_id += 1;
        }
    }
    (
        Workload {
            dataset: Dataset::ClawTasks,
            requests,
        },
        decode_tokens,
    )
}

/// Recurring-context workload (§7.2 routing / Table 6): `sessions`
/// conversations of `turns` turns where session `s` always retrieves the
/// SAME `k`-block context group (`s % groups`) — many users sharing a few
/// RAG corpora. Arrival is turn-major with a seeded per-wave session
/// shuffle. The worst case for blind session hashing (group members
/// scatter across shards and each shard re-prefills the group) and the
/// best case for context-aware placement (the whole group lands on one
/// shard and shares its prefix) — the workload `benches/bench_routing.rs`
/// and `tests/placement.rs` pin the placement comparison on.
pub fn recurring(
    dataset: Dataset,
    sessions: usize,
    turns: usize,
    groups: usize,
    k: usize,
    seed: u64,
) -> Workload {
    let profile = DatasetProfile::get(dataset);
    let groups = groups.max(1);
    let k = k.max(1);
    assert!(
        groups * k <= profile.n_docs,
        "corpus too small for {groups} disjoint groups of {k} blocks"
    );
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(sessions * turns);
    let mut next_id = 0u64;
    for t in 0..turns {
        let mut order: Vec<usize> = (0..sessions).collect();
        rng.shuffle(&mut order);
        for &s in &order {
            let g = s % groups;
            let context: Vec<BlockId> = (0..k).map(|i| BlockId((g * k + i) as u32)).collect();
            requests.push(Request {
                id: RequestId(next_id),
                session: SessionId(s as u32),
                turn: t as u32,
                context,
                query: qid(s as u32, t as u32),
            });
            next_id += 1;
        }
    }
    Workload { dataset, requests }
}

/// A zero-overlap adversarial workload (Appendix F): every request
/// retrieves disjoint blocks — the worst case for context reuse, isolating
/// pure ContextPilot overhead.
pub fn zero_overlap(n_requests: usize, k: usize, universe: usize, seed: u64) -> Workload {
    assert!(n_requests * k <= universe, "universe too small for zero overlap");
    let mut rng = Rng::new(seed);
    let mut perm: Vec<usize> = (0..universe).collect();
    rng.shuffle(&mut perm);
    let requests = (0..n_requests)
        .map(|i| {
            let context = perm[i * k..(i + 1) * k]
                .iter()
                .map(|&d| BlockId(d as u32))
                .collect();
            Request {
                id: RequestId(i as u64),
                session: SessionId(i as u32),
                turn: 0,
                context,
                query: qid(i as u32, 0),
            }
        })
        .collect();
    Workload {
        dataset: Dataset::MultihopRag,
        requests,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn multi_session_shapes() {
        let w = multi_session(Dataset::MultihopRag, 32, 15, 1);
        assert_eq!(w.len(), 32);
        for r in &w.requests {
            assert_eq!(r.context.len(), 15);
            assert_eq!(r.turn, 0);
        }
    }

    #[test]
    fn multi_session_has_cross_session_overlap() {
        let w = multi_session(Dataset::MultihopRag, 64, 15, 2);
        let mut counts: std::collections::HashMap<BlockId, usize> = Default::default();
        for r in &w.requests {
            for &b in &r.context {
                *counts.entry(b).or_default() += 1;
            }
        }
        let repeated = counts.values().filter(|&&c| c > 1).count();
        assert!(repeated > 20, "too little overlap: {repeated} repeated blocks");
    }

    #[test]
    fn multi_turn_overlaps_history() {
        let w = multi_turn(Dataset::MtRag, 12, 10, 3);
        assert_eq!(w.len(), 12);
        let mut seen: HashSet<BlockId> = HashSet::new();
        let mut overlap_turns = 0;
        for r in &w.requests {
            if r.context.iter().any(|b| seen.contains(b)) {
                overlap_turns += 1;
            }
            seen.extend(r.context.iter().copied());
        }
        assert!(overlap_turns >= 6, "only {overlap_turns} turns overlap history");
    }

    #[test]
    fn contexts_have_distinct_blocks() {
        for w in [
            multi_session(Dataset::Qasper, 20, 15, 4),
            multi_turn(Dataset::MtRag, 10, 10, 5),
            hybrid(Dataset::MtRag, 4, 5, 10, 6),
            mem0(4, 8, 10, 7),
        ] {
            for r in &w.requests {
                let set: HashSet<_> = r.context.iter().collect();
                assert_eq!(set.len(), r.context.len(), "dup block in {:?}", r.id);
                assert!(!r.context.is_empty());
            }
        }
    }

    #[test]
    fn hybrid_interleaves_sessions() {
        let w = hybrid(Dataset::MtRag, 4, 3, 10, 8);
        assert_eq!(w.len(), 12);
        // first 4 requests are turn 0 of sessions 0..4
        for (i, r) in w.requests.iter().take(4).enumerate() {
            assert_eq!(r.session, SessionId(i as u32));
            assert_eq!(r.turn, 0);
        }
        assert_eq!(w.requests[4].turn, 1);
    }

    #[test]
    fn mem0_requests_scoped_to_user() {
        let w = mem0(4, 6, 10, 9);
        let profile = DatasetProfile::get(Dataset::LoCoMo);
        let per_user = profile.n_docs / 4;
        for r in &w.requests {
            let u = r.session.0 as usize;
            for b in &r.context {
                let d = b.0 as usize;
                assert!(d >= u * per_user && d < (u + 1) * per_user);
            }
        }
    }

    #[test]
    fn coa_shards_pool_over_agents() {
        let w = chain_of_agents(Dataset::MultihopRag, 5, 3, 4, 10);
        assert_eq!(w.len(), 15);
        let sessions: HashSet<_> = w.requests.iter().map(|r| r.session).collect();
        assert_eq!(sessions.len(), 5);
    }

    #[test]
    fn openclaw_reuses_working_set() {
        let (w, decode) = openclaw(5, 20, 11, false);
        assert_eq!(w.len(), 100);
        assert_eq!(decode.len(), 100);
        // within a task, consecutive turns share most blocks
        let task0: Vec<_> = w.requests.iter().filter(|r| r.session == SessionId(0)).collect();
        let a: HashSet<_> = task0[1].context.iter().collect();
        let b: HashSet<_> = task0[2].context.iter().collect();
        assert!(!a.is_disjoint(&b));
    }

    #[test]
    fn openclaw_coding_decodes_longer() {
        let (_, d_doc) = openclaw(5, 10, 12, false);
        let (_, d_code) = openclaw(5, 10, 12, true);
        let m_doc: f64 = d_doc.iter().sum::<usize>() as f64 / d_doc.len() as f64;
        let m_code: f64 = d_code.iter().sum::<usize>() as f64 / d_code.len() as f64;
        assert!(m_code > 3.0 * m_doc);
    }

    #[test]
    fn recurring_sessions_stay_in_their_group() {
        let w = recurring(Dataset::MtRag, 12, 3, 4, 6, 0xE1);
        assert_eq!(w.len(), 36);
        for r in &w.requests {
            let g = (r.session.0 as usize) % 4;
            let want: Vec<BlockId> = (0..6).map(|i| BlockId((g * 6 + i) as u32)).collect();
            assert_eq!(r.context, want, "session {:?} left its group", r.session);
        }
        // turn-major waves: first 12 requests are all turn 0, etc.
        for (i, r) in w.requests.iter().enumerate() {
            assert_eq!(r.turn as usize, i / 12);
        }
        // every session appears exactly once per wave
        let wave0: std::collections::HashSet<u32> =
            w.requests[..12].iter().map(|r| r.session.0).collect();
        assert_eq!(wave0.len(), 12);
    }

    #[test]
    fn zero_overlap_is_disjoint() {
        let w = zero_overlap(20, 5, 200, 13);
        let mut seen = HashSet::new();
        for r in &w.requests {
            for b in &r.context {
                assert!(seen.insert(*b), "block {b} repeated");
            }
        }
    }

    #[test]
    fn poisson_arrivals_are_nondecreasing_and_near_rate() {
        let a = poisson_arrivals(2000, 8.0, 0xA11);
        assert_eq!(a.len(), 2000);
        assert!(a[0] > 0.0);
        for w in a.windows(2) {
            assert!(w[1] >= w[0], "arrival times regressed: {w:?}");
        }
        // 2000 samples at 8 qps should span ~250s; allow generous slack
        let rate = a.len() as f64 / a.last().unwrap();
        assert!((rate - 8.0).abs() < 1.0, "empirical rate {rate}");
    }

    #[test]
    fn diurnal_arrivals_swing_the_rate() {
        let period = 100.0;
        let a = diurnal_arrivals(4000, 10.0, 0.8, period, 0xD1u64);
        for w in a.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // count arrivals in the rising half-period vs the falling one:
        // with depth 0.8 the first half ([0, 50)) must see far more
        let hi = a.iter().filter(|&&t| (t % period) < period / 2.0).count();
        let lo = a.len() - hi;
        assert!(
            hi as f64 > 1.3 * lo as f64,
            "no diurnal swing: {hi} peak vs {lo} trough"
        );
    }

    #[test]
    fn arrival_schedules_are_deterministic() {
        let a = poisson_arrivals(256, 4.0, 7);
        let b = poisson_arrivals(256, 4.0, 7);
        assert_eq!(a, b, "poisson schedule must be bit-identical");
        let c = diurnal_arrivals(256, 4.0, 0.5, 60.0, 7);
        let d = diurnal_arrivals(256, 4.0, 0.5, 60.0, 7);
        assert_eq!(c, d, "diurnal schedule must be bit-identical");
        let w1 = open_loop(Dataset::MultihopRag, 32, 10, 4.0, 11);
        let w2 = open_loop(Dataset::MultihopRag, 32, 10, 4.0, 11);
        assert_eq!(w1.arrivals, w2.arrivals);
        assert_eq!(w1.len(), 32);
        assert!(w1.span() > 0.0);
        for (x, y) in w1.workload.requests.iter().zip(&w2.workload.requests) {
            assert_eq!(x.context, y.context);
        }
    }

    #[test]
    fn generators_deterministic() {
        let a = multi_session(Dataset::NarrativeQa, 16, 15, 42);
        let b = multi_session(Dataset::NarrativeQa, 16, 15, 42);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.context, y.context);
        }
    }
}
