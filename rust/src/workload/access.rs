//! Document-access statistics (Fig. 11): given a workload, compute the
//! cumulative access distribution and the top-20% coverage the paper uses
//! to motivate context reuse.

use std::collections::HashMap;

use crate::types::BlockId;
use crate::workload::generators::Workload;

#[derive(Clone, Debug)]
pub struct AccessStats {
    /// accesses per block, sorted descending
    pub counts: Vec<u64>,
    pub total: u64,
}

impl AccessStats {
    pub fn from_workload(w: &Workload) -> AccessStats {
        let mut map: HashMap<BlockId, u64> = HashMap::new();
        for r in &w.requests {
            for &b in &r.context {
                *map.entry(b).or_default() += 1;
            }
        }
        let mut counts: Vec<u64> = map.into_values().collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total = counts.iter().sum();
        AccessStats { counts, total }
    }

    /// Fraction of accesses covered by the top `frac` of *accessed* docs.
    pub fn top_coverage(&self, frac: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let cut = ((self.counts.len() as f64 * frac).ceil() as usize)
            .clamp(1, self.counts.len());
        self.counts[..cut].iter().sum::<u64>() as f64 / self.total as f64
    }

    /// CDF points (x = doc fraction, y = access fraction), `points` samples.
    pub fn cdf(&self, points: usize) -> Vec<(f64, f64)> {
        let n = self.counts.len().max(1);
        let mut out = Vec::with_capacity(points);
        let mut acc = 0u64;
        let mut next_idx = 0usize;
        for p in 1..=points {
            let target = (n * p).div_ceil(points);
            while next_idx < target.min(n) {
                acc += self.counts[next_idx];
                next_idx += 1;
            }
            out.push((
                next_idx as f64 / n as f64,
                if self.total == 0 {
                    0.0
                } else {
                    acc as f64 / self.total as f64
                },
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generators::multi_session;
    use crate::workload::profiles::Dataset;

    #[test]
    fn coverage_matches_paper_shape() {
        // MultihopRAG should be the most head-heavy of the three.
        let mh = AccessStats::from_workload(&multi_session(Dataset::MultihopRag, 400, 15, 1));
        let qa = AccessStats::from_workload(&multi_session(Dataset::Qasper, 400, 15, 1));
        let c_mh = mh.top_coverage(0.2);
        let c_qa = qa.top_coverage(0.2);
        assert!(c_mh > c_qa, "MultihopRAG {c_mh} <= QASPER {c_qa}");
        assert!(c_mh > 0.45, "MultihopRAG top-20% coverage too low: {c_mh}");
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let s = AccessStats::from_workload(&multi_session(Dataset::NarrativeQa, 200, 15, 2));
        let cdf = s.cdf(10);
        assert_eq!(cdf.len(), 10);
        let mut prev = 0.0;
        for &(x, y) in &cdf {
            assert!((0.0..=1.0 + 1e-9).contains(&x));
            assert!(y >= prev - 1e-12);
            prev = y;
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_workload_safe() {
        let w = Workload {
            dataset: Dataset::MultihopRag,
            requests: vec![],
        };
        let s = AccessStats::from_workload(&w);
        assert_eq!(s.top_coverage(0.2), 0.0);
    }
}
