//! Workload layer: dataset profiles calibrated to the paper's access
//! statistics, a retrieval simulator with cross-session / cross-turn
//! overlap, and generators for every evaluation scenario.

pub mod access;
pub mod generators;
pub mod profiles;
pub mod retrieval;

pub use generators::{
    chain_of_agents, diurnal_arrivals, hybrid, mem0, multi_session, multi_turn, open_loop,
    open_loop_diurnal, openclaw, poisson_arrivals, recurring, zero_overlap, TimedWorkload,
    Workload,
};
pub use profiles::{Dataset, DatasetProfile};
pub use retrieval::Retriever;
