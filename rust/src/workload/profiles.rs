//! Dataset profiles: synthetic stand-ins for the paper's evaluation
//! datasets, calibrated to the access statistics the paper reports
//! (DESIGN.md §5 Substitutions).
//!
//! Fig. 11: the top 20% most-accessed documents cover 79.2% (MultihopRAG),
//! 57.4% (NarrativeQA) and 49.6% (QASPER) of retrieval events. We solve the
//! Zipf exponent so the popularity mass matches those numbers; document
//! counts follow the real datasets' corpus sizes (scaled where noted).

use crate::util::prng::Zipf;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    MultihopRag,
    NarrativeQa,
    Qasper,
    MtRag,
    LoCoMo,
    ClawTasks,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::MultihopRag => "MultihopRAG",
            Dataset::NarrativeQa => "NarrativeQA",
            Dataset::Qasper => "QASPER",
            Dataset::MtRag => "MT-RAG",
            Dataset::LoCoMo => "LoCoMo",
            Dataset::ClawTasks => "claw-tasks",
        }
    }
}

#[derive(Clone, Debug)]
pub struct DatasetProfile {
    pub dataset: Dataset,
    pub n_docs: usize,
    /// Zipf exponent over document popularity (solved from `top20_mass`).
    pub zipf_s: f64,
    /// Paper-reported fraction of accesses covered by the top 20% docs.
    pub top20_mass: f64,
    /// Default retrieval depth (top-k) in the paper's experiments.
    pub k: usize,
    /// Cross-turn retrieval overlap for multi-turn workloads (§3.1: 40%
    /// for MT-RAG).
    pub turn_overlap: f64,
    /// Topic clusters: queries about the same topic retrieve from the same
    /// cluster of documents with perturbed ranking (Fig. 2a).
    pub cluster_size: usize,
    /// Lines per synthetic document (drives tokens/block; paper chunks are
    /// 1024 tokens — we scale 1 line ≈ 13 tokens).
    pub doc_lines: usize,
}

/// Solve the Zipf exponent s so that `Zipf(n, s).top_mass(0.2) == target`.
pub fn solve_zipf_exponent(n: usize, target: f64) -> f64 {
    let (mut lo, mut hi) = (0.01f64, 4.0f64);
    for _ in 0..48 {
        let mid = 0.5 * (lo + hi);
        let mass = Zipf::new(n, mid).top_mass(0.2);
        if mass < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

impl DatasetProfile {
    pub fn get(dataset: Dataset) -> DatasetProfile {
        // Corpus sizes follow the real datasets (MultihopRAG: 609 news
        // articles; NarrativeQA: 1,572 stories; QASPER: 1,585 papers),
        // scaled to keep experiment runtimes tractable on CPU.
        let (n_docs, top20, k, overlap, cluster, lines) = match dataset {
            Dataset::MultihopRag => (609, 0.792, 15, 0.30, 24, 10),
            Dataset::NarrativeQa => (1572, 0.574, 15, 0.30, 24, 14),
            Dataset::Qasper => (1585, 0.496, 15, 0.30, 24, 12),
            Dataset::MtRag => (800, 0.55, 10, 0.40, 20, 12),
            Dataset::LoCoMo => (400, 0.60, 20, 0.50, 30, 4),
            Dataset::ClawTasks => (22, 0.60, 8, 0.70, 22, 40),
        };
        DatasetProfile {
            dataset,
            n_docs,
            zipf_s: solve_zipf_exponent(n_docs, top20),
            top20_mass: top20,
            k,
            turn_overlap: overlap,
            cluster_size: cluster,
            doc_lines: lines,
        }
    }

    pub fn zipf(&self) -> Zipf {
        Zipf::new(self.n_docs, self.zipf_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_solver_hits_target() {
        for (n, target) in [(609, 0.792), (1572, 0.574), (1585, 0.496)] {
            let s = solve_zipf_exponent(n, target);
            let mass = Zipf::new(n, s).top_mass(0.2);
            assert!((mass - target).abs() < 0.005, "n={n}: {mass} vs {target}");
        }
    }

    #[test]
    fn profiles_load() {
        for d in [
            Dataset::MultihopRag,
            Dataset::NarrativeQa,
            Dataset::Qasper,
            Dataset::MtRag,
            Dataset::LoCoMo,
            Dataset::ClawTasks,
        ] {
            let p = DatasetProfile::get(d);
            assert!(p.n_docs > 0 && p.k > 0);
            assert!(p.zipf_s > 0.0);
            assert!(!d.name().is_empty());
        }
    }

    #[test]
    fn multihop_is_most_skewed() {
        let mh = DatasetProfile::get(Dataset::MultihopRag);
        let qa = DatasetProfile::get(Dataset::Qasper);
        assert!(mh.zipf_s > qa.zipf_s);
    }
}
