//! Core domain types shared across the coordinator, engine and workloads.
//!
//! Terminology follows the paper (§2.1): a **context block** (CB) is any
//! discrete unit of external context — a retrieved document, a chunk, or a
//! memory entry. A **context** is the ordered list of block IDs attached to
//! one request, ranked by retrieval relevance (index 0 = most relevant).

use std::fmt;

/// Identifier of a context block (document / chunk / memory entry).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

impl fmt::Display for BlockId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "CB_{}", self.0)
    }
}

/// Ordered list of context blocks for one request (relevance ranking).
pub type Context = Vec<BlockId>;

/// Engine-level request identifier; the prefix cache tracks these so the
/// context index can stay synchronized on eviction (paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SessionId(pub u32);

/// One inference request as produced by the workload generators and
/// consumed (possibly rewritten) by ContextPilot.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub session: SessionId,
    /// 0-based turn within the session (multi-turn workloads).
    pub turn: u32,
    /// Retrieval result, ordered by relevance.
    pub context: Context,
    /// Which synthetic query this is (drives the quality model).
    pub query: QueryId,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QueryId(pub u64);

/// The prompt layout ContextPilot hands to the engine: the (possibly
/// re-ordered, de-duplicated, annotated) sequence of prompt segments.
#[derive(Clone, Debug, PartialEq)]
pub enum Segment {
    System,
    /// A full context block, by id.
    Block(BlockId),
    /// Block-level location annotation: "refer to [CB_x] in the previous
    /// conversation" (paper §6).
    LocationRef(BlockId),
    /// A partial block: kept sub-block lines after content-level dedup.
    /// `kept` are line indices retained; elided spans are annotated with
    /// references to the blocks that first contained them (`refs`).
    PartialBlock {
        block: BlockId,
        kept: Vec<u32>,
        refs: Vec<BlockId>,
    },
    /// Order annotation listing the original relevance ranking (§5.3).
    OrderAnnotation(Vec<BlockId>),
    /// The user's question / instruction.
    Question(QueryId),
}

/// A fully-assembled prompt: what the engine tokenizes and prefills.
#[derive(Clone, Debug, PartialEq)]
pub struct Prompt {
    pub segments: Vec<Segment>,
}

impl Prompt {
    /// Baseline prompt: system + blocks in retrieval order + question.
    pub fn baseline(req: &Request) -> Prompt {
        let mut segments = vec![Segment::System];
        segments.extend(req.context.iter().map(|&b| Segment::Block(b)));
        segments.push(Segment::Question(req.query));
        Prompt { segments }
    }

    /// Block ids that appear as full blocks, in prompt order.
    pub fn full_blocks(&self) -> Vec<BlockId> {
        self.segments
            .iter()
            .filter_map(|s| match s {
                Segment::Block(b) => Some(*b),
                _ => None,
            })
            .collect()
    }

    pub fn has_order_annotation(&self) -> bool {
        self.segments
            .iter()
            .any(|s| matches!(s, Segment::OrderAnnotation(_)))
    }
}

/// Per-tier breakdown of one request's reused (cache-hit) tokens: `hbm`
/// tokens were hot in the radix cache, `dram`/`ssd` tokens were promoted
/// from a cold tier at that tier's reload cost
/// ([`crate::cache::TierStore`]). Engines without tiering report
/// everything as `hbm` ([`TierHits::hot`]); `hbm + dram + ssd ==
/// cached_tokens` always.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierHits {
    pub hbm: usize,
    pub dram: usize,
    pub ssd: usize,
}

impl TierHits {
    /// All hits from the hot tier (the non-tiered engine shape).
    pub fn hot(n: usize) -> TierHits {
        TierHits {
            hbm: n,
            dram: 0,
            ssd: 0,
        }
    }

    pub fn total(&self) -> usize {
        self.hbm + self.dram + self.ssd
    }

    /// Tokens that came from a cold tier (DRAM + SSD) — what the tier
    /// store added over discard-mode eviction.
    pub fn promoted(&self) -> usize {
        self.dram + self.ssd
    }
}

/// Outcome of serving one request (metrics inputs).
#[derive(Clone, Debug)]
pub struct ServedRequest {
    pub request: Request,
    pub prompt: Prompt,
    pub prompt_tokens: usize,
    pub cached_tokens: usize,
    /// Seconds until first output token (prefill latency + queueing).
    pub ttft: f64,
    /// Wall time including decode.
    pub wall: f64,
    /// Quality-model score in [0, 1] (the F1 proxy).
    pub quality: f64,
    /// Queue-aware TTFT: when this request's prefill finished on the
    /// shard's virtual clock, counting the time spent waiting behind (or
    /// interleaved with) other requests of the same admission wave.
    /// Engines set this to `ttft`; the chunked-prefill admission layer
    /// ([`crate::serve::admission`]) overwrites it with the scheduled value.
    pub queued_ttft: f64,
    /// Number of prefill chunks admission split this request into
    /// (1 = served as a single monolithic prefill).
    pub prefill_chunks: u32,
    /// Which tier each reused token came from;
    /// `tier_hits.total() == cached_tokens`.
    pub tier_hits: TierHits,
}

impl ServedRequest {
    pub fn hit_ratio(&self) -> f64 {
        if self.prompt_tokens == 0 {
            0.0
        } else {
            self.cached_tokens as f64 / self.prompt_tokens as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request {
            id: RequestId(1),
            session: SessionId(0),
            turn: 0,
            context: vec![BlockId(2), BlockId(1), BlockId(4)],
            query: QueryId(7),
        }
    }

    #[test]
    fn baseline_prompt_layout() {
        let p = Prompt::baseline(&req());
        assert_eq!(p.segments[0], Segment::System);
        assert_eq!(p.full_blocks(), vec![BlockId(2), BlockId(1), BlockId(4)]);
        assert_eq!(*p.segments.last().unwrap(), Segment::Question(QueryId(7)));
        assert!(!p.has_order_annotation());
    }

    #[test]
    fn block_id_display() {
        assert_eq!(BlockId(42).to_string(), "CB_42");
    }

    #[test]
    fn hit_ratio_guards_zero() {
        let s = ServedRequest {
            request: req(),
            prompt: Prompt::baseline(&req()),
            prompt_tokens: 0,
            cached_tokens: 0,
            ttft: 0.0,
            wall: 0.0,
            quality: 0.0,
            queued_ttft: 0.0,
            prefill_chunks: 1,
            tier_hits: TierHits::default(),
        };
        assert_eq!(s.hit_ratio(), 0.0);
    }

    #[test]
    fn tier_hits_arithmetic() {
        let t = TierHits {
            hbm: 10,
            dram: 5,
            ssd: 2,
        };
        assert_eq!(t.total(), 17);
        assert_eq!(t.promoted(), 7);
        let hot = TierHits::hot(9);
        assert_eq!((hot.total(), hot.promoted()), (9, 0));
        assert_eq!(TierHits::default().total(), 0);
    }
}
