//! Request scheduling with aligned contexts (§5.2, Algorithm 5).
//!
//! After alignment, requests are reordered so prefix-sharing contexts run
//! consecutively — otherwise a tight KV budget evicts a shared prefix
//! before its siblings arrive (Fig. 6). Three phases:
//!
//!   1. group by the first element of the search path  — O(N)
//!   2. sort within each group by path length, longest first — O(N log N)
//!   3. order groups by size (desc) and flatten
//!
//! Unlike RAGCache / SGLang-LPM global prefix selection (which rescans an
//! M-node radix tree per decision), this reuses the search paths computed
//! during alignment — complexity independent of M.

use std::collections::HashMap;

/// Schedule items by their alignment search paths. Returns the execution
/// order as indices into the input slice. Generic over the path storage
/// (`Vec<usize>`, `&[usize]`, …) so batch callers can schedule borrowed
/// paths without cloning them into a side `Vec`.
pub fn schedule_by_paths<P: AsRef<[usize]>>(paths: &[P]) -> Vec<usize> {
    // Phase 1: group by first path element (None for empty paths).
    let mut groups: HashMap<Option<usize>, Vec<usize>> = HashMap::new();
    let mut group_order: Vec<Option<usize>> = Vec::new();
    for (i, p) in paths.iter().enumerate() {
        let key = p.as_ref().first().copied();
        let entry = groups.entry(key).or_insert_with(|| {
            group_order.push(key);
            Vec::new()
        });
        entry.push(i);
    }
    // Phase 2: in-group sort by path length, longest first (stable so
    // arrival order breaks ties deterministically).
    for g in groups.values_mut() {
        g.sort_by(|&a, &b| paths[b].as_ref().len().cmp(&paths[a].as_ref().len()));
    }
    // Phase 3: groups by size descending (stable on first-seen order).
    group_order.sort_by(|a, b| groups[b].len().cmp(&groups[a].len()));
    let mut out = Vec::with_capacity(paths.len());
    for key in group_order {
        out.extend(groups.remove(&key).unwrap());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fig6_example() {
        // Ordered contexts C6 [0,0,2], C3 [0,1], C7 [1], C8 [0,0,3]
        // -> group 0: {C6, C3, C8} sorted by len desc => C6, C8, C3
        // -> group 1: {C7}
        // final: C6, C8, C3, C7
        let paths = vec![vec![0, 0, 2], vec![0, 1], vec![1], vec![0, 0, 3]];
        let order = schedule_by_paths(&paths);
        assert_eq!(order, vec![0, 3, 1, 2]);
    }

    #[test]
    fn output_is_permutation() {
        use crate::util::prng::Rng;
        use crate::util::prop;
        prop::quickcheck("schedule is a permutation", |rng: &mut Rng, size| {
            let n = size.min(40);
            let paths: Vec<Vec<usize>> = (0..n)
                .map(|_| {
                    let len = rng.below(5);
                    (0..len).map(|_| rng.below(4)).collect()
                })
                .collect();
            let order = schedule_by_paths(&paths);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            sorted == (0..n).collect::<Vec<_>>()
        });
    }

    #[test]
    fn within_group_longest_first() {
        use crate::util::prng::Rng;
        use crate::util::prop;
        prop::quickcheck("in-group path lengths non-increasing", |rng: &mut Rng, size| {
            let n = size.min(40).max(1);
            let paths: Vec<Vec<usize>> = (0..n)
                .map(|_| {
                    let len = rng.below(5);
                    (0..len).map(|_| rng.below(3)).collect()
                })
                .collect();
            let order = schedule_by_paths(&paths);
            // check monotone lengths within each contiguous same-group run
            for w in order.windows(2) {
                let (a, b) = (&paths[w[0]], &paths[w[1]]);
                if a.first() == b.first() && a.len() < b.len() {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn groups_are_contiguous() {
        let paths = vec![
            vec![0, 1],
            vec![1, 2],
            vec![0, 2, 3],
            vec![1],
            vec![0],
            vec![2],
        ];
        let order = schedule_by_paths(&paths);
        let keys: Vec<Option<usize>> = order.iter().map(|&i| paths[i].first().copied()).collect();
        // each group key appears in one contiguous run
        let mut seen = std::collections::HashSet::new();
        let mut prev = None;
        for k in keys {
            if Some(k) != prev {
                assert!(seen.insert(k), "group {k:?} split");
                prev = Some(k);
            }
        }
    }

    #[test]
    fn larger_groups_run_first() {
        let paths = vec![vec![1], vec![0, 1], vec![0, 2], vec![0]];
        let order = schedule_by_paths(&paths);
        // group 0 (3 members) precedes group 1 (1 member)
        assert_eq!(paths[order[0]].first(), Some(&0));
        assert_eq!(paths[*order.last().unwrap()].first(), Some(&1));
    }

    #[test]
    fn empty_and_single() {
        assert!(schedule_by_paths::<Vec<usize>>(&[]).is_empty());
        assert_eq!(schedule_by_paths(&[vec![7, 7]]), vec![0]);
    }

    #[test]
    fn stable_for_ties() {
        let paths = vec![vec![0, 1], vec![0, 2], vec![0, 3]];
        // equal lengths, same group: arrival order preserved
        assert_eq!(schedule_by_paths(&paths), vec![0, 1, 2]);
    }
}
