//! The paper's context distance function (Eq. 1):
//!
//! ```text
//! d_ij = 1 - |S_ij| / max(|C_i|, |C_j|)
//!          + alpha * ( sum_{k in S_ij} |p_i(k) - p_j(k)| ) / |S_ij|
//! ```
//!
//! where `S_ij` is the set of shared blocks, `p_i(k)` the position of block
//! `k` in context `i`, and `alpha in [0.001, 0.01]` keeps the overlap count
//! dominant while breaking ties by positional alignment (§4.1): contexts
//! sharing blocks *at similar positions* are closer, which conventional
//! cosine/L1/L2 measures cannot express.

use std::collections::HashMap;

use crate::types::{BlockId, Context};

/// Paper default (§7 evaluation setup).
pub const DEFAULT_ALPHA: f64 = 0.001;

/// Eq. 1. Returns 1.0 for disjoint contexts (the positional term is 0 when
/// `S_ij` is empty), 0.0 in the degenerate both-empty case.
///
/// Hot path: this runs O(N^2) times during index construction. Contexts
/// are short (k ≤ ~32), so position lookup uses a linear scan — no
/// allocation — which profiles ~8x faster than a HashMap per call
/// (EXPERIMENTS.md §Perf); a HashMap path covers unusually long contexts.
pub fn context_distance(a: &Context, b: &Context, alpha: f64) -> f64 {
    if a.is_empty() || b.is_empty() {
        return if a.is_empty() && b.is_empty() { 0.0 } else { 1.0 };
    }
    let mut shared = 0usize;
    let mut pos_gap = 0usize;
    if a.len() <= 32 {
        for (j, &x) in b.iter().enumerate() {
            if let Some(i) = a.iter().position(|&y| y == x) {
                shared += 1;
                pos_gap += i.abs_diff(j);
            }
        }
    } else {
        let pos_a: HashMap<BlockId, usize> =
            a.iter().enumerate().map(|(i, &x)| (x, i)).collect();
        for (j, &x) in b.iter().enumerate() {
            if let Some(&i) = pos_a.get(&x) {
                shared += 1;
                pos_gap += i.abs_diff(j);
            }
        }
    }
    if shared == 0 {
        return 1.0;
    }
    let overlap = shared as f64 / a.len().max(b.len()) as f64;
    1.0 - overlap + alpha * (pos_gap as f64 / shared as f64)
}

/// Shared blocks of `a` and `b`, in ascending BlockId order — the paper's
/// "sorted intersection" used as the context of merged (virtual) nodes.
pub fn sorted_intersection(a: &Context, b: &Context) -> Context {
    let set_a: std::collections::HashSet<BlockId> = a.iter().copied().collect();
    let mut out: Context = b.iter().copied().filter(|x| set_a.contains(x)).collect();
    out.sort_unstable();
    out.dedup();
    out
}

/// Number of shared blocks (cheap overlap check used during search).
/// Hot path: called per child per tree level during Alg.-1 search; the
/// allocation-free linear scan is ~6x faster than a HashSet for the short
/// contexts retrieval produces (EXPERIMENTS.md §Perf).
pub fn overlap_count(a: &Context, b: &Context) -> usize {
    if a.len() <= 32 {
        b.iter().filter(|x| a.contains(x)).count()
    } else {
        let set_a: std::collections::HashSet<BlockId> = a.iter().copied().collect();
        b.iter().filter(|x| set_a.contains(x)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(ids: &[u32]) -> Context {
        ids.iter().map(|&i| BlockId(i)).collect()
    }

    #[test]
    fn identical_contexts_have_zero_distance() {
        let c = ctx(&[3, 5, 1, 7]);
        assert_eq!(context_distance(&c, &c, 0.001), 0.0);
    }

    #[test]
    fn disjoint_contexts_have_distance_one() {
        assert_eq!(context_distance(&ctx(&[1, 2]), &ctx(&[3, 4]), 0.001), 1.0);
    }

    #[test]
    fn paper_example_positional_tiebreak() {
        // §4.1: A{3,5,1,7}, B{2,6,3,5}, C{3,5,8,9}, D{2,6,4,0}.
        // A-B, B-C, B-D all share two blocks, but B-D shares {2,6} at
        // matching positions 0-1, so d(B,D) must be smallest.
        let a = ctx(&[3, 5, 1, 7]);
        let b = ctx(&[2, 6, 3, 5]);
        let c = ctx(&[3, 5, 8, 9]);
        let d = ctx(&[2, 6, 4, 0]);
        let alpha = 0.001;
        let d_ab = context_distance(&a, &b, alpha);
        let d_bc = context_distance(&b, &c, alpha);
        let d_bd = context_distance(&b, &d, alpha);
        assert!(d_bd < d_ab, "d(B,D)={d_bd} !< d(A,B)={d_ab}");
        assert!(d_bd < d_bc, "d(B,D)={d_bd} !< d(B,C)={d_bc}");
        // overlap term identical across the three pairs
        assert!((d_ab - d_bc).abs() < alpha * 10.0);
    }

    #[test]
    fn symmetric() {
        let a = ctx(&[1, 2, 3, 9]);
        let b = ctx(&[2, 3, 4]);
        assert!(
            (context_distance(&a, &b, 0.005) - context_distance(&b, &a, 0.005)).abs() < 1e-12
        );
    }

    #[test]
    fn overlap_dominates_position() {
        // more shared blocks => smaller distance, regardless of positions
        let base = ctx(&[0, 1, 2, 3, 4]);
        let share3 = ctx(&[4, 3, 2, 9, 8]); // 3 shared, scrambled
        let share1 = ctx(&[0, 9, 8, 7, 6]); // 1 shared, perfectly placed
        let alpha = 0.01; // even at the max alpha
        assert!(
            context_distance(&base, &share3, alpha) < context_distance(&base, &share1, alpha)
        );
    }

    #[test]
    fn empty_cases() {
        assert_eq!(context_distance(&ctx(&[]), &ctx(&[]), 0.001), 0.0);
        assert_eq!(context_distance(&ctx(&[]), &ctx(&[1]), 0.001), 1.0);
    }

    #[test]
    fn sorted_intersection_paper_example() {
        // C1{2,1,3} and C2{2,6,1} share {1,2} (sorted)
        let s = sorted_intersection(&ctx(&[2, 1, 3]), &ctx(&[2, 6, 1]));
        assert_eq!(s, ctx(&[1, 2]));
    }

    #[test]
    fn intersection_of_disjoint_is_empty() {
        assert!(sorted_intersection(&ctx(&[1]), &ctx(&[2])).is_empty());
    }

    #[test]
    fn overlap_count_works() {
        assert_eq!(overlap_count(&ctx(&[1, 2, 3]), &ctx(&[3, 4, 1])), 2);
        assert_eq!(overlap_count(&ctx(&[]), &ctx(&[1])), 0);
    }

    #[test]
    fn distance_bounds() {
        use crate::util::prng::Rng;
        use crate::util::prop;
        prop::quickcheck("distance in [0, 1+alpha*max_gap]", |rng: &mut Rng, size| {
            let a: Context = prop::gen_distinct_ids(rng, size, 64)
                .into_iter()
                .map(|i| BlockId(i as u32))
                .collect();
            let b: Context = prop::gen_distinct_ids(rng, size, 64)
                .into_iter()
                .map(|i| BlockId(i as u32))
                .collect();
            let d = context_distance(&a, &b, 0.01);
            let max_gap = a.len().max(b.len()) as f64;
            d >= 0.0 && d <= 1.0 + 0.01 * max_gap + 1e-9
        });
    }
}
