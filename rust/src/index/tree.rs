//! The context index (§4): a tree over contexts mirroring the engine's
//! prefix-cache state.
//!
//! * The root is a synthetic empty context; top-level subtrees hang off it
//!   (unmatched contexts form standalone branches, §5.1).
//! * Internal ("virtual") nodes hold the sorted intersection of their
//!   subtree — the shared prefix reusable from the KV cache.
//! * Leaves hold aligned full contexts and carry the engine `RequestId`
//!   that owns the cached prefix, enabling O(h) eviction sync (§4.1).
//!
//! Four node attributes follow the paper: (1) the context (block ids),
//! (2) the search path from the root — recomputed on demand here so sibling
//! removals cannot leave stale paths, (3) an access-frequency counter, and
//! (4) the clustering distance at which the node was created.

use std::collections::{HashMap, HashSet};

use crate::index::distance::{context_distance, overlap_count, sorted_intersection};
use crate::types::{BlockId, Context, RequestId, SessionId};
use crate::util::json::Json;

pub type NodeId = usize;

#[derive(Clone, Debug)]
pub struct IndexNode {
    /// Leaves: the (aligned) full context. Virtual nodes: the sorted
    /// intersection of the subtree (shared prefix).
    pub context: Context,
    pub children: Vec<NodeId>,
    pub parent: Option<NodeId>,
    /// Access frequency (cache-eviction signal).
    pub freq: u64,
    /// Clustering distance at which this node was created (0 for leaves).
    pub cluster_dist: f64,
    /// Engine requests owning this cached context (leaves only; several
    /// when duplicate contexts share one leaf).
    pub requests: Vec<RequestId>,
    pub alive: bool,
}

impl IndexNode {
    pub fn is_leaf(&self) -> bool {
        self.children.is_empty()
    }
}

/// Per-conversation record for multi-turn de-duplication (§6): blocks and
/// content sub-block hashes seen in prior turns.
#[derive(Clone, Debug, Default)]
pub struct ConvRecord {
    pub seen_blocks: HashSet<BlockId>,
    /// sub-block content hash -> block that first contributed it
    pub seen_subblocks: HashMap<u64, BlockId>,
}

#[derive(Clone, Debug)]
pub struct ContextIndex {
    nodes: Vec<IndexNode>,
    free: Vec<NodeId>,
    pub root: NodeId,
    req_to_leaf: HashMap<RequestId, NodeId>,
    pub alpha: f64,
    conversations: HashMap<SessionId, ConvRecord>,
    /// Inverted block directory: `BlockId` → number of alive leaves whose
    /// context contains it (counted once per leaf, however often the block
    /// repeats inside one context). Kept write-through by `alloc`,
    /// `release` and §4.1 pruning so [`ContextIndex::known_blocks`] is
    /// O(context blocks) instead of a full leaf scan. Derived state:
    /// rebuilt on snapshot restore, never serialized.
    block_dir: HashMap<BlockId, u32>,
    /// Incremental alive-slot count mirroring the arena filter-scan.
    alive_count: usize,
}

/// Result of a context search (Algorithm 1).
#[derive(Clone, Debug, PartialEq)]
pub struct SearchResult {
    /// Child-position indices from the root to the best match.
    pub path: Vec<usize>,
    pub node: NodeId,
}

impl ContextIndex {
    pub fn new(alpha: f64) -> Self {
        let root = IndexNode {
            context: Vec::new(),
            children: Vec::new(),
            parent: None,
            freq: 0,
            cluster_dist: f64::INFINITY,
            requests: Vec::new(),
            alive: true,
        };
        Self {
            nodes: vec![root],
            free: Vec::new(),
            root: 0,
            req_to_leaf: HashMap::new(),
            alpha,
            conversations: HashMap::new(),
            block_dir: HashMap::new(),
            alive_count: 1,
        }
    }

    pub fn node(&self, id: NodeId) -> &IndexNode {
        &self.nodes[id]
    }

    pub(crate) fn node_mut(&mut self, id: NodeId) -> &mut IndexNode {
        &mut self.nodes[id]
    }

    pub fn len_alive(&self) -> usize {
        debug_assert_eq!(
            self.alive_count,
            self.nodes.iter().filter(|n| n.alive).count(),
            "alive counter drifted from the arena scan"
        );
        self.alive_count
    }

    /// Arena size (alive + dead slots) — for id iteration.
    pub fn capacity(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_alive(&self, id: NodeId) -> bool {
        self.nodes[id].alive
    }

    /// Mark a node dead and recycle its slot (build-phase restructuring).
    pub(crate) fn release(&mut self, id: NodeId) {
        debug_assert!(id != self.root);
        if self.nodes[id].alive {
            if self.nodes[id].is_leaf() {
                self.dir_remove_leaf(id);
            }
            self.alive_count -= 1;
        }
        self.nodes[id].alive = false;
        self.nodes[id].children.clear();
        self.nodes[id].context.clear();
        for r in std::mem::take(&mut self.nodes[id].requests) {
            self.req_to_leaf.remove(&r);
        }
        self.free.push(id);
    }

    pub fn leaf_of_request(&self, req: RequestId) -> Option<NodeId> {
        self.req_to_leaf.get(&req).copied()
    }

    /// Placement probe ([`crate::serve::placement`]): how many distinct
    /// blocks of `context` appear in any alive leaf. Side-effect-free
    /// (`&self` — no `freq` ticks, unlike [`ContextIndex::search`]), so
    /// the serving layer can poll it per queued request, and — since the
    /// inverted block directory — O(context blocks) with zero allocation:
    /// one directory lookup per distinct block, independent of how many
    /// leaves are alive. Leaves carry full aligned contexts, so the
    /// directory covers everything the index knows; eviction pruning
    /// (§4.1) drops a pruned leaf's refcounts, which is exactly what keeps
    /// context-aware placement honest about what is still cached.
    pub fn known_blocks(&self, context: &Context) -> usize {
        let mut found = 0usize;
        for (i, b) in context.iter().enumerate() {
            if context[..i].contains(b) {
                continue; // duplicate within the probe: already looked up
            }
            if self.block_dir.contains_key(b) {
                found += 1;
            }
        }
        found
    }

    /// The pre-directory probe — a full scan over alive leaves with two
    /// scratch `HashSet`s. Kept only as the oracle that the property tests
    /// pin [`ContextIndex::known_blocks`] against.
    #[cfg(test)]
    pub(crate) fn known_blocks_scan(&self, context: &Context) -> usize {
        if context.is_empty() {
            return 0;
        }
        let want: HashSet<BlockId> = context.iter().copied().collect();
        let mut found: HashSet<BlockId> = HashSet::new();
        for n in self.nodes.iter().filter(|n| n.alive && n.is_leaf()) {
            for b in &n.context {
                if want.contains(b) {
                    found.insert(*b);
                    if found.len() == want.len() {
                        return found.len();
                    }
                }
            }
        }
        found.len()
    }

    /// Distinct blocks known to any alive leaf — the size of the inverted
    /// directory (surfaced per shard as
    /// [`ShardStats::index_blocks`](crate::metrics::ShardStats)).
    pub fn distinct_blocks(&self) -> usize {
        self.block_dir.len()
    }

    /// Copy the directory's key set into `out` (cleared first). The
    /// serving layer's probe-snapshot publish path uses this to hand the
    /// placement prober an owned block set it can read without taking the
    /// shard lock.
    pub fn copy_block_set_into(&self, out: &mut HashSet<BlockId>) {
        out.clear();
        out.extend(self.block_dir.keys().copied());
    }

    /// Count a (childless, alive) leaf's distinct blocks into the
    /// directory.
    fn dir_add_leaf(&mut self, id: NodeId) {
        let ctx = &self.nodes[id].context;
        for (i, b) in ctx.iter().enumerate() {
            if ctx[..i].contains(b) {
                continue;
            }
            *self.block_dir.entry(*b).or_insert(0) += 1;
        }
    }

    /// Drop a leaf's distinct blocks from the directory (refcounts that
    /// reach zero are removed, so `block_dir.len()` stays the distinct
    /// known-block count).
    fn dir_remove_leaf(&mut self, id: NodeId) {
        let ctx = &self.nodes[id].context;
        for (i, b) in ctx.iter().enumerate() {
            if ctx[..i].contains(b) {
                continue;
            }
            if let Some(n) = self.block_dir.get_mut(b) {
                *n -= 1;
                if *n == 0 {
                    self.block_dir.remove(b);
                }
            } else {
                debug_assert!(false, "directory missing a block of an alive leaf");
            }
        }
    }

    /// Recompute the derived state — the inverted block directory and the
    /// incremental alive counter — from the arena. Used after snapshot
    /// restore (derived maps are deliberately not serialized, keeping the
    /// snapshot format byte-identical to the pre-directory codec) and by
    /// test fixtures that hand-wire tree structure.
    fn rebuild_derived(&mut self) {
        self.alive_count = self.nodes.iter().filter(|n| n.alive).count();
        self.block_dir.clear();
        for id in 0..self.nodes.len() {
            if self.nodes[id].alive && self.nodes[id].is_leaf() {
                self.dir_add_leaf(id);
            }
        }
    }

    pub(crate) fn alloc(&mut self, node: IndexNode) -> NodeId {
        let id = if let Some(id) = self.free.pop() {
            self.nodes[id] = node;
            id
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        };
        if self.nodes[id].alive {
            self.alive_count += 1;
            // nodes allocated childless are leaves, and no production path
            // ever gives a leaf children afterwards (splits create a fresh
            // virtual parent), so counting here keeps the directory exact;
            // virtual nodes arrive with children and never contribute
            if self.nodes[id].is_leaf() {
                self.dir_add_leaf(id);
            }
        }
        id
    }

    pub(crate) fn register_request(&mut self, req: RequestId, leaf: NodeId) {
        if !self.nodes[leaf].requests.contains(&req) {
            self.nodes[leaf].requests.push(req);
        }
        self.req_to_leaf.insert(req, leaf);
    }

    // ---------------------------------------------------------------------
    // Algorithm 1: context search
    // ---------------------------------------------------------------------

    /// Greedy descent: at each level pick the overlapping child with the
    /// minimum Eq.-1 distance; stop at a leaf, when no child overlaps, or
    /// when the best children are equidistant *leaves* (the longest shared
    /// prefix is the current node, §4.2). Distance ties prefer virtual
    /// (internal) nodes — they represent shared prefixes with further
    /// reuse potential below them.
    pub fn search(&mut self, context: &Context) -> SearchResult {
        let mut cur = self.root;
        let mut path = Vec::new();
        loop {
            self.nodes[cur].freq += 1;
            let children = &self.nodes[cur].children;
            if children.is_empty() {
                return SearchResult { path, node: cur };
            }
            // score overlapping children: (distance, prefer-internal)
            let mut best: Option<(f64, bool, usize, NodeId)> = None;
            let mut tied_at_best = 0usize;
            for (pos, &c) in children.iter().enumerate() {
                let child = &self.nodes[c];
                if overlap_count(&child.context, context) == 0 {
                    continue;
                }
                let d = context_distance(&child.context, context, self.alpha);
                let internal = !child.is_leaf();
                match &mut best {
                    None => {
                        best = Some((d, internal, pos, c));
                        tied_at_best = 1;
                    }
                    Some((bd, bint, bpos, bc)) => {
                        if d < *bd - 1e-12 {
                            (*bd, *bint, *bpos, *bc) = (d, internal, pos, c);
                            tied_at_best = 1;
                        } else if (d - *bd).abs() <= 1e-12 {
                            tied_at_best += 1;
                            // tie-break: internal beats leaf
                            if internal && !*bint {
                                (*bd, *bint, *bpos, *bc) = (d, internal, pos, c);
                            }
                        }
                    }
                }
            }
            let Some((_, is_internal, pos, next)) = best else {
                // no overlapping child: cur is the best match
                return SearchResult { path, node: cur };
            };
            if tied_at_best > 1 && !is_internal {
                // equidistant leaves: cur already is the longest shared prefix
                return SearchResult { path, node: cur };
            }
            path.push(pos);
            if self.nodes[next].is_leaf() {
                self.nodes[next].freq += 1;
                return SearchResult { path, node: next };
            }
            cur = next;
        }
    }

    // ---------------------------------------------------------------------
    // insertion (§4.2)
    // ---------------------------------------------------------------------

    /// Insert an (aligned) context under the node found by `search`.
    /// Internal match: append as child, O(1). Leaf match: create a new
    /// virtual node with the shared prefix, O(|C|). Returns the new leaf
    /// and its search path.
    ///
    /// The split node's context is the longest common *block prefix* of
    /// the existing leaf and the (already aligned) incoming context — the
    /// part the engine's radix cache will actually share. (The offline
    /// clustering build uses sorted intersections followed by top-down
    /// re-alignment, which yields the same prefix property.)
    pub fn insert_at(
        &mut self,
        found: &SearchResult,
        context: Context,
        req: RequestId,
    ) -> (NodeId, Vec<usize>) {
        let target = found.node;
        if self.nodes[target].is_leaf() && target != self.root {
            // split: new virtual parent with the shared block prefix
            let inter: Context = self.nodes[target]
                .context
                .iter()
                .zip(context.iter())
                .take_while(|(a, b)| a == b)
                .map(|(a, _)| *a)
                .collect();
            let inter = if inter.is_empty() {
                sorted_intersection(&self.nodes[target].context, &context)
            } else {
                inter
            };
            let parent = self.nodes[target].parent.expect("non-root leaf has parent");
            let pos_in_parent = self.nodes[parent]
                .children
                .iter()
                .position(|&c| c == target)
                .expect("leaf linked in parent");
            let virt = self.alloc(IndexNode {
                context: inter,
                children: vec![target],
                parent: Some(parent),
                freq: self.nodes[target].freq,
                cluster_dist: 0.0,
                requests: Vec::new(),
                alive: true,
            });
            self.nodes[parent].children[pos_in_parent] = virt;
            self.nodes[target].parent = Some(virt);
            let leaf = self.alloc(IndexNode {
                context,
                children: Vec::new(),
                parent: Some(virt),
                freq: 1,
                cluster_dist: 0.0,
                requests: vec![req],
                alive: true,
            });
            self.nodes[virt].children.push(leaf);
            self.req_to_leaf.insert(req, leaf);
            let mut path = found.path.clone();
            path.push(1); // new leaf is the second child of the split node
            (leaf, path)
        } else {
            // internal (or root): append as a new child
            let leaf = self.alloc(IndexNode {
                context,
                children: Vec::new(),
                parent: Some(target),
                freq: 1,
                cluster_dist: 0.0,
                requests: vec![req],
                alive: true,
            });
            self.nodes[target].children.push(leaf);
            self.req_to_leaf.insert(req, leaf);
            let mut path = found.path.clone();
            path.push(self.nodes[target].children.len() - 1);
            (leaf, path)
        }
    }

    // ---------------------------------------------------------------------
    // eviction sync (§4.1) — O(h) per evicted request id
    // ---------------------------------------------------------------------

    /// Engine eviction callback: remove the leaves owned by these request
    /// ids and recursively prune empty parents.
    pub fn on_evict(&mut self, reqs: &[RequestId]) {
        for &r in reqs {
            if let Some(leaf) = self.req_to_leaf.remove(&r) {
                if self.nodes[leaf].alive {
                    self.nodes[leaf].requests.retain(|&x| x != r);
                    if self.nodes[leaf].requests.is_empty() {
                        // uncount the leaf here, not in `remove_node`: the
                        // recursive prune also removes transiently childless
                        // former-internal parents, which were never counted
                        // into the directory
                        self.dir_remove_leaf(leaf);
                        self.remove_node(leaf);
                    }
                }
            }
        }
    }

    fn remove_node(&mut self, id: NodeId) {
        debug_assert!(self.nodes[id].children.is_empty());
        let parent = self.nodes[id].parent;
        self.nodes[id].alive = false;
        self.alive_count -= 1;
        self.nodes[id].context.clear();
        for r in std::mem::take(&mut self.nodes[id].requests) {
            self.req_to_leaf.remove(&r);
        }
        self.free.push(id);
        if let Some(p) = parent {
            self.nodes[p].children.retain(|&c| c != id);
            if self.nodes[p].children.is_empty() && p != self.root {
                self.remove_node(p);
            }
        }
    }

    // ---------------------------------------------------------------------
    // traversal (§4.2)
    // ---------------------------------------------------------------------

    /// Follow a search path from the root; O(h).
    pub fn traverse(&self, path: &[usize]) -> Option<NodeId> {
        let mut cur = self.root;
        for &p in path {
            cur = *self.nodes[cur].children.get(p)?;
        }
        Some(cur)
    }

    /// Recompute the search path of a node by walking up; O(h·branching).
    pub fn path_of(&self, mut id: NodeId) -> Vec<usize> {
        let mut rev = Vec::new();
        while let Some(p) = self.nodes[id].parent {
            let pos = self.nodes[p]
                .children
                .iter()
                .position(|&c| c == id)
                .expect("node linked in parent");
            rev.push(pos);
            id = p;
        }
        rev.reverse();
        rev
    }

    // ---------------------------------------------------------------------
    // conversation records (for §6 de-duplication)
    // ---------------------------------------------------------------------

    pub fn conversation(&mut self, session: SessionId) -> &mut ConvRecord {
        self.conversations.entry(session).or_default()
    }

    pub fn conversation_ref(&self, session: SessionId) -> Option<&ConvRecord> {
        self.conversations.get(&session)
    }

    // ---------------------------------------------------------------------
    // snapshot / restore (durability)
    // ---------------------------------------------------------------------

    /// Serialize the full arena — alive *and* dead slots, the free list,
    /// the request backlinks, and the §6 conversation records — so that
    /// [`ContextIndex::from_snapshot`] reproduces the index
    /// byte-identically: node ids, child order, and freq clocks all
    /// survive, and re-snapshotting the restored index yields the exact
    /// same string (hash-map iteration order is canonicalized by sorting;
    /// `u64` counters ride as strings so values past 2^53 stay exact; the
    /// root's infinite `cluster_dist` uses an `"inf"` sentinel because the
    /// JSON codec cannot carry non-finite numbers).
    pub fn to_snapshot(&self) -> Json {
        fn dist(d: f64) -> Json {
            if d == f64::INFINITY {
                Json::str("inf")
            } else {
                Json::Num(d)
            }
        }
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| {
                Json::obj(vec![
                    (
                        "ctx",
                        Json::Arr(n.context.iter().map(|b| Json::Num(b.0 as f64)).collect()),
                    ),
                    (
                        "children",
                        Json::Arr(n.children.iter().map(|&c| Json::Num(c as f64)).collect()),
                    ),
                    ("parent", n.parent.map_or(Json::Null, |p| Json::Num(p as f64))),
                    ("freq", Json::u64(n.freq)),
                    ("dist", dist(n.cluster_dist)),
                    (
                        "reqs",
                        Json::Arr(n.requests.iter().map(|r| Json::u64(r.0)).collect()),
                    ),
                    ("alive", Json::Bool(n.alive)),
                ])
            })
            .collect();
        let mut backlinks: Vec<(u64, usize)> =
            self.req_to_leaf.iter().map(|(r, &l)| (r.0, l)).collect();
        backlinks.sort_unstable();
        let mut convs: Vec<(u32, &ConvRecord)> =
            self.conversations.iter().map(|(s, c)| (s.0, c)).collect();
        convs.sort_unstable_by_key(|(s, _)| *s);
        Json::obj(vec![
            ("alpha", Json::Num(self.alpha)),
            ("root", Json::Num(self.root as f64)),
            (
                "free",
                Json::Arr(self.free.iter().map(|&i| Json::Num(i as f64)).collect()),
            ),
            ("nodes", Json::Arr(nodes)),
            (
                "backlinks",
                Json::Arr(
                    backlinks
                        .into_iter()
                        .map(|(r, l)| Json::Arr(vec![Json::u64(r), Json::Num(l as f64)]))
                        .collect(),
                ),
            ),
            (
                "conversations",
                Json::Arr(
                    convs
                        .into_iter()
                        .map(|(s, c)| {
                            let mut blocks: Vec<u32> = c.seen_blocks.iter().map(|b| b.0).collect();
                            blocks.sort_unstable();
                            let mut subs: Vec<(u64, u32)> =
                                c.seen_subblocks.iter().map(|(&h, b)| (h, b.0)).collect();
                            subs.sort_unstable();
                            Json::obj(vec![
                                ("session", Json::Num(s as f64)),
                                (
                                    "blocks",
                                    Json::Arr(
                                        blocks.into_iter().map(|b| Json::Num(b as f64)).collect(),
                                    ),
                                ),
                                (
                                    "subblocks",
                                    Json::Arr(
                                        subs.into_iter()
                                            .map(|(h, b)| {
                                                Json::Arr(vec![
                                                    Json::u64(h),
                                                    Json::Num(b as f64),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Rebuild an index from [`ContextIndex::to_snapshot`] output. Every
    /// structural error — missing fields, out-of-range node ids, a dead
    /// root, backlinks into dead leaves — is a `Err(String)`, never a
    /// panic; the caller maps it to
    /// [`crate::api::Error::CorruptSnapshot`]. A successfully decoded
    /// index additionally passes [`ContextIndex::check_invariants`].
    pub fn from_snapshot(j: &Json) -> Result<ContextIndex, String> {
        fn node_id(j: &Json, bound: usize, what: &str) -> Result<NodeId, String> {
            let id = j.as_usize().ok_or_else(|| format!("{what}: not a node id"))?;
            if id >= bound {
                return Err(format!("{what}: node id {id} out of range (< {bound})"));
            }
            Ok(id)
        }
        let alpha = j.get("alpha").as_f64().ok_or("alpha missing")?;
        let nodes_j = j.get("nodes").as_arr().ok_or("nodes missing")?;
        let bound = nodes_j.len();
        if bound == 0 {
            return Err("empty node arena".to_string());
        }
        let mut nodes: Vec<IndexNode> = Vec::with_capacity(bound);
        for (i, nj) in nodes_j.iter().enumerate() {
            let context = nj
                .get("ctx")
                .as_arr()
                .ok_or_else(|| format!("node {i}: ctx missing"))?
                .iter()
                .map(|b| {
                    b.as_f64()
                        .filter(|n| n.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(n))
                        .map(|n| BlockId(n as u32))
                })
                .collect::<Option<Context>>()
                .ok_or_else(|| format!("node {i}: bad block id"))?;
            let children = nj
                .get("children")
                .as_arr()
                .ok_or_else(|| format!("node {i}: children missing"))?
                .iter()
                .map(|c| node_id(c, bound, &format!("node {i} child")))
                .collect::<Result<Vec<NodeId>, String>>()?;
            let parent = match nj.get("parent") {
                Json::Null => None,
                p => Some(node_id(p, bound, &format!("node {i} parent"))?),
            };
            let cluster_dist = match nj.get("dist") {
                Json::Str(s) if s == "inf" => f64::INFINITY,
                d => d
                    .as_f64()
                    .filter(|x| x.is_finite())
                    .ok_or_else(|| format!("node {i}: bad cluster_dist"))?,
            };
            let requests = nj
                .get("reqs")
                .as_arr()
                .ok_or_else(|| format!("node {i}: reqs missing"))?
                .iter()
                .map(|r| r.as_u64().map(RequestId))
                .collect::<Option<Vec<RequestId>>>()
                .ok_or_else(|| format!("node {i}: bad request id"))?;
            nodes.push(IndexNode {
                context,
                children,
                parent,
                freq: nj
                    .get("freq")
                    .as_u64()
                    .ok_or_else(|| format!("node {i}: bad freq"))?,
                cluster_dist,
                requests,
                alive: nj
                    .get("alive")
                    .as_bool()
                    .ok_or_else(|| format!("node {i}: bad alive flag"))?,
            });
        }
        let root = node_id(j.get("root"), bound, "root")?;
        if !nodes[root].alive || nodes[root].parent.is_some() {
            return Err("root must be an alive, parentless node".to_string());
        }
        let free = j
            .get("free")
            .as_arr()
            .ok_or("free list missing")?
            .iter()
            .map(|f| node_id(f, bound, "free slot"))
            .collect::<Result<Vec<NodeId>, String>>()?;
        for &f in &free {
            if nodes[f].alive {
                return Err(format!("free list holds alive node {f}"));
            }
        }
        let mut req_to_leaf: HashMap<RequestId, NodeId> = HashMap::new();
        for pair in j.get("backlinks").as_arr().ok_or("backlinks missing")? {
            let p = pair.as_arr().filter(|p| p.len() == 2).ok_or("bad backlink")?;
            let r = p[0].as_u64().map(RequestId).ok_or("bad backlink request")?;
            let leaf = node_id(&p[1], bound, "backlink leaf")?;
            if req_to_leaf.insert(r, leaf).is_some() {
                return Err(format!("request {} backlinked twice", r.0));
            }
        }
        let mut conversations: HashMap<SessionId, ConvRecord> = HashMap::new();
        for cj in j.get("conversations").as_arr().ok_or("conversations missing")? {
            let session = cj
                .get("session")
                .as_usize()
                .filter(|&s| s <= u32::MAX as usize)
                .map(|s| SessionId(s as u32))
                .ok_or("bad conversation session")?;
            let mut rec = ConvRecord::default();
            for b in cj.get("blocks").as_arr().ok_or("conversation blocks missing")? {
                let b = b
                    .as_usize()
                    .filter(|&v| v <= u32::MAX as usize)
                    .map(|v| BlockId(v as u32))
                    .ok_or("bad conversation block")?;
                rec.seen_blocks.insert(b);
            }
            for sb in cj
                .get("subblocks")
                .as_arr()
                .ok_or("conversation subblocks missing")?
            {
                let p = sb.as_arr().filter(|p| p.len() == 2).ok_or("bad subblock")?;
                let h = p[0].as_u64().ok_or("bad subblock hash")?;
                let b = p[1]
                    .as_usize()
                    .filter(|&v| v <= u32::MAX as usize)
                    .map(|v| BlockId(v as u32))
                    .ok_or("bad subblock block")?;
                rec.seen_subblocks.insert(h, b);
            }
            if conversations.insert(session, rec).is_some() {
                return Err("conversation recorded twice".to_string());
            }
        }
        let mut ix = ContextIndex {
            nodes,
            free,
            root,
            req_to_leaf,
            alpha,
            conversations,
            block_dir: HashMap::new(),
            alive_count: 0,
        };
        ix.rebuild_derived();
        ix.check_invariants()?;
        Ok(ix)
    }

    // ---------------------------------------------------------------------
    // invariants (tests / failure injection)
    // ---------------------------------------------------------------------

    pub fn check_invariants(&self) -> Result<(), String> {
        for (id, n) in self.nodes.iter().enumerate() {
            if !n.alive {
                continue;
            }
            for &c in &n.children {
                if !self.nodes[c].alive {
                    return Err(format!("node {id} has dead child {c}"));
                }
                if self.nodes[c].parent != Some(id) {
                    return Err(format!("child {c} parent mismatch (expect {id})"));
                }
            }
            if id != self.root {
                if n.parent.is_none() {
                    return Err(format!("non-root node {id} has no parent"));
                }
                if !n.is_leaf() && n.children.len() < 1 {
                    return Err(format!("internal node {id} with no children"));
                }
                // Note: no containment/overlap invariant is enforced
                // between virtual nodes and their children. The offline
                // clustering build produces subset-nested contexts, but
                // online inserts append children in O(1) without
                // restructuring (§4.2), so descendant splits can drift
                // from an ancestor's context. The index is a reuse
                // heuristic; correctness rests on the radix cache.
            }
            // path round-trip
            if n.is_leaf() && id != self.root {
                let p = self.path_of(id);
                if self.traverse(&p) != Some(id) {
                    return Err(format!("path round-trip failed for leaf {id}"));
                }
            }
        }
        for (&r, &leaf) in &self.req_to_leaf {
            if !self.nodes[leaf].alive {
                return Err(format!("request {r:?} maps to dead leaf {leaf}"));
            }
            if !self.nodes[leaf].requests.contains(&r) {
                return Err(format!("request {r:?} leaf backlink mismatch"));
            }
        }
        // derived state mirrors the arena exactly
        let alive_scan = self.nodes.iter().filter(|n| n.alive).count();
        if self.alive_count != alive_scan {
            return Err(format!(
                "alive counter {} != arena scan {alive_scan}",
                self.alive_count
            ));
        }
        let mut expect: HashMap<BlockId, u32> = HashMap::new();
        for n in self.nodes.iter().filter(|n| n.alive && n.is_leaf()) {
            for (i, b) in n.context.iter().enumerate() {
                if !n.context[..i].contains(b) {
                    *expect.entry(*b).or_insert(0) += 1;
                }
            }
        }
        if expect != self.block_dir {
            return Err(format!(
                "inverted block directory drifted from the leaf scan \
                 ({} entries vs {} expected)",
                self.block_dir.len(),
                expect.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(ids: &[u32]) -> Context {
        ids.iter().map(|&i| BlockId(i)).collect()
    }

    /// Build the paper's Figure-4 tree by hand:
    /// root -> C5{1} -> [C3{4,1,0}-aligned{1,4,0}, C4{1,2} -> [C1, C2]]
    fn fig4_index() -> (ContextIndex, NodeId, NodeId) {
        let mut ix = ContextIndex::new(0.001);
        let c5 = ix.alloc(IndexNode {
            context: ctx(&[1]),
            children: vec![],
            parent: Some(ix.root),
            freq: 0,
            cluster_dist: 0.9,
            requests: Vec::new(),
            alive: true,
        });
        ix.nodes[0].children.push(c5);
        let c4 = ix.alloc(IndexNode {
            context: ctx(&[1, 2]),
            children: vec![],
            parent: Some(c5),
            freq: 0,
            cluster_dist: 0.4,
            requests: Vec::new(),
            alive: true,
        });
        let c3 = ix.alloc(IndexNode {
            context: ctx(&[1, 4, 0]),
            children: vec![],
            parent: Some(c5),
            freq: 0,
            cluster_dist: 0.0,
            requests: vec![RequestId(3)],
            alive: true,
        });
        ix.nodes[c5].children.push(c4);
        ix.nodes[c5].children.push(c3);
        let c1 = ix.alloc(IndexNode {
            context: ctx(&[1, 2, 3]),
            children: vec![],
            parent: Some(c4),
            freq: 0,
            cluster_dist: 0.0,
            requests: vec![RequestId(1)],
            alive: true,
        });
        let c2 = ix.alloc(IndexNode {
            context: ctx(&[1, 2, 6]),
            children: vec![],
            parent: Some(c4),
            freq: 0,
            cluster_dist: 0.0,
            requests: vec![RequestId(2)],
            alive: true,
        });
        ix.nodes[c4].children.push(c1);
        ix.nodes[c4].children.push(c2);
        ix.req_to_leaf.insert(RequestId(1), c1);
        ix.req_to_leaf.insert(RequestId(2), c2);
        ix.req_to_leaf.insert(RequestId(3), c3);
        // the fixture allocs C5/C4 childless and wires their children by
        // hand, which no production path does — recompute the directory
        // and alive counter from the finished shape
        ix.rebuild_derived();
        ix.check_invariants().unwrap();
        (ix, c5, c4)
    }

    #[test]
    fn paper_search_example_c6() {
        // §4.2: C6{2,1,4} descends to C5 (path [0]), picks C4 over C3
        // (shares {1,2} vs {1}), then stops: C1 and C2 are equidistant.
        let (mut ix, _c5, c4) = fig4_index();
        let r = ix.search(&ctx(&[2, 1, 4]));
        assert_eq!(r.node, c4);
        assert_eq!(r.path, vec![0, 0]);
    }

    #[test]
    fn paper_insert_example_c6() {
        let (mut ix, _, c4) = fig4_index();
        let found = ix.search(&ctx(&[2, 1, 4]));
        let (leaf, path) = ix.insert_at(&found, ctx(&[1, 2, 4]), RequestId(6));
        // inserted as C4's third child -> final path [0,0,2]
        assert_eq!(path, vec![0, 0, 2]);
        assert_eq!(ix.traverse(&path), Some(leaf));
        assert_eq!(ix.node(c4).children.len(), 3);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn search_empty_index_returns_root() {
        let mut ix = ContextIndex::new(0.001);
        let r = ix.search(&ctx(&[1, 2]));
        assert_eq!(r.node, ix.root);
        assert!(r.path.is_empty());
    }

    #[test]
    fn unmatched_context_becomes_standalone_branch() {
        let (mut ix, _, _) = fig4_index();
        let found = ix.search(&ctx(&[7, 8, 9]));
        assert_eq!(found.node, ix.root);
        let (_, path) = ix.insert_at(&found, ctx(&[7, 8, 9]), RequestId(7));
        assert_eq!(path.len(), 1);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn leaf_match_splits_with_intersection() {
        let mut ix = ContextIndex::new(0.001);
        let found = ix.search(&ctx(&[1, 2, 3]));
        ix.insert_at(&found, ctx(&[1, 2, 3]), RequestId(1));
        // a very similar context matches the leaf and splits it
        let found2 = ix.search(&ctx(&[1, 2, 9]));
        assert!(ix.node(found2.node).is_leaf());
        let (leaf2, path2) = ix.insert_at(&found2, ctx(&[1, 2, 9]), RequestId(2));
        let virt = ix.node(leaf2).parent.unwrap();
        assert_eq!(ix.node(virt).context, ctx(&[1, 2]));
        assert_eq!(ix.node(virt).children.len(), 2);
        assert_eq!(path2.last(), Some(&1));
        ix.check_invariants().unwrap();
    }

    #[test]
    fn eviction_prunes_empty_parents() {
        let mut ix = ContextIndex::new(0.001);
        let f1 = ix.search(&ctx(&[1, 2, 3]));
        ix.insert_at(&f1, ctx(&[1, 2, 3]), RequestId(1));
        let f2 = ix.search(&ctx(&[1, 2, 9]));
        ix.insert_at(&f2, ctx(&[1, 2, 9]), RequestId(2));
        let alive_before = ix.len_alive();
        ix.on_evict(&[RequestId(1), RequestId(2)]);
        // both leaves and the virtual parent are gone; only root remains
        assert_eq!(ix.len_alive(), 1);
        assert!(alive_before > 1);
        assert!(ix.leaf_of_request(RequestId(1)).is_none());
        ix.check_invariants().unwrap();
    }

    #[test]
    fn eviction_of_unknown_request_is_noop() {
        let (mut ix, _, _) = fig4_index();
        let n = ix.len_alive();
        ix.on_evict(&[RequestId(999)]);
        assert_eq!(ix.len_alive(), n);
    }

    #[test]
    fn path_of_round_trips_after_mutation() {
        let (mut ix, _, _) = fig4_index();
        let f = ix.search(&ctx(&[1, 2, 4]));
        let (leaf, _) = ix.insert_at(&f, ctx(&[1, 2, 4]), RequestId(6));
        ix.on_evict(&[RequestId(1)]); // removes a sibling
        let p = ix.path_of(leaf);
        assert_eq!(ix.traverse(&p), Some(leaf));
        ix.check_invariants().unwrap();
    }

    #[test]
    fn conversation_records_isolated_per_session() {
        let mut ix = ContextIndex::new(0.001);
        ix.conversation(SessionId(1)).seen_blocks.insert(BlockId(5));
        assert!(ix
            .conversation_ref(SessionId(1))
            .unwrap()
            .seen_blocks
            .contains(&BlockId(5)));
        assert!(ix.conversation_ref(SessionId(2)).is_none());
    }

    #[test]
    fn known_blocks_probe_is_side_effect_free_and_tracks_eviction() {
        let (mut ix, _, _) = fig4_index();
        let freq_before: Vec<u64> = (0..ix.capacity()).map(|i| ix.node(i).freq).collect();
        // leaves hold {1,4,0}, {1,2,3}, {1,2,6}
        assert_eq!(ix.known_blocks(&ctx(&[1, 2, 4])), 3);
        assert_eq!(ix.known_blocks(&ctx(&[7, 8])), 0);
        assert_eq!(ix.known_blocks(&ctx(&[6, 9])), 1);
        assert_eq!(ix.known_blocks(&ctx(&[])), 0);
        let freq_after: Vec<u64> = (0..ix.capacity()).map(|i| ix.node(i).freq).collect();
        assert_eq!(freq_before, freq_after, "probe ticked freq counters");
        // §4.1 pruning shrinks the probe's view
        ix.on_evict(&[RequestId(1), RequestId(2)]);
        assert_eq!(ix.known_blocks(&ctx(&[2, 3, 6])), 0, "evicted leaves counted");
        assert_eq!(ix.known_blocks(&ctx(&[4, 0])), 2, "surviving leaf ignored");
    }

    #[test]
    fn freq_counts_accumulate_on_search() {
        let (mut ix, c5, _) = fig4_index();
        let f0 = ix.node(c5).freq;
        ix.search(&ctx(&[1, 4, 0]));
        ix.search(&ctx(&[1, 2, 3]));
        assert!(ix.node(c5).freq > f0);
    }

    // ---- snapshot / restore -----------------------------------------------

    use crate::util::prng::Rng;
    use crate::util::prop::{check, Config};

    /// A realistic index: interleaved insert/evict so the arena has dead
    /// slots and a non-empty free list, plus §6 conversation records with
    /// a sub-block hash past 2^53 (the f64-precision trap).
    fn seeded_index(rng: &mut Rng, ops: usize) -> ContextIndex {
        let mut ix = ContextIndex::new(0.001);
        let mut next_req = 0u64;
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..ops {
            if rng.below(4) < 3 || live.is_empty() {
                let len = 1 + rng.below(5);
                let c: Context = (0..len).map(|_| BlockId(rng.below(30) as u32)).collect();
                let f = ix.search(&c);
                ix.insert_at(&f, c, RequestId(next_req));
                live.push(next_req);
                next_req += 1;
            } else {
                let i = rng.below(live.len());
                ix.on_evict(&[RequestId(live.swap_remove(i))]);
            }
        }
        ix.conversation(SessionId(1)).seen_blocks.insert(BlockId(3));
        ix.conversation(SessionId(2))
            .seen_subblocks
            .insert(0xDEAD_BEEF_DEAD_BEEF, BlockId(7));
        ix
    }

    /// Satellite: snapshot → restore round-trips the index byte-identically
    /// on seeded workloads — invariants hold, `known_blocks` and `search`
    /// agree, and re-snapshotting reproduces the exact same string.
    #[test]
    fn prop_snapshot_restore_roundtrips_byte_identically() {
        check(
            "index snapshot round-trip",
            Config {
                cases: 48,
                base_seed: 0x55AA,
                max_size: 40,
            },
            |rng: &mut Rng, size| {
                let ix = seeded_index(rng, size.max(1));
                let snap = ix.to_snapshot().to_string();
                let parsed = Json::parse(&snap).map_err(|e| e.to_string())?;
                let restored =
                    ContextIndex::from_snapshot(&parsed).map_err(|e| format!("restore: {e}"))?;
                restored.check_invariants()?;
                if restored.to_snapshot().to_string() != snap {
                    return Err("re-snapshot diverged from the original".to_string());
                }
                for probe in [&[1u32, 2, 3][..], &[5][..], &[9, 10, 11, 12][..]] {
                    let c: Context = probe.iter().map(|&b| BlockId(b)).collect();
                    if restored.known_blocks(&c) != ix.known_blocks(&c) {
                        return Err("known_blocks diverged after restore".to_string());
                    }
                    // search mutates freq clocks: drive two clones in lockstep
                    let (mut a, mut b) = (ix.clone(), restored.clone());
                    if a.search(&c) != b.search(&c) {
                        return Err("search diverged after restore".to_string());
                    }
                }
                if restored.conversation_ref(SessionId(2)).map(|c| {
                    c.seen_subblocks.get(&0xDEAD_BEEF_DEAD_BEEF).copied()
                }) != Some(Some(BlockId(7)))
                {
                    return Err("sub-block hash lost precision".to_string());
                }
                Ok(())
            },
        );
    }

    /// Tentpole oracle: the directory-backed [`ContextIndex::known_blocks`]
    /// equals the pre-directory full leaf scan after every step of
    /// randomized insert / evict / snapshot-restore sequences, and the
    /// derived state (directory + alive counter) never drifts from the
    /// arena (`check_invariants` recomputes both).
    #[test]
    fn prop_directory_matches_leaf_scan() {
        check(
            "inverted directory == leaf scan",
            Config {
                cases: 48,
                base_seed: 0xB10C,
                max_size: 60,
            },
            |rng: &mut Rng, size| {
                let mut ix = ContextIndex::new(0.001);
                let mut next_req = 0u64;
                let mut live: Vec<u64> = Vec::new();
                for step in 0..size.max(1) {
                    let op = rng.below(8);
                    if op < 5 || live.is_empty() {
                        // insert (contexts may repeat blocks: the directory
                        // must count a leaf's block once, however often it
                        // appears in one context)
                        let len = 1 + rng.below(6);
                        let c: Context =
                            (0..len).map(|_| BlockId(rng.below(24) as u32)).collect();
                        let f = ix.search(&c);
                        ix.insert_at(&f, c, RequestId(next_req));
                        live.push(next_req);
                        next_req += 1;
                    } else if op < 7 {
                        // §4.1 eviction prune
                        let i = rng.below(live.len());
                        ix.on_evict(&[RequestId(live.swap_remove(i))]);
                    } else {
                        // snapshot → restore, then keep mutating the restored
                        // copy (its rebuilt directory must be seamless)
                        let snap = ix.to_snapshot().to_string();
                        let parsed = Json::parse(&snap).map_err(|e| e.to_string())?;
                        ix = ContextIndex::from_snapshot(&parsed)
                            .map_err(|e| format!("restore: {e}"))?;
                    }
                    for _ in 0..3 {
                        let len = rng.below(6);
                        let probe: Context =
                            (0..len).map(|_| BlockId(rng.below(30) as u32)).collect();
                        let (dir, scan) = (ix.known_blocks(&probe), ix.known_blocks_scan(&probe));
                        if dir != scan {
                            return Err(format!(
                                "step {step}: directory probe {dir} != leaf scan {scan} \
                                 for {probe:?}"
                            ));
                        }
                    }
                }
                ix.check_invariants()
            },
        );
    }

    /// Satellite: a damaged snapshot is an `Err`, never a panic.
    #[test]
    fn corrupt_snapshots_error_instead_of_panicking() {
        let (ix, _, _) = fig4_index();
        let good = ix.to_snapshot().to_string();
        assert!(ContextIndex::from_snapshot(&Json::parse(&good).unwrap()).is_ok());
        // truncation anywhere: either the JSON no longer parses, or the
        // decoded value is structurally rejected — in no case a panic
        for cut in 1..good.len() {
            if let Ok(j) = Json::parse(&good[..cut]) {
                assert!(ContextIndex::from_snapshot(&j).is_err(), "cut at {cut}");
            }
        }
        let one_node = r#""nodes":[{"alive":true,"children":[],"ctx":[],"dist":"inf","freq":"0","parent":null,"reqs":[]}]"#;
        for bad in [
            "null".to_string(),
            "{}".to_string(),
            // root out of range / dead / parented
            format!(r#"{{"alpha":0.001,"backlinks":[],"conversations":[],"free":[],{one_node},"root":5}}"#),
            // child id out of range
            format!(r#"{{"alpha":0.001,"backlinks":[],"conversations":[],"free":[],"nodes":[{{"alive":true,"children":[9],"ctx":[],"dist":"inf","freq":"0","parent":null,"reqs":[]}}],"root":0}}"#),
            // free list holding an alive node
            format!(r#"{{"alpha":0.001,"backlinks":[],"conversations":[],"free":[0],{one_node},"root":0}}"#),
            // backlink to a node that does not list the request
            format!(r#"{{"alpha":0.001,"backlinks":[["7",0]],"conversations":[],"free":[],{one_node},"root":0}}"#),
            // freq that is not a u64
            r#"{"alpha":0.001,"backlinks":[],"conversations":[],"free":[],"nodes":[{"alive":true,"children":[],"ctx":[],"dist":"inf","freq":-3,"parent":null,"reqs":[]}],"root":0}"#.to_string(),
        ] {
            let j = Json::parse(&bad).expect("test inputs are valid JSON");
            assert!(
                ContextIndex::from_snapshot(&j).is_err(),
                "accepted corrupt snapshot: {bad}"
            );
        }
    }
}
