//! Offline context-index construction via hierarchical clustering
//! (Algorithm 4, §4.1).
//!
//! Phase 1 — pairwise Eq.-1 distances + agglomerative clustering. We use
//! the nearest-neighbor-array formulation: O(N) memory, O(N^2) expected
//! time, with the initial neighbor scan parallelized across cores (the
//! paper parallelizes this phase on CPUs/GPUs; 2k contexts: 8 s CPU /
//! 0.82 s GPU).
//!
//! Phase 2 — build the tree with duplicate-context detection: identical
//! contexts share one leaf with a bumped frequency counter.
//!
//! Phase 3 — top-down prefix alignment: every node's context is reordered
//! to `parent.context ⊕ (context \ parent.context)`, so each leaf's final
//! ordering starts with the shared prefix its ancestors cache.

use std::collections::HashMap;

use crate::index::distance::{context_distance, sorted_intersection};
use crate::index::tree::{ContextIndex, IndexNode, NodeId};
use crate::types::{Context, RequestId};
use crate::util::threadpool::{default_threads, par_map};

/// Outcome of an offline build: the index plus each input's aligned
/// context and search path (initialization contexts inherit their prefix
/// from their parent chain, §5.1).
#[derive(Debug)]
pub struct BuildResult {
    pub index: ContextIndex,
    /// Per input (same order): (leaf node, aligned context, search path).
    pub placed: Vec<(NodeId, Context, Vec<usize>)>,
}

struct Cluster {
    context: Context,
    node: NodeId,
    alive: bool,
}

/// Distance between clusters; empty virtual contexts repel (they would
/// otherwise merge eagerly since d(∅,∅)=0).
fn cluster_distance(a: &Context, b: &Context, alpha: f64) -> f64 {
    if a.is_empty() || b.is_empty() {
        return 2.0;
    }
    context_distance(a, b, alpha)
}

/// Build the index over a batch of (request, context) pairs.
pub fn build_clustered(inputs: &[(RequestId, Context)], alpha: f64) -> BuildResult {
    build_clustered_with_threads(inputs, alpha, default_threads())
}

pub fn build_clustered_with_threads(
    inputs: &[(RequestId, Context)],
    alpha: f64,
    threads: usize,
) -> BuildResult {
    let mut index = ContextIndex::new(alpha);
    if inputs.is_empty() {
        return BuildResult {
            index,
            placed: Vec::new(),
        };
    }

    // ---- Phase 2a: leaves with duplicate detection -----------------------
    let mut leaf_of_context: HashMap<Context, NodeId> = HashMap::new();
    let mut clusters: Vec<Cluster> = Vec::new();
    // inputs index -> cluster leaf node
    let mut input_leaf: Vec<NodeId> = Vec::with_capacity(inputs.len());
    for (req, ctx) in inputs {
        if let Some(&leaf) = leaf_of_context.get(ctx) {
            // duplicate context: redirect, bump frequency
            index.node_mut(leaf).freq += 1;
            index.register_request(*req, leaf);
            input_leaf.push(leaf);
            continue;
        }
        let leaf = index.alloc(IndexNode {
            context: ctx.clone(),
            children: Vec::new(),
            parent: None, // linked during merging
            freq: 1,
            cluster_dist: 0.0,
            requests: vec![*req],
            alive: true,
        });
        index.register_request(*req, leaf);
        leaf_of_context.insert(ctx.clone(), leaf);
        clusters.push(Cluster {
            context: ctx.clone(),
            node: leaf,
            alive: true,
        });
        input_leaf.push(leaf);
    }

    // ---- Phase 1: agglomerative clustering (NN arrays) -------------------
    let n = clusters.len();
    let mut nn: Vec<(f64, usize)> = if n > 1 {
        let idx: Vec<usize> = (0..n).collect();
        par_map(&idx, threads, |&i| {
            let mut best = (f64::INFINITY, usize::MAX);
            for j in 0..n {
                if j == i {
                    continue;
                }
                let d = cluster_distance(&clusters[i].context, &clusters[j].context, alpha);
                if d < best.0 {
                    best = (d, j);
                }
            }
            best
        })
    } else {
        vec![(f64::INFINITY, usize::MAX)]
    };

    let mut active = n;
    while active > 1 {
        // closest pair via NN arrays
        let mut best = (f64::INFINITY, usize::MAX);
        for i in 0..clusters.len() {
            if clusters[i].alive && nn[i].0 < best.0 {
                best = (nn[i].0, i);
            }
        }
        let i = best.1;
        let j = nn[i].1;
        debug_assert!(clusters[i].alive && clusters[j].alive);
        let merged_ctx = sorted_intersection(&clusters[i].context, &clusters[j].context);
        let virt = index.alloc(IndexNode {
            context: merged_ctx.clone(),
            children: vec![clusters[i].node, clusters[j].node],
            parent: None,
            freq: 0,
            cluster_dist: best.0,
            requests: Vec::new(),
            alive: true,
        });
        index.node_mut(clusters[i].node).parent = Some(virt);
        index.node_mut(clusters[j].node).parent = Some(virt);
        // replace cluster i with merged, kill j
        clusters[i] = Cluster {
            context: merged_ctx,
            node: virt,
            alive: true,
        };
        clusters[j].alive = false;
        nn[j] = (f64::INFINITY, usize::MAX);
        active -= 1;
        if active == 1 {
            break;
        }
        // recompute NN for merged cluster and any cluster pointing at i/j
        for t in 0..clusters.len() {
            if !clusters[t].alive || t == i {
                continue;
            }
            let d = cluster_distance(&clusters[i].context, &clusters[t].context, alpha);
            if d < nn[t].0 {
                nn[t] = (d, i);
            } else if nn[t].1 == i || nn[t].1 == j {
                // stale: rescan
                let mut bb = (f64::INFINITY, usize::MAX);
                for u in 0..clusters.len() {
                    if u == t || !clusters[u].alive {
                        continue;
                    }
                    let du = cluster_distance(&clusters[t].context, &clusters[u].context, alpha);
                    if du < bb.0 {
                        bb = (du, u);
                    }
                }
                nn[t] = bb;
            }
        }
        {
            let mut bb = (f64::INFINITY, usize::MAX);
            for u in 0..clusters.len() {
                if u == i || !clusters[u].alive {
                    continue;
                }
                let du = cluster_distance(&clusters[i].context, &clusters[u].context, alpha);
                if du < bb.0 {
                    bb = (du, u);
                }
            }
            nn[i] = bb;
        }
    }

    // link the final cluster under the synthetic root
    let top = clusters.iter().find(|c| c.alive).map(|c| c.node);
    if let Some(top) = top {
        let root = index.root;
        index.node_mut(top).parent = Some(root);
        index.node_mut(root).children.push(top);
    }

    // ---- Phase 2b: remove empty internal nodes ---------------------------
    remove_empty_internals(&mut index);

    // ---- Phase 3: top-down prefix alignment ------------------------------
    align_top_down(&mut index);

    // collect placements for the inputs
    let placed = input_leaf
        .into_iter()
        .map(|leaf| {
            let aligned = index.node(leaf).context.clone();
            let path = index.path_of(leaf);
            (leaf, aligned, path)
        })
        .collect();

    BuildResult { index, placed }
}

/// Remove internal nodes whose context is empty (no shared prefix),
/// re-linking their children to the grandparent (Alg. 4 phase 2). The
/// synthetic root (also empty) is kept.
fn remove_empty_internals(index: &mut ContextIndex) {
    // iterate until fixpoint (removals can cascade)
    loop {
        let victim = (0..index.capacity())
            .find(|&id| {
                id != index.root
                    && index.is_alive(id)
                    && !index.node(id).is_leaf()
                    && index.node(id).context.is_empty()
            });
        let Some(v) = victim else { break };
        let parent = index.node(v).parent.expect("internal node has parent");
        let children = index.node(v).children.clone();
        let pos = index
            .node(parent)
            .children
            .iter()
            .position(|&c| c == v)
            .expect("linked");
        // splice children into parent's child list at v's position
        let mut new_children = index.node(parent).children.clone();
        new_children.remove(pos);
        for (off, c) in children.iter().enumerate() {
            new_children.insert(pos + off, *c);
            index.node_mut(*c).parent = Some(parent);
        }
        index.node_mut(parent).children = new_children;
        index.release(v);
    }
}

/// Phase 3: reorder every node's context to start with its parent's
/// (already aligned) context: `v.docs = parent.docs ⊕ (v.docs \ parent.docs)`.
fn align_top_down(index: &mut ContextIndex) {
    let root = index.root;
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(root);
    while let Some(v) = queue.pop_front() {
        let parent_ctx: Option<Context> = index.node(v).parent.map(|p| index.node(p).context.clone());
        if let Some(pc) = parent_ctx {
            if !pc.is_empty() {
                let own = index.node(v).context.clone();
                let in_parent: std::collections::HashSet<_> = pc.iter().copied().collect();
                let mut aligned: Context = pc
                    .iter()
                    .copied()
                    .filter(|b| own.contains(b))
                    .collect();
                aligned.extend(own.iter().copied().filter(|b| !in_parent.contains(b)));
                index.node_mut(v).context = aligned;
            }
        }
        for &c in &index.node(v).children {
            queue.push_back(c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::BlockId;

    fn ctx(ids: &[u32]) -> Context {
        ids.iter().map(|&i| BlockId(i)).collect()
    }

    fn fig4_inputs() -> Vec<(RequestId, Context)> {
        vec![
            (RequestId(1), ctx(&[2, 1, 3])),
            (RequestId(2), ctx(&[2, 6, 1])),
            (RequestId(3), ctx(&[4, 1, 0])),
        ]
    }

    #[test]
    fn paper_fig4_construction() {
        // C1{2,1,3} and C2{2,6,1} merge first (share {1,2}); C3 joins at
        // the root level with shared {1}.
        let r = build_clustered(&fig4_inputs(), 0.001);
        r.index.check_invariants().unwrap();
        // C1's aligned context must start with the sorted shared prefix {1,2}
        let (_, aligned_c1, _) = &r.placed[0];
        assert_eq!(&aligned_c1[..2], &ctx(&[1, 2])[..]);
        assert_eq!(aligned_c1[2], BlockId(3));
        let (_, aligned_c2, _) = &r.placed[1];
        assert_eq!(&aligned_c2[..2], &ctx(&[1, 2])[..]);
        assert_eq!(aligned_c2[2], BlockId(6));
        // C3 aligned starts with {1}
        let (_, aligned_c3, _) = &r.placed[2];
        assert_eq!(aligned_c3[0], BlockId(1));
    }

    #[test]
    fn fig4_tree_shape() {
        let mut r = build_clustered(&fig4_inputs(), 0.001);
        // C4 (virtual) has context {1,2}; root-level virtual C5 has {1}
        let s = r.index.search(&ctx(&[2, 1, 4]));
        let n = r.index.node(s.node);
        assert_eq!(n.context, ctx(&[1, 2]), "search should land on C4");
        assert_eq!(s.path, vec![0, 0]);
    }

    #[test]
    fn duplicate_contexts_share_leaf() {
        let inputs = vec![
            (RequestId(1), ctx(&[1, 2, 3])),
            (RequestId(2), ctx(&[1, 2, 3])),
            (RequestId(3), ctx(&[9, 8, 7])),
        ];
        let r = build_clustered(&inputs, 0.001);
        assert_eq!(r.placed[0].0, r.placed[1].0, "dup contexts share a leaf");
        assert_eq!(r.index.node(r.placed[0].0).freq, 2);
        r.index.check_invariants().unwrap();
    }

    #[test]
    fn disjoint_groups_get_empty_merges_removed() {
        let inputs = vec![
            (RequestId(1), ctx(&[1, 2])),
            (RequestId(2), ctx(&[1, 3])),
            (RequestId(3), ctx(&[10, 11])),
            (RequestId(4), ctx(&[10, 12])),
        ];
        let r = build_clustered(&inputs, 0.001);
        r.index.check_invariants().unwrap();
        // no alive internal node (other than root) may have empty context
        for id in 0..r.index.capacity() {
            if r.index.is_alive(id) && id != r.index.root {
                let n = r.index.node(id);
                if !n.is_leaf() {
                    assert!(!n.context.is_empty(), "empty internal node {id} survived");
                }
            }
        }
    }

    #[test]
    fn single_input() {
        let r = build_clustered(&[(RequestId(1), ctx(&[5, 6]))], 0.001);
        assert_eq!(r.placed.len(), 1);
        assert_eq!(r.placed[0].1, ctx(&[5, 6]));
        r.index.check_invariants().unwrap();
    }

    #[test]
    fn empty_input() {
        let r = build_clustered(&[], 0.001);
        assert_eq!(r.placed.len(), 0);
        assert_eq!(r.index.len_alive(), 1);
    }

    #[test]
    fn all_paths_round_trip() {
        let inputs: Vec<(RequestId, Context)> = (0..40u64)
            .map(|i| {
                let mut rng = crate::util::prng::Rng::new(i);
                let ids = rng.sample_indices(30, 5);
                (
                    RequestId(i),
                    ids.into_iter().map(|x| BlockId(x as u32)).collect(),
                )
            })
            .collect();
        let r = build_clustered(&inputs, 0.001);
        r.index.check_invariants().unwrap();
        for (leaf, _, path) in &r.placed {
            assert_eq!(r.index.traverse(path), Some(*leaf));
        }
    }

    #[test]
    fn aligned_context_is_permutation_of_input() {
        let inputs = fig4_inputs();
        let r = build_clustered(&inputs, 0.001);
        for ((_, original), (_, aligned, _)) in inputs.iter().zip(&r.placed) {
            let mut a = original.clone();
            let mut b = aligned.clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "alignment must be a permutation");
        }
    }

    #[test]
    fn threads_do_not_change_result() {
        let inputs: Vec<(RequestId, Context)> = (0..30u64)
            .map(|i| {
                let mut rng = crate::util::prng::Rng::new(i * 7);
                let ids = rng.sample_indices(20, 4);
                (
                    RequestId(i),
                    ids.into_iter().map(|x| BlockId(x as u32)).collect(),
                )
            })
            .collect();
        let a = build_clustered_with_threads(&inputs, 0.001, 1);
        let b = build_clustered_with_threads(&inputs, 0.001, 4);
        for ((_, ca, pa), (_, cb, pb)) in a.placed.iter().zip(&b.placed) {
            assert_eq!(ca, cb);
            assert_eq!(pa, pb);
        }
    }
}
