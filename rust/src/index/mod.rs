//! The paper's context index (§4): Eq.-1 distance, tree structure,
//! Algorithm-1 search, O(h) eviction sync, and Algorithm-4 offline
//! construction via hierarchical clustering.

pub mod build;
pub mod distance;
pub mod tree;

pub use build::{build_clustered, BuildResult};
pub use distance::{context_distance, sorted_intersection, DEFAULT_ALPHA};
pub use tree::{ContextIndex, ConvRecord, IndexNode, NodeId, SearchResult};
