//! The proxy ↔ engine contract (paper §4.1, Fig. 3).
//!
//! ContextPilot's headline architectural claim is a *clean interface that
//! integrates with existing inference engines*: the proxy rewrites prompts
//! and schedules batches, the engine owns the KV cache and reports
//! evictions back by request id. [`InferenceEngine`] captures exactly that
//! surface, so every serving layer ([`crate::serve`], the experiment
//! runner, the CLI) is generic over the backend:
//!
//! ```text
//!             ContextPilot proxy (align / dedup / annotate / Alg.-5)
//!                               │ serve(request, prompt)
//!                               ▼
//!                    trait InferenceEngine
//!                      │                │
//!            ┌─────────┴───────┐ ┌──────┴─────────────┐
//!            │ engine::SimEngine│ │ runtime::RealEngine│
//!            │ (latency model + │ │ (TinyLM via PJRT,  │
//!            │  radix cache)    │ │  `pjrt` feature)   │
//!            └──────────────────┘ └────────────────────┘
//! ```
//!
//! The trait is deliberately narrow: `serve` returns the engine request
//! ids evicted to make room (the §4.1 eviction callback the proxy's
//! context index consumes), `peek_cached`/`lpm_order` expose the
//! side-effect-free cache introspection schedulers need, and
//! `chunk_boundaries` exposes the prefix-shareable token offsets the
//! chunked-prefill admission layer splits long prefills at.
//!
//! `Send + 'static` are supertraits because the sharded serving engine
//! behind [`crate::api::Server`] moves one engine instance behind each
//! shard mutex and drives shards both from a worker pool and from the
//! long-lived per-shard scheduler threads ([`crate::serve`]'s sched
//! layer), which outlive any single call frame.

use crate::corpus::Corpus;
use crate::quality::QualityModel;
use crate::types::{Prompt, Request, RequestId, ServedRequest};

/// Prefix-cache counters every engine exposes for telemetry
/// ([`crate::metrics::ShardStats`], Fig. 12/13 reporting).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Tokens currently resident in the KV/prefix cache.
    pub resident_tokens: usize,
    /// Cache capacity in tokens.
    pub capacity_tokens: usize,
    /// Cumulative tokens looked up.
    pub lookup_tokens: u64,
    /// Cumulative tokens matched (hits).
    pub matched_tokens: u64,
    /// Cumulative tokens inserted.
    pub inserted_tokens: u64,
    /// Cumulative tokens evicted.
    pub evicted_tokens: u64,
    // -- tier-store counters (zero for engines without tiering) ----------
    /// Tokens resident in the DRAM tier ([`crate::cache::TierStore`]).
    pub dram_resident_tokens: usize,
    /// Tokens resident in the SSD tier.
    pub ssd_resident_tokens: usize,
    /// Cumulative hit tokens served hot from HBM.
    pub hot_hit_tokens: u64,
    /// Cumulative hit tokens promoted from DRAM (warm).
    pub warm_hit_tokens: u64,
    /// Cumulative hit tokens promoted from SSD (cold).
    pub cold_hit_tokens: u64,
    /// Cumulative tokens demoted into the tier store on eviction.
    pub demoted_tokens: u64,
    /// Cumulative tokens promoted back into HBM from a cold tier.
    pub promoted_tokens: u64,
    /// Cumulative tokens that left the hierarchy entirely (admission
    /// refusal or last-tier overflow) — discard-mode eviction reports 0
    /// here and everything under `evicted_tokens`.
    pub discarded_tokens: u64,
}

/// The engine side of the proxy↔engine contract (§4.1).
///
/// Implementations: [`crate::engine::sim::SimEngine`] (simulated latency
/// model, always available), [`crate::runtime::RealEngine`] (PJRT-backed
/// TinyLM, behind the `pjrt` feature) and
/// [`crate::util::prop::MockEngine`] (scripted, for serving-layer tests).
pub trait InferenceEngine: Send + 'static {
    /// Serve one request: prefill `prompt` (reusing whatever prefix the
    /// cache holds), decode, and return the served record plus the engine
    /// request ids evicted to make room — the caller must feed those to
    /// [`crate::pilot::ContextPilot::on_evict`] (§4.1).
    fn serve(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
        quality: &QualityModel,
        decode_tokens: usize,
    ) -> (ServedRequest, Vec<RequestId>);

    /// How many leading tokens of this prompt would hit the cache right
    /// now. Must be observably side-effect-free: no LRU touch, no stat
    /// counters (schedulers poll this per queued request).
    fn peek_cached(&mut self, req: &Request, prompt: &Prompt, corpus: &Corpus) -> usize;

    /// SGLang-style longest-prefix-match queue ordering: indices of
    /// `batch` sorted by currently-cached baseline-prompt prefix length,
    /// descending (stable, so arrival order breaks ties).
    fn lpm_order(&mut self, batch: &[Request], corpus: &Corpus) -> Vec<usize> {
        let peeks: Vec<usize> = batch
            .iter()
            .map(|r| self.peek_cached(r, &Prompt::baseline(r), corpus))
            .collect();
        let mut order: Vec<usize> = (0..batch.len()).collect();
        order.sort_by(|&a, &b| peeks[b].cmp(&peeks[a]));
        order
    }

    /// Whether baseline (pilot-less) queues should be LPM-ordered for this
    /// engine. Engines whose reuse mechanism is not prefix-shaped (e.g.
    /// CacheBlend-style block matching) serve in arrival order instead.
    fn prefers_lpm(&self) -> bool {
        true
    }

    /// Token offsets (ascending, last == total prompt tokens) at which the
    /// rendered prompt can be split without breaking prefix sharing — the
    /// positions where radix-cache nodes naturally end (segment/snapshot
    /// boundaries). The chunked-prefill admission layer snaps chunk cuts
    /// to these.
    fn chunk_boundaries(&mut self, req: &Request, prompt: &Prompt, corpus: &Corpus)
        -> Vec<usize>;

    /// Conversation sessions tracked by this engine (serving telemetry).
    fn session_count(&self) -> usize {
        0
    }

    /// Durable-shutdown hook: demote every resident hot/warm span into
    /// the cold tier's storage backend and flush it. Returns the request
    /// ids whose content finally left the hierarchy (capacity overflow) —
    /// the caller must feed them to §4.1 pruning *before* snapshotting
    /// its context index, exactly as it would serve-time evictions. An
    /// `Err` carries the storage backend's failure message (the facade
    /// maps it to [`crate::api::Error::Storage`]). Engines without a
    /// durable cold tier have nothing to spill: the default is a no-op.
    fn spill_for_checkpoint(&mut self) -> Result<Vec<RequestId>, String> {
        Ok(Vec::new())
    }

    /// Prefix-cache occupancy and cumulative hit/miss counters.
    fn cache_stats(&self) -> CacheStats;
}
