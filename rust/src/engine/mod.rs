//! Inference-engine layer: cost profiles, prompt rendering, the simulated
//! serving engine (paper-scale sweeps), and the multi-worker router.
//! The real PJRT-backed engine lives in [`crate::runtime`].

pub mod costmodel;
pub mod render;
pub mod router;
pub mod sim;

pub use costmodel::{CostProfile, ModelSku};
pub use render::Renderer;
pub use router::{RoutePolicy, Router};
pub use sim::{ReusePolicy, SimEngine};
