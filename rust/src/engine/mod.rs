//! Inference-engine layer: the [`InferenceEngine`] trait (the proxy↔engine
//! contract of §4.1), cost profiles, prompt rendering, the simulated
//! serving engine (paper-scale sweeps), and the multi-worker router.
//! The real PJRT-backed engine lives in [`crate::runtime`] and implements
//! the same trait behind the `pjrt` feature.

pub mod costmodel;
pub mod iface;
pub mod render;
pub mod router;
pub mod sim;

pub use costmodel::{CostProfile, ModelSku};
pub use iface::{CacheStats, InferenceEngine};
pub use render::Renderer;
pub use router::{RoutePolicy, Router};
pub use sim::{ReusePolicy, SimEngine};
