//! Inference-engine layer: the [`InferenceEngine`] trait (the proxy↔engine
//! contract of §4.1), cost profiles, prompt rendering, and the simulated
//! serving engine (paper-scale sweeps). The real PJRT-backed engine lives
//! in [`crate::runtime`] and implements the same trait behind the `pjrt`
//! feature.
//!
//! Multi-worker routing no longer lives here: the §7.2 context-aware
//! routing that the old `engine::Router` prototyped is now a first-class
//! placement policy of the serving layer ([`crate::serve::placement`]),
//! where it probes real per-shard state instead of a shadow map.

pub mod costmodel;
pub mod iface;
pub mod render;
pub mod sim;

pub use costmodel::{CostProfile, ModelSku};
pub use iface::{CacheStats, InferenceEngine};
pub use render::Renderer;
pub use sim::{ReusePolicy, SimEngine};
