//! Analytic latency model for paper-scale model/hardware combinations
//! (DESIGN.md §5 Substitutions).
//!
//! ContextPilot's gains come from *which tokens skip prefill*; latency is
//! `uncached_tokens / prefill_rate + overhead`, with per-system extras
//! (LMCache CPU-offload loads, CacheBlend partial recompute). Rates are
//! anchored to the paper's own reported vanilla throughputs so ratios —
//! who wins, by how much — are meaningful; absolute numbers are not
//! claimed (see EXPERIMENTS.md).

#[allow(non_camel_case_types)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelSku {
    Qwen3_4B,
    Llama31_8B,
    Qwen3_32B,
    Qwen3_30BA3B,
    Llama33_70B,
    DeepSeekR1_16xH20,
    DeepSeekR1_32xH20,
    /// Llama-3.2-1B on an M3 MacBook Air (llama.cpp, bs=1).
    Edge1B_M3Air,
    /// Llama-3.2-1B on a Jetson AGX Orin.
    Edge1B_Jetson,
    /// Qwen3-4B on a single RTX 5090 (OpenClaw deployment).
    Qwen3_4B_RTX5090,
}

#[derive(Clone, Copy, Debug)]
pub struct CostProfile {
    pub sku: ModelSku,
    /// Raw prefill rate for uncached tokens (tok/s).
    pub prefill_rate: f64,
    /// Decode rate (tok/s).
    pub decode_rate: f64,
    /// Fixed per-request overhead (scheduling, tokenize, launch) seconds.
    pub overhead_s: f64,
    /// Cost per *reused* token when KV must be fetched from CPU/offload
    /// tiers (LMCache's penalty; 0 for GPU-resident caches).
    pub offload_s_per_tok: f64,
}

impl ModelSku {
    pub fn name(&self) -> &'static str {
        match self {
            ModelSku::Qwen3_4B => "Qwen3-4B-Instruct-2507",
            ModelSku::Llama31_8B => "Llama3.1-8B-Instruct",
            ModelSku::Qwen3_32B => "Qwen3-32B",
            ModelSku::Qwen3_30BA3B => "Qwen3-30B-A3B-Thinking-2507",
            ModelSku::Llama33_70B => "Llama3.3-70B-Instruct",
            ModelSku::DeepSeekR1_16xH20 => "DeepSeek-R1 (16xH20)",
            ModelSku::DeepSeekR1_32xH20 => "DeepSeek-R1 (32xH20)",
            ModelSku::Edge1B_M3Air => "Llama-3.2-1B (M3 MacBook Air)",
            ModelSku::Edge1B_Jetson => "Llama-3.2-1B (Jetson AGX Orin)",
            ModelSku::Qwen3_4B_RTX5090 => "Qwen3-4B (RTX 5090)",
        }
    }

    /// Anchored to the paper's vanilla (no-reuse) throughputs on H100
    /// unless stated otherwise (Table 2 LMCache column ~ vanilla + offload;
    /// Table 6 vanilla rows for DeepSeek-R1; Table 5 edge latencies).
    pub fn profile(&self) -> CostProfile {
        let (prefill_rate, decode_rate, overhead_s, offload) = match self {
            ModelSku::Qwen3_4B => (60_000.0, 180.0, 0.010, 0.0),
            ModelSku::Llama31_8B => (42_000.0, 140.0, 0.010, 0.0),
            ModelSku::Qwen3_32B => (20_000.0, 80.0, 0.015, 0.0),
            ModelSku::Qwen3_30BA3B => (26_000.0, 110.0, 0.015, 0.0),
            ModelSku::Llama33_70B => (14_000.0, 45.0, 0.020, 0.0),
            ModelSku::DeepSeekR1_16xH20 => (10_200.0, 60.0, 0.050, 0.0),
            ModelSku::DeepSeekR1_32xH20 => (19_400.0, 110.0, 0.050, 0.0),
            ModelSku::Edge1B_M3Air => (700.0, 35.0, 0.050, 0.0),
            ModelSku::Edge1B_Jetson => (1_500.0, 50.0, 0.050, 0.0),
            ModelSku::Qwen3_4B_RTX5090 => (7_000.0, 90.0, 0.020, 0.0),
        };
        CostProfile {
            sku: *self,
            prefill_rate,
            decode_rate,
            overhead_s,
            offload_s_per_tok: offload,
        }
    }
}

impl CostProfile {
    /// Prefill latency (== TTFT contribution) for a prompt where
    /// `cached` of `total` tokens hit the KV cache.
    pub fn prefill_latency(&self, total: usize, cached: usize) -> f64 {
        let uncached = total.saturating_sub(cached);
        self.overhead_s
            + uncached as f64 / self.prefill_rate
            + cached as f64 * self.offload_s_per_tok
    }

    /// Decode wall time for `n` output tokens.
    pub fn decode_latency(&self, n: usize) -> f64 {
        n as f64 / self.decode_rate
    }

    /// Variant with an LMCache-style CPU offload penalty.
    pub fn with_offload(mut self, s_per_tok: f64) -> Self {
        self.offload_s_per_tok = s_per_tok;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caching_reduces_latency() {
        let p = ModelSku::Qwen3_32B.profile();
        let cold = p.prefill_latency(20_000, 0);
        let warm = p.prefill_latency(20_000, 15_000);
        assert!(warm < cold);
        assert!((cold - 0.015 - 1.0).abs() < 1e-9); // 20k tok @ 20k tok/s
    }

    #[test]
    fn offload_penalizes_reuse() {
        let p = ModelSku::Qwen3_32B.profile().with_offload(1e-5);
        let no_reuse = p.prefill_latency(10_000, 0);
        let full_reuse = p.prefill_latency(10_000, 10_000);
        assert!(full_reuse < no_reuse, "offload reuse must still win");
        assert!(full_reuse > p.overhead_s, "offload not free");
    }

    #[test]
    fn bigger_models_are_slower() {
        let t4 = ModelSku::Qwen3_4B.profile().prefill_latency(10_000, 0);
        let t32 = ModelSku::Qwen3_32B.profile().prefill_latency(10_000, 0);
        let t70 = ModelSku::Llama33_70B.profile().prefill_latency(10_000, 0);
        assert!(t4 < t32 && t32 < t70);
    }

    #[test]
    fn paper_scale_sanity_32b_20k_tokens_seconds() {
        // §2.2: 20k-130k prefill tokens => 3-10 s on a 32B dense model.
        let p = ModelSku::Qwen3_32B.profile();
        let lat = p.prefill_latency(60_000, 0);
        assert!((1.0..10.0).contains(&lat), "{lat}");
    }

    #[test]
    fn all_profiles_well_formed() {
        for sku in [
            ModelSku::Qwen3_4B,
            ModelSku::Llama31_8B,
            ModelSku::Qwen3_32B,
            ModelSku::Qwen3_30BA3B,
            ModelSku::Llama33_70B,
            ModelSku::DeepSeekR1_16xH20,
            ModelSku::DeepSeekR1_32xH20,
            ModelSku::Edge1B_M3Air,
            ModelSku::Edge1B_Jetson,
            ModelSku::Qwen3_4B_RTX5090,
        ] {
            let p = sku.profile();
            assert!(p.prefill_rate > 0.0 && p.decode_rate > 0.0);
            assert!(p.overhead_s >= 0.0);
            assert!(!sku.name().is_empty());
        }
    }
}
