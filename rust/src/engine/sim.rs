//! Simulated inference engine: a faithful prefix-cache + latency model for
//! paper-scale sweeps (the *real* PJRT engine lives in `runtime/`).
//!
//! The engine owns the radix prefix cache, per-session conversation
//! history, and the reuse policy under test. The three baseline systems
//! are mechanism-level re-implementations (DESIGN.md §5):
//!
//!  * `RadixPrefix` — token-level longest-prefix reuse (SGLang RadixCache;
//!    also what ContextPilot-rewritten prompts run on);
//!  * `DocPrefix` — document-granular exact prefix matching with a CPU
//!    offload penalty per reused token (LMCache);
//!  * `Approximate` — CacheBlend-style KV matching: block KV reused at any
//!    position with a partial-recompute fraction, at an accuracy cost
//!    (`kv_noise`).

use std::collections::{HashMap, HashSet};

use crate::cache::{RadixCache, Storage, StorageError, Tier, TierConfig, TierStore};
use crate::corpus::Corpus;
use crate::engine::costmodel::CostProfile;
use crate::engine::iface::{CacheStats, InferenceEngine};
use crate::engine::render::Renderer;
use crate::quality::QualityModel;
use crate::tokenizer::Tokenizer;
use crate::types::{
    BlockId, Prompt, Request, RequestId, Segment, ServedRequest, SessionId, TierHits,
};

#[derive(Clone, Copy, Debug)]
pub enum ReusePolicy {
    RadixPrefix,
    DocPrefix { offload_s_per_tok: f64 },
    Approximate { recompute_frac: f64, kv_noise: f64 },
}

impl ReusePolicy {
    pub fn kv_noise(&self) -> f64 {
        match self {
            ReusePolicy::Approximate { kv_noise, .. } => *kv_noise,
            _ => 0.0,
        }
    }
}

pub struct SimEngine {
    pub cache: RadixCache<()>,
    pub renderer: Renderer,
    pub profile: CostProfile,
    pub policy: ReusePolicy,
    /// DRAM/SSD tiers behind the radix cache: eviction demotes into it,
    /// prefix matches landing there promote at the tier's reload cost.
    /// `None` = classic discard-mode eviction. Only meaningful for
    /// [`ReusePolicy::RadixPrefix`] (the prefix-shaped mechanism).
    tiers: Option<TierStore<()>>,
    /// Cumulative per-tier hit tokens (Fig. 12/13-style reporting plus the
    /// tier axis).
    stat_hot_hit_tokens: u64,
    stat_warm_hit_tokens: u64,
    stat_cold_hit_tokens: u64,
    /// Token history per conversation (prior prompts + answers).
    history: HashMap<SessionId, Vec<u32>>,
    history_blocks: HashMap<SessionId, HashSet<BlockId>>,
    /// CacheBlend block store: block -> token length held.
    blend_store: HashMap<BlockId, usize>,
    blend_order: Vec<BlockId>,
    blend_resident: usize,
}

impl SimEngine {
    pub fn new(profile: CostProfile, policy: ReusePolicy, capacity_tokens: usize) -> Self {
        Self {
            cache: RadixCache::new(capacity_tokens),
            renderer: Renderer::new(Tokenizer::default()),
            profile,
            policy,
            tiers: None,
            stat_hot_hit_tokens: 0,
            stat_warm_hit_tokens: 0,
            stat_cold_hit_tokens: 0,
            history: HashMap::new(),
            history_blocks: HashMap::new(),
            blend_store: HashMap::new(),
            blend_order: Vec::new(),
            blend_resident: 0,
        }
    }

    /// Engine with a DRAM/SSD tier store behind the radix cache
    /// (`capacity_tokens` remains the HBM budget). Eviction becomes
    /// demotion and cold-tier prefix matches promote at the owning tier's
    /// reload cost; the admission comparator is this profile's recompute
    /// rate. Tiering is prefix-shaped, so for non-radix policies the
    /// config is ignored (classic discard eviction).
    pub fn with_tiers(
        profile: CostProfile,
        policy: ReusePolicy,
        capacity_tokens: usize,
        tier_cfg: &TierConfig,
    ) -> Self {
        let mut engine = SimEngine::new(profile, policy, capacity_tokens);
        if matches!(policy, ReusePolicy::RadixPrefix) {
            engine.cache.enable_demotion();
            engine.tiers = Some(TierStore::new(tier_cfg, 1.0 / profile.prefill_rate));
        }
        engine
    }

    /// Like [`SimEngine::with_tiers`], but the cold (SSD) shelf is
    /// mirrored into a durable [`Storage`] backend. `rehydrate = true`
    /// re-seeds the shelf from whatever the backend already holds (the
    /// resume path); `false` starts cold over a fresh/truncated backend.
    /// Non-radix policies have no tier store, so the backend is dropped —
    /// durability, like tiering, is prefix-shaped only.
    pub fn with_tiers_storage(
        profile: CostProfile,
        policy: ReusePolicy,
        capacity_tokens: usize,
        tier_cfg: &TierConfig,
        store: Box<dyn Storage>,
        rehydrate: bool,
    ) -> Result<Self, StorageError> {
        let mut engine = SimEngine::new(profile, policy, capacity_tokens);
        if matches!(policy, ReusePolicy::RadixPrefix) {
            engine.cache.enable_demotion();
            engine.tiers = Some(TierStore::with_storage(
                tier_cfg,
                1.0 / profile.prefill_rate,
                store,
                rehydrate,
            )?);
        }
        Ok(engine)
    }

    /// Number of conversation sessions tracked by this engine — serving
    /// layer telemetry ([`crate::metrics::ShardStats`]).
    pub fn session_count(&self) -> usize {
        self.history.len()
    }

    /// Durable shutdown: evict every resident HBM span through the
    /// demotion sink, spill it (and the whole DRAM shelf) into the SSD
    /// tier, and flush the storage backend. Returns the request ids whose
    /// content could not fit — the caller prunes the §4.1 index with them
    /// before snapshotting it, exactly as for serve-time discards. The
    /// spill bypasses the admission *cost* gate (this is shutdown, not
    /// steady state) but never the SSD capacity. Without a tier store
    /// there is nothing durable to spill: the call is a no-op.
    ///
    /// Per-session conversation history is deliberately NOT part of
    /// durable state — a resumed engine starts fresh sessions over the
    /// spilled context blocks (see `tests/recovery.rs`).
    pub fn spill_for_checkpoint(&mut self) -> Result<Vec<RequestId>, String> {
        let Some(tiers) = self.tiers.as_mut() else {
            return Ok(Vec::new());
        };
        let resident = self.cache.resident_tokens();
        let mut pruned = self.cache.evict_tokens(resident);
        let hot = self.cache.take_demotions();
        pruned.extend(tiers.spill_for_checkpoint(hot));
        pruned.sort_unstable();
        pruned.dedup();
        tiers.storage_flush().map_err(|e| e.to_string())?;
        Ok(pruned)
    }

    /// Peek how many leading tokens of this prompt would hit the cache
    /// (LPM scheduling uses this without disturbing LRU state).
    ///
    /// Deliberately **hot-tier only**, even with a tier store attached:
    /// (1) it keeps the peek observably side-effect-free by construction
    /// (the tier probe could never be allowed to promote), and (2) it
    /// makes LPM queue ordering identical between discard-mode and
    /// demote-mode engines, so tiering changes *costs*, never *schedules*
    /// — the property the bench_tiering acceptance comparison relies on.
    pub fn peek_cached(&mut self, req: &Request, prompt: &Prompt, corpus: &Corpus) -> usize {
        let tokens = self.assemble(req.session, prompt, corpus);
        self.cache.peek_prefix_len(&tokens)
    }

    /// Side-effect-free probe of the *whole* hierarchy (hot match plus the
    /// longest cold-tier extension) — telemetry / diagnostics; not used
    /// for scheduling (see [`SimEngine::peek_cached`]). Reports an UPPER
    /// BOUND: the cold extension is not run through the promotion
    /// profitability gate, so a short span counted here may still be
    /// recomputed rather than reloaded at serve time. Crate-visible
    /// diagnostics only — nothing schedules or reports off it yet, hence
    /// the dead_code allowance (its callers live in #[cfg(test)]).
    #[allow(dead_code)]
    pub(crate) fn peek_reusable(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
    ) -> usize {
        let tokens = self.assemble(req.session, prompt, corpus);
        let hot = self.cache.peek_prefix_len(&tokens);
        match &self.tiers {
            Some(t) => t.peek_longest(&tokens, hot),
            None => hot,
        }
    }

    fn assemble(&mut self, session: SessionId, prompt: &Prompt, corpus: &Corpus) -> Vec<u32> {
        let mut tokens = self.history.get(&session).cloned().unwrap_or_default();
        self.renderer.render_into(prompt, corpus, &mut tokens);
        tokens
    }

    /// Token offsets of segment boundaries in the rendered prompt region
    /// (used by document-granular matching).
    fn segment_boundaries(
        &mut self,
        history_len: usize,
        prompt: &Prompt,
        corpus: &Corpus,
    ) -> Vec<usize> {
        let mut out = vec![history_len];
        let mut acc = history_len;
        for seg in &prompt.segments {
            let mut buf = Vec::new();
            let one = Prompt {
                segments: vec![seg.clone()],
            };
            self.renderer.render_into(&one, corpus, &mut buf);
            acc += buf.len();
            out.push(acc);
        }
        out
    }

    /// Serve one request: returns the metrics record and the engine
    /// request-ids evicted to make room (feed these to `ContextPilot::on_evict`).
    pub fn serve(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
        quality: &QualityModel,
        decode_tokens: usize,
    ) -> (ServedRequest, Vec<RequestId>) {
        let history_len = self.history.get(&req.session).map_or(0, |h| h.len());
        let tokens = self.assemble(req.session, prompt, corpus);
        let total = tokens.len();

        let (cached_effective, evicted, tier_hits, promo_load_s) = match self.policy {
            ReusePolicy::RadixPrefix => {
                let m = self.cache.match_prefix(&tokens);
                let hot = m.len;
                // tier promotion: the longest demoted prefix extending the
                // hot match is reloaded at its tier's cost instead of
                // recomputed at the prefill rate
                let promo = self.tiers.as_mut().and_then(|t| t.promote(&tokens, hot));
                let (_, mut ev) = self.cache.insert(&tokens, req.id);
                if let Some(t) = &mut self.tiers {
                    // demotion sink: evicted leaves fall into the tier
                    // store; only entries the store finally discards are
                    // reported for §4.1 index pruning
                    for entry in self.cache.take_demotions() {
                        ev.extend(t.demote(entry));
                    }
                    ev.sort_unstable();
                    ev.dedup();
                }
                let mut hits = TierHits::hot(hot);
                let mut load_s = 0.0;
                if let Some(p) = promo {
                    let span = p.matched - hot;
                    match p.tier {
                        Tier::Dram => hits.dram = span,
                        Tier::Ssd => hits.ssd = span,
                        Tier::Hbm => unreachable!("store holds no HBM entries"),
                    }
                    load_s = p.load_s;
                    // the promoted span is hot again (the insert above
                    // covers it — we model a reload, not a recompute);
                    // re-tag its owners so future evictions keep the §4.1
                    // eviction→prune chain intact
                    let covered = self.cache.tag_requests(&tokens[..p.matched], &p.request_ids);
                    if covered < p.matched {
                        // extreme thrash: the insert's own make_room evicted
                        // part of the just-promoted span before tagging, so
                        // the owners' ids could not ride along into the
                        // demotion entries — fall back to coarse §4.1
                        // pruning rather than leaking them from the chain
                        ev.extend(p.request_ids.iter().copied());
                        ev.sort_unstable();
                        ev.dedup();
                    }
                }
                (hits.total(), ev, hits, load_s)
            }
            ReusePolicy::DocPrefix { .. } => {
                let m = self.cache.match_prefix(&tokens);
                // floor the match to a segment boundary: LMCache reuses
                // whole-document KV entries only
                let bounds = self.segment_boundaries(history_len, prompt, corpus);
                let floored = bounds
                    .iter()
                    .copied()
                    .filter(|&b| b <= m.len)
                    .max()
                    .unwrap_or(0);
                let (_, ev) = self.cache.insert(&tokens, req.id);
                (floored, ev, TierHits::hot(floored), 0.0)
            }
            ReusePolicy::Approximate { recompute_frac, .. } => {
                // block KV reusable at any position; recompute_frac of the
                // reused tokens is recomputed to blend caches
                let mut reused = 0usize;
                for seg in &prompt.segments {
                    if let Segment::Block(b) = seg {
                        if let Some(len) = self.blend_store.get(b) {
                            reused += len;
                        }
                    }
                }
                // register new blocks (FIFO capacity)
                for seg in &prompt.segments {
                    if let Segment::Block(b) = seg {
                        if !self.blend_store.contains_key(b) {
                            let len = corpus.doc_tokens(*b);
                            self.blend_store.insert(*b, len);
                            self.blend_order.push(*b);
                            self.blend_resident += len;
                            while self.blend_resident > self.cache.capacity()
                                && self.blend_order.len() > 1
                            {
                                let victim = self.blend_order.remove(0);
                                if let Some(l) = self.blend_store.remove(&victim) {
                                    self.blend_resident -= l;
                                }
                            }
                        }
                    }
                }
                let effective = (reused as f64 * (1.0 - recompute_frac)) as usize;
                let eff = effective.min(total);
                (eff, Vec::new(), TierHits::hot(eff), 0.0)
            }
        };
        self.stat_hot_hit_tokens += tier_hits.hbm as u64;
        self.stat_warm_hit_tokens += tier_hits.dram as u64;
        self.stat_cold_hit_tokens += tier_hits.ssd as u64;

        let offload = match self.policy {
            ReusePolicy::DocPrefix { offload_s_per_tok } => offload_s_per_tok,
            _ => 0.0,
        };
        let ttft = self.profile.overhead_s
            + (total - cached_effective) as f64 / self.profile.prefill_rate
            + cached_effective as f64 * offload
            + promo_load_s;
        let wall = ttft + self.profile.decode_latency(decode_tokens);

        // quality
        let empty = HashSet::new();
        let hist_blocks = self.history_blocks.get(&req.session).unwrap_or(&empty);
        let q = quality.score(req, prompt, hist_blocks, self.policy.kv_noise());

        // conversation history: prompt region + the generated answer
        let hist = self.history.entry(req.session).or_default();
        hist.extend_from_slice(&tokens[history_len.min(tokens.len())..]);
        let answer = self.renderer.answer_tokens(req.query, decode_tokens.min(64));
        hist.extend_from_slice(&answer);
        let hb = self.history_blocks.entry(req.session).or_default();
        for seg in &prompt.segments {
            if let Segment::Block(b) | Segment::PartialBlock { block: b, .. } = seg {
                hb.insert(*b);
            }
        }

        (
            ServedRequest {
                request: req.clone(),
                prompt: prompt.clone(),
                prompt_tokens: total,
                cached_tokens: cached_effective,
                ttft,
                wall,
                quality: q,
                queued_ttft: ttft,
                prefill_chunks: 1,
                tier_hits,
            },
            evicted,
        )
    }
}

/// The §4.1 proxy↔engine contract: every method delegates to the inherent
/// implementation above, so concrete-typed callers (tests, examples) and
/// generic serving code observe identical behaviour.
impl InferenceEngine for SimEngine {
    fn serve(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
        quality: &QualityModel,
        decode_tokens: usize,
    ) -> (ServedRequest, Vec<RequestId>) {
        SimEngine::serve(self, req, prompt, corpus, quality, decode_tokens)
    }

    fn peek_cached(&mut self, req: &Request, prompt: &Prompt, corpus: &Corpus) -> usize {
        SimEngine::peek_cached(self, req, prompt, corpus)
    }

    // `lpm_order` uses the trait default (stable sort by `peek_cached`,
    // descending) — one copy of the baseline scheduling logic for every
    // engine.

    /// Only the radix mechanism is prefix-shaped; the DocPrefix and
    /// Approximate baselines serve queues in arrival order (mirroring
    /// LMCache / CacheBlend schedulers).
    fn prefers_lpm(&self) -> bool {
        matches!(self.policy, ReusePolicy::RadixPrefix)
    }

    fn chunk_boundaries(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
    ) -> Vec<usize> {
        let history_len = self.history.get(&req.session).map_or(0, |h| h.len());
        self.segment_boundaries(history_len, prompt, corpus)
    }

    fn session_count(&self) -> usize {
        SimEngine::session_count(self)
    }

    fn spill_for_checkpoint(&mut self) -> Result<Vec<RequestId>, String> {
        SimEngine::spill_for_checkpoint(self)
    }

    fn cache_stats(&self) -> CacheStats {
        let (dram_resident, ssd_resident, demoted, promoted, discarded) = match &self.tiers {
            Some(t) => (
                t.dram_resident_tokens(),
                t.ssd_resident_tokens(),
                t.stat_demoted_tokens,
                t.stat_promoted_tokens,
                t.stat_discarded_tokens,
            ),
            None => (0, 0, 0, 0, 0),
        };
        CacheStats {
            resident_tokens: self.cache.resident_tokens(),
            capacity_tokens: self.cache.capacity(),
            lookup_tokens: self.cache.stat_lookup_tokens,
            matched_tokens: self.cache.stat_matched_tokens,
            inserted_tokens: self.cache.stat_inserted_tokens,
            evicted_tokens: self.cache.stat_evicted_tokens,
            dram_resident_tokens: dram_resident,
            ssd_resident_tokens: ssd_resident,
            hot_hit_tokens: self.stat_hot_hit_tokens,
            warm_hit_tokens: self.stat_warm_hit_tokens,
            cold_hit_tokens: self.stat_cold_hit_tokens,
            demoted_tokens: demoted,
            promoted_tokens: promoted,
            discarded_tokens: discarded,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusConfig;
    use crate::engine::costmodel::ModelSku;
    use crate::quality::ModelEra;
    use crate::types::QueryId;

    fn setup(policy: ReusePolicy, cap: usize) -> (SimEngine, Corpus, QualityModel) {
        let tok = Tokenizer::default();
        let corpus = Corpus::generate(
            &CorpusConfig {
                n_docs: 40,
                ..Default::default()
            },
            &tok,
        );
        (
            SimEngine::new(ModelSku::Qwen3_32B.profile(), policy, cap),
            corpus,
            QualityModel::new(ModelEra::Modern, false),
        )
    }

    fn req(id: u64, session: u32, turn: u32, ids: &[u32]) -> Request {
        Request {
            id: RequestId(id),
            session: SessionId(session),
            turn,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(id),
        }
    }

    #[test]
    fn radix_reuses_shared_prefix_across_sessions() {
        let (mut e, corpus, qm) = setup(ReusePolicy::RadixPrefix, 1 << 20);
        let r1 = req(1, 1, 0, &[1, 2, 3]);
        let r2 = req(2, 2, 0, &[1, 2, 9]);
        let (s1, _) = e.serve(&r1, &Prompt::baseline(&r1), &corpus, &qm, 8);
        let (s2, _) = e.serve(&r2, &Prompt::baseline(&r2), &corpus, &qm, 8);
        assert_eq!(s1.cached_tokens, 0);
        assert!(s2.cached_tokens > 0, "prefix should hit");
        assert!(s2.ttft < s1.ttft);
    }

    #[test]
    fn multi_turn_history_is_a_cached_prefix() {
        let (mut e, corpus, qm) = setup(ReusePolicy::RadixPrefix, 1 << 20);
        let r1 = req(1, 7, 0, &[1, 2]);
        let r2 = req(2, 7, 1, &[3, 4]);
        e.serve(&r1, &Prompt::baseline(&r1), &corpus, &qm, 8);
        let (s2, _) = e.serve(&r2, &Prompt::baseline(&r2), &corpus, &qm, 8);
        // the whole first turn (prompt + answer) is the second turn's prefix
        assert!(s2.cached_tokens > 100, "history prefix not reused: {}", s2.cached_tokens);
    }

    #[test]
    fn doc_prefix_floors_to_block_boundary() {
        let (mut e, corpus, qm) = setup(
            ReusePolicy::DocPrefix {
                offload_s_per_tok: 0.0,
            },
            1 << 20,
        );
        let r1 = req(1, 1, 0, &[1, 2, 3]);
        // shares block 1, then diverges *within* the context region
        let r2 = req(2, 2, 0, &[1, 9, 3]);
        e.serve(&r1, &Prompt::baseline(&r1), &corpus, &qm, 8);
        let (s2, _) = e.serve(&r2, &Prompt::baseline(&r2), &corpus, &qm, 8);
        // cached must equal system + block1 exactly (a boundary), not more
        let mut renderer = Renderer::new(Tokenizer::default());
        let sys_len = renderer
            .render(
                &Prompt {
                    segments: vec![Segment::System],
                },
                &corpus,
            )
            .len();
        let expect = sys_len + corpus.doc_tokens(BlockId(1));
        assert_eq!(s2.cached_tokens, expect);
    }

    #[test]
    fn approximate_reuses_blocks_anywhere_but_degrades_quality() {
        let (mut e, corpus, qm) = setup(
            ReusePolicy::Approximate {
                recompute_frac: 0.15,
                kv_noise: 0.17,
            },
            1 << 20,
        );
        let r1 = req(1, 1, 0, &[1, 2, 3]);
        // same blocks in a *different order*: exact prefix would miss
        let r2 = req(2, 2, 0, &[3, 1, 2]);
        let (s1, _) = e.serve(&r1, &Prompt::baseline(&r1), &corpus, &qm, 8);
        let (s2, _) = e.serve(&r2, &Prompt::baseline(&r2), &corpus, &qm, 8);
        assert!(s2.cached_tokens > s1.cached_tokens);
        assert!(s2.cached_tokens > 0);
        // quality strictly below the exact-match engine's
        let (mut exact, corpus2, qm2) = setup(ReusePolicy::RadixPrefix, 1 << 20);
        let (s_exact, _) = exact.serve(&r2, &Prompt::baseline(&r2), &corpus2, &qm2, 8);
        assert!(s2.quality < s_exact.quality - 0.08);
    }

    #[test]
    fn eviction_feeds_request_ids_back() {
        let (mut e, corpus, qm) = setup(ReusePolicy::RadixPrefix, 600);
        let mut all_evicted = Vec::new();
        for i in 0..8u64 {
            let ids = [i as u32 * 4 + 1, i as u32 * 4 + 2, i as u32 * 4 + 3];
            let r = req(i, i as u32, 0, &ids);
            let (_, ev) = e.serve(&r, &Prompt::baseline(&r), &corpus, &qm, 4);
            all_evicted.extend(ev);
        }
        assert!(!all_evicted.is_empty(), "tight cache must evict");
        assert!(e.cache.resident_tokens() <= 600);
    }

    #[test]
    fn ttft_scales_with_uncached_tokens() {
        let (mut e, corpus, qm) = setup(ReusePolicy::RadixPrefix, 1 << 20);
        let small = req(1, 1, 0, &[1]);
        let big = req(2, 2, 0, &[2, 3, 4, 5, 6, 7]);
        let (s_small, _) = e.serve(&small, &Prompt::baseline(&small), &corpus, &qm, 4);
        let (s_big, _) = e.serve(&big, &Prompt::baseline(&big), &corpus, &qm, 4);
        assert!(s_big.ttft > s_small.ttft);
    }

    #[test]
    fn peek_does_not_disturb_stats() {
        let (mut e, corpus, qm) = setup(ReusePolicy::RadixPrefix, 1 << 20);
        let r1 = req(1, 1, 0, &[1, 2]);
        e.serve(&r1, &Prompt::baseline(&r1), &corpus, &qm, 4);
        let lookups_before = e.cache.stat_lookup_tokens;
        let peeked = e.peek_cached(&req(2, 2, 0, &[1, 2]), &Prompt::baseline(&req(2, 2, 0, &[1, 2])), &corpus);
        assert!(peeked > 0);
        assert_eq!(e.cache.stat_lookup_tokens, lookups_before);
    }

    // ---- tiered mode ------------------------------------------------------

    fn tiered_setup(cap: usize) -> (SimEngine, Corpus, QualityModel) {
        let tok = Tokenizer::default();
        let corpus = Corpus::generate(
            &CorpusConfig {
                n_docs: 40,
                ..Default::default()
            },
            &tok,
        );
        (
            SimEngine::with_tiers(
                ModelSku::Qwen3_32B.profile(),
                ReusePolicy::RadixPrefix,
                cap,
                &TierConfig::new(1 << 20, 1 << 20),
            ),
            corpus,
            QualityModel::new(ModelEra::Modern, false),
        )
    }

    /// Three ~380-token prompts cycled through a 600-token HBM budget:
    /// every return of a context finds it evicted from HBM. The demote
    /// engine must recover the evicted prefix from DRAM at reload cost.
    fn cycle_requests() -> Vec<Request> {
        let contexts: [&[u32]; 3] = [&[1, 2, 3], &[11, 12, 13], &[21, 22, 23]];
        (0..9u64)
            .map(|i| req(i, i as u32, 0, contexts[i as usize % 3]))
            .collect()
    }

    #[test]
    fn tiered_engine_promotes_evicted_prefixes_and_beats_discard() {
        let (mut tiered, corpus, qm) = tiered_setup(600);
        let (mut discard, _, _) = setup(ReusePolicy::RadixPrefix, 600);
        let mut t_reuse = 0usize;
        let mut d_reuse = 0usize;
        let mut t_ttft = 0.0f64;
        let mut d_ttft = 0.0f64;
        for r in cycle_requests() {
            let p = Prompt::baseline(&r);
            let (st, _) = tiered.serve(&r, &p, &corpus, &qm, 4);
            let (sd, _) = discard.serve(&r, &p, &corpus, &qm, 4);
            // demotion never changes the HOT tier's behaviour: per-request
            // hot hits equal discard-mode cached tokens exactly
            assert_eq!(st.tier_hits.hbm, sd.cached_tokens, "req {:?}", r.id);
            assert_eq!(st.cached_tokens, st.tier_hits.total());
            assert_eq!(st.prompt_tokens, sd.prompt_tokens);
            t_reuse += st.cached_tokens;
            d_reuse += sd.cached_tokens;
            t_ttft += st.ttft;
            d_ttft += sd.ttft;
        }
        assert!(
            t_reuse > d_reuse,
            "demote mode must reuse strictly more: {t_reuse} vs {d_reuse}"
        );
        assert!(
            t_ttft < d_ttft,
            "cost-gated promotion must lower TTFT: {t_ttft} vs {d_ttft}"
        );
        let stats = InferenceEngine::cache_stats(&tiered);
        assert!(stats.promoted_tokens > 0, "no promotion happened");
        assert!(stats.demoted_tokens > 0, "no demotion happened");
        assert_eq!(
            stats.warm_hit_tokens + stats.cold_hit_tokens,
            (t_reuse - d_reuse) as u64,
            "cold-tier hits are exactly the extra reuse"
        );
    }

    #[test]
    fn tiered_peek_is_observably_side_effect_free() {
        // engine-level extension of the radix regression: with content in
        // BOTH the hot tier and the tier store, neither peek_cached nor
        // peek_reusable may tick a clock, move a stat, or promote
        let (mut e, corpus, qm) = tiered_setup(600);
        for r in cycle_requests() {
            let p = Prompt::baseline(&r);
            e.serve(&r, &p, &corpus, &qm, 4);
        }
        let clock = e.cache.lru_clock();
        let stats_before = InferenceEngine::cache_stats(&e);
        let probe = req(100, 100, 0, &[1, 2, 3]);
        let p = Prompt::baseline(&probe);
        let hot = e.peek_cached(&probe, &p, &corpus);
        let reusable = e.peek_reusable(&probe, &p, &corpus);
        assert!(reusable >= hot);
        assert!(
            reusable > 0,
            "the cycled context must be reusable somewhere in the hierarchy"
        );
        let stats_after = InferenceEngine::cache_stats(&e);
        assert_eq!(e.cache.lru_clock(), clock, "peek ticked the LRU clock");
        assert_eq!(stats_after.lookup_tokens, stats_before.lookup_tokens);
        assert_eq!(stats_after.matched_tokens, stats_before.matched_tokens);
        assert_eq!(stats_after.promoted_tokens, stats_before.promoted_tokens);
        assert_eq!(
            stats_after.dram_resident_tokens + stats_after.ssd_resident_tokens,
            stats_before.dram_resident_tokens + stats_before.ssd_resident_tokens,
            "peek moved tier residency"
        );
    }

    #[test]
    fn tier_hits_always_sum_to_cached_tokens() {
        let (mut e, corpus, qm) = tiered_setup(600);
        for r in cycle_requests() {
            let p = Prompt::baseline(&r);
            let (s, _) = e.serve(&r, &p, &corpus, &qm, 4);
            assert_eq!(s.tier_hits.total(), s.cached_tokens);
        }
        // non-tiered engines report everything as hbm
        let (mut plain, corpus2, qm2) = setup(ReusePolicy::RadixPrefix, 1 << 20);
        let r1 = req(1, 1, 0, &[1, 2, 3]);
        let r2 = req(2, 2, 0, &[1, 2, 9]);
        plain.serve(&r1, &Prompt::baseline(&r1), &corpus2, &qm2, 4);
        let (s2, _) = plain.serve(&r2, &Prompt::baseline(&r2), &corpus2, &qm2, 4);
        assert!(s2.cached_tokens > 0);
        assert_eq!(s2.tier_hits, crate::types::TierHits::hot(s2.cached_tokens));
    }

    #[test]
    fn non_radix_policies_ignore_tier_config() {
        let e = SimEngine::with_tiers(
            ModelSku::Qwen3_32B.profile(),
            ReusePolicy::DocPrefix {
                offload_s_per_tok: 6e-6,
            },
            10_000,
            &TierConfig::new(1 << 20, 1 << 20),
        );
        assert!(e.tiers.is_none(), "tiering is prefix-shaped only");
        assert!(!e.cache.demotion_enabled());
        let stats = InferenceEngine::cache_stats(&e);
        assert_eq!(stats.dram_resident_tokens + stats.ssd_resident_tokens, 0);
    }

    #[test]
    fn eviction_to_tiers_defers_index_pruning_until_discard() {
        // a tiny DRAM+SSD store: evictions demote (no prune ids) until the
        // store overflows, at which point the discarded ids surface
        let tok = Tokenizer::default();
        let corpus = Corpus::generate(
            &CorpusConfig {
                n_docs: 40,
                ..Default::default()
            },
            &tok,
        );
        let qm = QualityModel::new(ModelEra::Modern, false);
        let mut cfg = TierConfig::new(500, 500);
        cfg.admission = crate::cache::AdmissionPolicy::Always;
        let mut e = SimEngine::with_tiers(
            ModelSku::Qwen3_32B.profile(),
            ReusePolicy::RadixPrefix,
            600,
            &cfg,
        );
        let mut evicted_ids = Vec::new();
        for i in 0..8u64 {
            let ids = [i as u32 * 4 + 1, i as u32 * 4 + 2, i as u32 * 4 + 3];
            let r = req(i, i as u32, 0, &ids);
            let (_, ev) = e.serve(&r, &Prompt::baseline(&r), &corpus, &qm, 4);
            evicted_ids.extend(ev);
        }
        assert!(
            !evicted_ids.is_empty(),
            "overflowing every tier must eventually surface prune ids"
        );
        let stats = InferenceEngine::cache_stats(&e);
        assert!(stats.discarded_tokens > 0);
        assert!(stats.demoted_tokens > 0);
    }

    #[test]
    fn zero_capacity_cold_tier_is_bit_identical_to_discard_mode() {
        // `dram=0,ssd=0` leaves demotion enabled but every demoted entry
        // is refused and discarded on the spot — serving results and §4.1
        // prune ids must match classic discard eviction exactly
        let (mut discard, corpus, qm) = setup(ReusePolicy::RadixPrefix, 600);
        let mut zero = SimEngine::with_tiers(
            ModelSku::Qwen3_32B.profile(),
            ReusePolicy::RadixPrefix,
            600,
            &TierConfig::new(0, 0),
        );
        for i in 0..8u64 {
            let ids = [i as u32 * 4 + 1, i as u32 * 4 + 2, i as u32 * 4 + 3];
            let r = req(i, i as u32, 0, &ids);
            let p = Prompt::baseline(&r);
            let (sz, mut ez) = zero.serve(&r, &p, &corpus, &qm, 4);
            let (sd, mut ed) = discard.serve(&r, &p, &corpus, &qm, 4);
            ez.sort_unstable();
            ed.sort_unstable();
            assert_eq!(ez, ed, "prune ids diverged at req {i}");
            assert_eq!(sz.cached_tokens, sd.cached_tokens, "req {i}");
            assert_eq!(sz.ttft, sd.ttft, "req {i}");
            assert_eq!(sz.tier_hits, sd.tier_hits, "req {i}");
        }
        let z = InferenceEngine::cache_stats(&zero);
        let d = InferenceEngine::cache_stats(&discard);
        assert_eq!(z.matched_tokens, d.matched_tokens);
        assert_eq!(z.resident_tokens, d.resident_tokens);
        assert_eq!(z.dram_resident_tokens + z.ssd_resident_tokens, 0);
        assert_eq!(z.promoted_tokens, 0);
        // spill over a zero-capacity store likewise just discards
        let pruned = zero.spill_for_checkpoint().expect("spill");
        assert!(!pruned.is_empty());
        assert_eq!(zero.cache.resident_tokens(), 0);
    }

    fn sim_tempdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ctxpilot-sim-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("tempdir");
        dir
    }

    #[test]
    fn spill_then_rehydrate_recovers_cold_hits_from_disk() {
        use crate::cache::FileStorage;
        let tok = Tokenizer::default();
        let corpus = Corpus::generate(
            &CorpusConfig {
                n_docs: 40,
                ..Default::default()
            },
            &tok,
        );
        let qm = QualityModel::new(ModelEra::Modern, false);
        let dir = sim_tempdir("rehydrate");
        let path = dir.join("cold.jsonl");
        let cfg = TierConfig::new(1 << 20, 1 << 20);
        let profile = ModelSku::Qwen3_32B.profile();

        let mut first = SimEngine::with_tiers_storage(
            profile,
            ReusePolicy::RadixPrefix,
            600,
            &cfg,
            Box::new(FileStorage::open(&path, false).expect("open fresh")),
            false,
        )
        .expect("fresh engine");
        for r in cycle_requests() {
            let p = Prompt::baseline(&r);
            first.serve(&r, &p, &corpus, &qm, 4);
        }
        let pruned = first.spill_for_checkpoint().expect("spill");
        assert!(pruned.is_empty(), "roomy SSD must not discard: {pruned:?}");
        assert_eq!(first.cache.resident_tokens(), 0, "HBM must be drained");
        drop(first);

        let mut resumed = SimEngine::with_tiers_storage(
            profile,
            ReusePolicy::RadixPrefix,
            600,
            &cfg,
            Box::new(FileStorage::open(&path, true).expect("reopen")),
            true,
        )
        .expect("resumed engine");
        let stats = InferenceEngine::cache_stats(&resumed);
        assert!(
            stats.ssd_resident_tokens > 0,
            "rehydration must repopulate the SSD shelf"
        );
        // a NEW session over a spilled context reloads from SSD instead of
        // re-prefilling — the acceptance property of the recovery story
        let probe = req(100, 100, 0, &[1, 2, 3]);
        let p = Prompt::baseline(&probe);
        let (s, _) = resumed.serve(&probe, &p, &corpus, &qm, 4);
        assert!(
            s.tier_hits.ssd > 0,
            "resumed engine re-prefilled instead of reloading: {:?}",
            s.tier_hits
        );
        assert_eq!(s.cached_tokens, s.tier_hits.total());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
