//! Prompt rendering: turn a [`Prompt`]'s segments into the token stream
//! the engine prefills. Block token sequences are memoized per corpus so
//! the serving hot path never re-tokenizes documents.

use std::collections::HashMap;

use crate::corpus::Corpus;
use crate::tokenizer::Tokenizer;
use crate::types::{BlockId, Prompt, QueryId, Segment};

const SYSTEM_TEXT: &str =
    "system: you are a helpful assistant answer using the retrieved context blocks";

pub struct Renderer {
    pub tokenizer: Tokenizer,
    block_tokens: HashMap<BlockId, Vec<u32>>,
    system_tokens: Vec<u32>,
}

impl Renderer {
    pub fn new(tokenizer: Tokenizer) -> Self {
        let system_tokens = tokenizer.encode(SYSTEM_TEXT);
        Self {
            tokenizer,
            block_tokens: HashMap::new(),
            system_tokens,
        }
    }

    fn block(&mut self, b: BlockId, corpus: &Corpus) -> &[u32] {
        let tok = &self.tokenizer;
        self.block_tokens
            .entry(b)
            .or_insert_with(|| tok.encode(&corpus.doc(b).text()))
    }

    fn location_ref_text(b: BlockId) -> String {
        format!("note please refer to {b} in the previous conversation")
    }

    fn order_annotation_text(ranking: &[BlockId]) -> String {
        let order = ranking
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join(" > ");
        format!("please read the context in the following priority order {order} and answer the question")
    }

    fn question_text(q: QueryId) -> String {
        format!("question q{} please answer concisely", q.0)
    }

    /// Render a prompt into tokens, appending to `out`.
    pub fn render_into(&mut self, prompt: &Prompt, corpus: &Corpus, out: &mut Vec<u32>) {
        for seg in &prompt.segments {
            match seg {
                Segment::System => out.extend_from_slice(&self.system_tokens),
                Segment::Block(b) => {
                    let toks = self.block(*b, corpus);
                    out.extend_from_slice(toks);
                }
                Segment::LocationRef(b) => {
                    self.tokenizer
                        .encode_into(&Self::location_ref_text(*b), out);
                }
                Segment::PartialBlock { block, kept, refs } => {
                    // kept lines verbatim + one reference per elided origin
                    for &l in kept {
                        let line = &corpus.doc(*block).lines[l as usize];
                        self.tokenizer.encode_into(line, out);
                    }
                    for r in refs {
                        self.tokenizer.encode_into(&Self::location_ref_text(*r), out);
                    }
                }
                Segment::OrderAnnotation(ranking) => {
                    self.tokenizer
                        .encode_into(&Self::order_annotation_text(ranking), out);
                }
                Segment::Question(q) => {
                    self.tokenizer.encode_into(&Self::question_text(*q), out);
                }
            }
        }
    }

    pub fn render(&mut self, prompt: &Prompt, corpus: &Corpus) -> Vec<u32> {
        let mut out = Vec::with_capacity(256);
        self.render_into(prompt, corpus, &mut out);
        out
    }

    /// Deterministic pseudo-answer tokens for a query (appended to the
    /// conversation history after decode).
    pub fn answer_tokens(&self, q: QueryId, n: usize) -> Vec<u32> {
        (0..n)
            .map(|i| {
                let h = q.0.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(i as u64);
                16 + (h % (self.tokenizer.vocab as u64 - 16)) as u32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusConfig;
    use crate::types::{Request, RequestId, SessionId};

    fn setup() -> (Renderer, Corpus) {
        let tok = Tokenizer::default();
        let corpus = Corpus::generate(
            &CorpusConfig {
                n_docs: 20,
                ..Default::default()
            },
            &tok,
        );
        (Renderer::new(Tokenizer::default()), corpus)
    }

    fn req(ids: &[u32]) -> Request {
        Request {
            id: RequestId(1),
            session: SessionId(0),
            turn: 0,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(5),
        }
    }

    #[test]
    fn rendering_is_deterministic_and_cached() {
        let (mut r, corpus) = setup();
        let p = Prompt::baseline(&req(&[1, 2, 3]));
        let a = r.render(&p, &corpus);
        let b = r.render(&p, &corpus);
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn identical_block_prefix_yields_identical_token_prefix() {
        let (mut r, corpus) = setup();
        let p1 = Prompt::baseline(&req(&[1, 2, 3]));
        let p2 = Prompt::baseline(&req(&[1, 2, 7]));
        let t1 = r.render(&p1, &corpus);
        let t2 = r.render(&p2, &corpus);
        // shared prefix: system + block1 + block2
        let shared = r.tokenizer.encode(
            "system: you are a helpful assistant answer using the retrieved context blocks",
        )
        .len()
            + corpus.doc_tokens(BlockId(1))
            + corpus.doc_tokens(BlockId(2));
        assert_eq!(t1[..shared], t2[..shared]);
        assert_ne!(t1, t2);
    }

    #[test]
    fn location_ref_is_much_shorter_than_block() {
        let (mut r, corpus) = setup();
        let full = Prompt {
            segments: vec![Segment::Block(BlockId(3))],
        };
        let loc = Prompt {
            segments: vec![Segment::LocationRef(BlockId(3))],
        };
        let t_full = r.render(&full, &corpus).len();
        let t_loc = r.render(&loc, &corpus).len();
        assert!(t_loc * 4 < t_full, "loc {t_loc} vs full {t_full}");
    }

    #[test]
    fn partial_block_renders_kept_lines_only() {
        let (mut r, corpus) = setup();
        let all_lines = corpus.doc(BlockId(2)).lines.len() as u32;
        let partial = Prompt {
            segments: vec![Segment::PartialBlock {
                block: BlockId(2),
                kept: (0..all_lines / 2).collect(),
                refs: vec![BlockId(1)],
            }],
        };
        let full = Prompt {
            segments: vec![Segment::Block(BlockId(2))],
        };
        let t_partial = r.render(&partial, &corpus).len();
        let t_full = r.render(&full, &corpus).len();
        assert!(t_partial < t_full);
    }

    #[test]
    fn order_annotation_token_overhead_is_small() {
        let (mut r, corpus) = setup();
        let base = Prompt::baseline(&req(&[1, 2, 3, 4, 5]));
        let mut with_ann = base.clone();
        with_ann.segments.insert(
            with_ann.segments.len() - 1,
            Segment::OrderAnnotation(req(&[1, 2, 3, 4, 5]).context),
        );
        let t0 = r.render(&base, &corpus).len();
        let t1 = r.render(&with_ann, &corpus).len();
        assert!(t1 > t0);
        assert!((t1 - t0) < t0 / 5, "annotation overhead {} vs {}", t1 - t0, t0);
    }

    #[test]
    fn answer_tokens_deterministic_in_vocab() {
        let (r, _) = setup();
        let a = r.answer_tokens(QueryId(3), 10);
        assert_eq!(a, r.answer_tokens(QueryId(3), 10));
        assert!(a.iter().all(|&t| t >= 16 && t < r.tokenizer.vocab));
        assert_ne!(a, r.answer_tokens(QueryId(4), 10));
    }
}
