//! Multi-worker router (Table 6 / §7.2 agent-aware routing): distributes
//! requests over N engine instances. Context-aware routing sends recurring
//! context blocks to the worker already holding their KV — the mechanism
//! behind ContextPilot's DeepSeek-R1 multi-node speedups.

use std::collections::HashMap;

use crate::corpus::Corpus;
use crate::engine::costmodel::CostProfile;
use crate::engine::sim::{ReusePolicy, SimEngine};
use crate::quality::QualityModel;
use crate::types::{BlockId, Prompt, Request, RequestId, ServedRequest};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Vanilla: spread load evenly, ignore cache affinity.
    RoundRobin,
    /// ContextPilot: route to the worker holding the most of this
    /// request's blocks (ties -> least loaded).
    ContextAware,
}

pub struct Router {
    pub workers: Vec<SimEngine>,
    pub policy: RoutePolicy,
    /// block -> worker that last prefilled it
    block_home: HashMap<BlockId, usize>,
    served_per_worker: Vec<usize>,
    rr_next: usize,
}

impl Router {
    pub fn new(
        n_workers: usize,
        profile: CostProfile,
        reuse: ReusePolicy,
        capacity_tokens: usize,
        policy: RoutePolicy,
    ) -> Self {
        assert!(n_workers > 0);
        Self {
            workers: (0..n_workers)
                .map(|_| SimEngine::new(profile, reuse, capacity_tokens))
                .collect(),
            policy,
            block_home: HashMap::new(),
            served_per_worker: vec![0; n_workers],
            rr_next: 0,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Pick a worker for this request.
    pub fn route(&mut self, req: &Request) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                let w = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.workers.len();
                w
            }
            RoutePolicy::ContextAware => {
                let mut votes = vec![0usize; self.workers.len()];
                for b in &req.context {
                    if let Some(&w) = self.block_home.get(b) {
                        votes[w] += 1;
                    }
                }
                let max = votes.iter().copied().max().unwrap_or(0);
                if max == 0 {
                    // no affinity: least-loaded
                    (0..self.workers.len())
                        .min_by_key(|&w| self.served_per_worker[w])
                        .unwrap()
                } else {
                    (0..self.workers.len())
                        .filter(|&w| votes[w] == max)
                        .min_by_key(|&w| self.served_per_worker[w])
                        .unwrap()
                }
            }
        }
    }

    /// Route + serve. Returns (worker, record, evicted request ids).
    pub fn serve(
        &mut self,
        req: &Request,
        prompt: &Prompt,
        corpus: &Corpus,
        quality: &QualityModel,
        decode_tokens: usize,
    ) -> (usize, ServedRequest, Vec<RequestId>) {
        let w = self.route(req);
        self.served_per_worker[w] += 1;
        for b in &req.context {
            self.block_home.insert(*b, w);
        }
        let (served, evicted) = self.workers[w].serve(req, prompt, corpus, quality, decode_tokens);
        (w, served, evicted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusConfig;
    use crate::engine::costmodel::ModelSku;
    use crate::quality::ModelEra;
    use crate::tokenizer::Tokenizer;
    use crate::types::{QueryId, SessionId};

    fn setup(policy: RoutePolicy) -> (Router, Corpus, QualityModel) {
        let corpus = Corpus::generate(
            &CorpusConfig {
                n_docs: 40,
                ..Default::default()
            },
            &Tokenizer::default(),
        );
        (
            Router::new(
                4,
                ModelSku::DeepSeekR1_16xH20.profile(),
                ReusePolicy::RadixPrefix,
                1 << 20,
                policy,
            ),
            corpus,
            QualityModel::new(ModelEra::Modern, true),
        )
    }

    fn req(id: u64, ids: &[u32]) -> Request {
        Request {
            id: RequestId(id),
            session: SessionId(id as u32),
            turn: 0,
            context: ids.iter().map(|&i| BlockId(i)).collect(),
            query: QueryId(id),
        }
    }

    #[test]
    fn round_robin_cycles() {
        let (mut r, _, _) = setup(RoutePolicy::RoundRobin);
        let ws: Vec<usize> = (0..8).map(|i| r.route(&req(i, &[1]))).collect();
        assert_eq!(ws, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn context_aware_returns_to_block_home() {
        let (mut r, corpus, qm) = setup(RoutePolicy::ContextAware);
        let (w1, _, _) = r.serve(&req(1, &[5, 6, 7]), &Prompt::baseline(&req(1, &[5, 6, 7])), &corpus, &qm, 4);
        // fill other workers with unrelated requests
        for i in 2..5u64 {
            let ids = [i as u32 * 8, i as u32 * 8 + 1];
            r.serve(&req(i, &ids), &Prompt::baseline(&req(i, &ids)), &corpus, &qm, 4);
        }
        // a recurring context must return to w1
        let (w2, s2, _) = r.serve(&req(9, &[5, 6, 7]), &Prompt::baseline(&req(9, &[5, 6, 7])), &corpus, &qm, 4);
        assert_eq!(w1, w2, "recurring blocks not routed home");
        assert!(s2.cached_tokens > 0, "affinity routing should hit the cache");
    }

    #[test]
    fn context_aware_beats_round_robin_on_recurring_workload() {
        let reqs: Vec<Request> = (0..40u64)
            .map(|i| {
                // 3 recurring block groups over 4 workers: round-robin
                // cannot stay aligned with the recurrence pattern
                let g = (i % 3) as u32;
                req(i, &[g * 3 + 1, g * 3 + 2, g * 3 + 3])
            })
            .collect();
        let mut hit = |policy| {
            let (mut r, corpus, qm) = setup(policy);
            let mut cached = 0usize;
            let mut total = 0usize;
            for rq in &reqs {
                let (_, s, _) = r.serve(rq, &Prompt::baseline(rq), &corpus, &qm, 4);
                cached += s.cached_tokens;
                total += s.prompt_tokens;
            }
            cached as f64 / total as f64
        };
        let h_aware = hit(RoutePolicy::ContextAware);
        let h_rr = hit(RoutePolicy::RoundRobin);
        assert!(
            h_aware > h_rr,
            "context-aware {h_aware} <= round-robin {h_rr}"
        );
    }

    #[test]
    fn no_affinity_falls_back_to_least_loaded() {
        let (mut r, _, _) = setup(RoutePolicy::ContextAware);
        // three routes with disjoint fresh blocks spread across workers
        let a = r.route(&req(1, &[1]));
        r.served_per_worker[a] += 1;
        let b = r.route(&req(2, &[2]));
        r.served_per_worker[b] += 1;
        assert_ne!(a, b);
    }
}
