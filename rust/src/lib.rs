//! # ContextPilot
//!
//! Reproduction of *"ContextPilot: Fast Long-Context Inference via Context
//! Reuse"* (MLSys 2026) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the paper's coordination contribution: a
//!   context index ([`index`]), context alignment ([`align`]), request
//!   scheduling ([`schedule`]), de-duplication ([`dedup`]) and annotations,
//!   fronting any inference engine behind the
//!   [`engine::InferenceEngine`] trait — the §4.1 proxy↔engine contract.
//!   The stable entry point is [`api`]: a builder-configured
//!   [`api::Server`] with a session/ticket request lifecycle and typed
//!   errors ([`api::Error`]); the sharded serving machinery underneath is
//!   crate-private:
//!
//!   ```text
//!   CLI / experiment runner / benches / library users
//!        │  Server::builder(sku)…build()?; session(id).submit(req)?
//!        │  → Ticket::wait()?; serve_batch / serve_one shims;
//!        │  submit_at(req, t) → seal_arrivals / drain (open-loop)
//!        ▼
//!   api::Server                  the facade: pending-wave tickets, typed
//!        │                       errors, corpus ownership
//!        ▼
//!   serve::sched                 continuous batching: long-lived per-shard
//!        │                       scheduler loops (spawn/pause/drain/stop),
//!        │                       waves + virtual-time arrivals in one run
//!        │                       queue, no flush barrier; SLO backpressure
//!        │                       (queue bound / deadline, shed or delay)
//!        ▼
//!   serving engine (crate-private, [`serve`])
//!        │                       lock-striped shards
//!        │                       (the sequential runner is this at n = 1);
//!        │                       serve::placement picks each session's
//!        │                       first-turn shard (session-hash / round-
//!        │                       robin / context-aware reuse voting)
//!        ▼
//!   shard                        ContextPilot proxy ([`pilot`]) +
//!        │                       chunked-prefill admission
//!        │                       ([`serve::admission`])
//!        ▼
//!   engine::InferenceEngine      serve(request, prompt)
//!        │        │                 -> (ServedRequest, evicted ids)
//!        ▼        ▼
//!   engine::SimEngine        runtime::RealEngine (`pjrt` feature)
//!   (radix prefix cache      (TinyLM via PJRT, KV snapshots on the
//!    [`cache`] + latency      same radix cache)
//!    model)
//!        │ evict = demote ▼  ▲ promote @ reload cost
//!   cache::TierStore (DRAM ⇄ SSD tiers behind the radix cache, `--tiers`;
//!    cost-aware admission/promotion in [`cache::policy`])
//!        │ SSD shelf write-through ▼  ▲ rebuilt on resume
//!   cache::storage::Storage (durable cold-tier backend, `--state-dir`:
//!    MemStorage default / FileStorage segment log + warm-state snapshot)
//!   ```
//!
//!   Sessions are pinned to shards (each owning a context index, a prefix
//!   cache and an engine instance) and long-lived per-shard scheduler
//!   loops ([`serve::sched`]) drive the run queues — admission waves and
//!   open-loop virtual-time arrivals ([`workload::poisson_arrivals`] /
//!   [`workload::diurnal_arrivals`], CLI `--qps`) interleave with no
//!   flush barrier, under deterministic SLO backpressure.
//!   *Which* shard a session is pinned to is the placement layer's call
//!   ([`serve::placement`], CLI `--placement session|rr|context`): the
//!   context-aware policy votes by each shard's real index/cache state so
//!   users sharing a corpus land where its KV already lives (§7.2 /
//!   Table 6 routing, folded into the serving layer). Votes read
//!   published per-shard probe snapshots backed by the index's inverted
//!   block directory — O(request blocks) per probe, zero shard-lock
//!   acquisitions on the probe path. Prompts whose
//!   uncached prefill exceeds `--prefill-chunk` are split at radix-node
//!   boundaries and interleaved across their shard queue so short
//!   requests are not head-of-line blocked, with queue-aware TTFT
//!   accounting in [`metrics`]. Alongside the pipeline, [`obs`] is the
//!   observability layer: an always-on atomic counter registry, opt-in
//!   per-shard tracers stamping request lifecycle events on the same
//!   virtual clock (so traces are deterministic and worker-count
//!   invariant), and Chrome-trace / run-telemetry JSON exporters behind
//!   `--trace-out` / `--metrics-out`.
//! - **Layer 2** — a JAX transformer (`python/compile/model.py`) AOT-lowered
//!   to HLO text, executed from Rust via PJRT ([`runtime`]; gated on the
//!   `pjrt` cargo feature, which needs the external `xla`/`anyhow` crates).
//! - **Layer 1** — a Pallas block-wise prefill-attention kernel
//!   (`python/compile/kernels/attention.py`).
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `rust/README.md` for build/test/bench instructions.

pub mod api;

pub mod align;
pub mod cache;
pub mod corpus;
pub mod dedup;
pub mod engine;
pub mod experiments;
pub mod index;
pub mod obs;
pub mod pilot;
pub mod quality;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod metrics;
pub mod tokenizer;
pub mod types;
pub mod util;
pub mod workload;

pub use util::prng::Rng;
