//! # ContextPilot
//!
//! Reproduction of *"ContextPilot: Fast Long-Context Inference via Context
//! Reuse"* (MLSys 2026) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the paper's coordination contribution: a
//!   context index ([`index`]), context alignment ([`align`]), request
//!   scheduling ([`schedule`]), de-duplication ([`dedup`]) and annotations,
//!   fronting an in-repo inference engine ([`engine`]) with a radix prefix
//!   cache ([`cache`]). The concurrent sharded serving layer ([`serve`])
//!   runs that whole pipeline for many sessions in parallel: sessions are
//!   pinned to lock-striped shards (each owning a context index, a prefix
//!   cache and an engine) and a worker pool drives shard queues, with
//!   per-shard hit-rate/latency/queue metrics ([`metrics`]).
//! - **Layer 2** — a JAX transformer (`python/compile/model.py`) AOT-lowered
//!   to HLO text, executed from Rust via PJRT ([`runtime`]; gated on the
//!   `pjrt` cargo feature, which needs the external `xla`/`anyhow` crates).
//! - **Layer 1** — a Pallas block-wise prefill-attention kernel
//!   (`python/compile/kernels/attention.py`).
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `rust/README.md` for build/test/bench instructions.

pub mod align;
pub mod cache;
pub mod corpus;
pub mod dedup;
pub mod engine;
pub mod experiments;
pub mod index;
pub mod pilot;
pub mod quality;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod metrics;
pub mod tokenizer;
pub mod types;
pub mod util;
pub mod workload;

pub use util::prng::Rng;
