//! `ctxpilot` — CLI for the ContextPilot reproduction.
//!
//! Subcommands:
//!   serve        run a workload through a system and print metrics
//!                (--shards N --workers N switches to the concurrent
//!                sharded api::Server and prints per-shard stats;
//!                --engine sim|real selects the backend behind the
//!                InferenceEngine trait; --prefill-chunk T enables
//!                chunked-prefill admission; --tiers hbm=N,dram=N,ssd=N
//!                attaches a KV tier store so eviction demotes to
//!                DRAM/SSD instead of discarding; --placement
//!                session|rr|context picks the first-turn session →
//!                shard policy, `context` being §7.2 reuse-aware
//!                placement; --trace-out / --metrics-out export the
//!                observability layer's Chrome trace and run telemetry;
//!                --qps F drives the workload open-loop through the
//!                continuous-batching scheduler — seeded --arrival
//!                poisson|diurnal virtual arrival times, no flush
//!                barrier — with --queue-bound / --deadline / --overload
//!                shed|delay SLO backpressure)
//!   bench <id>   regenerate one paper table/figure (table1..table8,
//!                fig7, fig8, fig11, fig12, fig13, appendix_f,
//!                appendix_g) or the capacity-pressure table (capacity)
//!   index        build a context index over synthetic contexts and time it
//!   demo         the quickstart walkthrough (see examples/quickstart.rs)

use contextpilot::api::{Error, Server, ServerBuilder};
use contextpilot::cache::TierConfig;
use contextpilot::engine::{InferenceEngine, ModelSku};
use contextpilot::experiments as exp;
use contextpilot::experiments::{corpus_for, run_f1, run_system, RunConfig, SystemKind};
use contextpilot::pilot::PilotConfig;
use contextpilot::serve::{OverloadPolicy, PlacementKind};
use contextpilot::util::cli::Args;
use contextpilot::workload::{
    diurnal_arrivals, hybrid, mem0, multi_session, multi_turn, poisson_arrivals, Dataset, Workload,
};

/// CLI error boundary: every facade [`Error`] (bad flag values at parse
/// time, poisoned shards at run time) prints once and exits 2.
fn fail(ctx: &str, e: Error) -> ! {
    eprintln!("{ctx}: {e}");
    std::process::exit(2);
}

fn check<T>(ctx: &str, r: Result<T, Error>) -> T {
    r.unwrap_or_else(|e| fail(ctx, e))
}

fn parse_dataset(s: &str) -> Dataset {
    match s.to_ascii_lowercase().as_str() {
        "multihoprag" | "multihop" => Dataset::MultihopRag,
        "narrativeqa" => Dataset::NarrativeQa,
        "qasper" => Dataset::Qasper,
        "mtrag" | "mt-rag" => Dataset::MtRag,
        "locomo" => Dataset::LoCoMo,
        other => {
            eprintln!("unknown dataset '{other}'");
            std::process::exit(2);
        }
    }
}

fn parse_system(s: &str) -> SystemKind {
    match s.to_ascii_lowercase().as_str() {
        "lmcache" => SystemKind::LMCache,
        "cacheblend" => SystemKind::CacheBlend,
        "radixcache" | "radix" => SystemKind::RadixCache,
        "contextpilot" | "pilot" => SystemKind::ContextPilot(PilotConfig::default()),
        other => {
            eprintln!("unknown system '{other}'");
            std::process::exit(2);
        }
    }
}

/// Drive a sharded server (any backend) over the workload, one batch per
/// arrival wave, then print aggregate + per-shard stats.
fn drive_sharded<E: InferenceEngine>(
    server: &Server<E>,
    system_name: &str,
    dataset: Dataset,
    workload: &Workload,
    offline: bool,
    total_capacity_tokens: usize,
) {
    if offline {
        check("offline build", server.build_offline(&workload.requests));
    }
    // one batch per arrival wave, matching the sequential runner's
    // batching so sharded and unsharded output stay comparable
    let reqs = &workload.requests;
    let t0 = std::time::Instant::now();
    let mut served_total = 0usize;
    for (i, j) in exp::turn_waves(reqs) {
        served_total += check("serve", server.serve_batch(&reqs[i..j])).len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let (mut m, per_shard) = check("metrics", server.metrics());
    let cfg = server.config();
    println!("system           : {system_name} (sharded)");
    println!("dataset          : {}", dataset.name());
    println!(
        "shards x workers : {} x {}",
        server.n_shards(),
        server.n_workers()
    );
    println!(
        "KV budget        : {total_capacity_tokens} tokens total ({} per shard)",
        cfg.capacity_tokens
    );
    match cfg.prefill_chunk {
        Some(c) => println!("prefill chunk    : {c} tokens"),
        None => println!("prefill chunk    : off (monolithic prefills)"),
    }
    match &cfg.tiers {
        Some(t) => println!(
            "KV tiers         : dram={} ssd={} tokens per shard (evict = demote)",
            t.dram_tokens, t.ssd_tokens
        ),
        None => println!("KV tiers         : off (evict = discard)"),
    }
    println!("placement        : {}", cfg.placement);
    println!("requests         : {served_total}");
    println!(
        "batch wall       : {:.3}s ({:.0} req/s)",
        wall,
        served_total as f64 / wall.max(1e-9)
    );
    println!("prefill tok/s    : {:.0}", m.prefill_throughput());
    println!("prefill chunks   : {}", m.total_prefill_chunks);
    println!("cache hit ratio  : {:.1}%", m.hit_ratio() * 100.0);
    if cfg.placement == contextpilot::serve::PlacementKind::ContextAware {
        println!(
            "affinity reuse   : {} of {} cached tokens on affinity-placed sessions",
            m.total_affinity_hit_tokens, m.total_cached_tokens
        );
        let counter = |name: &str| {
            server
                .counters()
                .iter()
                .find(|(k, _)| *k == name)
                .map_or(0, |&(_, v)| v)
        };
        println!(
            "placement probes : {} block lookups, {} probe-path shard locks",
            counter("placement_probe_ops"),
            counter("placement_probe_shard_locks")
        );
    }
    if cfg.tiers.is_some() {
        println!(
            "reuse h/w/c tok  : {} hot / {} warm / {} cold",
            m.total_hot_hit_tokens, m.total_warm_hit_tokens, m.total_cold_hit_tokens
        );
    }
    println!("mean TTFT        : {:.4}s", m.mean_ttft());
    println!("p99 TTFT         : {:.4}s", m.p99_ttft());
    println!("p99 queued TTFT  : {:.4}s", m.p99_queued_ttft());
    for s in per_shard {
        // gate on the config, not per-shard activity, so every shard row
        // has the same columns whenever --tiers is on
        let tiers = if cfg.tiers.is_some() {
            format!(
                ", dram {} tok, ssd {} tok, {} warm + {} cold hits",
                s.dram_resident_tokens, s.ssd_resident_tokens, s.warm_hit_tokens, s.cold_hit_tokens
            )
        } else {
            String::new()
        };
        let affinity = if cfg.placement == contextpilot::serve::PlacementKind::ContextAware {
            format!(", {} affinity tok", s.affinity_hit_tokens)
        } else {
            String::new()
        };
        println!(
            "  shard {:>2}: {:>5} reqs, hit {:>5.1}%, p50 {:.4}s, p99 {:.4}s, p99q {:.4}s, queue<={}, {} chunks, {} index nodes ({} blocks), {} sessions ({} placed), {} resident tok{}{}",
            s.shard,
            s.served,
            s.hit_ratio * 100.0,
            s.p50_ttft,
            s.p99_ttft,
            s.p99_queued_ttft,
            s.max_queue_depth,
            s.prefill_chunks,
            s.index_nodes,
            s.index_blocks,
            s.sessions,
            s.placed_sessions,
            s.resident_tokens,
            affinity,
            tiers
        );
    }
}

/// Drive the server open-loop (`--qps`): submit every request at its
/// seeded virtual arrival time through the continuous-batching scheduler
/// — no flush barrier — then seal the arrival process, drain the
/// per-shard loops, wait out the tickets and print load statistics.
/// Sojourn TTFT is completion minus arrival on the shard virtual clocks;
/// goodput excludes shed requests.
fn drive_open_loop<E: InferenceEngine>(
    server: &Server<E>,
    system_name: &str,
    dataset: Dataset,
    workload: &Workload,
    arrivals: &[f64],
    arrival_name: &str,
    offline: bool,
) {
    use contextpilot::util::histogram::Summary;
    if offline {
        check("offline build", server.build_offline(&workload.requests));
    }
    let span = arrivals.last().copied().unwrap_or(0.0);
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = workload
        .requests
        .iter()
        .zip(arrivals)
        .map(|(r, &at)| check("submit arrival", server.submit_at(r.clone(), at)))
        .collect();
    check("seal arrivals", server.seal_arrivals());
    check("drain", server.drain());
    let mut sojourns = Summary::new();
    let mut served = 0usize;
    let mut shed = 0usize;
    let mut completion = 0.0f64;
    for (t, &at) in tickets.into_iter().zip(arrivals) {
        match t.wait() {
            Ok(s) => {
                served += 1;
                sojourns.record(s.queued_ttft);
                completion = completion.max(at + s.queued_ttft);
            }
            Err(Error::Overloaded(_)) => shed += 1,
            Err(e) => fail("open-loop ticket", e),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let makespan = completion.max(span);
    let cfg = server.config();
    let counter = |name: &str| {
        server
            .counters()
            .iter()
            .find(|(k, _)| *k == name)
            .map_or(0, |&(_, v)| v)
    };
    println!("system           : {system_name} (open-loop)");
    println!("dataset          : {}", dataset.name());
    println!(
        "arrivals         : {arrival_name}, {:.1} offered req/s ({} requests over {:.2}s)",
        workload.len() as f64 / span.max(1e-9),
        workload.len(),
        span
    );
    println!(
        "shards x workers : {} x {}",
        server.n_shards(),
        server.n_workers()
    );
    match cfg.queue_bound {
        Some(b) => println!(
            "queue bound      : {b} per shard (overload = {})",
            cfg.on_overload.name()
        ),
        None => println!("queue bound      : off (unbounded admission)"),
    }
    match cfg.deadline {
        Some(d) => println!("deadline         : {d}s admission SLO (miss = shed)"),
        None => println!("deadline         : off"),
    }
    println!(
        "served / shed    : {served} / {shed} ({} delayed admissions)",
        counter("backpressure_delayed")
    );
    println!("p50 sojourn TTFT : {:.4}s", sojourns.p50());
    println!("p99 sojourn TTFT : {:.4}s", sojourns.p99());
    println!(
        "goodput          : {:.1} req/s over {makespan:.2}s virtual makespan",
        served as f64 / makespan.max(1e-9)
    );
    println!("batch wall       : {wall:.3}s");
}

/// `--trace-out` / `--metrics-out`: write the observability exports
/// ([`contextpilot::obs`]) once the workload — and any checkpoint, whose
/// storage-flush events belong in the trace — has drained.
fn export_obs<E: InferenceEngine>(
    server: &Server<E>,
    system_name: &str,
    dataset: Dataset,
    trace_out: Option<&std::path::Path>,
    metrics_out: Option<&std::path::Path>,
) {
    use contextpilot::obs::{chrome_trace, run_telemetry};
    if trace_out.is_none() && metrics_out.is_none() {
        return;
    }
    let events = check("trace", server.trace_events());
    if let Some(path) = trace_out {
        let doc = chrome_trace(&events);
        if let Err(e) = std::fs::write(path, format!("{doc}\n")) {
            eprintln!("writing {}: {e}", path.display());
            std::process::exit(2);
        }
        println!(
            "trace            : {} ({} events)",
            path.display(),
            events.len()
        );
    }
    if let Some(path) = metrics_out {
        let (mut m, per_shard) = check("metrics", server.metrics());
        let doc = run_telemetry(
            system_name,
            dataset.name(),
            &mut m,
            &per_shard,
            &server.counters(),
            events.len(),
        );
        if let Err(e) = std::fs::write(path, format!("{doc}\n")) {
            eprintln!("writing {}: {e}", path.display());
            std::process::exit(2);
        }
        println!("telemetry        : {}", path.display());
    }
}

/// `--engine real`: the PJRT-backed TinyLM engine behind the same trait.
#[cfg(feature = "pjrt")]
#[allow(clippy::too_many_arguments)]
fn serve_real(
    scfg: contextpilot::serve::ServeConfig,
    system_name: &str,
    dataset: Dataset,
    workload: &Workload,
    corpus: &contextpilot::corpus::Corpus,
    offline: bool,
    total_capacity_tokens: usize,
    trace_out: Option<&std::path::Path>,
    metrics_out: Option<&std::path::Path>,
) {
    use contextpilot::runtime::{RealEngine, TinyLmRuntime};
    let artifacts = std::env::var("CTXPILOT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let server = check(
        "--engine real",
        ServerBuilder::from_config(scfg)
            .corpus(corpus.clone())
            .build_with(|c| {
                let rt = TinyLmRuntime::load(&artifacts)
                    .expect("load AOT artifacts (run `make artifacts` / python/compile/aot.py)");
                RealEngine::new(rt, c.capacity_tokens)
            }),
    );
    drive_sharded(
        &server,
        system_name,
        dataset,
        workload,
        offline,
        total_capacity_tokens,
    );
    export_obs(&server, system_name, dataset, trace_out, metrics_out);
}

fn cmd_serve(args: &Args) {
    let dataset = parse_dataset(args.get_or("dataset", "multihoprag"));
    let system = parse_system(args.get_or("system", "contextpilot"));
    let sessions = args.get_usize("sessions", 200);
    let turns = args.get_usize("turns", 1);
    let k = args.get_usize("k", 15);
    let seed = args.get_u64("seed", 0x5EED);
    let workload = match args.get_or("workload", "multi-session") {
        "multi-session" => multi_session(dataset, sessions, k, seed),
        "multi-turn" => multi_turn(dataset, turns.max(2), k, seed),
        "hybrid" => hybrid(dataset, sessions, turns.max(2), k, seed),
        "mem0" => mem0(sessions, turns.max(2), k, seed),
        other => {
            eprintln!("unknown workload '{other}'");
            std::process::exit(2);
        }
    };
    let corpus = corpus_for(dataset);
    let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);
    cfg.offline = turns <= 1;
    cfg.capacity_tokens = args.get_usize("capacity", cfg.capacity_tokens);

    let engine_kind = args.get_or("engine", "sim").to_string();
    let shards = args.get_usize("shards", 1);
    let workers = args.get_usize("workers", 1);
    let prefill_chunk = args.get_usize("prefill-chunk", 0);
    let placement = check(
        "--placement",
        PlacementKind::parse(args.get_or("placement", "session")),
    );
    // --tiers hbm=N,dram=N,ssd=N — total budgets, divided across shards
    // like --capacity; hbm replaces --capacity as the radix budget
    let tiers = args
        .get("tiers")
        .map(|spec| check("--tiers", TierConfig::parse(spec)));
    // --state-dir DIR — durable serving: cold KV segments + a warm-state
    // snapshot live under DIR; a run against a DIR that already holds a
    // snapshot resumes from it, otherwise it starts fresh. A checkpoint
    // is written after the workload drains.
    let state_dir = args.get("state-dir").map(std::path::PathBuf::from);
    if state_dir.is_some() && engine_kind != "sim" {
        eprintln!("--state-dir requires --engine sim (custom engines own their storage)");
        std::process::exit(2);
    }
    // --trace-out FILE — Chrome-trace JSON of the per-request lifecycle
    // (Perfetto-loadable); --metrics-out FILE — run-telemetry JSON. Both
    // route through the sharded server (obs lives in the serving layer).
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let metrics_out = args.get("metrics-out").map(std::path::PathBuf::from);
    // --qps F — open-loop load: requests arrive on the shard virtual
    // clocks per a seeded --arrival process instead of as flush waves;
    // --queue-bound / --deadline / --overload configure the scheduler's
    // SLO backpressure (0 = off for the numeric knobs).
    let qps = args.get_f64("qps", 0.0);
    let arrival = args.get_or("arrival", "poisson").to_string();
    let queue_bound = {
        let b = args.get_usize("queue-bound", 0);
        (b > 0).then_some(b)
    };
    let deadline = {
        let d = args.get_f64("deadline", 0.0);
        (d > 0.0).then_some(d)
    };
    let overload = check(
        "--overload",
        OverloadPolicy::parse(args.get_or("overload", "shed")),
    );

    if qps > 0.0
        || queue_bound.is_some()
        || deadline.is_some()
        || shards > 1
        || workers > 1
        || prefill_chunk > 0
        || engine_kind != "sim"
        || tiers.is_some()
        || placement != PlacementKind::SessionHash
        || state_dir.is_some()
        || trace_out.is_some()
        || metrics_out.is_some()
    {
        // concurrent sharded serving path (trait-generic backend)
        let mut scfg = exp::serve_config(&system, &workload, &cfg);
        scfg.n_shards = shards.max(1);
        scfg.n_workers = workers.max(1);
        scfg.placement = placement;
        scfg.queue_bound = queue_bound;
        scfg.deadline = deadline;
        scfg.on_overload = overload;
        scfg.obs.trace = trace_out.is_some();
        // --capacity is the TOTAL KV budget in both modes: divide it across
        // shards so sharded and unsharded runs are capacity-comparable
        scfg.capacity_tokens = (cfg.capacity_tokens / shards.max(1)).max(1);
        scfg.prefill_chunk = (prefill_chunk > 0).then_some(prefill_chunk);
        if let Some((hbm, tier_cfg)) = tiers {
            cfg.capacity_tokens = hbm;
            scfg.capacity_tokens = (hbm / shards.max(1)).max(1);
            // tiering is prefix-shaped: only the radix reuse mechanism can
            // demote/promote, so keep the config off (and say so) for
            // other systems rather than printing demote-mode headers over
            // discard-mode results
            if matches!(scfg.policy, contextpilot::engine::ReusePolicy::RadixPrefix)
                && engine_kind == "sim"
            {
                scfg.tiers = Some(tier_cfg.per_shard(shards.max(1)));
            } else {
                eprintln!(
                    "note: --tiers applies to the simulated radix-prefix engine only; \
                     running {} with discard-mode eviction (hbm budget still applied)",
                    system.name()
                );
            }
        }
        match engine_kind.as_str() {
            "sim" => {
                let mut builder = ServerBuilder::from_config(scfg).corpus(corpus.clone());
                if let Some(dir) = &state_dir {
                    builder = if dir.join("snapshot.json").exists() {
                        println!("state dir        : {} (resuming from snapshot)", dir.display());
                        builder.resume_from(dir)
                    } else {
                        println!("state dir        : {} (fresh)", dir.display());
                        builder.state_dir(dir)
                    };
                }
                let server = check("serve config", builder.build());
                if qps > 0.0 {
                    let arrivals = match arrival.as_str() {
                        "poisson" => poisson_arrivals(workload.len(), qps, seed),
                        "diurnal" => diurnal_arrivals(
                            workload.len(),
                            qps,
                            0.8,
                            args.get_f64("period", 60.0),
                            seed,
                        ),
                        other => {
                            eprintln!("unknown arrival process '{other}' — try: poisson | diurnal");
                            std::process::exit(2);
                        }
                    };
                    drive_open_loop(
                        &server,
                        system.name(),
                        dataset,
                        &workload,
                        &arrivals,
                        &arrival,
                        cfg.offline,
                    );
                } else {
                    drive_sharded(
                        &server,
                        system.name(),
                        dataset,
                        &workload,
                        cfg.offline,
                        cfg.capacity_tokens,
                    );
                }
                if state_dir.is_some() {
                    let path = check("checkpoint", server.checkpoint());
                    println!("checkpoint       : {}", path.display());
                }
                // after the checkpoint, so its storage-flush events land
                // in the exported trace
                export_obs(
                    &server,
                    system.name(),
                    dataset,
                    trace_out.as_deref(),
                    metrics_out.as_deref(),
                );
            }
            "real" => {
                if qps > 0.0 {
                    eprintln!("--qps (open-loop load) supports --engine sim only for now");
                    std::process::exit(2);
                }
                #[cfg(feature = "pjrt")]
                {
                    serve_real(
                        scfg,
                        system.name(),
                        dataset,
                        &workload,
                        &corpus,
                        cfg.offline,
                        cfg.capacity_tokens,
                        trace_out.as_deref(),
                        metrics_out.as_deref(),
                    );
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    eprintln!(
                        "--engine real needs the PJRT runtime: build with \
                         `--features pjrt` (plus the external xla/anyhow crates, \
                         see rust/README.md)"
                    );
                    std::process::exit(2);
                }
            }
            other => {
                eprintln!("unknown engine '{other}' — try: sim | real");
                std::process::exit(2);
            }
        }
        return;
    }

    let mut m = run_system(&system, &workload, &corpus, &cfg);
    println!("system           : {}", system.name());
    println!("dataset          : {}", dataset.name());
    println!("requests         : {}", m.len());
    println!("prefill tok/s    : {:.0}", m.prefill_throughput());
    println!("cache hit ratio  : {:.1}%", m.hit_ratio() * 100.0);
    println!("mean TTFT        : {:.4}s", m.mean_ttft());
    println!("p99 TTFT         : {:.4}s", m.p99_ttft());
    println!("quality (proxy)  : {:.3}", m.mean_quality());
    println!("anchored F1      : {:.1}", run_f1(&m, &workload, &cfg, 60.4));
}

fn cmd_bench(args: &Args) {
    let quick = !args.flag("full");
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let all: Vec<(&str, fn(bool) -> Vec<contextpilot::util::table::Table>)> = vec![
        ("table1", exp::table1::run),
        ("table2", exp::table2::run),
        ("table3a", exp::table3a::run),
        ("table3b", exp::table3b::run),
        ("table3c", exp::table3c::run),
        ("table4", exp::table4::run),
        ("table5", exp::table5::run),
        ("table6", exp::table6::run),
        ("table7", exp::table7::run),
        ("table8", exp::table8::run),
        ("fig7", exp::fig7::run),
        ("fig8", exp::fig8::run),
        ("fig11", exp::fig11::run),
        ("fig12", exp::fig12::run),
        ("fig13", exp::fig13::run),
        ("appendix_f", exp::appendix_f::run),
        ("appendix_g", exp::appendix_g::run),
        ("capacity", exp::capacity::run),
    ];
    let mut ran = false;
    for (id, f) in all {
        if which == "all" || which == id {
            for t in f(quick) {
                t.emit(id);
            }
            ran = true;
        }
    }
    if !ran {
        eprintln!("unknown bench id '{which}'");
        std::process::exit(2);
    }
}

fn cmd_index(args: &Args) {
    let n = args.get_usize("n", 2000);
    let k = args.get_usize("k", 15);
    let inputs = exp::table3c::synth_contexts(n, k, args.get_u64("seed", 0xC0));
    let t0 = std::time::Instant::now();
    let built = contextpilot::index::build::build_clustered(&inputs, 0.001);
    println!(
        "clustered build: {n} contexts (k={k}) in {:.2}s, {} nodes",
        t0.elapsed().as_secs_f64(),
        built.index.len_alive()
    );
    let t1 = std::time::Instant::now();
    let ix = exp::table3c::build_incremental(&inputs, 0.001);
    println!(
        "incremental build: {n} contexts in {:.2}s, {} nodes",
        t1.elapsed().as_secs_f64(),
        ix.len_alive()
    );
}

fn main() {
    let args = Args::from_env();
    match args.subcommand() {
        Some("serve") => cmd_serve(&args),
        Some("bench") => cmd_bench(&args),
        Some("index") => cmd_index(&args),
        Some(cmd) => {
            eprintln!("unknown subcommand '{cmd}' — try: serve | bench <id> | index");
            std::process::exit(2);
        }
        None => {
            println!("ctxpilot — ContextPilot: fast long-context inference via context reuse");
            println!("usage: ctxpilot <serve|bench|index> [--options]");
            println!("  serve  --system pilot|radix|lmcache|cacheblend --dataset multihoprag");
            println!("         --workload multi-session|multi-turn|hybrid|mem0 --sessions N --k K");
            println!("         --shards N --workers N   (concurrent sharded serving layer)");
            println!("         --engine sim|real        (backend behind the InferenceEngine trait)");
            println!("         --prefill-chunk TOKENS   (chunked-prefill admission)");
            println!("         --tiers hbm=N,dram=N,ssd=N (KV tier store: evict = demote, not discard)");
            println!("         --placement session|rr|context (first-turn session -> shard policy)");
            println!("         --state-dir DIR          (durable cold KV + warm snapshot; resumes");
            println!("                                   automatically when DIR holds a snapshot)");
            println!("         --trace-out FILE         (Chrome-trace JSON of the request lifecycle;");
            println!("                                   load in Perfetto / chrome://tracing)");
            println!("         --metrics-out FILE       (machine-readable run telemetry JSON)");
            println!("         --qps F --arrival poisson|diurnal (open-loop load: seeded virtual");
            println!("                                   arrivals through the continuous-batching");
            println!("                                   scheduler — no flush barrier)");
            println!("         --queue-bound N --deadline S --overload shed|delay");
            println!("                                   (SLO backpressure: bounded per-shard run");
            println!("                                   queues, deadline-aware admission)");
            println!("  bench  <table1..table8|fig7|fig8|fig11|fig12|fig13|appendix_f|appendix_g|capacity|all> [--full]");
            println!("  index  --n 2000 --k 15");
        }
    }
}
