//! `ctxpilot` — CLI for the ContextPilot reproduction.
//!
//! Subcommands:
//!   serve        run a workload through a system and print metrics
//!                (--shards N --workers N switches to the concurrent
//!                sharded ServingEngine and prints per-shard stats)
//!   bench <id>   regenerate one paper table/figure (table1..table8,
//!                fig7, fig8, fig11, fig12, fig13, appendix_f, appendix_g)
//!   index        build a context index over synthetic contexts and time it
//!   demo         the quickstart walkthrough (see examples/quickstart.rs)

use contextpilot::engine::ModelSku;
use contextpilot::experiments as exp;
use contextpilot::experiments::{corpus_for, run_f1, run_system, RunConfig, SystemKind};
use contextpilot::pilot::PilotConfig;
use contextpilot::serve::{ServeConfig, ServingEngine};
use contextpilot::util::cli::Args;
use contextpilot::workload::{hybrid, mem0, multi_session, multi_turn, Dataset};

fn parse_dataset(s: &str) -> Dataset {
    match s.to_ascii_lowercase().as_str() {
        "multihoprag" | "multihop" => Dataset::MultihopRag,
        "narrativeqa" => Dataset::NarrativeQa,
        "qasper" => Dataset::Qasper,
        "mtrag" | "mt-rag" => Dataset::MtRag,
        "locomo" => Dataset::LoCoMo,
        other => {
            eprintln!("unknown dataset '{other}'");
            std::process::exit(2);
        }
    }
}

fn parse_system(s: &str) -> SystemKind {
    match s.to_ascii_lowercase().as_str() {
        "lmcache" => SystemKind::LMCache,
        "cacheblend" => SystemKind::CacheBlend,
        "radixcache" | "radix" => SystemKind::RadixCache,
        "contextpilot" | "pilot" => SystemKind::ContextPilot(PilotConfig::default()),
        other => {
            eprintln!("unknown system '{other}'");
            std::process::exit(2);
        }
    }
}

fn cmd_serve(args: &Args) {
    let dataset = parse_dataset(args.get_or("dataset", "multihoprag"));
    let system = parse_system(args.get_or("system", "contextpilot"));
    let sessions = args.get_usize("sessions", 200);
    let turns = args.get_usize("turns", 1);
    let k = args.get_usize("k", 15);
    let seed = args.get_u64("seed", 0x5EED);
    let workload = match args.get_or("workload", "multi-session") {
        "multi-session" => multi_session(dataset, sessions, k, seed),
        "multi-turn" => multi_turn(dataset, turns.max(2), k, seed),
        "hybrid" => hybrid(dataset, sessions, turns.max(2), k, seed),
        "mem0" => mem0(sessions, turns.max(2), k, seed),
        other => {
            eprintln!("unknown workload '{other}'");
            std::process::exit(2);
        }
    };
    let corpus = corpus_for(dataset);
    let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);
    cfg.offline = turns <= 1;
    cfg.capacity_tokens = args.get_usize("capacity", cfg.capacity_tokens);

    let shards = args.get_usize("shards", 1);
    let workers = args.get_usize("workers", 1);
    if shards > 1 || workers > 1 {
        // concurrent sharded serving path
        let mut scfg = ServeConfig::new(ModelSku::Qwen3_32B);
        scfg.n_shards = shards.max(1);
        scfg.n_workers = workers.max(1);
        // --capacity is the TOTAL KV budget in both modes: divide it across
        // shards so sharded and unsharded runs are capacity-comparable
        let per_shard_cap = (cfg.capacity_tokens / shards.max(1)).max(1);
        scfg.capacity_tokens = per_shard_cap;
        scfg.policy = system.reuse_policy();
        scfg.pilot = match &system {
            SystemKind::ContextPilot(pc) => Some(pc.clone()),
            _ => None,
        };
        scfg.era = cfg.era;
        scfg.multi_hop = cfg.multi_hop;
        scfg.decode_tokens = cfg.decode_tokens;
        let engine = ServingEngine::new(scfg);
        if cfg.offline {
            engine.build_offline(&workload.requests);
        }
        // one batch per arrival wave, matching the sequential runner's
        // batching so sharded and unsharded output stay comparable
        let reqs = &workload.requests;
        let t0 = std::time::Instant::now();
        let mut served_total = 0usize;
        for (i, j) in exp::turn_waves(reqs) {
            served_total += engine.serve_batch(&reqs[i..j], &corpus).len();
        }
        let wall = t0.elapsed().as_secs_f64();
        let (mut m, per_shard) = engine.metrics();
        println!("system           : {} (sharded)", system.name());
        println!("dataset          : {}", dataset.name());
        println!("shards x workers : {} x {}", shards.max(1), workers.max(1));
        println!(
            "KV budget        : {} tokens total ({per_shard_cap} per shard)",
            cfg.capacity_tokens
        );
        println!("requests         : {served_total}");
        println!(
            "batch wall       : {:.3}s ({:.0} req/s)",
            wall,
            served_total as f64 / wall.max(1e-9)
        );
        println!("prefill tok/s    : {:.0}", m.prefill_throughput());
        println!("cache hit ratio  : {:.1}%", m.hit_ratio() * 100.0);
        println!("mean TTFT        : {:.4}s", m.mean_ttft());
        println!("p99 TTFT         : {:.4}s", m.p99_ttft());
        for s in per_shard {
            println!(
                "  shard {:>2}: {:>5} reqs, hit {:>5.1}%, p50 {:.4}s, p99 {:.4}s, queue<={}, {} index nodes, {} sessions, {} resident tok",
                s.shard,
                s.served,
                s.hit_ratio * 100.0,
                s.p50_ttft,
                s.p99_ttft,
                s.max_queue_depth,
                s.index_nodes,
                s.sessions,
                s.resident_tokens
            );
        }
        return;
    }

    let mut m = run_system(&system, &workload, &corpus, &cfg);
    println!("system           : {}", system.name());
    println!("dataset          : {}", dataset.name());
    println!("requests         : {}", m.len());
    println!("prefill tok/s    : {:.0}", m.prefill_throughput());
    println!("cache hit ratio  : {:.1}%", m.hit_ratio() * 100.0);
    println!("mean TTFT        : {:.4}s", m.mean_ttft());
    println!("p99 TTFT         : {:.4}s", m.p99_ttft());
    println!("quality (proxy)  : {:.3}", m.mean_quality());
    println!("anchored F1      : {:.1}", run_f1(&m, &workload, &cfg, 60.4));
}

fn cmd_bench(args: &Args) {
    let quick = !args.flag("full");
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let all: Vec<(&str, fn(bool) -> Vec<contextpilot::util::table::Table>)> = vec![
        ("table1", exp::table1::run),
        ("table2", exp::table2::run),
        ("table3a", exp::table3a::run),
        ("table3b", exp::table3b::run),
        ("table3c", exp::table3c::run),
        ("table4", exp::table4::run),
        ("table5", exp::table5::run),
        ("table6", exp::table6::run),
        ("table7", exp::table7::run),
        ("table8", exp::table8::run),
        ("fig7", exp::fig7::run),
        ("fig8", exp::fig8::run),
        ("fig11", exp::fig11::run),
        ("fig12", exp::fig12::run),
        ("fig13", exp::fig13::run),
        ("appendix_f", exp::appendix_f::run),
        ("appendix_g", exp::appendix_g::run),
    ];
    let mut ran = false;
    for (id, f) in all {
        if which == "all" || which == id {
            for t in f(quick) {
                t.emit(id);
            }
            ran = true;
        }
    }
    if !ran {
        eprintln!("unknown bench id '{which}'");
        std::process::exit(2);
    }
}

fn cmd_index(args: &Args) {
    let n = args.get_usize("n", 2000);
    let k = args.get_usize("k", 15);
    let inputs = exp::table3c::synth_contexts(n, k, args.get_u64("seed", 0xC0));
    let t0 = std::time::Instant::now();
    let built = contextpilot::index::build::build_clustered(&inputs, 0.001);
    println!(
        "clustered build: {n} contexts (k={k}) in {:.2}s, {} nodes",
        t0.elapsed().as_secs_f64(),
        built.index.len_alive()
    );
    let t1 = std::time::Instant::now();
    let ix = exp::table3c::build_incremental(&inputs, 0.001);
    println!(
        "incremental build: {n} contexts in {:.2}s, {} nodes",
        t1.elapsed().as_secs_f64(),
        ix.len_alive()
    );
}

fn main() {
    let args = Args::from_env();
    match args.subcommand() {
        Some("serve") => cmd_serve(&args),
        Some("bench") => cmd_bench(&args),
        Some("index") => cmd_index(&args),
        Some(cmd) => {
            eprintln!("unknown subcommand '{cmd}' — try: serve | bench <id> | index");
            std::process::exit(2);
        }
        None => {
            println!("ctxpilot — ContextPilot: fast long-context inference via context reuse");
            println!("usage: ctxpilot <serve|bench|index> [--options]");
            println!("  serve  --system pilot|radix|lmcache|cacheblend --dataset multihoprag");
            println!("         --workload multi-session|multi-turn|hybrid|mem0 --sessions N --k K");
            println!("         --shards N --workers N   (concurrent sharded serving layer)");
            println!("  bench  <table1..table8|fig7|fig8|fig11|fig12|fig13|appendix_f|appendix_g|all> [--full]");
            println!("  index  --n 2000 --k 15");
        }
    }
}
