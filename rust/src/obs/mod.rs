//! Observability: deterministic tracing, counters, and run telemetry.
//!
//! The measurement substrate for every perf PR after it — three parts,
//! all dependency-free:
//!
//! ```text
//!                 ┌────────────────────────────────────────────┐
//!                 │              serve::ServingEngine          │
//!                 │  placement ──► shard queues ──► workers    │
//!                 └───────┬───────────────┬────────────────────┘
//!                         │               │
//!              counters   │               │  span events on the
//!              (always)   ▼               ▼  shard's virtual clock
//!                 ┌──────────────┐  ┌──────────────┐
//!                 │ obs::Registry │  │ obs::Tracer  │ (per shard,
//!                 │ atomic slots  │  │ ring buffer  │  opt-in)
//!                 └───────┬──────┘  └───────┬──────┘
//!                         │                 │ merge_events
//!                         ▼                 ▼
//!                 ┌────────────────────────────────────┐
//!                 │            obs::export             │
//!                 │ run_telemetry (--metrics-out)      │
//!                 │ chrome_trace  (--trace-out)        │
//!                 └────────────────────────────────────┘
//! ```
//!
//! * [`Registry`] — named atomic counters/gauges the hot paths bump
//!   unconditionally; it mirrors (never replaces) the deterministic
//!   [`RunMetrics`](crate::metrics::RunMetrics) accounting.
//! * [`Tracer`] — per-shard, per-request lifecycle events
//!   (`admitted → placed → queued → prefill_chunk* → tier* → resolved`,
//!   plus `storage` flushes) stamped on the shard's **virtual clock**,
//!   so traces are bit-identical across worker counts.
//! * [`export`] — Chrome trace-event JSON (Perfetto-loadable) and the
//!   [`TELEMETRY_SCHEMA`] run document shared by the CLI and benches.
//!
//! Tracing is **off by default** ([`ObsConfig::default`]); the disabled
//! path allocates nothing and serving output is pinned bit-identical to
//! the untraced build (`tests/obs.rs`).

pub mod export;
pub mod registry;
pub mod trace;

pub use export::{chrome_trace, run_telemetry, validate_telemetry, TELEMETRY_SCHEMA};
pub use registry::{Counter, Registry};
pub use trace::{merge_events, EventKind, StorageOp, TierOp, TraceEvent, Tracer};

/// Observability knobs, wired through
/// [`api::ServerBuilder::observability`](crate::api::ServerBuilder::observability)
/// and the `--trace-out` CLI flag.
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Collect per-request trace events (default off — the disabled
    /// path is zero-allocation).
    pub trace: bool,
    /// Ring-buffer capacity per shard; the oldest events are evicted
    /// (and counted under `trace_events_dropped`) past this.
    pub trace_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> ObsConfig {
        ObsConfig {
            trace: false,
            trace_capacity: 65_536,
        }
    }
}

impl ObsConfig {
    /// Convenience: tracing on with the default ring capacity.
    pub fn tracing() -> ObsConfig {
        ObsConfig {
            trace: true,
            ..ObsConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_off_and_tracing_turns_on() {
        let d = ObsConfig::default();
        assert!(!d.trace);
        assert!(d.trace_capacity > 0);
        let t = ObsConfig::tracing();
        assert!(t.trace);
        assert_eq!(t.trace_capacity, d.trace_capacity);
    }
}
