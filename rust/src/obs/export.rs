//! Trace/telemetry exporters and the telemetry schema validator.
//!
//! Two machine-readable outputs ride on [`crate::util::json`]:
//!
//! * [`chrome_trace`] — Chrome trace-event JSON (the `traceEvents`
//!   format), loadable in Perfetto / `chrome://tracing`. Virtual-clock
//!   seconds become microsecond `ts` values; each shard renders as one
//!   track (`tid`), prefill chunks as complete spans (`ph: "X"`) and
//!   everything else as instant events (`ph: "i"`).
//! * [`run_telemetry`] — a run-level summary document under the
//!   [`TELEMETRY_SCHEMA`] id, emitted both by `ctxpilot serve
//!   --metrics-out` and the serving bench, so every `BENCH_*.json` and
//!   CLI run shares one schema. [`validate_telemetry`] is the checker
//!   the tests, benches, and the CI `obs-smoke` job all call.

use crate::metrics::{RunMetrics, ShardStats};
use crate::util::json::Json;

use super::registry::Counter;
use super::trace::{EventKind, TraceEvent};

/// Schema identifier stamped into every telemetry document.
pub const TELEMETRY_SCHEMA: &str = "ctxpilot.telemetry.v1";

/// Render a merged event stream as Chrome trace-event JSON.
///
/// `pid` is always 0; `tid` is the shard, so Perfetto shows one lane per
/// shard on the shared virtual-clock timeline.
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    let rows: Vec<Json> = events.iter().map(trace_row).collect();
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::arr(rows)),
    ])
}

fn trace_row(e: &TraceEvent) -> Json {
    let mut args: Vec<(&str, Json)> = vec![("seq", Json::num(e.seq as f64))];
    if let Some(r) = e.request {
        args.push(("request", Json::u64(r)));
    }
    if let Some(s) = e.session {
        args.push(("session", Json::num(s as f64)));
    }
    match &e.kind {
        EventKind::Admitted | EventKind::Queued | EventKind::Resolved => {}
        EventKind::Placed { policy, affinity } => {
            args.push(("policy", Json::str(*policy)));
            args.push(("affinity", Json::Bool(*affinity)));
        }
        EventKind::PrefillChunk { index, of, tokens } => {
            args.push(("i", Json::num(*index as f64)));
            args.push(("n", Json::num(*of as f64)));
            args.push(("tokens", Json::num(*tokens as f64)));
        }
        EventKind::Tier { op, tier, tokens } => {
            args.push(("op", Json::str(op.name())));
            args.push(("tier", Json::str(*tier)));
            args.push(("tokens", Json::u64(*tokens)));
        }
        EventKind::Storage { op } => {
            args.push(("op", Json::str(op.name())));
        }
        EventKind::SchedStarted
        | EventKind::SchedPaused
        | EventKind::SchedResumed
        | EventKind::SchedDrained => {}
        EventKind::Backpressure { action } => {
            args.push(("action", Json::str(*action)));
        }
    }
    let mut row = vec![
        ("name", Json::str(e.kind.name())),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(e.shard as f64)),
        ("ts", Json::num(e.t * 1e6)),
    ];
    if matches!(e.kind, EventKind::PrefillChunk { .. }) {
        row.push(("ph", Json::str("X")));
        row.push(("dur", Json::num(e.dur * 1e6)));
    } else {
        row.push(("ph", Json::str("i")));
        row.push(("s", Json::str("t")));
    }
    row.push(("args", Json::obj(args)));
    Json::obj(row)
}

fn shard_row(s: &ShardStats) -> Json {
    Json::obj(vec![
        ("shard", Json::num(s.shard as f64)),
        ("served", Json::num(s.served as f64)),
        ("max_queue_depth", Json::num(s.max_queue_depth as f64)),
        ("hit_ratio", Json::num(s.hit_ratio)),
        ("p50_ttft_s", Json::num(s.p50_ttft)),
        ("p99_ttft_s", Json::num(s.p99_ttft)),
        ("p99_queued_ttft_s", Json::num(s.p99_queued_ttft)),
        ("prefill_chunks", Json::u64(s.prefill_chunks)),
        ("index_nodes", Json::num(s.index_nodes as f64)),
        ("index_blocks", Json::num(s.index_blocks as f64)),
        ("placed_sessions", Json::num(s.placed_sessions as f64)),
        ("affinity_hit_tokens", Json::u64(s.affinity_hit_tokens)),
        ("resident_tokens", Json::num(s.resident_tokens as f64)),
        ("dram_resident_tokens", Json::num(s.dram_resident_tokens as f64)),
        ("ssd_resident_tokens", Json::num(s.ssd_resident_tokens as f64)),
        ("warm_hit_tokens", Json::u64(s.warm_hit_tokens)),
        ("cold_hit_tokens", Json::u64(s.cold_hit_tokens)),
        ("sessions", Json::num(s.sessions as f64)),
    ])
}

/// Build the run-telemetry document ([`TELEMETRY_SCHEMA`]).
///
/// `metrics` is `&mut` because percentile queries sort the summaries
/// in place; `counters` comes from `Registry::snapshot`; `trace_events`
/// is the merged event count (0 with tracing off).
pub fn run_telemetry(
    system: &str,
    dataset: &str,
    metrics: &mut RunMetrics,
    per_shard: &[ShardStats],
    counters: &[(&'static str, u64)],
    trace_events: usize,
) -> Json {
    let (hit_series, cached_series) = metrics.series_with_tail();
    let hit_rows: Vec<Json> = hit_series
        .iter()
        .map(|(x, r)| Json::arr(vec![Json::num(*x), Json::num(*r)]))
        .collect();
    let cached_rows: Vec<Json> = cached_series
        .iter()
        .map(|(x, c)| Json::arr(vec![Json::num(*x), Json::u64(*c)]))
        .collect();
    let counter_obj: Vec<(&str, Json)> =
        counters.iter().map(|(k, v)| (*k, Json::u64(*v))).collect();
    let shard_rows: Vec<Json> = per_shard.iter().map(shard_row).collect();
    Json::obj(vec![
        ("schema", Json::str(TELEMETRY_SCHEMA)),
        ("system", Json::str(system)),
        ("dataset", Json::str(dataset)),
        ("requests", Json::num(metrics.len() as f64)),
        ("hit_ratio", Json::num(metrics.hit_ratio())),
        ("prefill_tokens_per_s", Json::num(metrics.prefill_throughput())),
        ("mean_ttft_s", Json::num(metrics.mean_ttft())),
        ("p50_ttft_s", Json::num(metrics.ttft.p50())),
        ("p95_ttft_s", Json::num(metrics.ttft.p95())),
        ("p99_ttft_s", Json::num(metrics.ttft.p99())),
        ("p99_queued_ttft_s", Json::num(metrics.p99_queued_ttft())),
        ("prompt_tokens", Json::u64(metrics.total_prompt_tokens)),
        ("cached_tokens", Json::u64(metrics.total_cached_tokens)),
        ("hot_hit_tokens", Json::u64(metrics.total_hot_hit_tokens)),
        ("warm_hit_tokens", Json::u64(metrics.total_warm_hit_tokens)),
        ("cold_hit_tokens", Json::u64(metrics.total_cold_hit_tokens)),
        (
            "affinity_hit_tokens",
            Json::u64(metrics.total_affinity_hit_tokens),
        ),
        ("prefill_chunks", Json::u64(metrics.total_prefill_chunks)),
        ("hit_series", Json::arr(hit_rows)),
        ("cached_series", Json::arr(cached_rows)),
        ("counters", Json::obj(counter_obj)),
        ("shards", Json::arr(shard_rows)),
        ("trace_events", Json::num(trace_events as f64)),
    ])
}

/// Check that `doc` is a well-formed [`TELEMETRY_SCHEMA`] document.
///
/// Shared by the unit tests, the serving bench and the CI smoke so the
/// schema cannot silently fork between emitters.
pub fn validate_telemetry(doc: &Json) -> Result<(), String> {
    if doc.as_obj().is_none() {
        return Err("telemetry document is not an object".to_string());
    }
    match doc.get("schema").as_str() {
        Some(TELEMETRY_SCHEMA) => {}
        Some(other) => return Err(format!("unknown schema {other:?}")),
        None => return Err("missing schema field".to_string()),
    }
    for key in ["system", "dataset"] {
        if doc.get(key).as_str().is_none() {
            return Err(format!("missing string field {key:?}"));
        }
    }
    for key in [
        "requests",
        "hit_ratio",
        "prefill_tokens_per_s",
        "mean_ttft_s",
        "p50_ttft_s",
        "p95_ttft_s",
        "p99_ttft_s",
        "p99_queued_ttft_s",
        "trace_events",
    ] {
        if doc.get(key).as_f64().is_none() {
            return Err(format!("missing numeric field {key:?}"));
        }
    }
    for key in [
        "prompt_tokens",
        "cached_tokens",
        "hot_hit_tokens",
        "warm_hit_tokens",
        "cold_hit_tokens",
        "affinity_hit_tokens",
        "prefill_chunks",
    ] {
        if doc.get(key).as_u64().is_none() {
            return Err(format!("missing u64 field {key:?}"));
        }
    }
    for key in ["hit_series", "cached_series", "shards"] {
        if doc.get(key).as_arr().is_none() {
            return Err(format!("missing array field {key:?}"));
        }
    }
    let counters = doc.get("counters");
    if counters.as_obj().is_none() {
        return Err("missing counters object".to_string());
    }
    for c in Counter::ALL {
        if counters.get(c.name()).as_u64().is_none() {
            return Err(format!("counters missing {:?}", c.name()));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;
    use crate::obs::trace::{StorageOp, TierOp};

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                shard: 0,
                seq: 0,
                t: 0.0,
                dur: 0.0,
                request: Some(7),
                session: Some(3),
                kind: EventKind::Placed {
                    policy: "context_aware",
                    affinity: true,
                },
            },
            TraceEvent {
                shard: 0,
                seq: 1,
                t: 0.25,
                dur: 0.5,
                request: Some(7),
                session: Some(3),
                kind: EventKind::PrefillChunk {
                    index: 0,
                    of: 2,
                    tokens: 512,
                },
            },
            TraceEvent {
                shard: 1,
                seq: 0,
                t: 1.0,
                dur: 0.0,
                request: None,
                session: None,
                kind: EventKind::Storage {
                    op: StorageOp::Flush,
                },
            },
            TraceEvent {
                shard: 1,
                seq: 1,
                t: 1.5,
                dur: 0.0,
                request: Some(8),
                session: None,
                kind: EventKind::Tier {
                    op: TierOp::Demote,
                    tier: "dram",
                    tokens: 4096,
                },
            },
        ]
    }

    #[test]
    fn chrome_trace_shape_and_roundtrip() {
        let doc = chrome_trace(&sample_events());
        let rows = doc.get("traceEvents").as_arr().unwrap();
        assert_eq!(rows.len(), 4);
        // prefill chunk is a complete span with µs ts/dur
        let chunk = &rows[1];
        assert_eq!(chunk.get("ph").as_str(), Some("X"));
        assert_eq!(chunk.get("ts").as_f64(), Some(0.25e6));
        assert_eq!(chunk.get("dur").as_f64(), Some(0.5e6));
        assert_eq!(chunk.get("args").get("tokens").as_f64(), Some(512.0));
        // instants carry the scope marker Perfetto expects
        assert_eq!(rows[0].get("ph").as_str(), Some("i"));
        assert_eq!(rows[0].get("s").as_str(), Some("t"));
        assert_eq!(rows[0].get("args").get("affinity").as_bool(), Some(true));
        assert_eq!(rows[3].get("args").get("tokens").as_u64(), Some(4096));
        // whole document survives the util::json round-trip
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(reparsed, doc);
    }

    #[test]
    fn telemetry_validates_and_roundtrips() {
        let mut m = RunMetrics::new();
        let reg = Registry::new();
        reg.add(Counter::RequestsServed, 2);
        let doc = run_telemetry("pilot", "mtrag", &mut m, &[], &reg.snapshot(), 4);
        validate_telemetry(&doc).expect("fresh document validates");
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(reparsed, doc);
        validate_telemetry(&reparsed).expect("reparsed document validates");
        assert_eq!(
            reparsed.get("counters").get("requests_served").as_u64(),
            Some(2)
        );
    }

    #[test]
    fn validator_rejects_garbage() {
        assert!(validate_telemetry(&Json::Null).is_err());
        assert!(validate_telemetry(&Json::obj(vec![])).is_err());
        let wrong_schema = Json::obj(vec![("schema", Json::str("nope"))]);
        assert!(validate_telemetry(&wrong_schema).is_err());
        // drop one required counter and the validator notices
        let mut m = RunMetrics::new();
        let doc = run_telemetry("pilot", "mtrag", &mut m, &[], &Registry::new().snapshot(), 0);
        let mut map = doc.as_obj().unwrap().clone();
        let mut counters = map["counters"].as_obj().unwrap().clone();
        counters.remove("queue_waves");
        map.insert("counters".to_string(), Json::Obj(counters));
        assert!(validate_telemetry(&Json::Obj(map)).is_err());
    }
}
