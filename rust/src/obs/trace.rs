//! Deterministic per-request lifecycle tracing.
//!
//! Every traced event is stamped on the **shard's virtual clock** — the
//! same simulated-seconds timeline `admission::interleave` already runs
//! requests on — never on wall time. Because placement happens in
//! arrival order before any worker runs and all shard state is
//! shard-local, the resulting event stream is a pure function of the
//! workload: bit-identical across `--workers 1/2/4/8` and across
//! machines (pinned by `tests/obs.rs`).
//!
//! Each shard owns one [`Tracer`] (a bounded ring buffer); the engine
//! snapshots all shards and merges the streams with [`merge_events`]
//! into a single timeline ordered by `(t, shard, seq)`.

use std::collections::VecDeque;
use std::sync::Arc;

use super::registry::{Counter, Registry};

/// Direction of a tier transition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TierOp {
    /// Tokens moved up into HBM (from DRAM or SSD) to serve a hit.
    Promote,
    /// Tokens moved down out of HBM under capacity pressure.
    Demote,
}

impl TierOp {
    /// Stable lowercase label for export.
    pub fn name(self) -> &'static str {
        match self {
            TierOp::Promote => "promote",
            TierOp::Demote => "demote",
        }
    }
}

/// Kind of storage-layer event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StorageOp {
    /// A durable snapshot flush (checkpoint) of shard state.
    Flush,
    /// Segment compaction in the cold-tier log.
    Compact,
}

impl StorageOp {
    /// Stable lowercase label for export.
    pub fn name(self) -> &'static str {
        match self {
            StorageOp::Flush => "flush",
            StorageOp::Compact => "compact",
        }
    }
}

/// Typed payload of a trace event — one variant per lifecycle phase.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Request accepted into the serving engine.
    Admitted,
    /// Placement decided a shard for the request.
    Placed {
        /// Name of the placement policy that made the call.
        policy: &'static str,
        /// Whether the request landed on the shard its session's
        /// context already lives on.
        affinity: bool,
    },
    /// Request enqueued on its shard's admission queue.
    Queued,
    /// One admitted prefill chunk ran on the virtual timeline.
    PrefillChunk {
        /// Chunk index within the request's plan (0-based).
        index: u32,
        /// Total chunks in the plan.
        of: u32,
        /// Approximate uncached tokens this chunk prefilled.
        tokens: u32,
    },
    /// Tokens crossed a tier boundary.
    Tier {
        /// Promote or demote.
        op: TierOp,
        /// The non-HBM side of the transition (`"dram"` or `"ssd"`).
        tier: &'static str,
        /// Token count that moved.
        tokens: u64,
    },
    /// The storage layer flushed or compacted durable state.
    Storage {
        /// Flush or compact.
        op: StorageOp,
    },
    /// Request finished: first token emitted, results recorded.
    Resolved,
    /// A per-shard scheduler loop came up ([`crate::serve`]'s sched
    /// layer). Emitted once per shard when the loops spawn.
    SchedStarted,
    /// The scheduler was paused: loops park and admit nothing until
    /// resumed. Emitted per shard from the control call, never from
    /// worker timing.
    SchedPaused,
    /// The scheduler resumed from a pause.
    SchedResumed,
    /// A drain completed: every admitted request on this shard had
    /// resolved when the control call returned.
    SchedDrained,
    /// Backpressure acted on an open-loop arrival.
    Backpressure {
        /// What happened: `"shed"` (rejected, ticket resolves
        /// [`Overloaded`](crate::api::Error::Overloaded)) or `"delayed"`
        /// (held in the arrival queue past its virtual arrival time).
        action: &'static str,
    },
}

impl EventKind {
    /// Stable event name shared by the exporters and the CI smoke.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admitted => "admitted",
            EventKind::Placed { .. } => "placed",
            EventKind::Queued => "queued",
            EventKind::PrefillChunk { .. } => "prefill_chunk",
            EventKind::Tier { .. } => "tier",
            EventKind::Storage { .. } => "storage",
            EventKind::Resolved => "resolved",
            EventKind::SchedStarted => "sched_started",
            EventKind::SchedPaused => "sched_paused",
            EventKind::SchedResumed => "sched_resumed",
            EventKind::SchedDrained => "sched_drained",
            EventKind::Backpressure { .. } => "backpressure",
        }
    }
}

/// One trace event, stamped on a shard's virtual clock.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Shard that emitted the event.
    pub shard: usize,
    /// Emission sequence number within the shard (ties on `t` keep
    /// emission order after a merge).
    pub seq: u64,
    /// Virtual-clock timestamp in simulated seconds.
    pub t: f64,
    /// Span duration in simulated seconds (0 for instant events).
    pub dur: f64,
    /// Request id, when the event belongs to one request.
    pub request: Option<u64>,
    /// Session id, when known.
    pub session: Option<u32>,
    /// Typed payload.
    pub kind: EventKind,
}

/// Per-shard bounded event buffer riding the shard's virtual clock.
///
/// The clock only moves via [`Tracer::advance`], which the shard calls
/// with the span of each admission wave — so timestamps are cumulative
/// simulated seconds from the start of the run, independent of how the
/// worker pool interleaved the waves in wall time.
#[derive(Debug)]
pub struct Tracer {
    shard: usize,
    capacity: usize,
    events: VecDeque<TraceEvent>,
    clock: f64,
    seq: u64,
    registry: Arc<Registry>,
}

impl Tracer {
    /// New tracer for `shard`, holding at most `capacity` events
    /// (oldest evicted first; evictions are counted in the registry).
    pub fn new(shard: usize, capacity: usize, registry: Arc<Registry>) -> Tracer {
        Tracer {
            shard,
            capacity,
            events: VecDeque::new(),
            clock: 0.0,
            seq: 0,
            registry,
        }
    }

    /// Current virtual-clock value (simulated seconds).
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Advance the virtual clock by `span` simulated seconds.
    pub fn advance(&mut self, span: f64) {
        self.clock += span;
    }

    /// Record an event at absolute virtual time `t`.
    pub fn emit(
        &mut self,
        t: f64,
        dur: f64,
        request: Option<u64>,
        session: Option<u32>,
        kind: EventKind,
    ) {
        let seq = self.seq;
        self.seq += 1;
        self.events.push_back(TraceEvent {
            shard: self.shard,
            seq,
            t,
            dur,
            request,
            session,
            kind,
        });
        if self.events.len() > self.capacity {
            self.events.pop_front();
            self.registry.add(Counter::TraceEventsDropped, 1);
        }
    }

    /// Copy of the buffered events, in emission order.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.events.iter().cloned().collect()
    }
}

/// Merge per-shard event streams into one timeline ordered by
/// `(t, shard, seq)`. Each input stream is already seq-ordered, so the
/// result is deterministic regardless of how many workers produced it.
pub fn merge_events(streams: Vec<Vec<TraceEvent>>) -> Vec<TraceEvent> {
    let mut all: Vec<TraceEvent> = streams.into_iter().flatten().collect();
    all.sort_by(|a, b| {
        a.t.partial_cmp(&b.t)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.shard.cmp(&b.shard))
            .then(a.seq.cmp(&b.seq))
    });
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracer(capacity: usize) -> Tracer {
        Tracer::new(0, capacity, Arc::new(Registry::new()))
    }

    #[test]
    fn clock_accumulates_across_waves() {
        let mut t = tracer(16);
        assert_eq!(t.clock(), 0.0);
        t.advance(1.5);
        t.advance(0.25);
        assert_eq!(t.clock(), 1.75);
    }

    #[test]
    fn ring_buffer_drops_oldest_and_counts() {
        let reg = Arc::new(Registry::new());
        let mut t = Tracer::new(3, 2, reg.clone());
        for i in 0..5 {
            t.emit(i as f64, 0.0, Some(i), None, EventKind::Admitted);
        }
        let snap = t.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].request, Some(3));
        assert_eq!(snap[1].request, Some(4));
        assert_eq!(reg.get(Counter::TraceEventsDropped), 3);
        assert_eq!(snap[0].shard, 3);
    }

    #[test]
    fn merge_orders_by_time_then_shard_then_seq() {
        let reg = Arc::new(Registry::new());
        let mut a = Tracer::new(0, 16, reg.clone());
        let mut b = Tracer::new(1, 16, reg);
        a.emit(2.0, 0.0, Some(1), None, EventKind::Resolved);
        a.emit(0.5, 0.0, Some(1), None, EventKind::Queued);
        b.emit(0.5, 0.0, Some(2), None, EventKind::Queued);
        b.emit(1.0, 0.0, Some(2), None, EventKind::Resolved);
        let merged = merge_events(vec![a.snapshot(), b.snapshot()]);
        let order: Vec<(usize, u64)> = merged.iter().map(|e| (e.shard, e.seq)).collect();
        // t=0.5 ties broken by shard; seq keeps per-shard emission order.
        assert_eq!(order, vec![(0, 1), (1, 0), (1, 1), (0, 0)]);
    }

    #[test]
    fn event_names_cover_all_phases() {
        let names = [
            EventKind::Admitted.name(),
            EventKind::Placed {
                policy: "session_hash",
                affinity: true,
            }
            .name(),
            EventKind::Queued.name(),
            EventKind::PrefillChunk {
                index: 0,
                of: 1,
                tokens: 8,
            }
            .name(),
            EventKind::Tier {
                op: TierOp::Promote,
                tier: "dram",
                tokens: 8,
            }
            .name(),
            EventKind::Storage {
                op: StorageOp::Flush,
            }
            .name(),
            EventKind::Resolved.name(),
            EventKind::SchedStarted.name(),
            EventKind::SchedPaused.name(),
            EventKind::SchedResumed.name(),
            EventKind::SchedDrained.name(),
            EventKind::Backpressure { action: "shed" }.name(),
        ];
        assert_eq!(
            names,
            [
                "admitted",
                "placed",
                "queued",
                "prefill_chunk",
                "tier",
                "storage",
                "resolved",
                "sched_started",
                "sched_paused",
                "sched_resumed",
                "sched_drained",
                "backpressure"
            ]
        );
    }
}
