//! Named counter/gauge registry.
//!
//! A fixed, enum-indexed table of atomic `u64` slots that the serving
//! hot paths bump with relaxed `fetch_add`/`fetch_max`. The registry is
//! *always on* — incrementing an atomic costs nothing measurable next to
//! a prefill — and it deliberately **mirrors** rather than replaces the
//! deterministic [`RunMetrics`](crate::metrics::RunMetrics)/
//! [`ShardStats`](crate::metrics::ShardStats) accounting: the pinned
//! bench/test numbers keep coming from the metrics structs, and a test
//! asserts the two stay equal where they overlap.
//!
//! Wall-clock durations are deliberately **not** in here: every value a
//! counter holds is a deterministic function of the workload, so counter
//! snapshots are reproducible across machines and worker counts and can
//! be pinned in tests like any other output.

use std::sync::atomic::{AtomicU64, Ordering};

/// Every counter/gauge the serving stack maintains. The discriminant is
/// the slot index into [`Registry`]; [`Counter::name`] is the stable
/// snake_case key used in telemetry exports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Requests fully served (one per [`ServedRequest`](crate::types::ServedRequest)).
    RequestsServed,
    /// Per-shard admission waves drained (one per non-empty `serve_queue`).
    QueueWaves,
    /// Placement waves begun (one per `serve_batch`/`build_offline` call).
    PlacementWaves,
    /// Shard-probe passes taken by load-aware placement (one per probed request).
    PlacementProbes,
    /// Block lookups performed by placement probes against the published
    /// probe directory (one per distinct request block per shard) — the
    /// deterministic cost of context-aware routing, O(request blocks),
    /// not O(alive index leaves). See [`crate::serve`]'s probe fast path.
    PlacementProbeOps,
    /// Shard-mutex acquisitions taken from the placement probe path.
    /// **Tripwire, pinned at zero**: probes read published snapshots and
    /// never lock shards; any future fallback that must lock a shard
    /// while probing must bump this, and `bench_routing` + CI assert it
    /// stays 0.
    PlacementProbeShardLocks,
    /// Gauge: deepest per-shard queue seen in any wave (`fetch_max`).
    MaxQueueDepth,
    /// Prefill chunks admitted across all requests.
    PrefillChunks,
    /// Prompt tokens presented for prefill.
    PromptTokens,
    /// Prompt tokens served from any cache tier.
    CachedTokens,
    /// Cached tokens resident in HBM at hit time.
    HotHitTokens,
    /// Cached tokens promoted from DRAM at hit time.
    WarmHitTokens,
    /// Cached tokens rehydrated from the cold (SSD) tier at hit time.
    ColdHitTokens,
    /// Tokens demoted out of HBM under capacity pressure.
    DemotedTokens,
    /// Tokens promoted back into HBM.
    PromotedTokens,
    /// Tokens evicted outright (no lower tier had room).
    DiscardedTokens,
    /// Durable snapshot flushes taken (one per shard per checkpoint).
    StorageFlushes,
    /// Trace events evicted from a full ring buffer (0 unless the
    /// configured `trace_capacity` was exceeded).
    TraceEventsDropped,
    /// Open-loop arrivals rejected by scheduler backpressure (queue bound
    /// exceeded under [`OverloadPolicy::Shed`](crate::serve::OverloadPolicy)
    /// or admission deadline blown): the ticket resolves with
    /// [`Error::Overloaded`](crate::api::Error::Overloaded). 0 on the
    /// batch path — wave entries are never shed.
    BackpressureShed,
    /// Open-loop arrivals held back at least once because the queue bound
    /// was hit under [`OverloadPolicy::Delay`](crate::serve::OverloadPolicy)
    /// (counted once per delayed request, not per re-check).
    BackpressureDelayed,
}

impl Counter {
    /// All counters, in slot order.
    pub const ALL: [Counter; 20] = [
        Counter::RequestsServed,
        Counter::QueueWaves,
        Counter::PlacementWaves,
        Counter::PlacementProbes,
        Counter::PlacementProbeOps,
        Counter::PlacementProbeShardLocks,
        Counter::MaxQueueDepth,
        Counter::PrefillChunks,
        Counter::PromptTokens,
        Counter::CachedTokens,
        Counter::HotHitTokens,
        Counter::WarmHitTokens,
        Counter::ColdHitTokens,
        Counter::DemotedTokens,
        Counter::PromotedTokens,
        Counter::DiscardedTokens,
        Counter::StorageFlushes,
        Counter::TraceEventsDropped,
        Counter::BackpressureShed,
        Counter::BackpressureDelayed,
    ];

    /// Stable snake_case key for telemetry export.
    pub fn name(self) -> &'static str {
        match self {
            Counter::RequestsServed => "requests_served",
            Counter::QueueWaves => "queue_waves",
            Counter::PlacementWaves => "placement_waves",
            Counter::PlacementProbes => "placement_probes",
            Counter::PlacementProbeOps => "placement_probe_ops",
            Counter::PlacementProbeShardLocks => "placement_probe_shard_locks",
            Counter::MaxQueueDepth => "max_queue_depth",
            Counter::PrefillChunks => "prefill_chunks",
            Counter::PromptTokens => "prompt_tokens",
            Counter::CachedTokens => "cached_tokens",
            Counter::HotHitTokens => "hot_hit_tokens",
            Counter::WarmHitTokens => "warm_hit_tokens",
            Counter::ColdHitTokens => "cold_hit_tokens",
            Counter::DemotedTokens => "demoted_tokens",
            Counter::PromotedTokens => "promoted_tokens",
            Counter::DiscardedTokens => "discarded_tokens",
            Counter::StorageFlushes => "storage_flushes",
            Counter::TraceEventsDropped => "trace_events_dropped",
            Counter::BackpressureShed => "backpressure_shed",
            Counter::BackpressureDelayed => "backpressure_delayed",
        }
    }
}

/// Lock-free table of all [`Counter`] slots. One instance is shared
/// (`Arc`) by the serving engine and every shard; increments are relaxed
/// atomics, so the registry never serializes the worker pool.
#[derive(Debug)]
pub struct Registry {
    slots: [AtomicU64; Counter::ALL.len()],
}

impl Registry {
    /// Fresh registry with every slot at zero.
    pub fn new() -> Registry {
        Registry {
            slots: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Add `n` to counter `c`.
    pub fn add(&self, c: Counter, n: u64) {
        self.slots[c as usize].fetch_add(n, Ordering::Relaxed);
    }

    /// Raise gauge `c` to at least `n` (monotone high-water mark).
    pub fn max(&self, c: Counter, n: u64) {
        self.slots[c as usize].fetch_max(n, Ordering::Relaxed);
    }

    /// Current value of counter `c`.
    pub fn get(&self, c: Counter) -> u64 {
        self.slots[c as usize].load(Ordering::Relaxed)
    }

    /// All `(name, value)` pairs in slot order.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        Counter::ALL.iter().map(|&c| (c.name(), self.get(c))).collect()
    }
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_snake_case() {
        let mut seen = std::collections::HashSet::new();
        for c in Counter::ALL {
            let n = c.name();
            assert!(seen.insert(n), "duplicate counter name {n}");
            assert!(
                n.chars().all(|ch| ch.is_ascii_lowercase() || ch == '_'),
                "non-snake_case name {n}"
            );
        }
        assert_eq!(seen.len(), Counter::ALL.len());
    }

    #[test]
    fn add_get_and_snapshot() {
        let r = Registry::new();
        assert_eq!(r.get(Counter::RequestsServed), 0);
        r.add(Counter::RequestsServed, 3);
        r.add(Counter::RequestsServed, 4);
        assert_eq!(r.get(Counter::RequestsServed), 7);
        let snap = r.snapshot();
        assert_eq!(snap.len(), Counter::ALL.len());
        assert!(snap.contains(&("requests_served", 7)));
        assert!(snap.contains(&("queue_waves", 0)));
    }

    #[test]
    fn max_is_a_high_water_mark() {
        let r = Registry::new();
        r.max(Counter::MaxQueueDepth, 5);
        r.max(Counter::MaxQueueDepth, 3);
        assert_eq!(r.get(Counter::MaxQueueDepth), 5);
        r.max(Counter::MaxQueueDepth, 9);
        assert_eq!(r.get(Counter::MaxQueueDepth), 9);
    }
}
