//! Bench target regenerating the paper's table3b (custom harness; see
//! DESIGN.md §3 experiment index). Quick sizes by default; paper-scale
//! with CTXPILOT_FULL=1.

use contextpilot::experiments::{table3b, full_mode};
use contextpilot::util::table::reset_result_file;

fn main() {
    let quick = !full_mode();
    reset_result_file("table3b");
    let t0 = std::time::Instant::now();
    for table in table3b::run(quick) {
        table.emit("table3b");
    }
    eprintln!("bench_table3b done in {:.2}s (quick={})", t0.elapsed().as_secs_f64(), quick);
}
