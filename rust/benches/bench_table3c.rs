//! Bench target regenerating the paper's table3c (custom harness; see
//! DESIGN.md §3 experiment index). Quick sizes by default; paper-scale
//! with CTXPILOT_FULL=1.

use contextpilot::experiments::{table3c, full_mode};
use contextpilot::util::table::reset_result_file;

fn main() {
    let quick = !full_mode();
    reset_result_file("table3c");
    let t0 = std::time::Instant::now();
    for table in table3c::run(quick) {
        table.emit("table3c");
    }
    eprintln!("bench_table3c done in {:.2}s (quick={})", t0.elapsed().as_secs_f64(), quick);
}
